"""Numerical-payoff tests: does compensation buy what the paper's motivation
(§1) claims?

Honest physics of the Kahan *dot* (vs. Kahan *sum*): compensation removes
*summation* rounding error but not *product* rounding error, so for a dot
with condition number `cond` in precision eps the best any
non-TwoProduct method can do is O(eps·cond). The wins we assert:

* Kahan sum crushes sequential naive sum on cancellation-heavy data.
* Kahan dot is never worse than sequential naive (Fig. 1a) and beats it
  by a large factor once n is big enough for naive error accumulation.
* Kahan dot error stays within a small constant times eps·cond (the
  theoretical floor set by product rounding).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def rel_err(approx: float, exact: float) -> float:
    if exact == 0.0:
        return abs(approx)
    return abs(approx - exact) / abs(exact)


def test_gen_dot_hits_condition_number():
    rng = np.random.default_rng(1)
    for target in (1e4, 1e8, 1e12):
        _, _, exact, cond = ref.gen_dot(512, target, rng, np.float64)
        assert np.isfinite(exact)
        # GenDot is stochastic; accept two orders of magnitude slack
        assert target / 1e2 <= cond <= target * 1e3


def test_kahan_sum_beats_naive_sum_large_accumulator():
    """Classic Kahan demo: a large accumulator absorbing many small addends
    (condition number ~1, so compensation is *able* to win — Kahan's error
    bound is 2*eps*cond and no single-compensation scheme can beat that).

    Sequential naive drops most of each small addend once the running sum is
    large (eps_f32(1e7) ~ 1); Kahan recovers them via the compensation term.
    """
    rng = np.random.default_rng(2)
    n = 65536
    x = rng.random(n).astype(np.float32)  # uniform(0,1), all positive
    x[0] = 1e8  # eps_f32(1e8) = 8: naive drops each small addend entirely
    exact = ref.exact_dot(x, np.ones_like(x))

    ks = float(model.ksum(jnp.array(x), block=4096, lanes=1024))
    naive_seq = float(ref.naive_dot_scan(jnp.array(x), jnp.ones(n, jnp.float32)))

    assert rel_err(ks, exact) < 1e-6
    assert rel_err(naive_seq, exact) > 1e-4  # naive visibly wrong
    assert rel_err(ks, exact) < rel_err(naive_seq, exact) / 100


@pytest.mark.parametrize("target_cond", [1e4, 1e6])
def test_kahan_dot_vs_sequential_naive_illconditioned(target_cond):
    rng = np.random.default_rng(3)
    n = 4096
    x, y, exact, cond = ref.gen_dot(n, target_cond, rng, np.float32)
    dk = float(model.dot(jnp.array(x), jnp.array(y), variant="kahan",
                         block=4096, lanes=1024))
    dn_seq = float(ref.naive_dot_scan(jnp.array(x), jnp.array(y)))

    ek, en = rel_err(dk, exact), rel_err(dn_seq, exact)
    # Kahan is at worst marginally above the product-rounding floor
    eps32 = 1.2e-7
    assert ek <= 16 * eps32 * cond + 16 * eps32
    # and never meaningfully worse than sequential naive
    assert ek <= en * 4 + 16 * eps32


def test_kahan_dot_beats_naive_seq_when_n_large():
    """Error growth: naive sequential error grows with n, Kahan's does not.

    Use well-conditioned data scaled so magnitudes vary: Kahan dot should be
    ~n/2 better in the worst case; we assert a conservative 4x on the median
    of several trials.
    """
    n = 65536
    wins = 0
    trials = 5
    for s in range(trials):
        rng = np.random.default_rng(100 + s)
        x = (rng.standard_normal(n) * np.exp(rng.uniform(0, 8, n))).astype(np.float32)
        y = rng.standard_normal(n).astype(np.float32)
        exact = ref.exact_dot(x, y)
        dk = float(model.dot(jnp.array(x), jnp.array(y), variant="kahan"))
        dn = float(ref.naive_dot_scan(jnp.array(x), jnp.array(y)))
        if rel_err(dk, exact) <= rel_err(dn, exact) / 4:
            wins += 1
    assert wins >= 3, f"kahan won only {wins}/{trials} trials"


def test_lane_parallel_naive_more_accurate_than_sequential():
    """Paper §3: 'partial sums usually improve the accuracy' — the naive
    SIMD/lane version should already beat strict sequential order."""
    n = 65536
    better = 0
    trials = 5
    for s in range(trials):
        rng = np.random.default_rng(200 + s)
        x = (rng.standard_normal(n) * np.exp(rng.uniform(0, 6, n))).astype(np.float32)
        y = rng.standard_normal(n).astype(np.float32)
        exact = ref.exact_dot(x, y)
        dl = float(model.dot(jnp.array(x), jnp.array(y), variant="naive"))
        ds = float(ref.naive_dot_scan(jnp.array(x), jnp.array(y)))
        if rel_err(dl, exact) <= rel_err(ds, exact):
            better += 1
    assert better >= 3


def test_kahan_scan_matches_neumaier_scale():
    """Sequential Kahan (Fig. 1b semantics) on f32 stays near the f64 truth
    for benign data."""
    rng = np.random.default_rng(4)
    n = 8192
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    exact = ref.exact_dot(x, y)
    dk = float(ref.kahan_dot_scan(jnp.array(x), jnp.array(y)))
    scale = ref.exact_dot(np.abs(x), np.abs(y))
    assert abs(dk - exact) <= 4e-7 * scale
