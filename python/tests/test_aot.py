"""AOT pipeline tests: every manifest entry lowers to parseable HLO text, and
the lowered modules contain what the Rust runtime expects (entry computation,
tuple return, correct parameter shapes)."""

import os
import re

import pytest

from compile import aot, model

import jax
import jax.numpy as jnp


@pytest.mark.parametrize("entry", aot.MANIFEST, ids=[e[0] for e in aot.MANIFEST])
def test_manifest_entry_lowers(entry, tmp_path):
    name, kind, variant, dtype_s, batch, n, block, lanes = entry
    # keep the slow giant entry out of the per-test path; it's covered by
    # `make artifacts` + the rust integration tests
    if n > 200_000:
        pytest.skip("large entry lowered by make artifacts")
    text, num_inputs = aot.build_entry(name, kind, variant, dtype_s, batch, n,
                                       block, lanes)
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # parameter count and element type visible in the entry signature
    ty = {"f32": "f32", "f64": "f64"}[dtype_s]
    assert ty in text
    assert num_inputs in (1, 2)


def test_hlo_text_has_no_custom_calls():
    """interpret=True must lower to plain HLO — a Mosaic custom-call would be
    unloadable by the CPU PJRT client."""
    text, _ = aot.build_entry("probe", "dot", "kahan", "f32", 0, 4096, 4096, 1024)
    assert "custom-call" not in text or "mosaic" not in text.lower()


def test_aot_main_writes_artifacts(tmp_path, monkeypatch):
    """End-to-end aot.py run over a reduced manifest."""
    small = [e for e in aot.MANIFEST if e[5] <= 4096][:2]
    monkeypatch.setattr(aot, "MANIFEST", small)
    import sys
    monkeypatch.setattr(sys, "argv", ["aot", "--out", str(tmp_path)])
    aot.main()
    assert (tmp_path / "manifest.tsv").exists()
    assert (tmp_path / "manifest.json").exists()
    lines = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert lines[0].startswith("# name")
    assert len(lines) == 1 + len(small)
    for e in small:
        assert (tmp_path / f"{e[0]}.hlo.txt").exists()


def test_aot_incremental_skip(tmp_path, monkeypatch):
    small = [e for e in aot.MANIFEST if e[5] <= 4096][:1]
    monkeypatch.setattr(aot, "MANIFEST", small)
    import sys
    monkeypatch.setattr(sys, "argv", ["aot", "--out", str(tmp_path)])
    aot.main()
    first = (tmp_path / f"{small[0][0]}.hlo.txt").stat().st_mtime_ns
    aot.main()  # second run must skip (mtime unchanged)
    second = (tmp_path / f"{small[0][0]}.hlo.txt").stat().st_mtime_ns
    assert first == second


def test_lowered_module_executes_in_jax():
    """The jitted L2 fn itself must produce the same value as eager dot."""
    import numpy as np
    fn, args = model.make_dot(4096, jnp.float32, variant="kahan",
                              block=4096, lanes=1024)
    rng = np.random.default_rng(9)
    x = jnp.array(rng.standard_normal(4096).astype(np.float32))
    y = jnp.array(rng.standard_normal(4096).astype(np.float32))
    jit_out = jax.jit(fn)(x, y)[0]
    eager = model.dot(x, y, variant="kahan", block=4096, lanes=1024)
    assert float(jit_out) == float(eager)
