"""Layer-1 correctness: Pallas kernels vs pure-jnp oracles.

The strongest assertion here is *bitwise* equality between the Pallas kernels
and the lane-emulation references: both implement the identical sequence of
floating-point operations, so any deviation is a kernel bug, not "numerics".
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import kahan as K
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)


def _rand(n, dtype, seed):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(n).astype(dtype),
            rng.standard_normal(n).astype(dtype))


GEOMS = [
    # (n, block, lanes)
    (4096, 4096, 1024),
    (8192, 4096, 512),
    (16384, 8192, 1024),
    (2048, 1024, 128),
    (1024, 1024, 1024),
]


@pytest.mark.parametrize("variant", ["kahan", "naive"])
@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("n,block,lanes", GEOMS)
def test_lane_dot_bitwise_vs_ref(variant, dtype, n, block, lanes):
    x, y = _rand(n, dtype, seed=n + lanes)
    s_k, c_k = K.lane_dot(jnp.array(x), jnp.array(y), variant=variant,
                          block=block, lanes=lanes)
    fn = {"kahan": ref.kahan_dot_lanes_ref, "naive": ref.naive_dot_lanes_ref}[variant]
    s_r, c_r = fn(jnp.array(x), jnp.array(y), block=block, lanes=lanes)
    assert s_k.dtype == s_r.dtype
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("n,block,lanes", GEOMS[:3])
def test_lane_sum_bitwise_vs_ref(dtype, n, block, lanes):
    x, _ = _rand(n, dtype, seed=n)
    s_k, c_k = K.lane_sum(jnp.array(x), block=block, lanes=lanes)
    s_r, c_r = ref.kahan_sum_lanes_ref(jnp.array(x), block=block, lanes=lanes)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))


def test_naive_comp_is_zero():
    x, y = _rand(4096, np.float32, seed=7)
    _, c = K.lane_dot(jnp.array(x), jnp.array(y), variant="naive",
                      block=4096, lanes=1024)
    assert np.all(np.asarray(c) == 0.0)


@pytest.mark.parametrize("variant", ["kahan", "naive"])
def test_dot_padding_matches_manual_pad(variant):
    """model.dot pads internally with zeros; must equal dotting padded arrays."""
    n, block, lanes = 5000, 4096, 1024
    x, y = _rand(n, np.float32, seed=3)
    d1 = model.dot(jnp.array(x), jnp.array(y), variant=variant,
                   block=block, lanes=lanes)
    pad = (-n) % block
    xp = np.pad(x, (0, pad))
    yp = np.pad(y, (0, pad))
    d2 = model.dot(jnp.array(xp), jnp.array(yp), variant=variant,
                   block=block, lanes=lanes)
    assert float(d1) == float(d2)


def test_dot_close_to_exact_well_conditioned():
    x, y = _rand(65536, np.float32, seed=11)
    exact = ref.exact_dot(x, y)
    for variant in ("kahan", "naive"):
        d = float(model.dot(jnp.array(x), jnp.array(y), variant=variant))
        scale = ref.exact_dot(np.abs(x), np.abs(y))
        assert abs(d - exact) <= 1e-5 * scale


def test_dot_matches_f64_when_f64():
    x, y = _rand(16384, np.float64, seed=13)
    d = float(model.dot(jnp.array(x), jnp.array(y), variant="kahan"))
    exact = ref.exact_dot(x, y)
    assert abs(d - exact) <= 1e-12 * abs(exact) + 1e-13


def test_batched_dot_matches_loop():
    b, n = 4, 4096
    rng = np.random.default_rng(5)
    xs = rng.standard_normal((b, n)).astype(np.float32)
    ys = rng.standard_normal((b, n)).astype(np.float32)
    out = model.batched_dot(jnp.array(xs), jnp.array(ys), variant="kahan",
                            block=4096, lanes=1024)
    for i in range(b):
        single = model.dot(jnp.array(xs[i]), jnp.array(ys[i]), variant="kahan",
                           block=4096, lanes=1024)
        assert float(out[i]) == float(single)


def test_ksum_equals_dot_with_ones():
    n = 8192
    x, _ = _rand(n, np.float32, seed=17)
    s = model.ksum(jnp.array(x), block=4096, lanes=1024)
    ones = jnp.ones(n, jnp.float32)
    # not bitwise (sum kernel skips the multiply) but must agree to ulp-level
    d = model.dot(jnp.array(x), ones, variant="kahan", block=4096, lanes=1024)
    np.testing.assert_allclose(float(s), float(d), rtol=1e-6)


def test_geometry_validation():
    x = jnp.zeros(4096, jnp.float32)
    with pytest.raises(ValueError):
        K.lane_dot(x, x, block=1000, lanes=512)  # block % lanes != 0
    with pytest.raises(ValueError):
        K.lane_dot(x, x, block=8192, lanes=1024)  # n % block != 0
    with pytest.raises(ValueError):
        K.lane_dot(x, jnp.zeros(4095, jnp.float32), block=4096, lanes=1024)


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes / dtypes / geometries
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    dtype=st.sampled_from([np.float32, np.float64]),
    variant=st.sampled_from(["kahan", "naive"]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_model_dot_any_shape_close_to_exact(n, dtype, variant, seed):
    """model.dot must accept any n >= 1 (padding) and stay near the exact dot
    for Gaussian data at any geometry."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(dtype)
    y = rng.standard_normal(n).astype(dtype)
    d = float(model.dot(jnp.array(x), jnp.array(y), variant=variant,
                        block=1024, lanes=256))
    exact = ref.exact_dot(x, y)
    scale = max(ref.exact_dot(np.abs(x), np.abs(y)), 1e-30)
    eps = 1.2e-7 if dtype == np.float32 else 2.3e-16
    # generous bound: a handful of eps per summand in the worst lane
    assert abs(d - exact) <= 64 * eps * scale + 64 * eps


@settings(max_examples=15, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=8),
    lanes_pow=st.integers(min_value=4, max_value=10),
    grid=st.integers(min_value=1, max_value=4),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_lane_dot_bitwise_random_geometry(rows, lanes_pow, grid, seed):
    lanes = 1 << lanes_pow
    block = rows * lanes
    n = grid * block
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    y = rng.standard_normal(n).astype(np.float32)
    s_k, c_k = K.lane_dot(jnp.array(x), jnp.array(y), variant="kahan",
                          block=block, lanes=lanes)
    s_r, c_r = ref.kahan_dot_lanes_ref(jnp.array(x), jnp.array(y),
                                       block=block, lanes=lanes)
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_r))
