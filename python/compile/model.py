"""Layer-2 JAX computation graphs for the (Kahan-)compensated scalar product.

These are the functions that get AOT-lowered to HLO text (`aot.py`) and
executed by the Rust runtime; they call the Layer-1 Pallas kernels and add:

* zero-padding to the kernel's block geometry (zeros are numerically neutral
  for a dot product, including under compensation),
* the final compensated cross-lane reduction,
* a batched variant (the request shape served by the Rust coordinator).

Python is build-time only: nothing here runs on the request path.
"""

from __future__ import annotations

import functools

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from .kernels import kahan as K

VARIANTS = ("naive", "kahan")


def reduce_lanes(sums, comp):
    """Compensated sequential fold of per-lane partial sums.

    This is the paper's horizontal reduction after the SIMD loop. Each lane
    contributes its partial sum and its residual compensation; the fold itself
    is Kahan-compensated so the cross-lane step does not reintroduce the error
    the lanes worked to remove.
    """

    def step(carry, inp):
        s, c = carry
        v, cv = inp
        y = v - (c + cv)
        t = s + y
        c_new = (t - s) - y
        return (t, c_new), None

    dtype = sums.dtype
    (s, _), _ = jax.lax.scan(
        step, (jnp.zeros((), dtype), jnp.zeros((), dtype)), (sums, comp)
    )
    return s


def _pad_to_block(v, block: int):
    n = v.shape[0]
    rem = n % block
    if rem == 0:
        return v
    return jnp.pad(v, (0, block - rem))


def dot(
    x,
    y,
    *,
    variant: str = "kahan",
    block: int = K.DEFAULT_BLOCK,
    lanes: int = K.DEFAULT_LANES,
):
    """Full scalar product: pad -> lane-parallel kernel -> compensated fold."""
    if variant not in VARIANTS:
        raise ValueError(f"unknown variant {variant!r}")
    n = x.shape[0]
    blk = min(block, max(lanes, 1 << (n - 1).bit_length())) if n < block else block
    xp = _pad_to_block(x, blk)
    yp = _pad_to_block(y, blk)
    sums, comp = K.lane_dot(xp, yp, variant=variant, block=blk, lanes=min(lanes, blk))
    return reduce_lanes(sums, comp)


def ksum(x, *, block: int = K.DEFAULT_BLOCK, lanes: int = K.DEFAULT_LANES):
    """Full compensated summation (dot against implicit ones)."""
    n = x.shape[0]
    blk = min(block, max(lanes, 1 << (n - 1).bit_length())) if n < block else block
    xp = _pad_to_block(x, blk)
    sums, comp = K.lane_sum(xp, block=blk, lanes=min(lanes, blk))
    return reduce_lanes(sums, comp)


def batched_dot(xs, ys, *, variant: str = "kahan", block: int = K.DEFAULT_BLOCK,
                lanes: int = K.DEFAULT_LANES):
    """Batched scalar products: (B, n) x (B, n) -> (B,).

    This is the artifact shape the Rust coordinator's dynamic batcher executes:
    requests of equal length are grouped into one PJRT call.
    """
    f = functools.partial(dot, variant=variant, block=block, lanes=lanes)
    return jax.vmap(f)(xs, ys)


def make_dot(n: int, dtype, *, variant: str, block: int = K.DEFAULT_BLOCK,
             lanes: int = K.DEFAULT_LANES):
    """Return (fn, example_args) for AOT lowering of a fixed-size dot."""
    spec = jax.ShapeDtypeStruct((n,), dtype)

    def fn(x, y):
        return (dot(x, y, variant=variant, block=block, lanes=lanes),)

    return fn, (spec, spec)


def make_batched_dot(batch: int, n: int, dtype, *, variant: str,
                     block: int = K.DEFAULT_BLOCK, lanes: int = K.DEFAULT_LANES):
    """Return (fn, example_args) for AOT lowering of a batched dot."""
    spec = jax.ShapeDtypeStruct((batch, n), dtype)

    def fn(xs, ys):
        return (batched_dot(xs, ys, variant=variant, block=block, lanes=lanes),)

    return fn, (spec, spec)


def make_ksum(n: int, dtype, *, block: int = K.DEFAULT_BLOCK,
              lanes: int = K.DEFAULT_LANES):
    """Return (fn, example_args) for AOT lowering of a fixed-size Kahan sum."""
    spec = jax.ShapeDtypeStruct((n,), dtype)

    def fn(x):
        return (ksum(x, block=block, lanes=lanes),)

    return fn, (spec,)
