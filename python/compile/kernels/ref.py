"""Pure-jnp correctness oracles for the Pallas kernels.

Three tiers of reference:

* ``*_lanes_ref`` — bit-exact emulations of the lane-parallel kernels
  (same stripe order, same per-lane compensated updates). The pytest suite
  asserts *bitwise* equality against the Pallas kernels; any divergence means
  the kernel does not implement the algorithm it claims to.
* ``kahan_dot_scan`` / ``naive_dot_scan`` — the paper's Fig. 1 sequential
  semantics (one scalar accumulator), via ``lax.scan``.
* ``exact_dot`` — a higher-precision ground truth (f64 accumulation of f32
  data; Neumaier in f64 for f64 data) used for *accuracy* assertions, i.e.
  that Kahan actually buys the precision the paper's motivation claims.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# bit-exact lane emulations
# ---------------------------------------------------------------------------

def kahan_dot_lanes_ref(x, y, *, block: int, lanes: int):
    """Bit-exact emulation of kernels.kahan.lane_dot(variant='kahan')."""
    n = x.shape[0]
    rows_total = n // lanes
    xs = x.reshape(rows_total, lanes)
    ys = y.reshape(rows_total, lanes)

    def step(carry, xy):
        s, c = carry
        xr, yr = xy
        prod = xr * yr
        t = prod - c
        u = s + t
        c_new = (u - s) - t
        return (u, c_new), None

    init = (jnp.zeros(lanes, x.dtype), jnp.zeros(lanes, x.dtype))
    (s, c), _ = jax.lax.scan(step, init, (xs, ys))
    return s, c


def naive_dot_lanes_ref(x, y, *, block: int, lanes: int):
    """Bit-exact emulation of kernels.kahan.lane_dot(variant='naive')."""
    n = x.shape[0]
    xs = x.reshape(n // lanes, lanes)
    ys = y.reshape(n // lanes, lanes)

    def step(s, xy):
        xr, yr = xy
        return s + xr * yr, None

    s, _ = jax.lax.scan(step, jnp.zeros(lanes, x.dtype), (xs, ys))
    return s, jnp.zeros(lanes, x.dtype)


def kahan_sum_lanes_ref(x, *, block: int, lanes: int):
    """Bit-exact emulation of kernels.kahan.lane_sum."""
    n = x.shape[0]
    xs = x.reshape(n // lanes, lanes)

    def step(carry, xr):
        s, c = carry
        t = xr - c
        u = s + t
        c_new = (u - s) - t
        return (u, c_new), None

    init = (jnp.zeros(lanes, x.dtype), jnp.zeros(lanes, x.dtype))
    (s, c), _ = jax.lax.scan(step, init, xs)
    return s, c


def reduce_lanes_ref(sums, comp):
    """Bit-exact emulation of model.reduce_lanes: sequential compensated fold
    of the per-lane partial sums, seeding each step's compensation with the
    lane's own residual term."""

    def step(carry, inp):
        s, c = carry
        v, cv = inp
        y = v - (c + cv)
        t = s + y
        c_new = (t - s) - y
        return (t, c_new), None

    dtype = sums.dtype
    (s, c), _ = jax.lax.scan(
        step, (jnp.zeros((), dtype), jnp.zeros((), dtype)), (sums, comp)
    )
    return s


# ---------------------------------------------------------------------------
# paper Fig. 1 sequential semantics
# ---------------------------------------------------------------------------

def naive_dot_scan(x, y):
    """Fig. 1a: strictly sequential naive dot (C-standard order)."""

    def step(s, xy):
        return s + xy[0] * xy[1], None

    s, _ = jax.lax.scan(step, jnp.zeros((), x.dtype), (x, y))
    return s


def kahan_dot_scan(x, y):
    """Fig. 1b: strictly sequential Kahan-compensated dot."""

    def step(carry, xy):
        s, c = carry
        prod = xy[0] * xy[1]
        yv = prod - c
        t = s + yv
        c_new = (t - s) - yv
        return (t, c_new), None

    (s, _), _ = jax.lax.scan(
        step, (jnp.zeros((), x.dtype), jnp.zeros((), x.dtype)), (x, y)
    )
    return s


# ---------------------------------------------------------------------------
# higher-precision ground truth (numpy, host-side)
# ---------------------------------------------------------------------------

def exact_dot(x, y) -> float:
    """Ground-truth dot for accuracy experiments.

    f32 inputs: products are exact in f64; Neumaier-compensated f64
    accumulation leaves the error many orders below the f32 quantities being
    compared. For f64 inputs this is "only" Neumaier-in-f64 — adequate for
    the condition numbers the tests generate (the Rust `accuracy` module
    carries the fully exact expansion arithmetic).
    """
    xa = np.asarray(x, dtype=np.float64)
    ya = np.asarray(y, dtype=np.float64)
    prods = xa * ya
    s = 0.0
    c = 0.0
    for p in prods:
        t = s + p
        if abs(s) >= abs(p):
            c += (s - t) + p
        else:
            c += (p - t) + s
        s = t
    return float(s + c)


def gen_dot(n: int, target_cond: float, rng: np.random.Generator, dtype=np.float32):
    """Ogita–Rump–Oishi GenDot: generate (x, y) whose dot product has a
    prescribed condition number. Returns (x, y, exact_value, actual_cond).

    The running dot is tracked with an incremental Neumaier accumulator so
    generation is O(n), not O(n^2)."""
    if n < 6:
        raise ValueError("gen_dot needs n >= 6")
    b = np.log2(target_cond)
    half = n // 2
    e = np.rint(rng.uniform(0.0, b / 2.0, size=half))
    e[0] = np.rint(b / 2.0)
    e[-1] = 0.0
    x = np.zeros(n)
    y = np.zeros(n)
    x[:half] = (2.0 * rng.random(half) - 1.0) * (2.0 ** e)
    y[:half] = (2.0 * rng.random(half) - 1.0) * (2.0 ** e)

    s = 0.0  # running Neumaier accumulator over x[i]*y[i]
    c = 0.0

    def acc(p):
        nonlocal s, c
        t = s + p
        if abs(s) >= abs(p):
            c += (s - t) + p
        else:
            c += (p - t) + s
        s = t

    for i in range(half):
        acc(float(x[i]) * float(y[i]))

    # second half: successively cancel the running dot towards zero
    e2 = np.rint(np.linspace(b / 2.0, 0.0, n - half))
    for i in range(half, n):
        x[i] = (2.0 * rng.random() - 1.0) * (2.0 ** e2[i - half])
        if x[i] == 0.0:
            x[i] = 1.0
        cur = s + c
        y[i] = ((2.0 * rng.random() - 1.0) * (2.0 ** e2[i - half]) - cur) / x[i]
        acc(float(x[i]) * float(y[i]))
    x = x.astype(dtype)
    y = y.astype(dtype)
    exact = exact_dot(x, y)
    abs_dot = exact_dot(np.abs(x), np.abs(y))
    cond = 2.0 * abs_dot / abs(exact) if exact != 0 else np.inf
    return x, y, exact, cond
