"""Layer-1 Pallas kernels: lane-parallel (Kahan-)compensated scalar product.

The paper's optimal x86 kernels keep one partial sum *and one compensation
term per SIMD lane* and only reduce across lanes after the main loop; that is
the only way to vectorize Kahan, because the compensation `c` is a
loop-carried dependency within a lane but independent *across* lanes.

The TPU/Pallas adaptation (DESIGN.md §6) maps paper SIMD lanes to a VMEM lane
accumulator of shape ``(LANES,)`` (logically an ``(8, 128)`` VPU tile), the
modulo-unrolled register blocks to a 1-D grid whose HBM->VMEM block copies are
pipelined by BlockSpec, and the final horizontal reduction to a compensated
fold done by the Layer-2 wrapper (`model.py`).

All kernels are lowered with ``interpret=True`` — the CPU PJRT client cannot
execute Mosaic custom-calls (see /opt/xla-example/README.md).

Kernel contract (shared by all variants):
    inputs  x, y : f32/f64[n]          with  n % block == 0, block % lanes == 0
    outputs sums : dtype[lanes], comp : dtype[lanes]
such that ``dot(x, y) ~= reduce(sums) + reduce(comp)``. The naive variant
returns ``comp == 0``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_LANES = 1024  # one (8, 128) f32 VPU tile
DEFAULT_BLOCK = 8192


def _kahan_lane_step(prod, s, c):
    """One compensated accumulation step, per lane (Fig. 1b of the paper)."""
    y = prod - c
    t = s + y
    c_new = (t - s) - y
    return t, c_new


def _kahan_dot_kernel(x_ref, y_ref, sum_ref, c_ref, *, lanes: int, rows: int):
    """Grid step: fold `rows` stripes of `lanes` elements into the lane accs.

    sum_ref/c_ref live in the output window that every grid step maps to the
    same block (index_map -> 0), so they behave as grid-carried accumulators —
    the Pallas analog of the paper's accumulation registers.
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x = x_ref[...].reshape(rows, lanes)
    y = y_ref[...].reshape(rows, lanes)

    def body(r, carry):
        s, c = carry
        prod = x[r, :] * y[r, :]
        return _kahan_lane_step(prod, s, c)

    s, c = jax.lax.fori_loop(0, rows, body, (sum_ref[...], c_ref[...]))
    sum_ref[...] = s
    c_ref[...] = c


def _naive_dot_kernel(x_ref, y_ref, sum_ref, c_ref, *, lanes: int, rows: int):
    """Naive (uncompensated) lane-parallel dot — the paper's baseline.

    Keeps the same (sums, comp) output contract with comp == 0 so the L2/L3
    layers treat all variants uniformly.
    """

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x = x_ref[...].reshape(rows, lanes)
    y = y_ref[...].reshape(rows, lanes)

    def body(r, s):
        return s + x[r, :] * y[r, :]

    sum_ref[...] = jax.lax.fori_loop(0, rows, body, sum_ref[...])


def _kahan_sum_kernel(x_ref, sum_ref, c_ref, *, lanes: int, rows: int):
    """Compensated summation (dot with implicit y == 1): the classic Kahan."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    x = x_ref[...].reshape(rows, lanes)

    def body(r, carry):
        s, c = carry
        return _kahan_lane_step(x[r, :], s, c)

    s, c = jax.lax.fori_loop(0, rows, body, (sum_ref[...], c_ref[...]))
    sum_ref[...] = s
    c_ref[...] = c


def _check_geometry(n: int, block: int, lanes: int) -> int:
    if block % lanes != 0:
        raise ValueError(f"block ({block}) must be a multiple of lanes ({lanes})")
    if n % block != 0:
        raise ValueError(f"n ({n}) must be a multiple of block ({block}); pad in L2")
    return block // lanes


def lane_dot(
    x,
    y,
    *,
    variant: str = "kahan",
    block: int = DEFAULT_BLOCK,
    lanes: int = DEFAULT_LANES,
):
    """Lane-parallel (compensated) dot product.

    Returns ``(sums, comp)``, each of shape ``(lanes,)``; the caller performs
    the final compensated cross-lane reduction (see model.reduce_lanes).
    """
    n = x.shape[0]
    if y.shape != x.shape:
        raise ValueError(f"shape mismatch: {x.shape} vs {y.shape}")
    rows = _check_geometry(n, block, lanes)
    grid = n // block
    kernel = {"kahan": _kahan_dot_kernel, "naive": _naive_dot_kernel}[variant]

    out_dtype = x.dtype
    return pl.pallas_call(
        functools.partial(kernel, lanes=lanes, rows=rows),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), out_dtype),
            jax.ShapeDtypeStruct((lanes,), out_dtype),
        ],
        interpret=True,
    )(x, y)


def lane_sum(x, *, block: int = DEFAULT_BLOCK, lanes: int = DEFAULT_LANES):
    """Lane-parallel Kahan summation. Returns ``(sums, comp)``."""
    n = x.shape[0]
    rows = _check_geometry(n, block, lanes)
    grid = n // block
    return pl.pallas_call(
        functools.partial(_kahan_sum_kernel, lanes=lanes, rows=rows),
        grid=(grid,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((lanes,), x.dtype),
            jax.ShapeDtypeStruct((lanes,), x.dtype),
        ],
        interpret=True,
    )(x)


def vmem_footprint_bytes(block: int, lanes: int, dtype_bytes: int) -> int:
    """Estimated VMEM footprint of one grid step (DESIGN.md §7, L1 perf).

    Two input blocks + two lane accumulators + the reshaped working tiles.
    """
    inputs = 2 * block * dtype_bytes
    accs = 2 * lanes * dtype_bytes
    working = 2 * block * dtype_bytes  # reshaped row views materialized
    return inputs + accs + working
