"""AOT lowering: JAX/Pallas -> HLO *text* -> artifacts/ for the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids, while the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs:
    artifacts/<name>.hlo.txt   one per entry in MANIFEST below
    artifacts/manifest.tsv     machine-readable index for the Rust runtime
    artifacts/manifest.json    human-readable index

Run as:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


DTYPES = {"f32": jnp.float32, "f64": jnp.float64}

# (name, kind, variant, dtype, batch, n, block, lanes)
# Sizes chosen to bracket the paper's working-set regimes while staying cheap
# to execute through the interpret-mode Pallas lowering on CPU.
MANIFEST = [
    ("dot_naive_f32_n4096", "dot", "naive", "f32", 0, 4096, 4096, 1024),
    ("dot_kahan_f32_n4096", "dot", "kahan", "f32", 0, 4096, 4096, 1024),
    ("dot_naive_f32_n65536", "dot", "naive", "f32", 0, 65536, 8192, 1024),
    ("dot_kahan_f32_n65536", "dot", "kahan", "f32", 0, 65536, 8192, 1024),
    ("dot_naive_f64_n65536", "dot", "naive", "f64", 0, 65536, 8192, 1024),
    ("dot_kahan_f64_n65536", "dot", "kahan", "f64", 0, 65536, 8192, 1024),
    ("dot_kahan_f32_n1048576", "dot", "kahan", "f32", 0, 1048576, 16384, 1024),
    ("ksum_f32_n65536", "ksum", "kahan", "f32", 0, 65536, 8192, 1024),
    ("batched_dot_kahan_f32_b8_n16384", "dot", "kahan", "f32", 8, 16384, 8192, 1024),
    ("batched_dot_naive_f32_b8_n16384", "dot", "naive", "f32", 8, 16384, 8192, 1024),
    ("batched_dot_kahan_f32_b4_n4096", "dot", "kahan", "f32", 4, 4096, 4096, 1024),
]


def build_entry(name, kind, variant, dtype_s, batch, n, block, lanes):
    dtype = DTYPES[dtype_s]
    if kind == "ksum":
        fn, args = model.make_ksum(n, dtype, block=block, lanes=lanes)
    elif batch > 0:
        fn, args = model.make_batched_dot(batch, n, dtype, variant=variant,
                                          block=block, lanes=lanes)
    else:
        fn, args = model.make_dot(n, dtype, variant=variant, block=block,
                                  lanes=lanes)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered), len(args)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on names")
    ap.add_argument("--force", action="store_true", help="rebuild even if fresh")
    ns = ap.parse_args()
    os.makedirs(ns.out, exist_ok=True)

    src_mtime = max(
        os.path.getmtime(p)
        for p in [
            __file__,
            os.path.join(os.path.dirname(__file__), "model.py"),
            os.path.join(os.path.dirname(__file__), "kernels", "kahan.py"),
        ]
    )

    rows = []
    for name, kind, variant, dtype_s, batch, n, block, lanes in MANIFEST:
        path = os.path.join(ns.out, f"{name}.hlo.txt")
        rows.append(
            dict(name=name, kind=kind, variant=variant, dtype=dtype_s,
                 batch=batch, n=n, block=block, lanes=lanes,
                 file=os.path.basename(path))
        )
        if ns.only and ns.only not in name:
            continue
        if (not ns.force and os.path.exists(path)
                and os.path.getmtime(path) >= src_mtime):
            print(f"fresh   {name}")
            continue
        text, _num_inputs = build_entry(name, kind, variant, dtype_s, batch, n,
                                        block, lanes)
        with open(path, "w") as f:
            f.write(text)
        print(f"lowered {name}: {len(text)} chars -> {path}")

    with open(os.path.join(ns.out, "manifest.tsv"), "w") as f:
        f.write("# name\tkind\tvariant\tdtype\tbatch\tn\tblock\tlanes\tfile\n")
        for r in rows:
            f.write("\t".join(str(r[k]) for k in
                              ("name", "kind", "variant", "dtype", "batch",
                               "n", "block", "lanes", "file")) + "\n")
    with open(os.path.join(ns.out, "manifest.json"), "w") as f:
        json.dump(rows, f, indent=2)
    print(f"manifest: {len(rows)} entries")


if __name__ == "__main__":
    main()
