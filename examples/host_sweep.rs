//! Host microbenchmark run: the likwid-bench analog on *this* machine.
//!
//! Sweeps every available SIMD kernel through the cache hierarchy and prints
//! cycles per cache line, then verifies the paper's headline on real
//! silicon: once the working set leaves the L1 cache, the vectorized Kahan
//! dot costs the same as the naive dot.
//!
//! Run: `cargo run --release --example host_sweep [-- --full]`

use kahan_ecm::bench::{self, kernels::by_name};
use kahan_ecm::machine::detect;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let m = detect::detect_host();
    println!("host: {} | {} cores | {:.2} GHz (tsc)", m.name, m.cores, m.clock_ghz);
    let simd = detect::host_simd();
    println!(
        "simd: sse={} avx2={} fma={} avx512f={}\n",
        simd.sse, simd.avx2, simd.fma, simd.avx512f
    );

    println!(
        "{}",
        kahan_ecm::coordinator::experiments::host_sweep_table(5, !full).render()
    );

    // headline check on real silicon: Kahan ~ naive beyond L1
    let naive = by_name("naive-AVX2-SP").unwrap();
    let kahan = by_name("kahan-AVX2-SP").unwrap();
    let l1 = 16 * 1024u64;
    let mem = 48 * 1024 * 1024u64;
    let r = |k: &bench::HostKernel, ws: u64| bench::run_sweep(k, &[ws], 7, 3)[0].cy_per_cl;
    let ratio_l1 = r(&kahan, l1) / r(&naive, l1);
    let ratio_mem = r(&kahan, mem) / r(&naive, mem);
    println!("kahan-AVX2 / naive-AVX2 cost ratio:");
    println!("  L1-resident   : {ratio_l1:.2}x  (paper predicts ~2x)");
    println!("  memory-bound  : {ratio_mem:.2}x  (paper predicts ~1x: 'Kahan for free')");

    println!(
        "\nmeasured load-only bandwidth: {:.1} GB/s",
        bench::sweep::measure_load_bandwidth()
    );
}
