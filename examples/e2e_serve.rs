//! End-to-end driver (DESIGN.md §4, experiment E2E): the serving stack
//! under a real workload.
//!
//!   client threads ──► DotClient ──► mpsc ──► worker ──► backend
//!        ▲                                       │
//!        └────────── per-request responses ◄─────┘
//!
//! * default backend is the **persistent host engine** (`crate::engine`):
//!   pooled 64-byte-aligned buffers, pinned long-lived workers, autotuned
//!   SIMD kernel dispatch — no artifacts, no Python, works anywhere;
//! * `--pjrt` switches to the original PJRT batching path (requires AOT
//!   artifacts and the `pjrt` cargo feature);
//! * requests arrive in bursts with mixed sizes and variants; every
//!   response is checked against the exact dot, and the run reports
//!   throughput, latency percentiles and accuracy.
//!
//! Run: `cargo run --release --example e2e_serve [-- --requests N] [--pjrt]`

use kahan_ecm::accuracy::exact::exact_dot_f32;
use kahan_ecm::coordinator::{Backend, DotService, ServiceConfig};
use kahan_ecm::util::{stats, Rng};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut requests: usize = 2000;
    let mut backend = Backend::Host;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--requests" {
            requests = args.next().and_then(|v| v.parse().ok()).unwrap_or(requests);
        } else if a == "--pjrt" {
            backend = Backend::Pjrt;
        }
    }

    match backend {
        Backend::Host => println!("starting dot service (persistent host engine)..."),
        Backend::Pjrt => println!("starting dot service (PJRT CPU, dynamic batching, window 2 ms)..."),
    }
    let (svc, client) = DotService::start(ServiceConfig { backend, ..ServiceConfig::default() })?;

    // --- workload: bursts of mixed-size, mixed-variant requests ---
    let mut rng = Rng::new(2024);
    let sizes = [512usize, 2048, 8192, 16384];
    let t0 = Instant::now();
    let mut latencies_us: Vec<f64> = Vec::with_capacity(requests);
    let mut batch_sizes: Vec<f64> = Vec::with_capacity(requests);
    let mut max_rel_err = 0.0f64;
    let mut served = 0usize;
    let mut id = 0u64;

    while served < requests {
        // a burst of 4..12 requests, then a think-time gap
        let burst = 4 + rng.below(9) as usize;
        let mut inflight = Vec::new();
        for _ in 0..burst.min(requests - served) {
            let n = sizes[rng.below(sizes.len() as u64) as usize];
            let variant = if rng.uniform() < 0.8 { "kahan" } else { "naive" };
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let exact = exact_dot_f32(&a, &b);
            let scale: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x * y).abs() as f64)
                .sum::<f64>()
                .max(1e-30);
            inflight.push((client.submit(id, variant, a, b), exact, scale));
            id += 1;
        }
        for (rx, exact, scale) in inflight {
            let resp = rx.recv().expect("response");
            let v = resp.value.expect("dot value") as f64;
            max_rel_err = max_rel_err.max((v - exact).abs() / scale);
            latencies_us.push(resp.latency.as_secs_f64() * 1e6);
            batch_sizes.push(resp.batch_size as f64);
            served += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let stats_out = svc.stop();

    // --- report ---
    println!("\n=== E2E serving report ===");
    println!("backend            : {backend:?}");
    println!("requests           : {served}");
    println!("wall time          : {wall:.2} s");
    println!("throughput         : {:.0} req/s", served as f64 / wall);
    println!(
        "latency p50/p95/p99: {:.0} / {:.0} / {:.0} us",
        stats::percentile(&latencies_us, 50.0),
        stats::percentile(&latencies_us, 95.0),
        stats::percentile(&latencies_us, 99.0)
    );
    match backend {
        Backend::Host => {
            let e = kahan_ecm::engine::ShardedEngine::global().stats();
            println!(
                "engine             : {} calls on {} shard(s) ({} chunked-parallel, {} split), pool hits/misses {}/{}",
                stats_out.engine_calls, e.shards, e.parallel, e.split_dots, e.pool.hits, e.pool.misses
            );
        }
        Backend::Pjrt => {
            println!("mean batch size    : {:.2}", stats::mean(&batch_sizes));
            println!(
                "PJRT calls         : {} ({} batched) for {} requests",
                stats_out.pjrt_calls, stats_out.batched_calls, stats_out.requests
            );
        }
    }
    println!("errors             : {}", stats_out.errors);
    println!("max rel error      : {max_rel_err:.3e} (vs exact dot, scaled by |a|.|b|)");

    assert_eq!(stats_out.errors, 0, "no request may fail");
    assert!(max_rel_err < 1e-5, "accuracy must hold end-to-end");
    match backend {
        Backend::Host => assert_eq!(
            stats_out.engine_calls as usize, served,
            "every request must execute on the engine"
        ),
        Backend::Pjrt => assert!(
            (stats_out.pjrt_calls as usize) < served,
            "batching must fuse requests ({} calls for {served})",
            stats_out.pjrt_calls
        ),
    }
    println!("\nE2E PASS: all responses correct, backend effective");
    Ok(())
}
