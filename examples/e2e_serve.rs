//! End-to-end driver (DESIGN.md §4, experiment E2E): the serving stack
//! under a real workload.
//!
//!   client threads ──► DotClient (routes) ──► per-shard bounded queues
//!        ▲                                        │
//!        │                            submitter pool (one per shard)
//!        │                                        │
//!        └────────── per-request responses ◄── backend engine
//!
//! * default backend is the **persistent host engine** (`crate::engine`)
//!   behind the service's router pool: pooled 64-byte-aligned buffers,
//!   pinned long-lived workers, autotuned SIMD kernel dispatch — no
//!   artifacts, no Python, works anywhere. `--clients N` threads submit
//!   concurrently (default 4); independent requests execute on different
//!   shards in parallel;
//! * `--pjrt` switches to the original PJRT batching path (requires AOT
//!   artifacts and the `pjrt` cargo feature);
//! * requests arrive in bursts with mixed sizes and accuracy tiers; every
//!   response is checked against the exact dot, and the run reports
//!   throughput, latency percentiles, accuracy, and router-lane balance.
//!
//! Run: `cargo run --release --example e2e_serve [-- --requests N] [--clients C] [--pjrt]`

use kahan_ecm::accuracy::exact::exact_dot_f32;
use kahan_ecm::coordinator::{Backend, DotClient, DotService, ServiceConfig};
use kahan_ecm::util::{stats, Rng};
use std::time::Instant;

/// One client thread's share of the workload: bursts of mixed-size,
/// mixed-accuracy-tier requests (kahan-heavy with dot2 and naive
/// sprinkled in, like a real mixed-SLA stream). Returns
/// (latencies_us, batch_sizes, max_rel_err).
fn run_client(
    client: &DotClient,
    thread_id: u64,
    requests: usize,
) -> (Vec<f64>, Vec<f64>, f64) {
    let mut rng = Rng::new(2024 + thread_id);
    let sizes = [512usize, 2048, 8192, 16384];
    let mut latencies_us: Vec<f64> = Vec::with_capacity(requests);
    let mut batch_sizes: Vec<f64> = Vec::with_capacity(requests);
    let mut max_rel_err = 0.0f64;
    let mut served = 0usize;
    let mut id = thread_id << 32;

    while served < requests {
        // a burst of 4..12 requests, then a think-time gap
        let burst = 4 + rng.below(9) as usize;
        let mut inflight = Vec::new();
        for _ in 0..burst.min(requests - served) {
            let n = sizes[rng.below(sizes.len() as u64) as usize];
            let accuracy = match rng.below(10) {
                0..=6 => "kahan",
                7..=8 => "dot2",
                _ => "naive",
            };
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let exact = exact_dot_f32(&a, &b);
            let scale: f64 = a
                .iter()
                .zip(&b)
                .map(|(x, y)| (x * y).abs() as f64)
                .sum::<f64>()
                .max(1e-30);
            inflight.push((client.submit(id, accuracy, a, b), exact, scale));
            id += 1;
        }
        for (rx, exact, scale) in inflight {
            let resp = rx.recv().expect("response");
            let v = resp.value.expect("dot value") as f64;
            max_rel_err = max_rel_err.max((v - exact).abs() / scale);
            latencies_us.push(resp.latency.as_secs_f64() * 1e6);
            batch_sizes.push(resp.batch_size as f64);
            served += 1;
        }
    }
    (latencies_us, batch_sizes, max_rel_err)
}

fn main() -> anyhow::Result<()> {
    let mut requests: usize = 2000;
    let mut clients: usize = 4;
    let mut backend = Backend::Host;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--requests" {
            requests = args.next().and_then(|v| v.parse().ok()).unwrap_or(requests);
        } else if a == "--clients" {
            clients = args.next().and_then(|v| v.parse().ok()).unwrap_or(clients).max(1);
        } else if a == "--pjrt" {
            backend = Backend::Pjrt;
        }
    }

    match backend {
        Backend::Host => println!(
            "starting dot service (persistent host engine, router pool, {clients} client thread(s))..."
        ),
        Backend::Pjrt => println!("starting dot service (PJRT CPU, dynamic batching, window 2 ms)..."),
    }
    let (svc, client) = DotService::start(ServiceConfig { backend, ..ServiceConfig::default() })?;

    // --- workload: `clients` threads submit concurrently ---
    let t0 = Instant::now();
    let per_client = requests / clients;
    let remainder = requests % clients;
    let mut latencies_us: Vec<f64> = Vec::with_capacity(requests);
    let mut batch_sizes: Vec<f64> = Vec::with_capacity(requests);
    let mut max_rel_err = 0.0f64;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                let client = client.clone();
                let share = per_client + usize::from(c < remainder);
                s.spawn(move || run_client(&client, c as u64, share))
            })
            .collect();
        for h in handles {
            let (lat, bsz, err) = h.join().expect("client thread");
            latencies_us.extend(lat);
            batch_sizes.extend(bsz);
            max_rel_err = max_rel_err.max(err);
        }
    });
    let served = latencies_us.len();
    let wall = t0.elapsed().as_secs_f64();
    let stats_out = svc.stop();

    // --- report ---
    println!("\n=== E2E serving report ===");
    println!("backend            : {backend:?}");
    println!("client threads     : {clients}");
    println!("requests           : {served}");
    println!("wall time          : {wall:.2} s");
    println!("throughput         : {:.0} req/s", served as f64 / wall);
    println!(
        "latency p50/p95/p99: {:.0} / {:.0} / {:.0} us",
        stats::percentile(&latencies_us, 50.0),
        stats::percentile(&latencies_us, 95.0),
        stats::percentile(&latencies_us, 99.0)
    );
    match backend {
        Backend::Host => {
            let e = kahan_ecm::engine::ShardedEngine::global().stats();
            println!(
                "engine             : {} calls on {} shard(s) ({} chunked-parallel, {} split), pool hits/misses {}/{}",
                stats_out.engine_calls, e.shards, e.parallel, e.split_dots, e.pool.hits, e.pool.misses
            );
            for (i, lane) in stats_out.lanes.iter().enumerate() {
                println!(
                    "router lane {i}      : {} routed, {} executed, {} queue-full stalls",
                    lane.routed, lane.executed, lane.queue_full_stalls
                );
            }
            println!("queue-full stalls  : {}", stats_out.queue_full_stalls);
            println!(
                "lane batching      : {} batches fused {} of {} requests ({} admit batches, \
                 {} adaptive-window waits)",
                stats_out.batches,
                stats_out.batched_requests,
                stats_out.requests,
                stats_out.admit_batches,
                stats_out.window_waits
            );
            println!(
                "self-healing       : {} worker respawn(s), {} lane restart(s), {} \
                 quarantine(s)",
                stats_out.respawns, stats_out.lane_restarts, stats_out.quarantines
            );
            // degraded-health warnings: the run still passed (recovery is
            // bit-exact), but a healthy host should show zeros here
            if stats_out.respawns > 0 || stats_out.lane_restarts > 0 {
                println!(
                    "WARNING: degraded run — workers or lane submitters died and were \
                     replaced mid-workload; investigate the host"
                );
            }
            if e.pin_failures > 0 || stats_out.respawn_pin_failures > 0 {
                println!(
                    "WARNING: {} pin failure(s) + {} respawn pin failure(s) — some \
                     workers run unpinned; NUMA placement is degraded",
                    e.pin_failures, stats_out.respawn_pin_failures
                );
            }
        }
        Backend::Pjrt => {
            println!("mean batch size    : {:.2}", stats::mean(&batch_sizes));
            println!(
                "PJRT calls         : {} ({} batched) for {} requests",
                stats_out.pjrt_calls, stats_out.batched_calls, stats_out.requests
            );
        }
    }
    println!("errors             : {}", stats_out.errors);
    println!("max rel error      : {max_rel_err:.3e} (vs exact dot, scaled by |a|.|b|)");

    assert_eq!(stats_out.errors, 0, "no request may fail");
    assert!(max_rel_err < 1e-5, "accuracy must hold end-to-end");
    match backend {
        Backend::Host => {
            // a batch of k requests is one engine call: singles
            // (engine_calls - batches) plus batched requests must account
            // for every served request
            assert_eq!(
                (stats_out.engine_calls - stats_out.batches + stats_out.batched_requests)
                    as usize,
                served,
                "every request must execute on the engine (as a single or inside a batch)"
            );
            assert_eq!(
                stats_out.lanes.iter().map(|l| l.executed).sum::<u64>() as usize,
                served,
                "every request must be accounted to a router lane"
            );
        }
        Backend::Pjrt => assert!(
            (stats_out.pjrt_calls as usize) < served,
            "batching must fuse requests ({} calls for {served})",
            stats_out.pjrt_calls
        ),
    }
    println!("\nE2E PASS: all responses correct, backend effective");
    Ok(())
}
