//! Accuracy study: why anyone uses Kahan at all.
//!
//! Sweeps the condition number of generated dot-product inputs and reports
//! the relative error of every algorithm in the zoo — sequential naive,
//! sequential Kahan, the paper's SIMD Kahan, Neumaier, pairwise and Dot2 —
//! against a provably exact reference. The same data is then pushed through
//! the *real* AOT Pallas kernels via PJRT to show the numerical behaviour
//! carries over to the deployed artifact.
//!
//! Run: `cargo run --release --example accuracy_study`

use kahan_ecm::accuracy::{self, exact::exact_dot_f32, gen_dot_f32};
use kahan_ecm::runtime::Runtime;
use kahan_ecm::util::{Rng, Table};

fn rel(x: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        x.abs()
    } else {
        ((x - exact) / exact).abs()
    }
}

fn main() -> anyhow::Result<()> {
    // ---- algorithm zoo vs condition number (pure Rust) ----
    println!("{}", kahan_ecm::coordinator::experiments::accuracy_table(2048, 7).render());

    // ---- the same story through the deployed PJRT artifacts ----
    let mut rt = Runtime::new()?;
    let mut t = Table::new("PJRT artifacts on ill-conditioned data (n = 4096, f32)")
        .headers(["target cond", "achieved", "naive artifact", "kahan artifact"]);
    let mut rng = Rng::new(31);
    for target in [1e2, 1e5, 1e8] {
        let (a, b, exact, cond) = gen_dot_f32(4096, target, &mut rng);
        let naive = rt.dot_f32("dot_naive_f32_n4096", &a, &b)? as f64;
        let kahan = rt.dot_f32("dot_kahan_f32_n4096", &a, &b)? as f64;
        t.row([
            format!("{target:.0e}"),
            format!("{cond:.2e}"),
            format!("{:.2e}", rel(naive, exact)),
            format!("{:.2e}", rel(kahan, exact)),
        ]);
    }
    println!("{}", t.render());

    // ---- the classic large-accumulator demo, end to end ----
    let n = 65_536;
    let mut rng = Rng::new(5);
    let mut a: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
    a[0] = 1e8;
    let ones = vec![1.0f32; n];
    let exact = exact_dot_f32(&a, &ones);
    let kahan = rt.dot_f32("dot_kahan_f32_n65536", &a, &ones)? as f64;
    let naive_seq = kahan_ecm::accuracy::algorithms::naive_f32(&a, &ones) as f64;
    println!("large-accumulator demo (1e8 + 65k uniform(0,1)):");
    println!("  exact              = {exact:.3}");
    println!("  PJRT kahan         = {kahan:.3}   (rel err {:.2e})", rel(kahan, exact));
    println!("  sequential naive   = {naive_seq:.3}   (rel err {:.2e})", rel(naive_seq, exact));
    let improvement = rel(naive_seq, exact) / rel(kahan, exact).max(1e-18);
    println!("  improvement        = {improvement:.1e}x");

    // ground-truth self check
    assert!(accuracy::analysis::self_check(), "exact reference self-check");
    Ok(())
}
