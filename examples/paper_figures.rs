//! Regenerate every table and figure of the paper into `out/`
//! (plain-text, markdown and CSV series) — the one-command reproduction.
//!
//! Run: `cargo run --release --example paper_figures [-- --out DIR]`

use kahan_ecm::util::cli::Args;

fn main() {
    let mut raw: Vec<String> = std::env::args().skip(1).collect();
    raw.insert(0, "all".to_string());
    let args = Args::parse(raw).expect("args");
    match kahan_ecm::coordinator::cli::run(&args) {
        Ok(()) => println!("done — see out/ for every table/figure"),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
