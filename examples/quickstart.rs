//! Quickstart: the three things this crate does, in 60 lines.
//!
//!   1. model    — ECM prediction for a Kahan dot on a paper socket
//!   2. simulate — "measure" the same kernel on the virtual testbed
//!   3. execute  — run the real AOT-compiled Kahan kernel through PJRT
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use kahan_ecm::accuracy::exact::exact_dot_f32;
use kahan_ecm::ecm::{self, notation};
use kahan_ecm::isa::{generate, Precision, Simd, Variant};
use kahan_ecm::machine::preset;
use kahan_ecm::machine::PresetId;
use kahan_ecm::runtime::Runtime;
use kahan_ecm::sim;
use kahan_ecm::util::Rng;

fn main() -> anyhow::Result<()> {
    // ---- 1. the analytic ECM model (paper §3) ----
    let ivb = preset(PresetId::Ivb);
    let kernel = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    let model = ecm::build(&ivb, &kernel, true);
    println!("kernel          : {}", kernel.name);
    println!("machine         : {} ({})", ivb.name, ivb.xeon_model);
    println!("ECM model       : {} cy", notation::format_model(&model));
    println!("prediction      : {} cy", notation::format_prediction(&model));
    println!("performance     : {} GUP/s", notation::format_perf(&model));
    println!("saturation      : {} cores", model.saturation_cores());

    // ---- 2. the virtual testbed (the paper's "measurement") ----
    println!("\nworking-set sweep on simulated IVB (cy per cache line):");
    for ws in [16u64 << 10, 128 << 10, 4 << 20, 256 << 20] {
        let p = sim::simulate_working_set(&ivb, &kernel, ws / kernel.bytes_per_iter(), true);
        println!(
            "  {:>8} KiB -> {:5.2} cy/CL  ({:4.2} GUP/s)",
            ws >> 10,
            p.cy_per_cl,
            p.gups
        );
    }

    // ---- 3. the real thing: AOT Pallas kernel through PJRT ----
    let mut rt = Runtime::new()?;
    println!("\nPJRT platform   : {}", rt.platform());
    let mut rng = Rng::new(7);
    let a = rng.normal_f32_vec(4096);
    let b = rng.normal_f32_vec(4096);
    let kahan = rt.dot_f32("dot_kahan_f32_n4096", &a, &b)?;
    let naive = rt.dot_f32("dot_naive_f32_n4096", &a, &b)?;
    let exact = exact_dot_f32(&a, &b);
    println!("kahan dot       : {kahan}");
    println!("naive dot       : {naive}");
    println!("exact dot       : {exact}");
    println!(
        "abs err         : kahan {:.3e}, naive {:.3e}",
        (kahan as f64 - exact).abs(),
        (naive as f64 - exact).abs()
    );
    Ok(())
}
