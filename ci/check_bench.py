#!/usr/bin/env python3
"""Typed assertions over the bench JSON artifacts CI produces.

Replaces the old pile of `grep -E` steps in ci.yml: greps can't tell a
real number from the string "null" that the benches emit for non-finite
values, silently pass on fields hiding inside other fields, and drift
from the JSON the moment a key is renamed. This script parses the JSON,
dispatches on each file's "bench" field, and applies one typed predicate
per field.

Usage:
    python3 ci/check_bench.py BENCH_engine.json BENCH_sharded.json

Exit status 0 iff every check in every file passes; each check prints
one PASS/FAIL line so the CI log reads as a checklist.
"""

import json
import math
import sys


def is_num(v):
    """A real, finite JSON number (bool is an int in Python: excluded)."""
    return isinstance(v, (int, float)) and not isinstance(v, bool) and math.isfinite(v)


def num(lo=None):
    def pred(v):
        return is_num(v) and (lo is None or v >= lo)

    return pred, "finite number" + (f" >= {lo}" if lo is not None else "")


def intval(lo=None, exactly=None):
    def pred(v):
        if not isinstance(v, int) or isinstance(v, bool):
            return False
        if exactly is not None:
            return v == exactly
        return lo is None or v >= lo

    want = f"== {exactly}" if exactly is not None else f">= {lo}"
    return pred, f"integer {want}"


def nonempty_str():
    return (lambda v: isinstance(v, str) and len(v) > 0), "non-empty string"


def true_bool():
    return (lambda v: v is True), "true"


def num_list(min_len=1):
    def pred(v):
        return isinstance(v, list) and len(v) >= min_len and all(is_num(x) for x in v)

    return pred, f"list of >= {min_len} finite numbers"


# One (field, predicate) table per bench artifact. The engine checks are
# the ECM-governance loop, the accuracy-ladder sweep and the paper's
# MEM-class "Dot2 is free" claim; the sharded checks are lane batching,
# the adaptive-window sweep, and PR 8's overload-protection burst
# (sheds under deadline pressure, none in the no-deadline control, and a
# served-tail p99 that is a number even when every small was shed) plus
# PR 9's fault-recovery scenario: the bench runs with `--features
# faultinject`, injects worker/lane deaths against a dedicated engine,
# and must observe every recovery path (respawns, lane restarts, a
# quarantine) while the no-fault control on its own engine observes none.
ENGINE_CHECKS = [
    ("ecm_pred_sat_sp_mem", intval(lo=0)),
    ("ecm_pred_sat_dp_mem", intval(lo=0)),
    ("ecm_obs_sat_sp_mem", intval(lo=1)),
    ("ecm_obs_sat_dp_mem", intval(lo=1)),
    ("svc_rps_capped", num()),
    ("svc_rps_uncapped", num()),
    ("svc_capped_requests_governed", intval(lo=1)),
    ("svc_capped_requests_ungoverned", intval(exactly=0)),
    ("kahan_vs_naive_l1", num(lo=0)),
    ("kahan_vs_naive_llc", num(lo=0)),
    ("kahan_vs_naive_mem", num(lo=0)),
    ("dot2_vs_naive_l1", num(lo=0)),
    ("dot2_vs_naive_llc", num(lo=0)),
    ("dot2_vs_naive_mem", num(lo=0)),
    ("winner_kahan_mem", nonempty_str()),
    ("winner_dot2_mem", nonempty_str()),
    ("winner_dot2_l1", nonempty_str()),
    ("dot2_mem_free", true_bool()),
    # PR 10: the f64 accuracy ladder (the paper's DP column) and the
    # measured-calibration loop — a profile-seeded dispatch table must
    # start within 5% of the live-calibrated one, and the profile-derived
    # split threshold must not serve the MEM dot materially slower than
    # the built-in 4 MiB constant (lenient 0.8: CI boxes are noisy).
    ("kahan_vs_naive_f64_l1", num(lo=0)),
    ("kahan_vs_naive_f64_llc", num(lo=0)),
    ("kahan_vs_naive_f64_mem", num(lo=0)),
    ("dot2_vs_naive_f64_l1", num(lo=0)),
    ("dot2_vs_naive_f64_llc", num(lo=0)),
    ("dot2_vs_naive_f64_mem", num(lo=0)),
    ("dot2_mem_free_f64", true_bool()),
    ("calib_cold_start_ratio", num(lo=0.95)),
    ("calib_split_gain", num(lo=0.8)),
]

SHARDED_CHECKS = [
    ("svc_batches", intval(lo=1)),
    ("svc_window_rps", num_list(min_len=1)),
    ("svc_window_p50_us", num_list(min_len=1)),
    ("svc_window0_batches", intval(lo=1)),
    ("svc_p99_us", num(lo=0)),
    ("svc_p99_wait_us", intval(lo=0)),
    ("svc_p99_service_us", intval(lo=0)),
    ("svc_shed", intval(lo=1)),
    ("svc_shed_control", intval(exactly=0)),
    ("svc_respawns", intval(lo=1)),
    ("svc_respawns_control", intval(exactly=0)),
    ("svc_lane_restarts", intval(lo=1)),
    ("svc_lane_restarts_control", intval(exactly=0)),
    ("svc_quarantines", intval(lo=1)),
    ("svc_quarantines_control", intval(exactly=0)),
    # PR 10: deadline-aware routing — the synthetic-calibration run must
    # promote Parallel dots to Split (route changes, bits asserted
    # identical in the bench itself), the no-deadline control never.
    ("svc_deadline_split_served", intval(lo=1)),
    ("svc_deadline_split_control", intval(exactly=0)),
]

CHECKS = {
    "bench_engine": ENGINE_CHECKS,
    "bench_sharded": SHARDED_CHECKS,
}


def run_checks(path):
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"FAIL {path}: unreadable or invalid JSON: {e}")
        return 1

    kind = data.get("bench")
    checks = CHECKS.get(kind)
    if checks is None:
        print(f"FAIL {path}: unknown bench kind {kind!r} (want one of {sorted(CHECKS)})")
        return 1

    failures = 0
    for field, (pred, want) in checks:
        value = data.get(field, "<missing>")
        ok = field in data and pred(data[field])
        status = "PASS" if ok else "FAIL"
        print(f"{status} {kind}.{field}: {value!r} (want {want})")
        failures += 0 if ok else 1
    return failures


def main(argv):
    if len(argv) < 2:
        print(__doc__)
        return 2
    failures = sum(run_checks(p) for p in argv[1:])
    if failures:
        print(f"check_bench: {failures} check(s) failed")
        return 1
    print("check_bench: all checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
