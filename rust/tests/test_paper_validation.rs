//! Integration: the paper's published numbers, end to end through the
//! public API (machine presets -> kernel generation -> ECM -> simulator).

use kahan_ecm::coordinator::{experiments, validate};
use kahan_ecm::ecm;
use kahan_ecm::isa::{generate, paper_kernels, Precision, Simd, Variant};
use kahan_ecm::machine::{all_presets, presets};

#[test]
fn every_paper_number_within_tolerance() {
    let checks = validate::run_all();
    let failures: Vec<String> = checks
        .iter()
        .filter(|c| !c.pass())
        .map(|c| format!("{}: paper {} vs ours {:.4}", c.name, c.expected, c.got))
        .collect();
    assert!(
        failures.is_empty(),
        "{} of {} checks failed:\n{}",
        failures.len(),
        checks.len(),
        failures.join("\n")
    );
}

#[test]
fn validation_report_renders() {
    let (table, ok) = validate::report();
    assert!(ok);
    let r = table.render();
    assert!(r.contains("PASS"));
    assert!(!r.contains("FAIL"));
}

#[test]
fn table2_full_render_matches_paper_rows() {
    let r = experiments::table2().render();
    // every socket's performance row, as printed in the paper
    for s in [
        "{5.40 | 5.40 | 3.60 | 1.73}",
        "{4.40 | 4.40 | 2.93 | 1.68}",
        "{4.60 | 4.60 | 3.86 | 1.44}",
        "{3.60 | 3.60 | 3.60 | 1.80}",
    ] {
        assert!(r.contains(s), "missing {s} in\n{r}");
    }
}

/// The paper's overall conclusion, §5: "the Kahan algorithm comes with no
/// performance penalties ... in the L2 cache, the L3 cache, and in memory
/// if implemented optimally" — checked across ALL four sockets and both
/// precisions on the simulated testbed.
#[test]
fn kahan_for_free_on_every_socket() {
    for m in all_presets() {
        for prec in [Precision::Sp, Precision::Dp] {
            let naive = generate(Variant::Naive, Simd::Avx, prec, 0);
            let kahan = generate(Variant::Kahan, Simd::Avx, prec, 0);
            let en = ecm::build(&m, &naive, true);
            let ek = ecm::build(&m, &kahan, true);
            for level in 2..4 {
                // L3 and memory: free on every socket
                let ratio = ek.prediction(level) / en.prediction(level);
                assert!(
                    ratio <= 1.35,
                    "{} {} level {level}: kahan/naive = {ratio:.2}",
                    m.shorthand,
                    prec.name()
                );
            }
            // memory exactly free
            let ratio = ek.prediction(3) / en.prediction(3);
            assert!((ratio - 1.0).abs() < 1e-9, "{} mem ratio {ratio}", m.shorthand);
        }
    }
}

#[test]
fn kernel_zoo_is_complete_for_both_precisions() {
    for prec in [Precision::Sp, Precision::Dp] {
        let zoo = paper_kernels(prec);
        assert_eq!(zoo.len(), 4);
        // every kernel feeds the model without panicking on every socket
        for m in all_presets() {
            for k in &zoo {
                let e = ecm::build(&m, k, true);
                assert!(e.prediction(3) > 0.0);
                assert!(e.saturation_cores() >= 1);
            }
        }
    }
}

/// Cross-validation: analytic ECM core time vs the trace-driven scoreboard,
/// over the full kernel zoo and all sockets — the two must agree within 15%
/// because they consume the same instruction streams.
#[test]
fn ecm_and_scoreboard_agree_everywhere() {
    for m in all_presets() {
        for prec in [Precision::Sp, Precision::Dp] {
            for k in paper_kernels(prec) {
                let e = ecm::build(&m, &k, true);
                let sim = kahan_ecm::sim::core::steady_state_cycles_per_unit(&m.core, &k);
                let ana = e.prediction(0);
                let rel = (sim - ana).abs() / ana;
                assert!(
                    rel < 0.15,
                    "{} {}: scoreboard {sim:.2} vs ECM {ana:.2}",
                    m.shorthand,
                    k.name
                );
            }
        }
    }
}

/// The DP/SP relationship of §3: SIMD predictions in cycles are identical,
/// scalar DP is exactly half the scalar SP cycle count.
#[test]
fn dp_sp_cycle_relationships() {
    let m = presets::ivb();
    let sp = ecm::build(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), true);
    let dp = ecm::build(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Dp, 0), true);
    for level in 0..4 {
        assert!((sp.prediction(level) - dp.prediction(level)).abs() < 1e-9);
    }
    let sp_s = ecm::build(&m, &generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0), true);
    let dp_s = ecm::build(&m, &generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0), true);
    assert_eq!(sp_s.prediction(0), 2.0 * dp_s.prediction(0));
}
