//! Integration: the full three-layer stack — AOT artifacts (JAX/Pallas,
//! built by `make artifacts`) loaded and executed from Rust via PJRT,
//! including the batching service. These tests need artifacts and the
//! `pjrt` feature; without them each test skips (the engine-backed host
//! serving path is covered artifact-free in `test_engine.rs` and the
//! service's own tests).

use kahan_ecm::accuracy::exact::{exact_dot_f32, exact_dot_f64};
use kahan_ecm::coordinator::{Backend, DotService, ServiceConfig};
use kahan_ecm::runtime::{artifacts_dir, Manifest, Runtime};
use kahan_ecm::util::Rng;

/// Returns false (test should skip) when the PJRT artifacts are absent or
/// the crate was built without the `pjrt` feature (the stub `Runtime`
/// fails closed, so proceeding would panic rather than skip).
#[must_use]
fn artifacts_present() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    let ok = artifacts_dir().join("manifest.tsv").exists();
    if !ok {
        eprintln!("skipping: artifacts missing (run `make artifacts` for the PJRT tests)");
    }
    ok
}

#[test]
fn manifest_covers_required_artifacts() {
    // pure manifest parsing — needs the files on disk but no Runtime, so
    // it must run even in builds without the `pjrt` feature
    if !artifacts_dir().join("manifest.tsv").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return;
    }
    let m = Manifest::load_default().unwrap();
    for name in [
        "dot_naive_f32_n4096",
        "dot_kahan_f32_n4096",
        "dot_kahan_f32_n65536",
        "dot_naive_f32_n65536",
        "dot_kahan_f64_n65536",
        "dot_naive_f64_n65536",
        "ksum_f32_n65536",
        "batched_dot_kahan_f32_b8_n16384",
        "batched_dot_naive_f32_b8_n16384",
    ] {
        assert!(m.get(name).is_some(), "missing artifact {name}");
        let meta = m.get(name).unwrap();
        assert!(m.hlo_path(meta).exists(), "missing HLO file for {name}");
    }
}

#[test]
fn all_unbatched_f32_artifacts_compute_correct_dots() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let entries: Vec<_> = rt
        .manifest()
        .entries
        .iter()
        .filter(|e| e.kind == "dot" && e.dtype == "f32" && e.batch == 0 && e.n <= 65536)
        .cloned()
        .collect();
    assert!(entries.len() >= 4);
    let mut rng = Rng::new(17);
    for meta in entries {
        let a = rng.normal_f32_vec(meta.n);
        let b = rng.normal_f32_vec(meta.n);
        let got = rt.dot_f32(&meta.name, &a, &b).unwrap() as f64;
        let want = exact_dot_f32(&a, &b);
        let scale: f64 =
            a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1.0);
        assert!(
            (got - want).abs() / scale < 1e-5,
            "{}: got {got}, want {want}",
            meta.name
        );
    }
}

#[test]
fn f64_artifact_has_f64_accuracy() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let mut rng = Rng::new(23);
    let a = rng.normal_f64_vec(65536);
    let b = rng.normal_f64_vec(65536);
    let got = rt.dot_f64("dot_kahan_f64_n65536", &a, &b).unwrap();
    let want = exact_dot_f64(&a, &b);
    let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
    assert!((got - want).abs() / scale < 1e-14, "got {got}, want {want}");
}

#[test]
fn kahan_artifact_beats_naive_on_large_accumulator() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let n = 65536;
    let mut rng = Rng::new(29);
    let mut a: Vec<f32> = (0..n).map(|_| rng.uniform() as f32).collect();
    a[0] = 1e8;
    let ones = vec![1.0f32; n];
    let exact = exact_dot_f32(&a, &ones);
    let kahan = rt.dot_f32("dot_kahan_f32_n65536", &a, &ones).unwrap() as f64;
    let naive = rt.dot_f32("dot_naive_f32_n65536", &a, &ones).unwrap() as f64;
    let ek = (kahan - exact).abs() / exact;
    let en = (naive - exact).abs() / exact;
    // the lane-parallel naive artifact already splits sums across 1024
    // lanes, so its error is far below sequential naive; Kahan must still
    // not be worse, and must be near-exact
    assert!(ek < 1e-6, "kahan rel err {ek:e}");
    assert!(ek <= en + 1e-9, "kahan {ek:e} vs naive {en:e}");
}

#[test]
fn ksum_artifact_sums() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let mut rng = Rng::new(31);
    let x = rng.normal_f32_vec(65536);
    let got = rt.ksum_f32("ksum_f32_n65536", &x).unwrap() as f64;
    let want = exact_dot_f32(&x, &vec![1.0f32; x.len()]);
    assert!((got - want).abs() < 1e-2, "got {got} want {want}");
}

#[test]
fn batched_artifact_matches_singles() {
    if !artifacts_present() {
        return;
    }
    let mut rt = Runtime::new().unwrap();
    let mut rng = Rng::new(37);
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..5)
        .map(|i| {
            let n = 1000 + 500 * i; // ragged: exercises padding
            (rng.normal_f32_vec(n), rng.normal_f32_vec(n))
        })
        .collect();
    let batched = rt.batched_dot_f32("batched_dot_kahan_f32_b8_n16384", &pairs).unwrap();
    assert_eq!(batched.len(), 5);
    for (i, (a, b)) in pairs.iter().enumerate() {
        let want = exact_dot_f32(a, b);
        assert!(
            (batched[i] as f64 - want).abs() < 1e-2,
            "row {i}: {} vs {want}",
            batched[i]
        );
    }
}

#[test]
fn service_full_workload_with_errors_and_batching() {
    if !artifacts_present() {
        return;
    }
    let (svc, client) =
        DotService::start(ServiceConfig { backend: Backend::Pjrt, ..ServiceConfig::default() })
            .unwrap();
    let mut rng = Rng::new(41);

    // mix of good requests, an oversized one, and a length-mismatched one
    let mut rxs = Vec::new();
    let mut wants = Vec::new();
    for i in 0..8u64 {
        let a = rng.normal_f32_vec(3000);
        let b = rng.normal_f32_vec(3000);
        wants.push(exact_dot_f32(&a, &b));
        rxs.push(client.submit(i, if i % 2 == 0 { "kahan" } else { "naive" }, a, b));
    }
    let bad_big = client.submit(100, "kahan", vec![0.0; 1 << 21], vec![0.0; 1 << 21]);
    let bad_len = client.submit(101, "kahan", vec![0.0; 10], vec![0.0; 11]);

    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        let v = resp.value.expect("good request") as f64;
        assert!((v - wants[i]).abs() < 1e-2);
    }
    assert!(bad_big.recv().unwrap().value.is_err(), "oversized must error");
    assert!(bad_len.recv().unwrap().value.is_err(), "mismatch must error");

    let stats = svc.stop();
    assert_eq!(stats.requests, 10);
    assert!(stats.errors >= 1);
}

#[test]
fn hlo_artifacts_are_text_not_proto() {
    if !artifacts_present() {
        return;
    }
    let m = Manifest::load_default().unwrap();
    for e in &m.entries {
        let head: String = std::fs::read_to_string(m.hlo_path(e))
            .unwrap()
            .chars()
            .take(64)
            .collect();
        assert!(
            head.starts_with("HloModule"),
            "{}: artifacts must be HLO text (xla_extension 0.5.1 rejects jax>=0.5 protos)",
            e.name
        );
    }
}
