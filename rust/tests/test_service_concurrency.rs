//! Serving-tier concurrency: the router pool must execute independent
//! requests on different shards in parallel, keep bit-identity between
//! concurrent and sequential submission, keep the sequential Kahan error
//! bound on the pooled and split paths under concurrent load, and shut
//! down gracefully (no hangs, no dropped-but-accepted requests).
//!
//! Every test runs the service on a leaked private `ShardedEngine` over a
//! synthetic `Topology::fake_even` layout, so multi-shard routing is
//! exercised even on the single-NUMA-node CI runner.

use kahan_ecm::accuracy::exact::{exact_dot_f32, exact_dot_f64};
use kahan_ecm::accuracy::{gen_dot_f32, gen_dot_f64};
use kahan_ecm::coordinator::{DotService, ServiceConfig};
use kahan_ecm::engine::{EngineConfig, ShardedConfig, ShardedEngine, Topology};
use kahan_ecm::isa::Accuracy;
use kahan_ecm::prop_assert;
use kahan_ecm::util::{prop, Rng};
use std::sync::Barrier;
use std::time::Duration;

/// A private engine for one test: submitter threads need `'static`, and
/// the leak dies with the test process.
fn leak_engine(topo: &Topology, threads: usize, split_min_bytes: usize) -> &'static ShardedEngine {
    Box::leak(Box::new(ShardedEngine::from_topology(
        topo,
        ShardedConfig {
            engine: EngineConfig { threads, ..EngineConfig::default() },
            split_min_bytes,
            chunks: 0,
        },
    )))
}

fn absdot_f32(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum::<f64>().max(1e-30)
}

/// Sequential-Kahan-style bound with merge slack (see test_engine.rs).
fn f32_bound(absdot: f64) -> f64 {
    64.0 * (f32::EPSILON as f64 / 2.0) * absdot
}

fn f64_bound(absdot: f64) -> f64 {
    64.0 * (f64::EPSILON / 2.0) * absdot.max(1e-300)
}

/// Deterministic per-request workload: the concurrent and the sequential
/// phase must regenerate the exact same inputs.
fn case_inputs(t: usize, k: usize) -> (&'static str, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(0xC0FFEE + (t as u64) * 1000 + k as u64);
    let n = 512 + 256 * ((t + k) % 5);
    let variant = if k % 3 == 0 { "naive" } else { "kahan" };
    (variant, rng.normal_f32_vec(n), rng.normal_f32_vec(n))
}

/// Barrier-started threads hammer the service with small pooled-size dots;
/// all must complete, land on more than one shard, and agree bit-for-bit
/// with the same dots submitted sequentially afterwards.
#[test]
fn concurrent_small_dots_use_multiple_shards_and_match_sequential() {
    let engine = leak_engine(&Topology::fake_even(2), 1, 4 << 20);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);

    const THREADS: usize = 4;
    const PER_THREAD: usize = 8;
    let barrier = Barrier::new(THREADS);
    let concurrent: Vec<Vec<u32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let client = client.clone();
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    (0..PER_THREAD)
                        .map(|k| {
                            let (variant, a, b) = case_inputs(t, k);
                            let rx = client.submit((t * PER_THREAD + k) as u64, variant, a, b);
                            let resp = rx
                                .recv_timeout(Duration::from_secs(60))
                                .expect("response under concurrency");
                            resp.value.expect("value").to_bits()
                        })
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread")).collect()
    });

    // sequential reference over the SAME service and inputs
    for (t, bits) in concurrent.iter().enumerate() {
        for (k, &got) in bits.iter().enumerate() {
            let (variant, a, b) = case_inputs(t, k);
            let serial = client.dot_blocking(variant, a, b).expect("serial value");
            assert_eq!(
                got,
                serial.to_bits(),
                "thread {t} request {k}: concurrent submission changed the bits"
            );
        }
    }

    let stats = svc.stop();
    let total = (2 * THREADS * PER_THREAD) as u64;
    assert_eq!(stats.requests, total, "{stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
    assert_eq!(stats.lanes.len(), 2);
    let busy_lanes = stats.lanes.iter().filter(|l| l.executed > 0).count();
    assert!(busy_lanes > 1, "work must land on more than one shard: {stats:?}");
    assert_eq!(stats.lanes.iter().map(|l| l.executed).sum::<u64>(), total);
    // the engine's own per-shard counters agree that both shards computed
    let per_shard = engine.stats_per_shard();
    assert!(
        per_shard.iter().filter(|s| s.requests > 0).count() > 1,
        "engine-side per-shard stats must show multi-shard execution: {per_shard:?}"
    );
}

/// Shutdown under load: submitting threads race `stop()`. Every submitted
/// request must resolve — served with a correct value or a clean
/// disconnect — and every request the service accepted must have been
/// replied to (the drain guarantee), with no hang either way.
#[test]
fn shutdown_under_load_neither_hangs_nor_drops_accepted_requests() {
    let engine = leak_engine(&Topology::fake_even(2), 1, 4 << 20);
    let (svc, client) = DotService::start_on(
        ServiceConfig { router_queue_depth: 4, ..ServiceConfig::default() },
        engine,
    );

    const THREADS: usize = 4;
    const PER_THREAD: usize = 50;
    let barrier = Barrier::new(THREADS + 1);
    let (served, stopped, stats) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|t| {
                let client = client.clone();
                let barrier = &barrier;
                s.spawn(move || {
                    barrier.wait();
                    let mut rng = Rng::new(4000 + t as u64);
                    let rxs: Vec<_> = (0..PER_THREAD)
                        .map(|k| {
                            let n = 256;
                            client.submit(
                                (t * PER_THREAD + k) as u64,
                                "kahan",
                                rng.normal_f32_vec(n),
                                rng.normal_f32_vec(n),
                            )
                        })
                        .collect();
                    let mut served = 0u64;
                    let mut stopped = 0u64;
                    for rx in rxs {
                        // a timeout here IS the hang the test exists to catch
                        match rx.recv_timeout(Duration::from_secs(60)) {
                            Ok(resp) => {
                                resp.value.expect("served request must carry a value");
                                served += 1;
                            }
                            Err(mpsc_err) => {
                                assert!(
                                    matches!(
                                        mpsc_err,
                                        std::sync::mpsc::RecvTimeoutError::Disconnected
                                    ),
                                    "request neither served nor cleanly rejected"
                                );
                                stopped += 1;
                            }
                        }
                    }
                    (served, stopped)
                })
            })
            .collect();
        barrier.wait();
        // stop while the producers are mid-burst
        std::thread::sleep(Duration::from_millis(2));
        let stats = svc.stop();
        let mut served = 0u64;
        let mut stopped = 0u64;
        for h in handles {
            let (sv, st) = h.join().expect("producer thread");
            served += sv;
            stopped += st;
        }
        (served, stopped, stats)
    });

    assert_eq!(served + stopped, (THREADS * PER_THREAD) as u64);
    // drain guarantee: everything the service accepted was served and
    // replied to — an accepted-but-dropped request would leave
    // requests > served (its reply channel died without a response)
    assert_eq!(stats.requests, served, "{stats:?} served={served} stopped={stopped}");
    assert_eq!(stats.errors, 0, "{stats:?}");
}

/// Property: pooled-path dots fired concurrently from N threads are
/// bit-identical to the same dots submitted serially, and stay inside the
/// sequential Kahan bound — Ogita–Rump–Oishi ill-conditioned f32 inputs,
/// where a single lost or reordered partial would blow the bound by
/// orders of magnitude.
#[test]
fn prop_pooled_f32_concurrent_bit_identical_to_serial() {
    let engine = leak_engine(&Topology::fake_even(2), 2, 4 << 20);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);

    prop::check("pooled-concurrent-f32", 6, |rng| {
        // spans the inline and the chunked-parallel home-shard path
        let n = 4096 + rng.below(60_000) as usize;
        let (a, b, exact, _cond) = gen_dot_f32(n, 1e6, rng);
        let absdot = absdot_f32(&a, &b);
        let ha = client.admit_blocking(a)?;
        let hb = client.admit_near_blocking(b, Some(ha))?;

        let serial = client.dot_pooled_blocking("kahan", ha, hb)?;
        prop_assert!(
            (serial as f64 - exact).abs() <= f32_bound(absdot),
            "n={n}: serial pooled dot broke the Kahan bound: {serial} vs {exact}"
        );

        let bits: Vec<u32> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let client = client.clone();
                    s.spawn(move || {
                        (0..2)
                            .map(|_| {
                                client
                                    .dot_pooled_blocking("kahan", ha, hb)
                                    .expect("pooled dot")
                                    .to_bits()
                            })
                            .collect::<Vec<u32>>()
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().expect("thread")).collect()
        });
        for got in bits {
            prop_assert!(
                got == serial.to_bits(),
                "n={n}: concurrent pooled dot changed bits: {got:#x} vs {:#x}",
                serial.to_bits()
            );
        }
        client.release(ha);
        client.release(hb);
        Ok(())
    });
    let stats = svc.stop();
    assert_eq!(stats.errors, 0, "{stats:?}");
}

/// The f64 flavour of the same property, through the engine's pooled
/// (homed) path that the service wraps: concurrent `dot_homed_f64` calls
/// are bit-identical to a serial call and inside the Kahan bound on
/// ill-conditioned inputs.
#[test]
fn prop_pooled_f64_concurrent_bit_identical_to_serial() {
    let engine = leak_engine(&Topology::fake_even(2), 2, 4 << 20);

    prop::check("pooled-concurrent-f64", 5, |rng| {
        let n = 2048 + rng.below(30_000) as usize;
        let (a, b, exact, _cond) = gen_dot_f64(n, 1e10, rng);
        let absdot: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
        let ha = engine.admit_f64(&a);
        let hb = engine.admit_to_f64(ha.shard, &b);

        let serial = engine.dot_homed_f64(Accuracy::Kahan, &ha, &hb);
        prop_assert!(
            (serial - exact).abs() <= f64_bound(absdot),
            "n={n}: serial homed dot broke the Kahan bound: {serial} vs {exact}"
        );
        let exact_check = exact_dot_f64(&a, &b);
        prop_assert!(exact_check == exact, "generator/exact mismatch");

        let bits: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|_| {
                    let (ha, hb) = (ha.clone(), hb.clone());
                    s.spawn(move || {
                        engine.dot_homed_f64(Accuracy::Kahan, &ha, &hb).to_bits()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("thread")).collect()
        });
        for got in bits {
            prop_assert!(
                got == serial.to_bits(),
                "n={n}: concurrent homed dot changed bits"
            );
        }
        Ok(())
    });
}

/// The split (cross-shard fan-out) path under concurrent submission:
/// results stay bit-identical to a 1-shard engine with the same chunk
/// geometry, and inside the Kahan bound — the acceptance criterion that
/// sharding plus request-level parallelism changes no numerics.
#[test]
fn split_path_bits_and_bound_survive_concurrent_submission() {
    // same total worker count (=> same global chunk geometry) on both
    let two = leak_engine(&Topology::fake_even(2), 1, 64 << 10);
    let one = leak_engine(&Topology::single_node(), 2, 64 << 10);
    let (svc2, client2) = DotService::start_on(ServiceConfig::default(), two);
    let (svc1, client1) = DotService::start_on(ServiceConfig::default(), one);

    let mut rng = Rng::new(61);
    let n = 100_000; // 800 KB total >> 64 KB split threshold on both
    let a = rng.normal_f32_vec(n);
    let b = rng.normal_f32_vec(n);
    let exact = exact_dot_f32(&a, &b);
    let absdot = absdot_f32(&a, &b);

    let serial2 = client2.dot_blocking("kahan", a.clone(), b.clone()).expect("2-shard dot");
    let serial1 = client1.dot_blocking("kahan", a.clone(), b.clone()).expect("1-shard dot");
    assert_eq!(
        serial2.to_bits(),
        serial1.to_bits(),
        "1-vs-2-shard split must be bit-identical"
    );
    assert!(
        (serial2 as f64 - exact).abs() <= f32_bound(absdot),
        "split dot broke the Kahan bound: {serial2} vs {exact}"
    );

    let bits: Vec<u32> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let client = client2.clone();
                let (a, b) = (a.clone(), b.clone());
                s.spawn(move || {
                    (0..3)
                        .map(|_| {
                            client
                                .dot_blocking("kahan", a.clone(), b.clone())
                                .expect("concurrent split dot")
                                .to_bits()
                        })
                        .collect::<Vec<u32>>()
                })
            })
            .collect();
        handles.into_iter().flat_map(|h| h.join().expect("thread")).collect()
    });
    for got in bits {
        assert_eq!(got, serial2.to_bits(), "concurrent split submission changed bits");
    }

    assert!(two.stats().split_dots >= 13, "{:?}", two.stats());
    svc2.stop();
    svc1.stop();
}

/// Shutdown racing self-healing: only with `--features faultinject`
/// (CI's serialized faultinject job — the fault plan is process-global).
#[cfg(feature = "faultinject")]
mod faultinject_shutdown {
    use super::*;
    use kahan_ecm::util::faults::{self, FaultAction, FaultPlan};

    /// `stop()` while lanes are dead or mid-restart: injected submitter
    /// deaths (including one that kills the replacement) race a fast
    /// supervisor and an immediate shutdown. Every submitted request must
    /// still resolve — served bit-identically by a replacement or the
    /// shutdown drain, or cleanly disconnected (the dead incarnation's
    /// in-hand messages) — and `stop()` must return instead of hanging on
    /// a lane that no longer serves its queue.
    #[test]
    fn shutdown_during_lane_recovery_neither_hangs_nor_drops() {
        faults::reset();
        let engine = leak_engine(&Topology::fake_even(2), 1, 4 << 20);
        let reference = {
            let mut rng = Rng::new(77);
            let (a, b) = (rng.normal_f32_vec(512), rng.normal_f32_vec(512));
            (engine.dot_f32(Accuracy::Kahan, &a, &b).to_bits(), a, b)
        };
        let (ref_bits, a, b) = reference;

        // lane 0 dies on its first wake-up AND its replacement dies on
        // the next; lane 1 dies once — shutdown arrives while the
        // supervisor is still replaying restarts
        FaultPlan::new()
            .fault("lane", 0, 0, FaultAction::Die)
            .fault("lane", 0, 1, FaultAction::Die)
            .fault("lane", 1, 0, FaultAction::Die)
            .install();
        let (svc, client) = DotService::start_on(
            ServiceConfig { supervise_interval_us: 500, ..ServiceConfig::default() },
            engine,
        );
        // wave 1 trips the first death on each lane (round-robin routing
        // puts 4 requests on each); the sleep lets the supervisor replay
        // restarts before wave 2 arrives
        let mut rxs: Vec<_> = (0..8u64)
            .map(|i| client.submit(i, "kahan", a.clone(), b.clone()))
            .collect();
        std::thread::sleep(Duration::from_millis(5));
        // wave 2 lands after lane 1's only scheduled death is consumed,
        // so its lane-1 half MUST be served (by the replacement or the
        // shutdown drain); lane 0's replacement may still die once more
        rxs.extend((8..24u64).map(|i| client.submit(i, "kahan", a.clone(), b.clone())));
        std::thread::sleep(Duration::from_millis(1));
        let stats = svc.stop();
        faults::reset();

        let (mut served, mut disconnected) = (0u64, 0u64);
        for rx in rxs {
            // a timeout here IS the hang this test exists to catch
            match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(resp) => {
                    let v = resp.value.expect("served request must carry a value");
                    assert_eq!(
                        v.to_bits(),
                        ref_bits,
                        "a request served across a lane restart changed bits"
                    );
                    served += 1;
                }
                Err(e) => {
                    assert!(
                        matches!(e, std::sync::mpsc::RecvTimeoutError::Disconnected),
                        "request neither served nor cleanly disconnected"
                    );
                    disconnected += 1;
                }
            }
        }
        assert_eq!(served + disconnected, 24, "every request must resolve");
        // only a dead incarnation's in-hand messages may disconnect; wave
        // 2's lane-1 half sits beyond every scheduled death on its lane
        assert!(
            served >= 8,
            "requests past the death schedule were not re-served: \
             served={served} disconnected={disconnected} {stats:?}"
        );
    }
}
