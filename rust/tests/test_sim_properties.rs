//! Property-based integration tests on the simulator and model invariants
//! (hand-rolled harness in `util::prop`; proptest is unavailable offline).

use kahan_ecm::ecm;
use kahan_ecm::isa::{generate, generate_ext, Precision, Simd, Variant};
use kahan_ecm::machine::{all_presets, presets::ivb};
use kahan_ecm::prop_assert;
use kahan_ecm::sim;
use kahan_ecm::util::prop::check;

fn random_kernel(rng: &mut kahan_ecm::util::Rng) -> kahan_ecm::isa::KernelDesc {
    let variant = match rng.below(3) {
        0 => Variant::Naive,
        1 => Variant::Kahan,
        _ => Variant::KahanFma,
    };
    let simd = match rng.below(4) {
        0 => Simd::Scalar,
        1 => Simd::Sse,
        2 => Simd::Avx,
        _ => Simd::Avx512,
    };
    let prec = if rng.below(2) == 0 { Precision::Sp } else { Precision::Dp };
    let unroll = rng.below(8) as usize; // 0 = auto
    generate(variant, simd, prec, unroll)
}

/// ECM predictions are monotone in residence level: deeper data can never be
/// faster.
#[test]
fn prop_ecm_monotone_in_level() {
    check("ecm-monotone-level", 100, |rng| {
        let m = &all_presets()[rng.below(4) as usize];
        let k = random_kernel(rng);
        let e = ecm::build(m, &k, rng.below(2) == 0);
        let p = e.predictions();
        for w in p.windows(2) {
            prop_assert!(w[1] >= w[0] - 1e-9, "{}: {:?}", k.name, p);
        }
        Ok(())
    });
}

/// T_ECM >= both of its overlap components (Eq. 1 lower bounds).
#[test]
fn prop_ecm_respects_overlap_bounds() {
    check("ecm-overlap-bounds", 100, |rng| {
        let m = &all_presets()[rng.below(4) as usize];
        let k = random_kernel(rng);
        let e = ecm::build(m, &k, true);
        for level in 0..4 {
            let p = e.prediction(level);
            prop_assert!(p >= e.t_ol - 1e-9, "T_ECM < T_OL");
            prop_assert!(p >= e.t_nol - 1e-9, "T_ECM < T_nOL");
        }
        Ok(())
    });
}

/// More unrolling never makes the ECM in-core time worse (until the
/// register budget caps it).
#[test]
fn prop_unroll_never_hurts_core_time() {
    check("unroll-monotone", 60, |rng| {
        let m = ivb();
        let variant = if rng.below(2) == 0 { Variant::Naive } else { Variant::Kahan };
        let simd = if rng.below(2) == 0 { Simd::Sse } else { Simd::Avx };
        let u = 1 + rng.below(6) as usize;
        let k1 = generate_ext(variant, simd, Precision::Sp, u, None);
        let k2 = generate_ext(variant, simd, Precision::Sp, u + 1, None);
        let e1 = ecm::build(&m, &k1, true).prediction(0);
        let e2 = ecm::build(&m, &k2, true).prediction(0);
        prop_assert!(e2 <= e1 + 1e-9, "unroll {u}->{}: {e1} -> {e2}", u + 1);
        Ok(())
    });
}

/// The simulator's sweep is weakly monotone in working-set size (up to its
/// deterministic jitter) and always at least the in-core time.
#[test]
fn prop_sim_sweep_monotone_in_ws() {
    check("sim-monotone-ws", 25, |rng| {
        let m = &all_presets()[rng.below(4) as usize];
        let k = random_kernel(rng);
        let t_core = sim::core::steady_state_cycles_per_unit(&m.core, &k);
        let mut prev = 0.0f64;
        for ws_kib in [8u64, 64, 1024, 8192, 262_144] {
            let elems = ws_kib * 1024 / k.bytes_per_iter();
            let p = sim::simulate_working_set(m, &k, elems.max(64), true);
            prop_assert!(
                p.cy_per_cl >= prev * 0.93,
                "{} on {}: {} then {}",
                k.name,
                m.shorthand,
                prev,
                p.cy_per_cl
            );
            prop_assert!(
                p.cy_per_cl * k.cls_per_unit() as f64 >= t_core * 0.93,
                "below core time"
            );
            prev = prev.max(p.cy_per_cl);
        }
        Ok(())
    });
}

/// Cache-sim conservation: every access is served by exactly one level.
#[test]
fn prop_cache_sim_conservation() {
    check("cache-conservation", 30, |rng| {
        let m = &all_presets()[rng.below(4) as usize];
        let mut cs = sim::cache::CacheSim::new(m);
        let n = 1000 + rng.below(20_000);
        for _ in 0..n {
            // random-ish strided mix of two streams
            let s = rng.below(2) << 30;
            cs.access(s + rng.below(1 << 22));
        }
        let served: u64 = cs.served.iter().sum();
        prop_assert!(served == cs.accesses, "{} vs {}", served, cs.accesses);
        prop_assert!(cs.accesses == n, "access count");
        Ok(())
    });
}

/// Repeated small-set accesses eventually all hit L1 (cache warms up).
#[test]
fn prop_cache_warms_up() {
    check("cache-warmup", 20, |rng| {
        let m = ivb();
        let mut cs = sim::cache::CacheSim::new(&m);
        let lines = 1 + rng.below(400); // <= 25 KiB, fits L1
        for _ in 0..3 {
            for i in 0..lines {
                cs.access(i * 64);
            }
        }
        cs.reset_counters();
        for i in 0..lines {
            cs.access(i * 64);
        }
        prop_assert!(cs.served[0] == lines, "{} of {} hit L1", cs.served[0], lines);
        Ok(())
    });
}

/// Multicore scaling: monotone in cores, capped by the roofline, and
/// linear before the knee.
#[test]
fn prop_scaling_invariants() {
    check("scaling-invariants", 20, |rng| {
        let m = &all_presets()[rng.below(4) as usize];
        let k = random_kernel(rng);
        let pts = sim::simulate_scaling(m, &k, 64 * 1024 * 1024, m.cores);
        let roof = m.memory.load_bw_gbs / k.bytes_per_iter() as f64;
        for w in pts.windows(2) {
            prop_assert!(w[1].gups >= w[0].gups - 1e-9, "non-monotone");
        }
        for p in &pts {
            prop_assert!(p.gups <= roof * 1.02, "{} exceeds roofline {roof}", p.gups);
            prop_assert!(p.bw_utilization <= 1.0 + 1e-9, "utilization");
        }
        // linearity before saturation
        if pts.len() >= 2 && pts[1].bw_utilization < 1.0 {
            let lin = pts[1].gups / pts[0].gups;
            prop_assert!((lin - 2.0).abs() < 0.02, "2-core linearity {lin}");
        }
        Ok(())
    });
}

/// Host kernels vs virtual kernels: the ISA generator's instruction counts
/// must match what the real AVX2 kernel does per unit (4 loads, 2 mul,
/// 8 adds per 16 SP iterations — the §3 counting).
#[test]
fn isa_counts_match_real_kernel_structure() {
    let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    let per_unit = |op: kahan_ecm::isa::Op| {
        k.insts.iter().filter(|i| i.op == op).count() as f64 / k.units_per_stream_pass as f64
    };
    assert_eq!(per_unit(kahan_ecm::isa::Op::Load), 4.0);
    assert_eq!(per_unit(kahan_ecm::isa::Op::Mul), 2.0);
    assert_eq!(per_unit(kahan_ecm::isa::Op::Add), 8.0);
}

// ---------------------------------------------------------------------------
// §5 generalization: the summation kernel family (one stream, no multiply)
// ---------------------------------------------------------------------------

/// ECM for the Kahan SUM on IVB (SP, AVX): one stream means half the loads
/// and half the transfer traffic of dot — {8 || 2 | 2 | 2 | ~3+1.45}:
/// ADD-bound flat through L3, and "for free" vs the naive sum in memory.
#[test]
fn sum_kernel_ecm_shapes() {
    use kahan_ecm::isa::kernelgen::generate_sum;
    let m = ivb();
    let kahan = generate_sum(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    let naive = generate_sum(Variant::Naive, Simd::Avx, Precision::Sp, 0);
    assert_eq!(kahan.n_streams, 1);
    let ek = ecm::build(&m, &kahan, true);
    let en = ecm::build(&m, &naive, true);
    // Kahan sum: 8 ADDs per unit on one port -> 8 cy, loads 2 cy
    assert_eq!(ek.t_ol, 8.0);
    assert_eq!(ek.t_nol, 2.0);
    assert_eq!(ek.t_l1l2, 2.0); // one CL per unit
    // ADD-bound flat through L3
    assert_eq!(ek.prediction(0), 8.0);
    assert_eq!(ek.prediction(1), 8.0);
    assert_eq!(ek.prediction(2), 8.0);
    // in memory: identical to the naive sum — Kahan for free
    let ratio = ek.prediction(3) / en.prediction(3);
    assert!((ratio - 1.0).abs() < 0.05, "kahan-sum/naive-sum in mem = {ratio}");
    // but 4x in L1 (1 ADD vs 4 ADDs; naive is load-bound at 2 cy)
    assert_eq!(en.prediction(0), 2.0);
}

/// The simulator handles one-stream kernels end to end.
#[test]
fn sum_kernel_simulates() {
    use kahan_ecm::isa::kernelgen::generate_sum;
    let m = ivb();
    let k = generate_sum(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    let e = ecm::build(&m, &k, true);
    for (level, ws) in [16u64 << 10, 128 << 10, 4 << 20, 256 << 20].iter().enumerate() {
        let elems = ws / k.bytes_per_iter();
        let p = sim::simulate_working_set(&m, &k, elems, true);
        let pred = e.prediction(level) / k.cls_per_unit() as f64;
        let rel = (p.cy_per_cl - pred).abs() / pred;
        assert!(rel < 0.30, "level {level}: sim {} vs model {pred}", p.cy_per_cl);
    }
    // scaling saturates at the sum roofline (1 update / 4 B)
    let pts = sim::simulate_scaling(&m, &k, 256 << 20, m.cores);
    let roof = m.memory.load_bw_gbs / 4.0;
    assert!((pts.last().unwrap().gups - roof).abs() / roof < 0.05);
}

/// Property: sum kernels have exactly half the per-unit transfer volume of
/// dot kernels at every SIMD width and precision.
#[test]
fn prop_sum_half_the_traffic_of_dot() {
    use kahan_ecm::isa::kernelgen::generate_sum;
    check("sum-half-traffic", 40, |rng| {
        let m = &all_presets()[rng.below(4) as usize];
        let simd = match rng.below(4) {
            0 => Simd::Scalar,
            1 => Simd::Sse,
            2 => Simd::Avx,
            _ => Simd::Avx512,
        };
        let prec = if rng.below(2) == 0 { Precision::Sp } else { Precision::Dp };
        let sum = generate_sum(Variant::Kahan, simd, prec, 0);
        let dot = generate(Variant::Kahan, simd, prec, 0);
        let es = ecm::build(m, &sum, true);
        let ed = ecm::build(m, &dot, true);
        prop_assert!(es.t_l1l2 * 2.0 == ed.t_l1l2, "L1L2 traffic");
        prop_assert!(
            (es.t_l3mem_bw * 2.0 - ed.t_l3mem_bw).abs() < 1e-9,
            "mem traffic"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// store-traffic extension: the axpy kernel (Stengel et al.'s canonical ECM
// example) — exercises store ports and write-back accounting
// ---------------------------------------------------------------------------

/// ECM for AVX daxpy on IVB: {2 || 4 | 6 | 6 | ~13.5} cy per unit (8 DP
/// iterations; 3 CL transfers per unit: x read, y read, y write-back).
#[test]
fn axpy_ecm_on_ivb() {
    use kahan_ecm::isa::generate_axpy;
    let m = ivb();
    let k = generate_axpy(Simd::Avx, Precision::Dp, 0);
    assert_eq!(k.n_streams, 2);
    assert_eq!(k.written_streams, 1);
    assert_eq!(k.cl_transfers_per_unit(), 3);
    assert_eq!(k.traffic_bytes_per_iter(), 24);
    let e = ecm::build(&m, &k, true);
    assert_eq!(e.t_ol, 2.0); // 2 MULs | 2 ADDs per unit, separate ports
    assert_eq!(e.t_nol, 4.0); // 4 split AVX loads / 2 ports; 2 split stores / 1 port
    assert_eq!(e.t_l1l2, 6.0); // 3 CLs x 2 cy
    assert_eq!(e.t_l2l3, 6.0);
    // memory-bound intensity: 1 update / 24 B -> 46.1/24 = 1.92 GUP/s roof
    assert!((e.roofline_gups() - 1.92).abs() < 0.01);
    // L1 prediction: store/load-port bound, not FP bound
    assert_eq!(e.prediction(0), 4.0);
}

/// On HSW the wider store path (32 B) halves the store-port time.
#[test]
fn axpy_hsw_store_path() {
    use kahan_ecm::isa::generate_axpy;
    let m = kahan_ecm::machine::presets::hsw();
    let k = generate_axpy(Simd::Avx, Precision::Dp, 0);
    let e = ecm::build(&m, &k, true);
    assert_eq!(e.t_nol, 2.0); // 2 LD/cy + 1 ST/cy at full AVX width
    assert_eq!(e.t_l1l2, 3.0); // 3 CLs x 1 cy on the 64 B/cy bus
}

/// The simulator consumes axpy end to end and lands on the model.
#[test]
fn axpy_simulates_and_scales() {
    use kahan_ecm::isa::generate_axpy;
    let m = ivb();
    let k = generate_axpy(Simd::Avx, Precision::Dp, 0);
    let e = ecm::build(&m, &k, true);
    for (level, ws) in [16u64 << 10, 128 << 10, 4 << 20, 256 << 20].iter().enumerate() {
        let elems = ws / k.bytes_per_iter();
        let p = sim::simulate_working_set(&m, &k, elems, true);
        let pred = e.prediction(level) / k.cl_transfers_per_unit() as f64;
        let rel = (p.cy_per_cl - pred).abs() / pred;
        assert!(rel < 0.35, "level {level}: sim {} vs model {pred}", p.cy_per_cl);
    }
    let pts = sim::simulate_scaling(&m, &k, 256 << 20, m.cores);
    let roof = m.memory.load_bw_gbs / 24.0;
    assert!((pts.last().unwrap().gups - roof).abs() / roof < 0.05, "axpy saturates at its roofline");
}
