//! Fault-injection integration tests: deterministic worker/lane deaths,
//! self-healing supervision (respawns, lane restarts, quarantine +
//! probe reinstatement), retry-with-budget clients, and the seeded chaos
//! capstone.
//!
//! The [`FaultPlan`] is process-global, so these tests MUST serialize —
//! CI's faultinject job runs
//!
//! ```text
//! cargo test --release --features faultinject -- --test-threads=1
//! ```
//!
//! and every test installs its plan first and `faults::reset()`s on the
//! way out. Each test builds its own leaked engine so no recovery
//! counter (they are engine-cumulative) leaks across tests.
#![cfg(feature = "faultinject")]

use kahan_ecm::coordinator::{DotService, RetryBudget, ServiceConfig, ServiceError};
use kahan_ecm::engine::{EngineConfig, ShardedConfig, ShardedEngine, Topology};
use kahan_ecm::isa::Accuracy;
use kahan_ecm::util::faults::{self, FaultAction, FaultPlan};
use kahan_ecm::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Two shards with two workers each, forced: a one-worker shard serves
/// everything inline and never reaches the pool's "worker" fault site,
/// and equal per-shard worker counts keep the chunk geometry — and
/// therefore the bits — identical whichever shard a request routes to.
fn leaked_engine() -> &'static ShardedEngine {
    let cfg = ShardedConfig {
        engine: EngineConfig { threads: 2, ..EngineConfig::default() },
        split_min_bytes: 512 << 10,
        ..ShardedConfig::default()
    };
    Box::leak(Box::new(ShardedEngine::from_topology(&Topology::fake_even(2), cfg)))
}

/// A parallel-class input pair: 384 KB total sits above the 256 KB
/// parallel cutoff (chunk jobs land on pool workers, where the "worker"
/// and "chunk" fault sites live) and below the 512 KB split threshold.
fn parallel_inputs(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    (rng.normal_f32_vec(48 * 1024), rng.normal_f32_vec(48 * 1024))
}

fn retry_budget() -> RetryBudget {
    RetryBudget {
        max_attempts: 8,
        budget_us: 10_000_000,
        base_backoff_us: 200,
        max_backoff_us: 20_000,
    }
}

fn wait_until<F: FnMut() -> bool>(mut cond: F, timeout: Duration, what: &str) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < timeout, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(2));
    }
}

/// An injected worker death makes the in-flight dot fail CLEANLY — the
/// dropped chunk propagates as a panic, never as a fabricated `0.0`
/// partial folded into a wrong value — and after the supervision sweep
/// respawns the worker, the same request is served bit-identically.
#[test]
fn worker_death_fails_cleanly_and_respawn_restores_bits() {
    faults::reset();
    let engine = leaked_engine();
    let (a, b) = parallel_inputs(11);
    let reference = engine.dot_f32(Accuracy::Kahan, &a, &b);

    // a supervision loop stands in for the service's supervisor thread:
    // chunk jobs queued behind a dead worker are only served once the
    // sweep respawns it onto the same queue
    let stop = Arc::new(AtomicBool::new(false));
    let sweeper = {
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                engine.supervise(0);
                std::thread::sleep(Duration::from_millis(1));
            }
        })
    };

    // whichever worker pops the first chunk job dies with it in hand
    FaultPlan::new()
        .fault("worker", 0, 0, FaultAction::Die)
        .fault("worker", 1, 0, FaultAction::Die)
        .install();
    let faulted = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        engine.dot_f32(Accuracy::Kahan, &a, &b)
    }));
    assert!(
        faulted.is_err(),
        "a dot whose worker died mid-chunk must fail, not return a partial value"
    );

    wait_until(
        || engine.stats().respawns >= 1,
        Duration::from_secs(10),
        "the supervision sweep to respawn the dead worker",
    );
    faults::reset();
    let healed = engine.dot_f32(Accuracy::Kahan, &a, &b);
    assert_eq!(
        healed.to_bits(),
        reference.to_bits(),
        "post-respawn serve must be bit-identical to the pre-fault serve"
    );
    stop.store(true, Ordering::Relaxed);
    sweeper.join().expect("sweeper");
}

/// A dead lane submitter drops only its in-hand requests (clients see
/// the reply channel disconnect, typed [`ServiceError::LaneDead`] by the
/// retry client); the supervisor restarts the lane, queued requests are
/// re-served by the replacement, and every retried serve is
/// bit-identical to a fault-free one.
#[test]
fn lane_death_is_restarted_and_retries_serve_bit_identically() {
    faults::reset();
    let engine = leaked_engine();
    let mut rng = Rng::new(23);
    let (a, b) = (rng.normal_f32_vec(1024), rng.normal_f32_vec(1024));
    let reference = engine.dot_f32(Accuracy::Kahan, &a, &b);

    // kill BOTH lanes' submitters on their first wake-up: whichever lane
    // a request routes to, its first serve attempt dies mid-flight
    FaultPlan::new()
        .fault("lane", 0, 0, FaultAction::Die)
        .fault("lane", 1, 0, FaultAction::Die)
        .install();
    let cfg = ServiceConfig { supervise_interval_us: 1_000, ..ServiceConfig::default() };
    let (svc, client) = DotService::start_on(cfg, engine);
    let budget = retry_budget();
    let mut extra_attempts = 0u32;
    for i in 0..8u64 {
        let (resp, attempts) =
            client.submit_with_retry(i, "kahan", a.clone(), b.clone(), 0, &budget);
        let v = resp
            .value
            .unwrap_or_else(|e| panic!("request {i} failed after {attempts} attempts: {e}"));
        assert_eq!(
            v.to_bits(),
            reference.to_bits(),
            "a retried serve must be bit-identical to a first-try serve"
        );
        extra_attempts += attempts - 1;
    }
    let stats = svc.stop();
    faults::reset();
    assert!(
        stats.lane_restarts >= 1,
        "the supervisor never restarted a dead lane: {stats:?}"
    );
    assert!(
        extra_attempts >= 1,
        "at least one request must have observed the dead lane and retried"
    );
}

/// A shard that exhausts its respawn budget is quarantined (dropped from
/// fresh routing — bits never change), probed each sweep, and reinstated
/// once every worker round-trips again; post-reinstatement serves match
/// the pre-fault bits.
#[test]
fn respawn_budget_quarantines_and_probes_reinstate() {
    faults::reset();
    let engine = leaked_engine();
    let (a, b) = parallel_inputs(31);
    let reference = engine.dot_f32(Accuracy::Kahan, &a, &b);

    // a burst of worker deaths: with a budget of 1, the first respawn on
    // a shard quarantines it. Probe jobs visit the same "worker" site, so
    // probes keep failing while scheduled deaths remain and succeed once
    // the schedule is exhausted — which is exactly when reinstatement is
    // safe.
    let mut plan = FaultPlan::new();
    for w in 0..2usize {
        for hit in 0..3u64 {
            plan = plan.fault("worker", w, hit, FaultAction::Die);
        }
    }
    plan.install();
    let cfg = ServiceConfig {
        supervise_interval_us: 1_000,
        shard_respawn_budget: 1,
        ..ServiceConfig::default()
    };
    let (svc, client) = DotService::start_on(cfg, engine);
    let budget = retry_budget();
    let t0 = Instant::now();
    let mut i = 0u64;
    while !(0..engine.shards()).any(|s| engine.is_quarantined(s)) {
        assert!(
            t0.elapsed() < Duration::from_secs(20),
            "no shard was ever quarantined despite exhausted respawn budgets"
        );
        // failures are expected mid-schedule (a killed worker fails its
        // request cleanly); the drive just has to keep hitting the pool
        let (resp, _) = client.submit_with_retry(i, "kahan", a.clone(), b.clone(), 0, &budget);
        let _ = resp.value;
        i += 1;
    }
    wait_until(
        || (0..engine.shards()).all(|s| !engine.is_quarantined(s)),
        Duration::from_secs(20),
        "probe-based reinstatement of every quarantined shard",
    );
    let (resp, _) = client.submit_with_retry(i, "kahan", a.clone(), b.clone(), 0, &budget);
    let healed = resp.value.expect("post-reinstatement serve");
    assert_eq!(
        healed.to_bits(),
        reference.to_bits(),
        "quarantine and reinstatement must never change bits"
    );
    let stats = svc.stop();
    faults::reset();
    assert!(stats.respawns >= 1, "worker deaths never respawned: {stats:?}");
    assert!(stats.quarantines >= 1, "respawn budget never quarantined: {stats:?}");
}

/// Capstone chaos test: a seeded random fault schedule (worker, chunk
/// and lane sites; panics, deaths and stalls) against a concurrent
/// retrying workload. Every request completes (no hangs), every success
/// is bit-identical to the fault-free serial reference, every failure is
/// a typed infrastructure outcome, and the recovery counters are bounded
/// by the schedule itself.
#[test]
fn chaos_seeded_schedule_recovers_and_preserves_bits() {
    faults::reset();
    let engine = leaked_engine();
    let inputs: Vec<(Vec<f32>, Vec<f32>)> =
        (0..4).map(|i| parallel_inputs(100 + i)).collect();
    let refs: Vec<u32> = inputs
        .iter()
        .map(|(a, b)| engine.dot_f32(Accuracy::Kahan, a, b).to_bits())
        .collect();

    let plan = FaultPlan::seeded(4242, 12, &["worker", "chunk", "lane"], 2, 6);
    let worker_faults = plan.count_at("worker") as u64;
    let lane_faults = plan.count_at("lane") as u64;
    plan.install();

    let cfg = ServiceConfig {
        supervise_interval_us: 1_000,
        shard_respawn_budget: 4,
        ..ServiceConfig::default()
    };
    let (svc, client) = DotService::start_on(cfg, engine);
    let budget = retry_budget();
    let results: Vec<(usize, Result<f32, ServiceError>)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3usize)
            .map(|t| {
                let client = client.clone();
                let inputs = &inputs;
                let budget = &budget;
                s.spawn(move || {
                    let mut out = Vec::new();
                    for k in 0..12usize {
                        let idx = (t * 12 + k) % inputs.len();
                        let (a, b) = &inputs[idx];
                        let (resp, _) = client.submit_with_retry(
                            (t * 100 + k) as u64,
                            "kahan",
                            a.clone(),
                            b.clone(),
                            0,
                            budget,
                        );
                        out.push((idx, resp.value));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("chaos client thread"))
            .collect()
    });
    let stats = svc.stop();
    faults::reset();

    assert_eq!(results.len(), 36, "every request must complete — no hangs, no drops");
    let mut ok = 0usize;
    for (idx, value) in &results {
        match value {
            Ok(v) => {
                ok += 1;
                assert_eq!(
                    v.to_bits(),
                    refs[*idx],
                    "a served value under chaos must be bit-identical to the fault-free \
                     serial reference"
                );
            }
            // infrastructure failures (dead lane, retry budget exhausted
            // on sheds) and clean engine panics are the only acceptable
            // outcomes — never a validation error, never a wrong value
            Err(ServiceError::EnginePanic(_)) | Err(ServiceError::LaneDead) => {}
            Err(e) if e.is_retryable() => {}
            Err(e) => panic!("unexpected failure class under chaos: {e:?}"),
        }
    }
    assert!(ok > 0, "no request survived the chaos schedule: {stats:?}");
    // wedge detection is off in this config, so only scheduled
    // worker-site faults can kill workers and only scheduled lane-site
    // faults can kill submitters
    assert!(
        stats.respawns <= worker_faults,
        "more respawns than scheduled worker faults: {stats:?}"
    );
    assert!(
        stats.lane_restarts <= lane_faults,
        "more lane restarts than scheduled lane faults: {stats:?}"
    );
}
