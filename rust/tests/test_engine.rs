//! Engine integration + accuracy properties: the chunked-parallel
//! compensated dot must keep the *sequential* Kahan error bound
//! `O(u)·Σ|aᵢbᵢ|` for every length, chunk count, and conditioning —
//! including Ogita–Rump–Oishi ill-conditioned inputs — and the engine
//! facade must serve correct results through both its inline and pooled
//! parallel paths.

use kahan_ecm::accuracy::exact::{exact_dot_f32, exact_dot_f64};
use kahan_ecm::accuracy::gen_dot_f32;
use kahan_ecm::bench::kernels::{by_name, scalar, KernelFn};
use kahan_ecm::engine::{
    parallel_dot_f32, parallel_dot_f64, BufferPool, DotEngine, EngineConfig, WorkerPool,
};
use kahan_ecm::isa::Variant;
use kahan_ecm::prop_assert;
use kahan_ecm::util::prop;
use std::sync::Arc;

/// Sequential-Kahan-style bound, with slack for the cross-chunk merge and
/// the f32 accumulation of `Σ|aᵢbᵢ|`: `err ≤ 64·u·Σ|aᵢbᵢ|` (u = 2⁻²⁴ for
/// f32). Sequential Kahan itself satisfies `2u + O(u²)`, so 64u leaves
/// room without ever excusing a broken merge (a single lost product would
/// show up at ~u·cond·|result|, orders of magnitude larger on the
/// ill-conditioned inputs below).
fn f32_bound(absdot: f64) -> f64 {
    64.0 * (f32::EPSILON as f64 / 2.0) * absdot.max(1e-30)
}

fn f64_bound(absdot: f64) -> f64 {
    64.0 * (f64::EPSILON / 2.0) * absdot.max(1e-300)
}

fn absdot_f32(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum()
}

/// Random lengths x random chunk counts x random data: the parallel
/// compensated reduction agrees with the exact dot to the sequential
/// Kahan bound.
#[test]
fn property_chunked_kahan_keeps_sequential_bound_f32() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-kahan-f32", 40, |rng| {
        let n = 8 + rng.below(6000) as usize;
        let chunks = 1 + rng.below(12) as usize;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f32(&pool, scalar::kahan_unrolled_f32, &a, &b, chunks) as f64;
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} chunks={chunks}: got {got}, exact {exact}, err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
}

/// Same property on ill-conditioned inputs from the Ogita–Rump–Oishi
/// generator: massive cancellation is exactly where a sloppy merge would
/// surface (error scales with `u·cond` for naive, stays at `u·Σ|aᵢbᵢ|`
/// for Kahan).
#[test]
fn property_chunked_kahan_ill_conditioned_gendot() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-kahan-gendot", 12, |rng| {
        let n = 64 + rng.below(2048) as usize;
        let chunks = 1 + rng.below(8) as usize;
        let target_cond = [1e4, 1e6, 1e8][rng.below(3) as usize];
        let (av, bv, exact, _cond) = gen_dot_f32(n.max(6), target_cond, rng);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        for f in [scalar::kahan_unrolled_f32, scalar::kahan_seq_f32] {
            let got = parallel_dot_f32(&pool, f, &a, &b, chunks) as f64;
            prop_assert!(
                (got - exact).abs() <= bound,
                "n={n} chunks={chunks} cond~{target_cond:e}: err {:e} > bound {bound:e}",
                (got - exact).abs()
            );
        }
        Ok(())
    });
}

/// The SIMD kernels behave identically under chunking (tail handling at
/// unaligned chunk boundaries is where they'd break).
#[test]
fn property_chunked_simd_kernels_agree_f32() {
    let Some(k) = by_name("kahan-AVX2-SP").filter(|k| k.available) else {
        eprintln!("skipping: no AVX2");
        return;
    };
    let KernelFn::F32(f) = k.f else { unreachable!() };
    let pool = WorkerPool::new(3);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-avx2", 25, |rng| {
        let n = 1 + rng.below(10_000) as usize;
        let chunks = 1 + rng.below(7) as usize;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f32(&pool, f, &a, &b, chunks) as f64;
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} chunks={chunks}: err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
}

#[test]
fn property_chunked_kahan_keeps_sequential_bound_f64() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-kahan-f64", 25, |rng| {
        let n = 8 + rng.below(5000) as usize;
        let chunks = 1 + rng.below(10) as usize;
        let av = rng.normal_f64_vec(n);
        let bv = rng.normal_f64_vec(n);
        let exact = exact_dot_f64(&av, &bv);
        let absdot: f64 = av.iter().zip(&bv).map(|(x, y)| (x * y).abs()).sum();
        let bound = f64_bound(absdot);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f64(&pool, scalar::kahan_unrolled_f64, &a, &b, chunks);
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} chunks={chunks}: err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
}

/// End-to-end through the engine facade (autotuned dispatch + pool +
/// workers): the served result keeps the bound on both the inline and the
/// chunked-parallel path, and repeated calls are bit-stable.
#[test]
fn engine_facade_serves_accurate_deterministic_results() {
    let engine = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
    let mut rng = kahan_ecm::util::Rng::new(123);
    for n in [4096usize, 500_000] {
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&a, &b);
        let bound = f32_bound(absdot_f32(&a, &b));
        let first = engine.dot_f32(Variant::Kahan, &a, &b);
        assert!(
            (first as f64 - exact).abs() <= bound,
            "n={n}: {first} vs {exact} (bound {bound:e})"
        );
        for _ in 0..3 {
            let again = engine.dot_f32(Variant::Kahan, &a, &b);
            assert_eq!(first.to_bits(), again.to_bits(), "n={n} must be bit-stable");
        }
    }
    let s = engine.stats();
    assert_eq!(s.requests, 8);
    assert_eq!(s.parallel, 4, "only the 500k dots go parallel: {s:?}");
    assert!(s.pool.hits >= 6, "steady state must recycle buffers: {s:?}");
}

/// The engine's ill-conditioned behaviour end-to-end: Kahan stays at the
/// bound while naive drifts far beyond it (sanity that dispatch routes
/// variants to genuinely different kernels).
#[test]
fn engine_kahan_beats_naive_on_ill_conditioned_input() {
    let engine = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
    let mut rng = kahan_ecm::util::Rng::new(7);
    let (a, b, exact, cond) = gen_dot_f32(4096, 1e7, &mut rng);
    let bound = f32_bound(absdot_f32(&a, &b));
    let kahan = engine.dot_f32(Variant::Kahan, &a, &b) as f64;
    let naive = engine.dot_f32(Variant::Naive, &a, &b) as f64;
    let ek = (kahan - exact).abs();
    let en = (naive - exact).abs();
    assert!(ek <= bound, "kahan err {ek:e} > bound {bound:e} (cond {cond:e})");
    assert!(
        ek * 10.0 < en.max(1e-30) || en <= bound,
        "kahan ({ek:e}) should beat naive ({en:e}) at cond {cond:e}"
    );
}
