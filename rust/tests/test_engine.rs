//! Engine integration + accuracy properties: the chunked-parallel
//! compensated dot must keep the *sequential* Kahan error bound
//! `O(u)·Σ|aᵢbᵢ|` for every length, chunk count, and conditioning —
//! including Ogita–Rump–Oishi ill-conditioned inputs — and the engine
//! facade must serve correct results through both its inline and pooled
//! parallel paths.

use kahan_ecm::accuracy::exact::{exact_dot_f32, exact_dot_f64};
use kahan_ecm::accuracy::gen_dot_f32;
use kahan_ecm::bench::kernels::{by_name, scalar, KernelFn};
use kahan_ecm::coordinator::{DotService, ServiceConfig};
use kahan_ecm::engine::{
    parallel_dot_f32, parallel_dot_f64, BufferPool, DotEngine, EngineConfig, ShardedConfig,
    ShardedEngine, Topology, WorkerPool,
};
use kahan_ecm::isa::Accuracy;
use kahan_ecm::prop_assert;
use kahan_ecm::util::prop;
use std::sync::Arc;

/// Sequential-Kahan-style bound, with slack for the cross-chunk merge and
/// the f32 accumulation of `Σ|aᵢbᵢ|`: `err ≤ 64·u·Σ|aᵢbᵢ|` (u = 2⁻²⁴ for
/// f32). Sequential Kahan itself satisfies `2u + O(u²)`, so 64u leaves
/// room without ever excusing a broken merge (a single lost product would
/// show up at ~u·cond·|result|, orders of magnitude larger on the
/// ill-conditioned inputs below).
fn f32_bound(absdot: f64) -> f64 {
    64.0 * (f32::EPSILON as f64 / 2.0) * absdot.max(1e-30)
}

fn f64_bound(absdot: f64) -> f64 {
    64.0 * (f64::EPSILON / 2.0) * absdot.max(1e-300)
}

/// Dot2-grade bound for the *chunked* execution paths. Three honest terms:
///
/// * `16u·|s|` — the final rounding plus the compensated cross-chunk merge
///   (the merge folds already-rounded chunk values, each fold step
///   protected).
/// * `8u·Σ|aᵢbᵢ|` — each chunk's TwoProd-compensated sub-dot is rounded to
///   working precision before the merge, and a chunk's true value is
///   bounded by its share of `Σ|aᵢbᵢ|`; the shares sum to the whole, so
///   chunk rounding costs at most `u·Σ|aᵢbᵢ|` (4× slack). This term is
///   what parallelism genuinely adds over sequential Dot2 — it is still
///   `O(u)`-with-a-small-constant, 8× below the `64u` Kahan test bound,
///   and crucially carries **no** `cond` factor.
/// * `4·γ²₂ₙ·Σ|aᵢbᵢ|` with `γ₂ₙ = 2nu` — the formal Ogita–Rump–Oishi
///   second-order term of the per-chunk Dot2 runs (each chunk's `γ` is
///   below the global one).
fn dot2_bound_f32(n: usize, absdot: f64, exact: f64) -> f64 {
    let u = f32::EPSILON as f64 / 2.0;
    let g = 2.0 * n as f64 * u;
    16.0 * u * exact.abs() + 8.0 * u * absdot.max(1e-30) + 4.0 * g * g * absdot.max(1e-30)
}

fn absdot_f32(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum()
}

/// Random lengths x random chunk counts x random data: the parallel
/// compensated reduction agrees with the exact dot to the sequential
/// Kahan bound.
#[test]
fn property_chunked_kahan_keeps_sequential_bound_f32() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-kahan-f32", 40, |rng| {
        let n = 8 + rng.below(6000) as usize;
        let chunks = 1 + rng.below(12) as usize;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f32(&pool, scalar::kahan_unrolled_f32, &a, &b, chunks) as f64;
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} chunks={chunks}: got {got}, exact {exact}, err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
}

/// Same property on ill-conditioned inputs from the Ogita–Rump–Oishi
/// generator: massive cancellation is exactly where a sloppy merge would
/// surface (error scales with `u·cond` for naive, stays at `u·Σ|aᵢbᵢ|`
/// for Kahan).
#[test]
fn property_chunked_kahan_ill_conditioned_gendot() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-kahan-gendot", 12, |rng| {
        let n = 64 + rng.below(2048) as usize;
        let chunks = 1 + rng.below(8) as usize;
        let target_cond = [1e4, 1e6, 1e8][rng.below(3) as usize];
        let (av, bv, exact, _cond) = gen_dot_f32(n.max(6), target_cond, rng);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        for f in [scalar::kahan_unrolled_f32, scalar::kahan_seq_f32] {
            let got = parallel_dot_f32(&pool, f, &a, &b, chunks) as f64;
            prop_assert!(
                (got - exact).abs() <= bound,
                "n={n} chunks={chunks} cond~{target_cond:e}: err {:e} > bound {bound:e}",
                (got - exact).abs()
            );
        }
        Ok(())
    });
}

/// The SIMD kernels behave identically under chunking (tail handling at
/// unaligned chunk boundaries is where they'd break).
#[test]
fn property_chunked_simd_kernels_agree_f32() {
    let Some(k) = by_name("kahan-AVX2-SP").filter(|k| k.available) else {
        eprintln!("skipping: no AVX2");
        return;
    };
    let KernelFn::F32(f) = k.f else { unreachable!() };
    let pool = WorkerPool::new(3);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-avx2", 25, |rng| {
        let n = 1 + rng.below(10_000) as usize;
        let chunks = 1 + rng.below(7) as usize;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f32(&pool, f, &a, &b, chunks) as f64;
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} chunks={chunks}: err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
}

#[test]
fn property_chunked_kahan_keeps_sequential_bound_f64() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    prop::check("engine-chunked-kahan-f64", 25, |rng| {
        let n = 8 + rng.below(5000) as usize;
        let chunks = 1 + rng.below(10) as usize;
        let av = rng.normal_f64_vec(n);
        let bv = rng.normal_f64_vec(n);
        let exact = exact_dot_f64(&av, &bv);
        let absdot: f64 = av.iter().zip(&bv).map(|(x, y)| (x * y).abs()).sum();
        let bound = f64_bound(absdot);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        let got = parallel_dot_f64(&pool, scalar::kahan_unrolled_f64, &a, &b, chunks);
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} chunks={chunks}: err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
}

/// End-to-end through the engine facade (autotuned dispatch + pool +
/// workers): the served result keeps the bound on both the inline and the
/// chunked-parallel path, and repeated calls are bit-stable.
#[test]
fn engine_facade_serves_accurate_deterministic_results() {
    let engine = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
    let mut rng = kahan_ecm::util::Rng::new(123);
    for n in [4096usize, 500_000] {
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&a, &b);
        let bound = f32_bound(absdot_f32(&a, &b));
        let first = engine.dot_f32(Accuracy::Kahan, &a, &b);
        assert!(
            (first as f64 - exact).abs() <= bound,
            "n={n}: {first} vs {exact} (bound {bound:e})"
        );
        for _ in 0..3 {
            let again = engine.dot_f32(Accuracy::Kahan, &a, &b);
            assert_eq!(first.to_bits(), again.to_bits(), "n={n} must be bit-stable");
        }
    }
    let s = engine.stats();
    assert_eq!(s.requests, 8);
    assert_eq!(s.parallel, 4, "only the 500k dots go parallel: {s:?}");
    assert!(s.pool.hits >= 6, "steady state must recycle buffers: {s:?}");
}

fn panicking_kernel(_a: &[f32], _b: &[f32]) -> f32 {
    panic!("injected kernel panic");
}

/// The headline bugfix regression: a panicking chunk kernel must neither
/// hang the caller (the old collector looped on a channel whose job died
/// holding `tx`, and the dead worker would deadlock every later dot routed
/// to it) nor fold a silent `0.0` partial into the result. The panic
/// propagates with its payload, and the same pool serves correct dots
/// afterwards.
#[test]
fn panicking_kernel_neither_hangs_nor_fabricates_a_value() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    let mut rng = kahan_ecm::util::Rng::new(99);
    let n = 20_000;
    let av = rng.normal_f32_vec(n);
    let bv = rng.normal_f32_vec(n);
    let a = Arc::new(bufs.admit(&av));
    let b = Arc::new(bufs.admit(&bv));

    for round in 0..2 {
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_dot_f32(&pool, panicking_kernel, &a, &b, 4)
        }));
        let err = match r {
            Err(e) => e,
            Ok(v) => panic!("round {round}: a panicking chunk must propagate, got {v}"),
        };
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string payload".into());
        assert!(msg.contains("injected kernel panic"), "round {round}: payload lost: {msg}");

        // no dead workers left behind: the same pool immediately serves a
        // correct dot whose chunks land on the same workers
        let exact = exact_dot_f32(&av, &bv);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let got = parallel_dot_f32(&pool, scalar::kahan_unrolled_f32, &a, &b, 4) as f64;
        assert!(
            (got - exact).abs() <= bound,
            "round {round}: pool is broken after a panicking job: {got} vs {exact}"
        );
    }
}

fn sharded_cfg(threads: usize, split_min_bytes: usize, chunks: usize) -> ShardedConfig {
    ShardedConfig {
        engine: EngineConfig { threads, ..EngineConfig::default() },
        split_min_bytes,
        chunks,
    }
}

/// Cross-shard merged Kahan keeps the *sequential* bound on
/// Ogita–Rump–Oishi ill-conditioned inputs: the shard merge is one more
/// compensated reduction level, and massive cancellation is exactly where
/// a sloppy cross-shard fold (or a lost shard partial) would surface.
#[test]
fn property_sharded_split_keeps_sequential_bound_ill_conditioned() {
    let sharded = ShardedEngine::from_topology(&Topology::fake_even(3), sharded_cfg(1, 1, 0));
    prop::check("sharded-split-gendot", 10, |rng| {
        let n = 512 + rng.below(4096) as usize;
        let target_cond = [1e4, 1e6, 1e8][rng.below(3) as usize];
        let (av, bv, exact, _cond) = gen_dot_f32(n, target_cond, rng);
        let bound = f32_bound(absdot_f32(&av, &bv));
        let got = sharded.dot_f32(Accuracy::Kahan, &av, &bv) as f64;
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} cond~{target_cond:e}: err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
    assert!(sharded.stats().split_dots > 0, "split threshold of 1 byte must force splits");
}

/// Fixed chunk geometry ⇒ the sharded result is bit-identical whether 1 or
/// N shards execute it: the split fold runs over the *global* per-chunk
/// partials in chunk order, so the shard assignment cannot change a bit.
#[test]
fn property_sharded_split_bit_identical_1_vs_n_shards() {
    let chunks = 8;
    let one = ShardedEngine::from_topology(&Topology::fake_even(1), sharded_cfg(2, 1, chunks));
    let two = ShardedEngine::from_topology(&Topology::fake_even(2), sharded_cfg(1, 1, chunks));
    let three = ShardedEngine::from_topology(&Topology::fake_even(3), sharded_cfg(1, 1, chunks));
    prop::check("sharded-bit-identity", 12, |rng| {
        let n = 256 + rng.below(40_000) as usize;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let base = one.dot_f32(Accuracy::Kahan, &av, &bv);
        for (label, e) in [("2 shards", &two), ("3 shards", &three)] {
            let got = e.dot_f32(Accuracy::Kahan, &av, &bv);
            prop_assert!(
                base.to_bits() == got.to_bits(),
                "n={n}: {label} diverged: {base:e} vs {got:e}"
            );
        }
        Ok(())
    });
}

/// The engine's ill-conditioned behaviour end-to-end: Kahan stays at the
/// bound while naive drifts far beyond it (sanity that dispatch routes
/// variants to genuinely different kernels).
#[test]
fn engine_kahan_beats_naive_on_ill_conditioned_input() {
    let engine = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
    let mut rng = kahan_ecm::util::Rng::new(7);
    let (a, b, exact, cond) = gen_dot_f32(4096, 1e7, &mut rng);
    let bound = f32_bound(absdot_f32(&a, &b));
    let kahan = engine.dot_f32(Accuracy::Kahan, &a, &b) as f64;
    let naive = engine.dot_f32(Accuracy::Naive, &a, &b) as f64;
    let ek = (kahan - exact).abs();
    let en = (naive - exact).abs();
    assert!(ek <= bound, "kahan err {ek:e} > bound {bound:e} (cond {cond:e})");
    assert!(
        ek * 10.0 < en.max(1e-30) || en <= bound,
        "kahan ({ek:e}) should beat naive ({en:e}) at cond {cond:e}"
    );
}

/// Satellite: the Dot2 tier under parallelism. The chunked reduction and
/// the cross-shard split must keep a Dot2-grade bound — small-constant
/// `O(u)` with **no** `cond` factor — on Ogita–Rump–Oishi ill-conditioned
/// inputs, for every length, chunk count, and shard count. Massive
/// cancellation is exactly where a merge that dropped the TwoProd
/// compensation would blow up to `u·cond`.
#[test]
fn property_parallel_and_sharded_dot2_keep_dot2_grade_bound() {
    let pool = WorkerPool::new(2);
    let bufs = BufferPool::new();
    let sharded = ShardedEngine::from_topology(&Topology::fake_even(3), sharded_cfg(1, 1, 0));
    prop::check("engine-dot2-gendot", 12, |rng| {
        let n = 64 + rng.below(4096) as usize;
        let chunks = 1 + rng.below(8) as usize;
        let target_cond = [1e4, 1e6, 1e8][rng.below(3) as usize];
        let (av, bv, exact, _cond) = gen_dot_f32(n, target_cond, rng);
        let bound = dot2_bound_f32(av.len(), absdot_f32(&av, &bv), exact);
        let a = Arc::new(bufs.admit(&av));
        let b = Arc::new(bufs.admit(&bv));
        for f in [scalar::dot2_seq_f32, scalar::dot2_unrolled_f32] {
            let got = parallel_dot_f32(&pool, f, &a, &b, chunks) as f64;
            prop_assert!(
                (got - exact).abs() <= bound,
                "n={n} chunks={chunks} cond~{target_cond:e}: chunked dot2 err {:e} > bound {bound:e}",
                (got - exact).abs()
            );
        }
        let got = sharded.dot_f32(Accuracy::Dot2, &av, &bv) as f64;
        prop_assert!(
            (got - exact).abs() <= bound,
            "n={n} cond~{target_cond:e}: sharded dot2 err {:e} > bound {bound:e}",
            (got - exact).abs()
        );
        Ok(())
    });
    assert!(sharded.stats().split_dots > 0, "split threshold of 1 byte must force splits");
}

/// Satellite: the accuracy ladder through the engine facade, judged
/// against `exact_dot_f32` ground truth on ill-conditioned inputs. Naive
/// drifts with `cond`, Kahan holds `O(u)·Σ|aᵢbᵢ|` (so its *relative*
/// error still degrades as `cond` grows), Dot2 holds the tighter
/// Dot2-grade bound, and the Exact tier is bit-for-bit the correctly
/// rounded dot. Pairwise ordering uses the same escape-clause style as
/// `engine_kahan_beats_naive_on_ill_conditioned_input`: a tier must beat
/// the one above it by 10× unless the one above already sits at the lower
/// tier's own bound.
#[test]
fn accuracy_ladder_orders_tiers_against_exact_ground_truth() {
    let engine = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
    let mut rng = kahan_ecm::util::Rng::new(0xACC);
    for target_cond in [1e6, 1e8] {
        let (a, b, exact, cond) = gen_dot_f32(4096, target_cond, &mut rng);
        let absdot = absdot_f32(&a, &b);
        let kbound = f32_bound(absdot);
        let d2bound = dot2_bound_f32(a.len(), absdot, exact);
        let e_naive = (engine.dot_f32(Accuracy::Naive, &a, &b) as f64 - exact).abs();
        let e_kahan = (engine.dot_f32(Accuracy::Kahan, &a, &b) as f64 - exact).abs();
        let e_dot2 = (engine.dot_f32(Accuracy::Dot2, &a, &b) as f64 - exact).abs();
        assert!(e_kahan <= kbound, "kahan err {e_kahan:e} > bound {kbound:e} (cond {cond:e})");
        assert!(e_dot2 <= d2bound, "dot2 err {e_dot2:e} > bound {d2bound:e} (cond {cond:e})");
        assert!(
            e_kahan * 10.0 < e_naive.max(1e-30) || e_naive <= kbound,
            "cond {cond:e}: kahan ({e_kahan:e}) should beat naive ({e_naive:e})"
        );
        assert!(
            e_dot2 * 10.0 < e_kahan.max(1e-30) || e_kahan <= d2bound,
            "cond {cond:e}: dot2 ({e_dot2:e}) should beat kahan ({e_kahan:e})"
        );
        // the exact tier is not "even more accurate": it is the correctly
        // rounded dot, bit-for-bit
        let want = exact_dot_f32(&a, &b) as f32;
        let got = engine.dot_f32(Accuracy::Exact, &a, &b);
        assert_eq!(got.to_bits(), want.to_bits(), "cond {cond:e}: exact tier must round correctly");
    }
}

/// `sharded_cfg` with the host's ECM governance switched off, so the
/// governance tests below control caps explicitly via `set_worker_caps`
/// instead of inheriting whatever the CI host's detected bandwidth says.
fn ungoverned_cfg(threads: usize, split_min_bytes: usize, chunks: usize) -> ShardedConfig {
    ShardedConfig {
        engine: EngineConfig { threads, governance: false, ..EngineConfig::default() },
        split_min_bytes,
        chunks,
    }
}

/// ECM governance end-to-end (PR 6): capping fan-out changes concurrency
/// only, never bits. The same Ogita–Rump–Oishi ill-conditioned request
/// returns bit-identical results through a governed and an ungoverned
/// stack at all three layers — engine facade (Parallel route), sharded
/// cross-shard split, and the serving tier — while `capped_requests`
/// attributes exactly the governed executions and nothing else.
#[test]
fn governance_bit_identity_across_engine_split_and_service_layers() {
    let tight = [[1usize; 3]; 2]; // every class capped to one worker
    let mut rng = kahan_ecm::util::Rng::new(0x6006);

    // --- engine facade: Parallel route (1.2 MB > cutoff) ---
    let open = DotEngine::new(EngineConfig {
        threads: 2,
        governance: false,
        ..EngineConfig::default()
    });
    let mut governed = DotEngine::new(EngineConfig {
        threads: 2,
        governance: false,
        ..EngineConfig::default()
    });
    governed.set_worker_caps(tight);
    for acc in [Accuracy::Kahan, Accuracy::Dot2] {
        for target_cond in [1e4, 1e6, 1e8] {
            let (a, b, _, _) = gen_dot_f32(150_000, target_cond, &mut rng);
            let ov = open.dot_f32(acc, &a, &b);
            let gv = governed.dot_f32(acc, &a, &b);
            assert_eq!(ov.to_bits(), gv.to_bits(), "engine layer, {acc:?} cond ~{target_cond:e}");
        }
    }
    let (os, gs) = (open.stats(), governed.stats());
    assert_eq!(os.capped_requests, 0, "ungoverned engine must never count caps");
    assert_eq!(gs.capped_requests, 6, "every parallel dot ran below 2 workers: {gs:?}");
    assert_eq!(gs.parallel, os.parallel, "capping must not change routing");
    assert_eq!(gs.requests, os.requests);

    // --- sharded split: fixed chunk geometry, capped worker subsets ---
    let open_sh =
        ShardedEngine::from_topology(&Topology::fake_even(2), ungoverned_cfg(2, 64 << 10, 4));
    let mut gov_sh =
        ShardedEngine::from_topology(&Topology::fake_even(2), ungoverned_cfg(2, 64 << 10, 4));
    gov_sh.set_worker_caps(tight);
    for acc in [Accuracy::Kahan, Accuracy::Dot2] {
        for target_cond in [1e4, 1e6, 1e8] {
            let (a, b, _, _) = gen_dot_f32(100_000, target_cond, &mut rng);
            let ov = open_sh.dot_f32(acc, &a, &b);
            let gv = gov_sh.dot_f32(acc, &a, &b);
            assert_eq!(ov.to_bits(), gv.to_bits(), "split layer, {acc:?} cond ~{target_cond:e}");
        }
    }
    let (oss, gss) = (open_sh.stats(), gov_sh.stats());
    assert_eq!(oss.capped_requests, 0, "ungoverned split must never count caps");
    assert_eq!(gss.capped_requests, 6, "every split dot was capped: {gss:?}");
    assert_eq!(gss.split_dots, oss.split_dots, "capping must not change the split decision");

    // --- serving tier: ecm_governance knob end-to-end ---
    let open_eng: &'static ShardedEngine = Box::leak(Box::new(ShardedEngine::from_topology(
        &Topology::fake_even(2),
        ungoverned_cfg(2, 1 << 30, 0),
    )));
    let gov_eng: &'static mut ShardedEngine = Box::leak(Box::new(ShardedEngine::from_topology(
        &Topology::fake_even(2),
        ungoverned_cfg(2, 1 << 30, 0),
    )));
    gov_eng.set_worker_caps(tight);
    let gov_eng: &'static ShardedEngine = gov_eng;
    let (osvc, ocl) = DotService::try_start_on(
        ServiceConfig { ecm_governance: "off".into(), ..ServiceConfig::default() },
        open_eng,
    )
    .expect("open service");
    let (gsvc, gcl) = DotService::try_start_on(
        ServiceConfig { ecm_governance: "on".into(), ..ServiceConfig::default() },
        gov_eng,
    )
    .expect("governed service");
    let (a, b, _, _) = gen_dot_f32(150_000, 1e6, &mut rng);
    let (oha, ohb) = ocl.admit_pair_blocking(a.clone(), b.clone()).expect("open admit");
    let (gha, ghb) = gcl.admit_pair_blocking(a, b).expect("governed admit");
    for tier in ["kahan", "dot2"] {
        let ov = ocl.dot_pooled_blocking(tier, oha, ohb).expect("open dot");
        let gv = gcl.dot_pooled_blocking(tier, gha, ghb).expect("governed dot");
        assert_eq!(ov.to_bits(), gv.to_bits(), "service layer, tier {tier}");
    }
    let (ost, gst) = (osvc.stop(), gsvc.stop());
    assert_eq!(ost.capped_requests, 0, "ecm_governance=off must serve uncapped: {ost:?}");
    assert_eq!(gst.capped_requests, 2, "both pooled dots must be capped: {gst:?}");
    assert_eq!(gst.pooled_calls, ost.pooled_calls);
}
