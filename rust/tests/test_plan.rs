//! Planner property tests (PR 5): the pure `engine::plan` layer is the
//! single choke point for every route/batch/split threshold, so its
//! decisions must be (a) deterministic, (b) monotone in request size and
//! batch size, and (c) in exact agreement with what the execution layers
//! actually do — including bit-identity between every plan route and its
//! pre-refactor execution path on Ogita–Rump–Oishi ill-conditioned
//! inputs.

use kahan_ecm::accuracy::gen_dot_f32;
use kahan_ecm::engine::plan::batch_exec;
use kahan_ecm::engine::{
    kernel_for_f32, DispatchTable, DotEngine, DotRoute, EngineConfig, PlanPolicy, ShardedConfig,
    ShardedEngine, SizeClass, Topology,
};
use kahan_ecm::isa::{Accuracy, Precision};
use kahan_ecm::util::Rng;

fn policy(cutoff: usize, split: usize, workers: Vec<usize>) -> PlanPolicy {
    PlanPolicy::new(cutoff, split, 0, workers)
}

/// Exhaustive small grid: plan decisions are a pure function of their
/// inputs (same input twice -> same plan) and the route is monotone in
/// the working-set size — growing a request can only move it
/// Inline -> Parallel -> Split, never backwards.
#[test]
fn plan_decisions_deterministic_and_monotone_in_length() {
    let cutoff = 64 << 10;
    let split = 1 << 20;
    for workers in [vec![1usize], vec![2], vec![4, 4], vec![2, 8, 2]] {
        let p = policy(cutoff, split, workers.clone());
        for preferred in 0..=4usize {
            let mut last = DotRoute::Inline;
            // dense byte grid crossing both thresholds, boundaries included
            let mut grid: Vec<u64> = (0u64..200).map(|i| i * 12 * 1024).collect();
            grid.extend([
                cutoff as u64 - 1,
                cutoff as u64,
                cutoff as u64 + 1,
                split as u64 - 1,
                split as u64,
                split as u64 + 1,
            ]);
            grid.sort_unstable();
            for total in grid {
                let a = p.plan_dot(preferred, Accuracy::Kahan, total);
                let b = p.plan_dot(preferred, Accuracy::Kahan, total);
                assert_eq!(a.route, b.route, "non-deterministic route at {total}");
                assert_eq!(a.shard, b.shard, "non-deterministic shard at {total}");
                assert_eq!(a.shard, preferred % workers.len(), "shard must be the clamp");
                assert!(
                    a.route >= last,
                    "route regressed at {total} bytes: {last:?} -> {:?} (workers {workers:?})",
                    a.route
                );
                // the route must agree with the predicates it is built from
                match a.route {
                    DotRoute::Split => assert!(p.splits(total)),
                    DotRoute::Inline => {
                        assert!(!p.splits(total) && p.serves_inline_on(a.shard, total))
                    }
                    DotRoute::Parallel => {
                        assert!(!p.splits(total) && !p.serves_inline_on(a.shard, total))
                    }
                }
                last = a.route;
            }
            // single-worker shards never plan Parallel
            if workers[preferred % workers.len()] == 1 {
                for total in [1u64, cutoff as u64, (split as u64) - 1] {
                    assert_ne!(
                        p.plan_dot(preferred, Accuracy::Kahan, total).route,
                        DotRoute::Parallel
                    );
                }
            }
        }
    }
}

/// The split geometry is a pure planner artifact: blocks are contiguous,
/// exhaustive, weighted by worker count, and deterministic.
#[test]
fn split_blocks_cover_all_chunks_contiguously() {
    for workers in [vec![1usize], vec![4], vec![8, 16], vec![3, 1, 2]] {
        let p = policy(64 << 10, 1 << 20, workers.clone());
        for chunks in 1..=64usize {
            let blocks = p.split_blocks(chunks);
            assert_eq!(blocks, p.split_blocks(chunks), "deterministic");
            let mut expect_lo = 0usize;
            for &(s, lo, hi) in &blocks {
                assert!(s < workers.len());
                assert_eq!(lo, expect_lo, "blocks must be contiguous");
                assert!(hi > lo, "empty blocks are dropped");
                expect_lo = hi;
            }
            assert_eq!(expect_lo, chunks, "every chunk must be assigned");
        }
    }
}

/// Batch-size monotonicity: for a fixed table and cell, once the planner
/// fuses at batch size k it fuses at every k' >= k (the only batch-size
/// threshold is "is there anything to fuse"), and the window decision is
/// monotone the other way — a fuller run never waits when a shorter one
/// would not.
#[test]
fn batch_decisions_monotone_in_batch_size() {
    // a tiny private calibration keeps this test self-contained and fast
    let table = DispatchTable::calibrate([8 << 10, 64 << 10, 256 << 10], 1);
    for prec in [Precision::Sp, Precision::Dp] {
        for acc in Accuracy::ALL {
            for class in SizeClass::ALL {
                let mut was_fused = false;
                for k in 0..=16usize {
                    let fused = batch_exec(&table, prec, acc, class, k).is_some();
                    assert!(
                        !was_fused || fused,
                        "fuse decision regressed at k={k} ({prec:?} {acc:?} {})",
                        class.name()
                    );
                    was_fused = fused;
                }
                // and it is exactly the table's kept twin gated on k >= 2
                assert!(batch_exec(&table, prec, acc, class, 1).is_none());
                assert_eq!(
                    batch_exec(&table, prec, acc, class, 2).is_some(),
                    table.select_batch(prec, acc, class).is_some()
                );
                // fuse-or-loop: tiers without fused twins always loop
                if acc == Accuracy::Dot2 || acc == Accuracy::Exact {
                    assert!(
                        table.select_batch(prec, acc, class).is_none(),
                        "{acc:?} must have no fused twin ({prec:?} {})",
                        class.name()
                    );
                }
            }
        }
    }
    for max_batch in 1..=8usize {
        let p = policy(64 << 10, 1 << 20, vec![2]).with_service(max_batch, 50);
        let mut was_some = p.batch_window(0, true).is_some();
        assert!(!was_some, "an empty run must never wait");
        for k in 1..=20usize {
            let now = p.batch_window(k, true).is_some();
            // once a run is too full to wait, a fuller one is too
            assert!(was_some || !now || k == 1, "window decision not monotone at k={k}");
            was_some = now;
            assert_eq!(
                now,
                max_batch >= 2 && k < max_batch,
                "window must wait exactly while the fuse can still grow (k={k}, \
                 max_batch={max_batch})"
            );
        }
    }
}

/// Every plan route produces bit-identical results to its pre-refactor
/// execution path on ORO ill-conditioned inputs, and the planner's route
/// prediction agrees with the counters the execution layers bump:
///
/// * Inline  — one kernel call on the caller's slices (`kernel_for_f32`);
/// * Parallel — the chunked reduction of a plain `DotEngine` with the
///   same worker count;
/// * Split   — the cross-shard split, bit-identical between a 1-shard and
///   a 2-shard engine with the same fixed chunk geometry.
#[test]
fn plan_routes_bit_identical_to_pre_refactor_paths_on_oro_inputs() {
    let cfg2 = ShardedConfig {
        engine: EngineConfig { threads: 2, ..EngineConfig::default() },
        split_min_bytes: 1 << 20,
        chunks: 4, // fixed geometry: split bits must not depend on shard count
    };
    let sharded2 = ShardedEngine::from_topology(&Topology::fake_even(2), cfg2);
    let sharded1 = ShardedEngine::from_topology(&Topology::fake_even(1), cfg2);
    let plain = DotEngine::new(EngineConfig { threads: 2, ..EngineConfig::default() });
    let policy = sharded2.policy();
    assert_eq!(policy.shards(), 2);

    let mut rng = Rng::new(0x9157);
    // (elements, expected route): 8 KB inline; 400 KB parallel; 1.6 MB split
    let cases = [
        (1_000usize, DotRoute::Inline),
        (50_000, DotRoute::Parallel),
        (200_000, DotRoute::Split),
    ];
    for (n, want_route) in cases {
        let total = (2 * n * std::mem::size_of::<f32>()) as u64;
        for shard in 0..policy.shards() {
            let plan = policy.plan_dot(shard, Accuracy::Kahan, total);
            assert_eq!(plan.route, want_route, "n={n} shard={shard}");
        }
        for acc in [Accuracy::Kahan, Accuracy::Naive, Accuracy::Dot2] {
            let (a, b, _, _) = gen_dot_f32(n, 1e6, &mut rng);
            let before = sharded2.stats();
            let got = sharded2.dot_f32(acc, &a, &b);
            let after = sharded2.stats();
            match want_route {
                DotRoute::Inline => {
                    let reference = kernel_for_f32(acc, total)(&a, &b);
                    assert_eq!(got.to_bits(), reference.to_bits(), "inline n={n}");
                    assert_eq!(after.parallel, before.parallel, "inline must not go parallel");
                    assert_eq!(after.split_dots, before.split_dots);
                }
                DotRoute::Parallel => {
                    let reference = plain.dot_f32(acc, &a, &b);
                    assert_eq!(got.to_bits(), reference.to_bits(), "parallel n={n}");
                    assert_eq!(after.parallel, before.parallel + 1, "must take the chunked path");
                    assert_eq!(after.split_dots, before.split_dots);
                }
                DotRoute::Split => {
                    let reference = sharded1.dot_f32(acc, &a, &b);
                    assert_eq!(
                        got.to_bits(),
                        reference.to_bits(),
                        "split n={n} ({acc:?}): 1-vs-2-shard bits diverged"
                    );
                    assert_eq!(after.split_dots, before.split_dots + 1, "must take the split path");
                }
            }
        }
    }
}

/// The exact tier is planner special-cased: whatever the size, the plan
/// is Inline on the preferred shard — scalar expansion arithmetic never
/// chunks, splits, or fans out, so routing can never touch its bits —
/// and the execution result is the correctly rounded reference at every
/// size and shard count.
#[test]
fn exact_tier_always_plans_inline_and_is_correctly_rounded() {
    let p = policy(64 << 10, 1 << 20, vec![2, 8]);
    for shard in 0..2usize {
        for total in [1u64, 64 << 10, 900 << 10, 4 << 20, 64 << 20] {
            let plan = p.plan_dot(shard, Accuracy::Exact, total);
            assert_eq!(plan.route, DotRoute::Inline, "exact must plan Inline at {total} bytes");
            // every other tier keeps its size-directed route
            let k = p.plan_dot(shard, Accuracy::Kahan, total);
            match k.route {
                DotRoute::Split => assert!(p.splits(total)),
                _ => assert!(!p.splits(total)),
            }
        }
    }

    let cfg = ShardedConfig {
        engine: EngineConfig { threads: 2, ..EngineConfig::default() },
        split_min_bytes: 1 << 20,
        chunks: 4,
    };
    let sharded2 = ShardedEngine::from_topology(&Topology::fake_even(2), cfg);
    let sharded1 = ShardedEngine::from_topology(&Topology::fake_even(1), cfg);
    let mut rng = Rng::new(0xE4AC);
    for n in [1_000usize, 50_000, 200_000] {
        let (a, b, _, _) = gen_dot_f32(n, 1e8, &mut rng);
        let want = (kahan_ecm::accuracy::exact::exact_dot_f32(&a, &b)) as f32;
        let before = sharded2.stats();
        let got2 = sharded2.dot_f32(Accuracy::Exact, &a, &b);
        let after = sharded2.stats();
        let got1 = sharded1.dot_f32(Accuracy::Exact, &a, &b);
        assert_eq!(got2.to_bits(), want.to_bits(), "exact n={n} must be correctly rounded");
        assert_eq!(got1.to_bits(), got2.to_bits(), "exact n={n}: shard count changed bits");
        assert_eq!(after.parallel, before.parallel, "exact must never fan out (n={n})");
        assert_eq!(after.split_dots, before.split_dots, "exact must never split (n={n})");
    }
}

/// The batch path partitions a mixed request set exactly as the planner
/// says it will: split-plan requests land on the split counter, the rest
/// stay off it, and the results match the serial loop bit for bit.
#[test]
fn batch_partition_agrees_with_planner_and_serial_bits() {
    let cfg = ShardedConfig {
        engine: EngineConfig { threads: 2, ..EngineConfig::default() },
        split_min_bytes: 1 << 20,
        chunks: 4,
    };
    let sharded = ShardedEngine::from_topology(&Topology::fake_even(2), cfg);
    let policy = sharded.policy().clone();
    let mut rng = Rng::new(0x515);
    let sizes = [700usize, 200_000, 4_096, 50_000, 200_000, 64];
    let reqs: Vec<(Vec<f32>, Vec<f32>)> = sizes
        .iter()
        .map(|&n| {
            let (a, b, _, _) = gen_dot_f32(n, 1e5, &mut rng);
            (a, b)
        })
        .collect();
    let view: Vec<(&[f32], &[f32])> =
        reqs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let predicted_splits = sizes
        .iter()
        .filter(|&&n| policy.splits((2 * n * std::mem::size_of::<f32>()) as u64))
        .count() as u64;
    assert_eq!(predicted_splits, 2, "the fixture must exercise the split arm");

    let serial: Vec<f32> =
        view.iter().map(|&(a, b)| sharded.dot_f32(Accuracy::Kahan, a, b)).collect();
    let before = sharded.stats();
    let batched = sharded.dot_batch_f32(Accuracy::Kahan, &view);
    let after = sharded.stats();
    for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
        assert_eq!(s.to_bits(), g.to_bits(), "req {i} (n={})", sizes[i]);
    }
    assert_eq!(
        after.split_dots,
        before.split_dots + predicted_splits,
        "the batch must split exactly the requests the planner plans to split"
    );
    assert_eq!(after.requests, before.requests + sizes.len() as u64);
}

/// ECM governance (PR 6) at the planning layer: the host verdict's caps
/// are monotone non-increasing with size class (paper §2: a larger
/// working set can only lower the predicted saturation point, never raise
/// it), a cap binds on a shard exactly when it is strictly below that
/// shard's realized worker count, and `with_governance`/`ungoverned`
/// round-trip the caps without touching any routing threshold.
#[test]
fn governance_caps_monotone_and_clamped_to_shard_workers() {
    let verdict = kahan_ecm::ecm::governance::host_verdict();
    let caps = verdict.worker_caps();
    for (pi, row) in caps.iter().enumerate() {
        assert!(
            row[0] >= row[1] && row[1] >= row[2],
            "caps must be non-increasing L1 -> LLC -> MEM (prec {pi}: {row:?})"
        );
        for &c in row {
            assert!(c >= 1, "a cap of zero workers is never valid");
        }
    }

    let workers = vec![1usize, 2, 8];
    let open = policy(64 << 10, 1 << 20, workers.clone());
    let governed = open.clone().with_governance(caps);
    for prec in [Precision::Sp, Precision::Dp] {
        for class in SizeClass::ALL {
            // ungoverned: no cap ever binds, on any shard
            for shard in 0..workers.len() {
                assert!(!open.governed(shard, prec, class), "default policy must be open");
                // binding is exactly "cap strictly below the shard's
                // realized worker count" — the execution-side clamp
                assert_eq!(
                    governed.governed(shard, prec, class),
                    governed.worker_cap(prec, class) < workers[shard],
                    "shard {shard} {prec:?} {}",
                    class.name()
                );
                // the effective fan-out after the clamp never exceeds the
                // shard's workers and never drops below one
                let eff = governed.worker_cap(prec, class).min(workers[shard]).max(1);
                assert!((1..=workers[shard]).contains(&eff));
            }
        }
    }

    // round-trip: governance only touches worker_caps
    let reopened = governed.clone().ungoverned();
    assert_eq!(reopened.worker_caps, open.worker_caps);
    assert_eq!(reopened.parallel_cutoff_bytes, governed.parallel_cutoff_bytes);
    assert_eq!(reopened.split_min_bytes, governed.split_min_bytes);
    assert_eq!(reopened.shard_workers, governed.shard_workers);
    // and routing is untouched by caps: same plan with and without
    for total in [1u64, 100 << 10, 900 << 10, 2 << 20] {
        for shard in 0..workers.len() {
            let g = governed.plan_dot(shard, Accuracy::Kahan, total);
            let o = open.plan_dot(shard, Accuracy::Kahan, total);
            assert_eq!(g.route, o.route, "governance must never change routing");
            assert_eq!(g.shard, o.shard);
            assert_eq!(g.class, o.class);
        }
    }
}

/// Degenerate lengths (PR 8): zero- and one-element dots are served by
/// every engine surface, in every accuracy tier, bit-identically across
/// the Inline, Parallel and Split routes — and an EMPTY dot never costs
/// a worker job, whatever the configured thresholds (even a pathological
/// policy whose cutoffs are zero).
#[test]
fn zero_and_one_length_dots_bit_identical_on_every_route_and_tier() {
    // planner level: 0 bytes plans Inline and never splits under ANY
    // thresholds; 8 bytes (one f32 pair) keeps its size-directed route
    for (cutoff, split) in [(0usize, 1usize), (1, 1 << 20), (64 << 10, 1 << 20)] {
        let p = policy(cutoff, split, vec![4, 4]);
        assert!(!p.splits(0));
        assert!(p.serves_inline_on(0, 0));
        assert!(p.splits(8) || p.serves_inline_on(0, 8) || cutoff <= 8);
        for acc in Accuracy::ALL {
            assert_eq!(
                p.plan_dot(1, acc, 0).route,
                DotRoute::Inline,
                "an empty dot must plan Inline ({acc:?}, cutoff {cutoff}, split {split})"
            );
        }
    }

    // execution level: three engines whose thresholds force a 1-element
    // dot down the Inline, Parallel and Split routes respectively
    let base = EngineConfig { threads: 2, ..EngineConfig::default() };
    let engines = [
        (
            "inline",
            ShardedEngine::from_topology(
                &Topology::fake_even(2),
                ShardedConfig { engine: base, split_min_bytes: 1 << 20, chunks: 4 },
            ),
        ),
        (
            "parallel",
            ShardedEngine::from_topology(
                &Topology::fake_even(2),
                ShardedConfig {
                    engine: EngineConfig { parallel_cutoff_bytes: 0, ..base },
                    split_min_bytes: 1 << 20,
                    chunks: 4,
                },
            ),
        ),
        (
            "split",
            ShardedEngine::from_topology(
                &Topology::fake_even(2),
                ShardedConfig {
                    engine: EngineConfig { parallel_cutoff_bytes: 0, ..base },
                    split_min_bytes: 1,
                    chunks: 4,
                },
            ),
        ),
    ];
    for acc in Accuracy::ALL {
        let a = [1.5f32];
        let b = [-2.25f32];
        let want = kernel_for_f32(acc, 8)(&a, &b);
        for (name, e) in &engines {
            // length 1: whatever route the thresholds force, the result is
            // the single kernel call bit for bit
            let got = e.dot_f32(acc, &a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "{name} {acc:?} n=1");

            // length 0: exactly +0.0 on the single and the batch path,
            // and never a parallel fan-out or a split
            let before = e.stats();
            let single = e.dot_f32(acc, &[], &[]);
            let batch = e.dot_batch_f32(acc, &[(&[], &[])]);
            let after = e.stats();
            assert_eq!(single.to_bits(), 0.0f32.to_bits(), "{name} {acc:?} n=0");
            assert_eq!(batch[0].to_bits(), 0.0f32.to_bits(), "{name} {acc:?} n=0 batch");
            assert_eq!(
                after.parallel, before.parallel,
                "an empty dot must never fan out ({name} {acc:?})"
            );
            assert_eq!(
                after.split_dots, before.split_dots,
                "an empty dot must never split ({name} {acc:?})"
            );
            assert_eq!(
                after.requests,
                before.requests + 2,
                "empty dots still count as served requests ({name} {acc:?})"
            );

            // a mixed batch: the empty request resolves in place and its
            // live neighbor keeps the exact single-request bits
            let mixed = e.dot_batch_f32(acc, &[(&[], &[]), (&a, &b)]);
            assert_eq!(mixed[0].to_bits(), 0.0f32.to_bits(), "{name} {acc:?} mixed");
            assert_eq!(
                mixed[1].to_bits(),
                want.to_bits(),
                "an empty batchmate must not change its neighbor's bits ({name} {acc:?})"
            );
        }
    }
}
