//! Measured-calibration property tests (PR 10): the persistent
//! `CalibrationProfile` may move thresholds, reroute requests, and seed
//! concurrency — it must NEVER change the bits a request's tier produces,
//! and a bad profile (corrupt, stale, version-mismatched, missing) must
//! be rejected whole with every built-in default left standing.

use kahan_ecm::accuracy::gen_dot_f32;
use kahan_ecm::engine::profile::{rejected_count, PROFILE_VERSION, SPLIT_MIN_CLAMP, WEDGE_FLOOR_US};
use kahan_ecm::engine::{
    CalibrationProfile, DispatchTable, DotRoute, EngineConfig, PlanCalibration, ShardedConfig,
    ShardedEngine, Topology, DEFAULT_SPLIT_MIN_BYTES,
};
use kahan_ecm::isa::Accuracy;
use kahan_ecm::machine::detect::detect_host_cached;
use kahan_ecm::util::Rng;

/// A synthetic profile for THIS host (so the staleness check passes):
/// 10 GB/s per-core throughput in every cell, no saturation, and the
/// given fixed split cost — the one knob the derived threshold turns on.
fn synth_profile(split_fixed_us: f64) -> CalibrationProfile {
    CalibrationProfile {
        version: PROFILE_VERSION,
        machine: detect_host_cached().name.to_string(),
        threads: 4,
        shards: 2,
        mem_bw_gbs: 40.0,
        split_fixed_us,
        kernel_gbs: [[10.0; 3]; 2],
        sat_cores: [[0; 3]; 2], // 0 = the class never saturates
        sat_scale: [[1.0; 3]; 2],
        kahan_vs_naive: [1.0; 3],
        dot2_vs_naive: [1.0; 3],
        winners: Default::default(),
        probe_cy: [[[0.0; 4]; 3]; 2],
        batches: Default::default(),
    }
}

fn engine_with_split_min(split_min_bytes: usize) -> ShardedEngine {
    ShardedEngine::from_topology(
        &Topology::fake_even(2),
        ShardedConfig {
            engine: EngineConfig { threads: 2, governance: false, ..EngineConfig::default() },
            split_min_bytes,
            chunks: 4, // fixed geometry: bits must not depend on the route
        },
    )
}

/// THE calibration contract: a profile-derived split threshold may flip a
/// request's route (that is its job) but every accuracy tier's bits are
/// identical under the no-profile default and under synthetic-low /
/// synthetic-high derived thresholds, on ORO ill-conditioned inputs.
#[test]
fn derived_thresholds_reroute_but_never_change_bits() {
    // a near-zero fixed cost derives the lowest legal threshold, a huge
    // one the highest — both straight from the profile layer's crossover
    let lo = synth_profile(0.5).derived_split_min_bytes(&[2, 2]).expect("low crossover");
    let hi = synth_profile(1e5).derived_split_min_bytes(&[2, 2]).expect("high crossover");
    assert_eq!(lo, SPLIT_MIN_CLAMP.0, "tiny fixed cost must clamp to the floor");
    assert_eq!(hi, SPLIT_MIN_CLAMP.1, "huge fixed cost must clamp to the ceiling");

    let engines = [
        engine_with_split_min(DEFAULT_SPLIT_MIN_BYTES), // no-profile fallback
        engine_with_split_min(lo as usize),             // synthetic-low profile
        engine_with_split_min(hi as usize),             // synthetic-high profile
    ];

    // 1.6 MB: above the low threshold (Split), below default and high
    // (Parallel) — the route demonstrably differs across the policies
    let flip_total = (2 * 200_000 * std::mem::size_of::<f32>()) as u64;
    let routes: Vec<DotRoute> =
        engines.iter().map(|e| e.policy().plan_dot(0, Accuracy::Kahan, flip_total).route).collect();
    assert_eq!(routes[1], DotRoute::Split, "low threshold must split 1.6 MB");
    assert_eq!(routes[0], DotRoute::Parallel, "default threshold must not split 1.6 MB");
    assert_eq!(routes[2], DotRoute::Parallel, "high threshold must not split 1.6 MB");

    let mut rng = Rng::new(0xCA11B);
    // sizes straddling every boundary: inline everywhere / the flip size
    // above / 8 MB (low + default split, high stays parallel)
    for n in [1_000usize, 200_000, 1_000_000] {
        let (a, b, _, _) = gen_dot_f32(n, 1e6, &mut rng);
        for acc in [Accuracy::Naive, Accuracy::Kahan, Accuracy::Dot2] {
            let bits: Vec<u32> =
                engines.iter().map(|e| e.dot_f32(acc, &a, &b).to_bits()).collect();
            assert_eq!(
                bits[0], bits[1],
                "default vs low-threshold bits diverged (n={n}, {acc:?})"
            );
            assert_eq!(
                bits[0], bits[2],
                "default vs high-threshold bits diverged (n={n}, {acc:?})"
            );
        }
    }
    // the exact tier plans Inline whatever the threshold says — still
    // bit-identical (and correctly rounded) across all three policies
    let (a, b, _, _) = gen_dot_f32(50_000, 1e8, &mut rng);
    let want = kahan_ecm::accuracy::exact::exact_dot_f32(&a, &b) as f32;
    for e in &engines {
        assert_eq!(e.dot_f32(Accuracy::Exact, &a, &b).to_bits(), want.to_bits());
    }
}

/// Deadline-aware routing at the engine surface: a synthetic calibration
/// that projects the one-shard path over a request's deadline promotes it
/// to Split (`deadline_splits`), the promoted bits equal the un-promoted
/// ones, and a chunk geometry that differs from the shard's worker count
/// vetoes the promotion entirely.
#[test]
fn deadline_promotion_bit_identical_and_geometry_gated() {
    let calib = PlanCalibration {
        shard_gbs: [[0.05; 3]; 2], // 1 MiB projects ~21 ms on one shard
        split_gbs: [[10.0; 3]; 2], // ~105 us split
        split_fixed_us: 0.0,
        kahan_vs_naive: [1.0; 3],
        dot2_vs_naive: [1.0; 3],
    };
    let mk = |chunks: usize| {
        let mut e = ShardedEngine::from_topology(
            &Topology::fake_even(2),
            ShardedConfig {
                engine: EngineConfig { threads: 2, governance: false, ..EngineConfig::default() },
                split_min_bytes: 1 << 30, // promotion is the only way to split
                chunks,
            },
        );
        e.set_calibration(calib);
        e
    };
    let gated = mk(2); // chunks == each shard's 2 workers: gate holds
    let vetoed = mk(4); // chunks != workers: promotion must never fire

    let mut rng = Rng::new(0xDEAD11);
    let (a, b, _, _) = gen_dot_f32(128 * 1024, 1e6, &mut rng); // 1 MiB total
    for acc in [Accuracy::Naive, Accuracy::Kahan, Accuracy::Dot2] {
        let plain = gated.dot_on_deadline_f32(0, acc, 0, &a, &b); // no deadline
        let before = gated.stats().deadline_splits;
        let promoted = gated.dot_on_deadline_f32(0, acc, 10_000, &a, &b);
        assert_eq!(
            gated.stats().deadline_splits,
            before + 1,
            "the 10 ms deadline must promote ({acc:?})"
        );
        assert_eq!(
            promoted.to_bits(),
            plain.to_bits(),
            "deadline promotion changed the bits ({acc:?})"
        );

        let v = vetoed.dot_on_deadline_f32(0, acc, 10_000, &a, &b);
        assert_eq!(vetoed.stats().deadline_splits, 0, "geometry gate must veto ({acc:?})");
        assert_eq!(v.to_bits(), plain.to_bits(), "vetoed route changed the bits ({acc:?})");
    }
    // a hopeless deadline (under even the split projection) never promotes
    let _ = gated.dot_on_deadline_f32(0, Accuracy::Kahan, 10, &a, &b);
    assert_eq!(gated.stats().deadline_splits, 3, "hopeless deadlines must not promote");
}

/// Serialization round-trip plus every rejection path: corrupt, version-
/// mismatched, stale, and missing profiles all load as clean `Err`s —
/// counted in `rejected_count`, never a panic — and a profile whose
/// winner names match no compiled kernel cannot seed a dispatch table.
#[test]
fn bad_profiles_rejected_cleanly_and_good_ones_round_trip() {
    let dir = std::env::temp_dir();
    let file = |name: &str| dir.join(format!("repro_test_profile_{}_{name}", std::process::id()));

    // round-trip: save → load reproduces the profile field for field
    let p = synth_profile(25.0);
    let good = file("good.json");
    p.save(&good).expect("save");
    let back = CalibrationProfile::load(&good).expect("round-trip load");
    assert_eq!(back, p, "save → load must be the identity");
    assert_eq!(CalibrationProfile::parse(&p.to_json()).expect("parse"), p);
    let _ = std::fs::remove_file(&good);

    let before = rejected_count();

    // corrupt: not the profile format at all
    let corrupt = file("corrupt.json");
    std::fs::write(&corrupt, "{ \"bench\": \"not_a_profile\" }").expect("write corrupt");
    let e = CalibrationProfile::load(&corrupt).expect_err("corrupt must be rejected");
    assert!(e.contains("corrupt"), "unexpected error: {e}");
    let _ = std::fs::remove_file(&corrupt);

    // version mismatch: a future schema must be rejected whole, not
    // half-parsed
    let mut vnext = p.clone();
    vnext.version = PROFILE_VERSION + 1;
    let mismatched = file("vnext.json");
    vnext.save(&mismatched).expect("save vnext");
    let e = CalibrationProfile::load(&mismatched).expect_err("version mismatch");
    assert!(e.contains("version mismatch"), "unexpected error: {e}");
    let _ = std::fs::remove_file(&mismatched);

    // stale: measured on another machine
    let mut other = p.clone();
    other.machine = "some-other-box".to_string();
    let stale = file("stale.json");
    other.save(&stale).expect("save stale");
    let e = CalibrationProfile::load(&stale).expect_err("stale must be rejected");
    assert!(e.contains("stale"), "unexpected error: {e}");
    let _ = std::fs::remove_file(&stale);

    // missing file
    let e = CalibrationProfile::load(&file("missing.json")).expect_err("missing file");
    assert!(e.contains("unreadable"), "unexpected error: {e}");

    // every rejection was counted (other tests may add their own, so >=)
    assert!(
        rejected_count() >= before + 4,
        "rejections must be counted: before={before}, after={}",
        rejected_count()
    );

    // a profile whose winners are empty strings matches no compiled
    // kernel: seeding must fail cleanly (the engine then falls back to
    // live calibration — it never panics and never half-seeds)
    assert!(
        DispatchTable::from_profile(&p).is_err(),
        "unknown winner names must not seed a table"
    );
}

/// The calibrated wedge defaults: derived from the slowest measured
/// per-core throughput with the documented floor, ×4 for lanes, and OFF
/// (0) when the profile has no usable throughput figure.
#[test]
fn wedge_defaults_derive_from_measured_throughput() {
    let p = synth_profile(25.0);
    let w = p.worker_wedge_default_us();
    // 64 MiB at 10 GB/s ≈ 6.7 ms, ×50 safety ≈ 335 ms — above the floor
    assert!(w >= WEDGE_FLOOR_US, "wedge default {w} must respect the floor");
    assert_eq!(p.lane_wedge_default_us(), w * 4, "lanes wait on whole requests");

    let mut dead = p.clone();
    dead.kernel_gbs = [[0.0; 3]; 2];
    assert_eq!(dead.worker_wedge_default_us(), 0, "no throughput figure = detection off");
    assert_eq!(dead.lane_wedge_default_us(), 0);
}
