//! The batching invariant, property-tested at every layer: batched
//! execution (fused kernels, engine `dot_batch_*`, the sharded tier's
//! batch/homed-batch paths, and the service's lane coalescing) is
//! bit-identical to serial single-request execution — on Ogita–Rump–Oishi
//! ill-conditioned inputs, mixed sizes, and mixed batch shapes. A batch
//! that changed even one bit would silently fork the serving tier's
//! determinism guarantee, so every test here compares `to_bits()`, never
//! tolerances.

use kahan_ecm::accuracy::{gen_dot_f32, gen_dot_f64};
use kahan_ecm::coordinator::{DotService, ServiceConfig};
use kahan_ecm::engine::{
    DotEngine, EngineConfig, ShardedConfig, ShardedEngine, Topology,
};
use kahan_ecm::isa::Accuracy;
use kahan_ecm::util::{prop, Rng};

fn cfg(threads: usize) -> EngineConfig {
    EngineConfig { threads, ..EngineConfig::default() }
}

fn sharded_cfg(threads: usize, split_min_bytes: usize) -> ShardedConfig {
    ShardedConfig { engine: cfg(threads), split_min_bytes, ..ShardedConfig::default() }
}

/// Mixed request generator: ill-conditioned ORO constructions plus plain
/// normal vectors at awkward lengths (tails, empties, cache-line edges).
fn gen_reqs_f32(rng: &mut Rng, count: usize) -> Vec<(Vec<f32>, Vec<f32>)> {
    (0..count)
        .map(|_| {
            if rng.uniform() < 0.5 {
                let n = 6 + rng.below(2000) as usize;
                let (a, b, _, _) = gen_dot_f32(n, 1e6, rng);
                (a, b)
            } else {
                let n = rng.below(3000) as usize;
                (rng.normal_f32_vec(n), rng.normal_f32_vec(n))
            }
        })
        .collect()
}

fn view_f32(reqs: &[(Vec<f32>, Vec<f32>)]) -> Vec<(&[f32], &[f32])> {
    reqs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect()
}

/// Engine layer: `dot_batch_f32` vs a serial loop of `dot_f32`, on ORO
/// inputs, every batch size, across accuracy tiers (Dot2 exercises the
/// fuse-or-loop fallback: no fused twin exists, so its runs serial-loop
/// inside the batch — bits must still match).
#[test]
fn engine_dot_batch_bit_identical_on_oro_inputs() {
    let e = DotEngine::new(cfg(2));
    prop::check("engine-dot-batch-bit-identical", 15, |rng| {
        let reqs = gen_reqs_f32(rng, 1 + rng.below(10) as usize);
        let view = view_f32(&reqs);
        let acc = match rng.below(10) {
            0..=4 => Accuracy::Kahan,
            5..=7 => Accuracy::Dot2,
            _ => Accuracy::Naive,
        };
        let serial: Vec<f32> = view.iter().map(|&(a, b)| e.dot_f32(acc, a, b)).collect();
        let batched = e.dot_batch_f32(acc, &view);
        for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
            kahan_ecm::prop_assert!(
                s.to_bits() == g.to_bits(),
                "req {i} (n={}, {acc:?}): serial {s:e} vs batched {g:e}",
                view[i].0.len()
            );
        }
        Ok(())
    });
}

/// Engine layer, f64: same invariant through the double-precision path.
#[test]
fn engine_dot_batch_f64_bit_identical_on_oro_inputs() {
    let e = DotEngine::new(cfg(2));
    prop::check("engine-dot-batch-f64-bit-identical", 10, |rng| {
        let reqs: Vec<(Vec<f64>, Vec<f64>)> = (0..1 + rng.below(8) as usize)
            .map(|_| {
                if rng.uniform() < 0.5 {
                    let n = 6 + rng.below(1500) as usize;
                    let (a, b, _, _) = gen_dot_f64(n, 1e10, rng);
                    (a, b)
                } else {
                    let n = rng.below(2000) as usize;
                    (rng.normal_f64_vec(n), rng.normal_f64_vec(n))
                }
            })
            .collect();
        let view: Vec<(&[f64], &[f64])> =
            reqs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let serial: Vec<f64> =
            view.iter().map(|&(a, b)| e.dot_f64(Accuracy::Kahan, a, b)).collect();
        let batched = e.dot_batch_f64(Accuracy::Kahan, &view);
        for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
            kahan_ecm::prop_assert!(
                s.to_bits() == g.to_bits(),
                "req {i}: serial {s:e} vs batched {g:e}"
            );
        }
        Ok(())
    });
}

/// Mixed-size batch: large dots inside a batch must take the unchanged
/// chunked-parallel path (visible in `parallel` stats), smalls the batch
/// path, and every result must still match serial bits.
#[test]
fn engine_mixed_size_batch_routes_larges_through_parallel_path() {
    let e = DotEngine::new(cfg(2));
    let mut rng = Rng::new(77);
    // 300_000 elems = 2.4 MB total ≥ the 256 KiB cutoff ⇒ parallel path
    let sizes = [1000usize, 300_000, 512, 300_000, 2048];
    let reqs: Vec<(Vec<f32>, Vec<f32>)> =
        sizes.iter().map(|&n| (rng.normal_f32_vec(n), rng.normal_f32_vec(n))).collect();
    let view = view_f32(&reqs);
    let serial: Vec<f32> = view.iter().map(|&(a, b)| e.dot_f32(Accuracy::Kahan, a, b)).collect();
    let before = e.stats();
    let batched = e.dot_batch_f32(Accuracy::Kahan, &view);
    let after = e.stats();
    for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
        assert_eq!(s.to_bits(), g.to_bits(), "req {i} (n={})", sizes[i]);
    }
    assert_eq!(
        after.parallel - before.parallel,
        2,
        "both larges must take the chunked-parallel path inside the batch"
    );
    assert_eq!(after.batched - before.batched, 3, "three smalls batched");
    assert_eq!(after.requests - before.requests, 5);
}

/// Sharded layer: `dot_batch_f32` across 2 forced shards vs the serial
/// loop, with the cross-shard split path exercised inside the batch.
#[test]
fn sharded_dot_batch_bit_identical_and_splits_larges() {
    let sharded =
        ShardedEngine::from_topology(&Topology::fake_even(2), sharded_cfg(1, 64 << 10));
    prop::check("sharded-dot-batch-bit-identical", 8, |rng| {
        let mut reqs = gen_reqs_f32(rng, 1 + rng.below(8) as usize);
        // one request above the 64 KiB split threshold (100k elems = 800 KB)
        reqs.push((rng.normal_f32_vec(100_000), rng.normal_f32_vec(100_000)));
        let view = view_f32(&reqs);
        let serial: Vec<f32> =
            view.iter().map(|&(a, b)| sharded.dot_f32(Accuracy::Kahan, a, b)).collect();
        let split_before = sharded.stats().split_dots;
        let batched = sharded.dot_batch_f32(Accuracy::Kahan, &view);
        let split_after = sharded.stats().split_dots;
        for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
            kahan_ecm::prop_assert!(
                s.to_bits() == g.to_bits(),
                "req {i} (n={}): serial {s:e} vs batched {g:e}",
                view[i].0.len()
            );
        }
        kahan_ecm::prop_assert!(
            split_after > split_before,
            "the large request must take the split path inside the batch"
        );
        Ok(())
    });
}

/// Sharded homed layer: batches of pooled pairs grouped by home shard vs
/// serial `dot_homed_f32`, including a cross-shard pair (operands homed on
/// different shards).
#[test]
fn sharded_homed_batch_bit_identical() {
    let sharded =
        ShardedEngine::from_topology(&Topology::fake_even(2), sharded_cfg(1, 4 << 20));
    prop::check("sharded-homed-batch-bit-identical", 8, |rng| {
        let count = 2 + rng.below(6) as usize;
        let homed: Vec<_> = (0..count)
            .map(|i| {
                let n = 6 + rng.below(4000) as usize;
                let (a, b, _, _) = gen_dot_f32(n, 1e5, rng);
                let ha = sharded.admit_f32(&a);
                // mostly co-located, sometimes deliberately cross-shard
                let hb = if rng.uniform() < 0.8 {
                    sharded.admit_to_f32(ha.shard, &b)
                } else {
                    sharded.admit_to_f32(ha.shard + i, &b)
                };
                (ha, hb)
            })
            .collect();
        let pairs: Vec<_> = homed.iter().map(|(a, b)| (a, b)).collect();
        let serial: Vec<f32> =
            pairs.iter().map(|&(a, b)| sharded.dot_homed_f32(Accuracy::Kahan, a, b)).collect();
        let batched = sharded.dot_batch_homed_f32(Accuracy::Kahan, &pairs);
        for (i, (s, g)) in serial.iter().zip(&batched).enumerate() {
            kahan_ecm::prop_assert!(
                s.to_bits() == g.to_bits(),
                "pair {i}: serial {s:e} vs batched {g:e}"
            );
        }
        Ok(())
    });
}

/// Service layer: concurrent bursty submission through the lanes (which
/// coalesce opportunistically) must be bit-identical to sequential
/// blocking resubmission of the same requests.
#[test]
fn service_bursts_bit_identical_to_sequential_resubmission() {
    let engine: &'static ShardedEngine = Box::leak(Box::new(ShardedEngine::from_topology(
        &Topology::fake_even(2),
        sharded_cfg(1, 4 << 20),
    )));
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    prop::check("service-burst-bit-identical", 6, |rng| {
        let reqs = gen_reqs_f32(rng, 4 + rng.below(12) as usize);
        // burst-submit without draining replies between sends, so lanes
        // can coalesce; then collect
        let rxs: Vec<_> = reqs
            .iter()
            .enumerate()
            .map(|(i, (a, b))| client.submit(i as u64, "kahan", a.clone(), b.clone()))
            .collect();
        let burst: Vec<f32> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("burst reply").value.expect("burst value"))
            .collect();
        for (i, (a, b)) in reqs.iter().enumerate() {
            let serial =
                client.dot_blocking("kahan", a.clone(), b.clone()).expect("serial value");
            kahan_ecm::prop_assert!(
                serial.to_bits() == burst[i].to_bits(),
                "req {i} (n={}): serial {serial:e} vs burst {:e}",
                a.len(),
                burst[i]
            );
        }
        Ok(())
    });
    let stats = svc.stop();
    assert_eq!(stats.errors, 0, "{stats:?}");
}
