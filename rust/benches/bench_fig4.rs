//! Bench F4a/F4b + FMA: the cross-architecture comparison figures.

use kahan_ecm::coordinator::experiments;
use kahan_ecm::isa::Precision;
use std::time::Instant;

fn main() {
    let t0 = Instant::now();
    println!("=== bench_fig4a: per-level cy/CL across sockets (AVX Kahan SP) ===\n");
    let rows = experiments::fig4a(Precision::Sp);
    println!("{}", experiments::fig4a_table(&rows).render());

    // paper claims: identical L1 on all archs; HSW/BDW faster in L2;
    // HSW worst in memory (big latency penalty), BDW clean.
    let get = |arch: &str| rows.iter().find(|r| r.arch == arch).unwrap();
    for r in &rows {
        assert!((r.sim_cy_per_cl[0] - 4.0).abs() < 0.5, "L1 ADD-bound on {}", r.arch);
    }
    assert!(get("HSW").sim_cy_per_cl[1] < get("IVB").sim_cy_per_cl[1], "HSW L2 faster");
    assert!(get("BDW").sim_cy_per_cl[1] < get("IVB").sim_cy_per_cl[1], "BDW L2 faster");
    assert!(
        get("HSW").sim_cy_per_cl[3] > get("IVB").sim_cy_per_cl[3],
        "HSW single-core memory is a step back"
    );
    assert_eq!(get("IVB").n_s, 4);

    println!("=== bench_fig4b: in-memory scaling across sockets ===\n");
    let series = experiments::fig4b(Precision::Sp);
    println!("{}", experiments::fig4b_table(&series).render());
    let peak = |arch: &str| {
        series
            .iter()
            .find(|(n, _)| n == arch)
            .unwrap()
            .1
            .last()
            .unwrap()
            .gups
    };
    assert!(peak("HSW") > peak("SNB") && peak("HSW") > peak("BDW"), "BW ranking");

    println!("=== FMA study (§4) ===\n");
    let fma = experiments::fma_study(Precision::Sp);
    println!("{}", fma.render());

    println!("bench_fig4: all cross-arch figures in {:.2} s — OK", t0.elapsed().as_secs_f64());
}
