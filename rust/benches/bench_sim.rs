//! Bench SIM: throughput of the virtual testbed itself — the §Perf targets
//! for the L3 hot paths (scoreboard issue rate, cache-sim access rate,
//! end-to-end sweep latency). This is what the performance pass optimizes.

use kahan_ecm::isa::{generate, Precision, Simd, Variant};
use kahan_ecm::machine::presets::ivb;
use kahan_ecm::sim;
use std::time::Instant;

fn main() {
    println!("=== bench_sim: simulator hot-path throughput ===\n");
    let m = ivb();
    let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);

    // scoreboard: instructions per second
    let mut sb = sim::core::Scoreboard::new(&m.core);
    let reps = 200_000usize;
    let t0 = Instant::now();
    for _ in 0..reps {
        for inst in &k.insts {
            sb.issue(inst, 0.0);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let insts = (reps * k.insts.len()) as f64;
    println!("scoreboard: {:.1} M instructions/s ({insts:.0} insts in {dt:.2} s)", insts / dt / 1e6);

    // cache sim: accesses per second (L2-resident stream)
    let mut cs = sim::cache::CacheSim::new(&m);
    let lines = 4096u64; // 256 KiB
    let t0 = Instant::now();
    let passes = 2000;
    for _ in 0..passes {
        for i in 0..lines {
            cs.access(i * 64);
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let acc = (passes * lines) as f64;
    println!("cache sim : {:.1} M accesses/s", acc / dt / 1e6);

    // end-to-end: one full Fig. 2 sweep
    let sizes: Vec<u64> = vec![16 << 10, 128 << 10, 1 << 20, 8 << 20, 64 << 20, 512 << 20];
    let t0 = Instant::now();
    let pts = sim::simulate_sweep(&m, &k, &sizes, true);
    let dt = t0.elapsed().as_secs_f64();
    println!("sweep     : {} sizes in {:.3} s ({:.1} ms/size)", pts.len(), dt, dt * 1e3 / pts.len() as f64);

    // multicore scaling curve
    let t0 = Instant::now();
    let _ = sim::simulate_scaling(&m, &k, 64 * 1024 * 1024, 10);
    println!("scaling   : 10-core curve in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    println!("bench_sim: OK");
}
