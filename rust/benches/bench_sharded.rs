//! Bench SHARDED: the NUMA-sharded serving tier vs the single persistent
//! engine, at LLC- and memory-resident sizes.
//!
//! Three configurations per size:
//! * "engine"       — one `DotEngine` spanning every online CPU (the PR 1
//!   single-socket baseline);
//! * "sharded-auto" — `ShardedEngine` over the *discovered* topology (on a
//!   single-node host this is one shard and should track "engine" within
//!   noise — that null result is itself the degrade-gracefully check);
//! * "sharded-2"    — a forced two-shard split of the online CPUs
//!   (`Topology::fake_even(2)`), exercising the cross-shard split + merge
//!   machinery even on single-node hosts. On a real multi-socket box the
//!   auto config is the one that shows the per-domain bandwidth win.
//!
//! Emits `BENCH_sharded.json` (path overridable with `--json P`; `--smoke`
//! shrinks sizes/reps for CI). The headline fields are `auto_speedup` and
//! `forced2_speedup`: sharded vs single-engine wall clock at the
//! memory-resident size.

use kahan_ecm::engine::{
    dispatch, topology_cached, DotEngine, EngineConfig, ShardedConfig, ShardedEngine, Topology,
};
use kahan_ecm::isa::Variant;
use kahan_ecm::machine::detect::detect_host_cached;
use kahan_ecm::util::{stats, Rng, Table};
use std::time::Instant;

fn median_us<F: FnMut() -> f32>(reps: usize, mut f: F) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    stats::median(&samples)
}

fn jnum(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

struct Row {
    label: &'static str,
    ws_bytes: u64,
    engine_us: f64,
    auto_us: f64,
    forced2_us: f64,
}

fn main() {
    let mut smoke = false;
    let mut json_path = "BENCH_sharded.json".to_string();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => json_path = args.next().unwrap_or(json_path),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg `{other}`"),
        }
    }

    println!("=== bench_sharded: NUMA-sharded tier vs single engine ===\n");
    let m = detect_host_cached();
    let topo = topology_cached();
    println!(
        "host: {} | numa: {} domain(s) [{}]",
        m.name,
        topo.nodes.len(),
        topo.render()
    );
    println!("calibrating autotuned dispatch (one-time)...");
    let _ = dispatch();

    let llc = m.caches[2].size_bytes;
    let mem_ws = if smoke {
        (2 * llc).min(32 << 20).max(llc + (4 << 20))
    } else {
        (2 * llc).min(64 << 20).max(llc + (8 << 20))
    };
    let sizes: Vec<(&'static str, u64)> =
        vec![("LLC-resident", llc / 2), ("memory-resident", mem_ws)];
    let reps = if smoke { 7 } else { 15 };

    // split threshold low enough that both probe sizes take the split path
    // on the multi-shard configs
    let sharded_cfg = ShardedConfig { split_min_bytes: 512 << 10, ..ShardedConfig::default() };
    let engine = DotEngine::new(EngineConfig::default());
    let auto = ShardedEngine::new(sharded_cfg);
    let forced2 = ShardedEngine::from_topology(&Topology::fake_even(2), sharded_cfg);
    println!(
        "engines: single ({} workers) | sharded-auto ({} shard(s), {} workers) | sharded-2 \
         ({} shards, {} workers)\n",
        engine.threads(),
        auto.shards(),
        auto.total_workers(),
        forced2.shards(),
        forced2.total_workers()
    );

    let mut rng = Rng::new(77);
    let mut rows: Vec<Row> = Vec::new();
    for &(label, ws) in &sizes {
        let n = (ws / 8).max(1024) as usize; // two f32 streams
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);

        // warm-up: page in sources, fill every pool
        std::hint::black_box(engine.dot_f32(Variant::Kahan, &a, &b));
        std::hint::black_box(auto.dot_f32(Variant::Kahan, &a, &b));
        std::hint::black_box(forced2.dot_f32(Variant::Kahan, &a, &b));

        let engine_us = median_us(reps, || engine.dot_f32(Variant::Kahan, &a, &b));
        let auto_us = median_us(reps, || auto.dot_f32(Variant::Kahan, &a, &b));
        let forced2_us = median_us(reps, || forced2.dot_f32(Variant::Kahan, &a, &b));
        rows.push(Row { label, ws_bytes: 2 * n as u64 * 4, engine_us, auto_us, forced2_us });
    }

    let mut t = Table::new("per-call wall clock (median, us; lower is better)").headers([
        "working set",
        "engine",
        "sharded-auto",
        "sharded-2",
        "auto speedup",
        "2-shard speedup",
    ]);
    for r in &rows {
        t.row([
            format!("{} ({})", r.label, kahan_ecm::util::fmt::bytes(r.ws_bytes)),
            format!("{:.1}", r.engine_us),
            format!("{:.1}", r.auto_us),
            format!("{:.1}", r.forced2_us),
            format!("{:.2}x", r.engine_us / r.auto_us),
            format!("{:.2}x", r.engine_us / r.forced2_us),
        ]);
    }
    println!("{}", t.render());

    let mem_row = rows.last().expect("memory row");
    let auto_speedup = mem_row.engine_us / mem_row.auto_us;
    let forced2_speedup = mem_row.engine_us / mem_row.forced2_us;
    let ast = auto.stats();
    let fst = forced2.stats();
    println!(
        "memory-resident: sharded-auto {auto_speedup:.2}x, forced-2 {forced2_speedup:.2}x vs \
         single engine"
    );
    println!(
        "sharded-auto stats: {} requests, {} split, pin failures {}",
        ast.requests, ast.split_dots, ast.pin_failures
    );
    println!(
        "sharded-2   stats: {} requests, {} split, pin failures {}",
        fst.requests, fst.split_dots, fst.pin_failures
    );

    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"bench_sharded\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str(&format!("  \"numa_domains\": {},\n", topo.nodes.len()));
    json.push_str(&format!("  \"auto_shards\": {},\n", auto.shards()));
    json.push_str(&format!("  \"total_workers\": {},\n", auto.total_workers()));
    json.push_str(&format!("  \"forced2_split_dots\": {},\n", fst.split_dots));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"ws_bytes\": {}, \"engine_us\": {}, \"sharded_auto_us\": {}, \"sharded2_us\": {}, \"auto_speedup\": {}, \"forced2_speedup\": {}}}{}\n",
            r.label,
            r.ws_bytes,
            jnum(r.engine_us),
            jnum(r.auto_us),
            jnum(r.forced2_us),
            jnum(r.engine_us / r.auto_us),
            jnum(r.engine_us / r.forced2_us),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"auto_speedup\": {},\n", jnum(auto_speedup)));
    json.push_str(&format!("  \"forced2_speedup\": {}\n", jnum(forced2_speedup)));
    json.push_str("}\n");
    std::fs::write(&json_path, &json).expect("write BENCH_sharded.json");
    println!("wrote {json_path}");

    // sanity, not a perf gate: the multi-shard config must actually have
    // split the measured dots, and results must agree with the baseline
    assert!(fst.split_dots > 0, "forced 2-shard config never split a dot");
    println!("bench_sharded: OK");
}
