//! Bench ENGINE: the persistent parallel dot engine vs the old
//! spawn-per-call request path, at LLC- and memory-resident sizes.
//!
//! Baseline ("spawn"): what the pre-engine code did per request — clone
//! both streams into fresh (unaligned, cold-page) `Vec`s, spawn + pin a
//! thread per chunk, join, fold. Engine ("engine"): admit into recycled
//! 64-byte-aligned pooled buffers and run on the persistent pinned worker
//! pool; "engine-pooled" is the zero-copy steady state (streams already
//! admitted, e.g. a server holding hot vectors).
//!
//! Emits `BENCH_engine.json` (path overridable with `--json P`; `--smoke`
//! shrinks sizes/reps for CI). The acceptance line is `memory_speedup`:
//! engine vs spawn-per-call at the memory-resident size.

use kahan_ecm::bench::kernels::{compensated_fold_f32, KernelFn};
use kahan_ecm::bench::threads::pin_to_cpu;
use kahan_ecm::coordinator::{DotService, ServiceConfig};
use kahan_ecm::ecm::governance::host_verdict;
use kahan_ecm::engine::{
    dispatch, kernel_for_f32, kernel_for_f64, parallel_dot_capped_f32, parallel_dot_capped_f64,
    BufferPool, DotEngine, EngineConfig, ShardedConfig, ShardedEngine, SizeClass, Topology,
    WorkerPool,
};
use kahan_ecm::isa::{Accuracy, Precision};
use kahan_ecm::machine::detect::detect_host_cached;
use kahan_ecm::util::{stats, Rng, Table};
use std::sync::Arc;
use std::time::Instant;

/// The old request path, verbatim in spirit: fresh clones, fresh threads.
fn spawn_per_call_dot(
    threads: usize,
    f: fn(&[f32], &[f32]) -> f32,
    a: &[f32],
    b: &[f32],
) -> f32 {
    let a: Arc<Vec<f32>> = Arc::new(a.to_vec());
    let b: Arc<Vec<f32>> = Arc::new(b.to_vec());
    let n = a.len();
    let chunk = (n + threads - 1) / threads;
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let a = Arc::clone(&a);
        let b = Arc::clone(&b);
        handles.push(std::thread::spawn(move || {
            pin_to_cpu(t);
            let lo = (t * chunk).min(n);
            let hi = ((t + 1) * chunk).min(n);
            f(&a[lo..hi], &b[lo..hi])
        }));
    }
    let sums: Vec<f32> = handles.into_iter().map(|h| h.join().expect("spawned chunk")).collect();
    let comps = vec![0.0f32; sums.len()];
    compensated_fold_f32(&sums, &comps)
}

fn median_us<F: FnMut() -> f32>(reps: usize, mut f: F) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    stats::median(&samples)
}

struct Row {
    label: &'static str,
    ws_bytes: u64,
    class: SizeClass,
    spawn_us: f64,
    engine_us: f64,
    engine_pooled_us: f64,
}

fn json_escape_free(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

/// A 2-shard sharded engine on a synthetic even topology, leaked for the
/// `'static` lifetime the service tier requires. Built ungoverned
/// (`governance: false`) so the scenario controls caps explicitly via
/// `set_worker_caps` — the comparison must not depend on the CI host's
/// detected memory bandwidth. The split threshold is set above any
/// request so every dot exercises the single-shard capped parallel path.
fn leak_sharded(shard_threads: usize) -> &'static mut ShardedEngine {
    Box::leak(Box::new(ShardedEngine::from_topology(
        &Topology::fake_even(2),
        ShardedConfig {
            engine: EngineConfig {
                threads: shard_threads,
                governance: false,
                ..EngineConfig::default()
            },
            split_min_bytes: 1 << 30,
            chunks: 0,
        },
    )))
}

/// Run one service scenario: `clients` threads each admit a co-located
/// MEM-class pair once, then issue `reqs` zero-copy pooled Kahan dots.
/// Returns (requests/sec, engine-level capped_requests from the stats
/// snapshot). Round-robin admission lands the clients on different
/// shards, so a capped engine leaves workers free for the other client.
fn run_service_scenario(
    engine: &'static ShardedEngine,
    governance: &'static str,
    n: usize,
    clients: usize,
    reqs: usize,
) -> (f64, u64) {
    let cfg = ServiceConfig { ecm_governance: governance.into(), ..ServiceConfig::default() };
    let (svc, client) = DotService::try_start_on(cfg, engine).expect("service start");
    let t = Instant::now();
    let mut handles = Vec::with_capacity(clients);
    for c in 0..clients {
        let cl = client.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(100 + c as u64);
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let (ha, hb) = cl.admit_pair_blocking(a, b).expect("admit pair");
            for _ in 0..reqs {
                std::hint::black_box(
                    cl.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot"),
                );
            }
        }));
    }
    for h in handles {
        h.join().expect("service client");
    }
    let secs = t.elapsed().as_secs_f64();
    let st = svc.stop();
    ((clients * reqs) as f64 / secs, st.capped_requests)
}

fn main() {
    let mut smoke = false;
    let mut json_path = "BENCH_engine.json".to_string();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => json_path = args.next().unwrap_or(json_path),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg `{other}`"),
        }
    }

    println!("=== bench_engine: persistent engine vs spawn-per-call ===\n");
    let m = detect_host_cached();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let llc = m.caches[2].size_bytes;
    let mem_ws = if smoke {
        (2 * llc).min(32 << 20).max(llc + (4 << 20))
    } else {
        (2 * llc).min(64 << 20).max(llc + (8 << 20))
    };
    let sizes: Vec<(&'static str, u64)> = vec![
        ("L2-resident", (m.caches[1].size_bytes / 2).max(128 << 10)),
        ("LLC-resident", llc / 2),
        ("memory-resident", mem_ws),
    ];
    let reps = if smoke { 7 } else { 15 };

    println!("host: {} | {} threads | LLC {}", m.name, threads, kahan_ecm::util::fmt::bytes(llc));
    println!("calibrating autotuned dispatch (one-time)...");
    let table = dispatch();
    println!("{}", table.render().render());

    let engine = DotEngine::new(EngineConfig::default());
    let mut rng = Rng::new(2025);
    let mut rows: Vec<Row> = Vec::new();

    for &(label, ws) in &sizes {
        let n = (ws / 8).max(1024) as usize; // two f32 streams
        let class = SizeClass::of(2 * n as u64 * 4);
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let f = match table.select(Precision::Sp, Accuracy::Kahan, class).f {
            KernelFn::F32(f) => f,
            KernelFn::F64(_) => unreachable!(),
        };

        // warm-up both paths (page in sources, fill the pool, calibrate)
        std::hint::black_box(engine.dot_f32(Accuracy::Kahan, &a, &b));
        std::hint::black_box(spawn_per_call_dot(threads, f, &a, &b));

        let spawn_us = median_us(reps, || spawn_per_call_dot(threads, f, &a, &b));
        let engine_us = median_us(reps, || engine.dot_f32(Accuracy::Kahan, &a, &b));
        let pa = engine.admit_f32(&a);
        let pb = engine.admit_f32(&b);
        let engine_pooled_us =
            median_us(reps, || engine.dot_pooled_f32(Accuracy::Kahan, &pa, &pb));

        rows.push(Row {
            label,
            ws_bytes: 2 * n as u64 * 4,
            class,
            spawn_us,
            engine_us,
            engine_pooled_us,
        });
    }

    let mut t = Table::new("per-call wall clock (median, us; lower is better)").headers([
        "working set",
        "class",
        "spawn/call",
        "engine",
        "engine (pooled)",
        "speedup",
        "speedup (pooled)",
    ]);
    for r in &rows {
        t.row([
            format!("{} ({})", r.label, kahan_ecm::util::fmt::bytes(r.ws_bytes)),
            r.class.name().to_string(),
            format!("{:.1}", r.spawn_us),
            format!("{:.1}", r.engine_us),
            format!("{:.1}", r.engine_pooled_us),
            format!("{:.2}x", r.spawn_us / r.engine_us),
            format!("{:.2}x", r.spawn_us / r.engine_pooled_us),
        ]);
    }
    println!("{}", t.render());

    let mem_row = rows.last().expect("memory row");
    let memory_speedup = mem_row.spawn_us / mem_row.engine_us;
    let memory_speedup_pooled = mem_row.spawn_us / mem_row.engine_pooled_us;
    let es = engine.stats();
    println!(
        "memory-resident: engine {:.2}x, pooled {:.2}x over spawn-per-call",
        memory_speedup, memory_speedup_pooled
    );
    println!(
        "engine stats: {} requests, {} parallel, pool hits/misses {}/{}",
        es.requests, es.parallel, es.pool.hits, es.pool.misses
    );

    // --- Accuracy ladder: what does each tier cost vs naive, per class? ---
    //
    // The paper's headline question, asked of the serving stack's own
    // calibrated winners: single-worker kernel throughput for each
    // accuracy tier at an L1-, LLC- and MEM-class working set. At MEM the
    // dot is bandwidth-bound, so Kahan — and, with FMA-based TwoProd,
    // Dot2 — is expected to be ~free; in L1 the extra arithmetic shows
    // its real cost.
    println!("\n=== accuracy ladder: per-class throughput vs naive ===");
    let l1_ws = m.caches[0].size_bytes / 2;
    let ladder_sets: [(&'static str, u64); 3] = [("l1", l1_ws), ("llc", llc / 2), ("mem", mem_ws)];
    const LADDER: [Accuracy; 3] = [Accuracy::Naive, Accuracy::Kahan, Accuracy::Dot2];
    // (json suffix, class, tier throughput / naive throughput, winner names)
    let mut ladder: Vec<(&'static str, SizeClass, [f64; 3], [&'static str; 3])> = Vec::new();
    for (suffix, ws) in ladder_sets {
        let n = (ws / 8).max(1024) as usize;
        let class = SizeClass::of(2 * n as u64 * 4);
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let mut us = [0.0f64; 3];
        let mut names = [""; 3];
        for (t, &acc) in LADDER.iter().enumerate() {
            let k = table.select(Precision::Sp, acc, class);
            names[t] = k.name;
            let f = match k.f {
                KernelFn::F32(f) => f,
                KernelFn::F64(_) => unreachable!(),
            };
            std::hint::black_box(f(&a, &b));
            us[t] = median_us(reps, || f(&a, &b));
        }
        let ratios = [1.0, us[0] / us[1], us[0] / us[2]];
        println!(
            "  {suffix} ({}, n = {n}): kahan {:.2}x of naive ({}), dot2 {:.2}x of naive ({})",
            class.name(),
            ratios[1],
            names[1],
            ratios[2],
            names[2]
        );
        ladder.push((suffix, class, ratios, names));
    }
    let dot2_mem_ratio = ladder.last().expect("mem ladder row").2[2];
    let dot2_mem_free = dot2_mem_ratio >= 0.9;
    if !dot2_mem_free {
        eprintln!(
            "WARNING: MEM-class dot2 throughput is {dot2_mem_ratio:.2}x of naive (< 0.9x) \
             — recorded in {json_path}"
        );
    }

    // --- f64 accuracy ladder: the paper's DP column of the same question ---
    //
    // DP streams are twice as wide, so the MEM class goes bandwidth-bound
    // at half the element count and the compensated tiers should be free
    // there exactly as in SP — the paper's core claim holds per precision,
    // and the serving stack routes f64 requests through the same
    // calibrated dispatch, so the DP ratios are asserted in CI too.
    println!("\n=== accuracy ladder (f64): per-class throughput vs naive ===");
    let mut ladder_f64: Vec<(&'static str, SizeClass, [f64; 3])> = Vec::new();
    for (suffix, ws) in ladder_sets {
        let n = (ws / 16).max(1024) as usize; // two f64 streams
        let class = SizeClass::of(2 * n as u64 * 8);
        let a = rng.normal_f64_vec(n);
        let b = rng.normal_f64_vec(n);
        let mut us = [0.0f64; 3];
        let mut names = [""; 3];
        for (t, &acc) in LADDER.iter().enumerate() {
            let k = table.select(Precision::Dp, acc, class);
            names[t] = k.name;
            let f = match k.f {
                KernelFn::F64(f) => f,
                KernelFn::F32(_) => unreachable!(),
            };
            std::hint::black_box(f(&a, &b));
            us[t] = median_us(reps, || f(&a, &b) as f32);
        }
        let ratios = [1.0, us[0] / us[1], us[0] / us[2]];
        println!(
            "  {suffix} ({}, n = {n}): kahan {:.2}x of naive ({}), dot2 {:.2}x of naive ({})",
            class.name(),
            ratios[1],
            names[1],
            ratios[2],
            names[2]
        );
        ladder_f64.push((suffix, class, ratios));
    }
    let dot2_mem_ratio_f64 = ladder_f64.last().expect("mem f64 ladder row").2[2];
    let dot2_mem_free_f64 = dot2_mem_ratio_f64 >= 0.9;
    if !dot2_mem_free_f64 {
        eprintln!(
            "WARNING: MEM-class f64 dot2 throughput is {dot2_mem_ratio_f64:.2}x of naive \
             (< 0.9x) — recorded in {json_path}"
        );
    }

    // --- ECM governance: predicted vs observed saturation ---
    //
    // The governance layer caps fan-out at the ECM-predicted saturation
    // point n_S (paper §2: the core count where aggregate demand first
    // meets the shared memory-bandwidth ceiling). Here we close the loop:
    // sweep the worker cap k = 1..=threads with FIXED chunk geometry (the
    // sweep varies only how many workers a dot may occupy — exactly what
    // governance changes in serving, never the chunk split), and take the
    // observed saturation as the smallest k within 5% of the best time.
    println!("\n=== ECM governance: predicted vs observed saturation ===");
    let verdict = host_verdict();
    println!("model: {}", verdict.source.describe());
    let gov_pool = WorkerPool::new(threads);
    let bufs = BufferPool::new();
    let sat_reps = if smoke { 3 } else { 7 };
    // (json field suffix, precision index, size class, predicted, observed)
    let mut sat_results: Vec<(&'static str, usize, SizeClass, u32, u32)> = Vec::new();
    macro_rules! sat_sweep {
        ($pi:expr, $genvec:ident, $capped:ident, $kernel_for:ident, $elem:expr, $wrap:expr, $sets:expr) => {
            for (suffix, n) in $sets {
                let n: usize = n;
                let av = rng.$genvec(n);
                let bv = rng.$genvec(n);
                let a = Arc::new(bufs.admit(&av));
                let b = Arc::new(bufs.admit(&bv));
                let total = 2 * n as u64 * $elem;
                let class = SizeClass::of(total);
                let f = $kernel_for(Accuracy::Kahan, total);
                let wrap = $wrap;
                let mut times = Vec::with_capacity(threads);
                for k in 1..=threads {
                    times.push(median_us(sat_reps, || {
                        wrap($capped(&gov_pool, f, &a, &b, threads, k))
                    }));
                }
                let best = times.iter().cloned().fold(f64::INFINITY, f64::min);
                let obs = (times.iter().position(|&t| t <= best * 1.05).unwrap_or(threads - 1)
                    + 1) as u32;
                let pred = verdict.sat_cores[$pi][class.index()];
                println!(
                    "  {suffix} ({}, n = {n}): predicted {}, observed saturation at {obs} of {threads} worker(s)",
                    class.name(),
                    if pred == 0 { "no ceiling".to_string() } else { format!("{pred} core(s)") },
                );
                sat_results.push((suffix, $pi, class, pred, obs));
            }
        };
    }
    sat_sweep!(
        0,
        normal_f32_vec,
        parallel_dot_capped_f32,
        kernel_for_f32,
        4u64,
        (|x: f32| x),
        [("sp_llc", (llc / 16) as usize), ("sp_mem", (mem_ws / 8) as usize)]
    );
    sat_sweep!(
        1,
        normal_f64_vec,
        parallel_dot_capped_f64,
        kernel_for_f64,
        8u64,
        (|x: f64| x as f32),
        [("dp_llc", (llc / 32) as usize), ("dp_mem", (mem_ws / 16) as usize)]
    );

    // --- ECM governance: governed vs ungoverned service throughput ---
    //
    // Two clients each hammer a MEM-class pooled pair through the service.
    // Ungoverned, every dot fans out across all of its shard's workers and
    // the two requests contend for saturated memory bandwidth; governed,
    // each dot is capped onto a worker subset and the freed workers serve
    // the concurrent client. The cap is set explicitly (strictly below the
    // per-shard worker count) so `capped_requests` is deterministic on any
    // CI host; the engines are built ungoverned so the host's detected
    // bandwidth cannot alter the comparison.
    println!("\n=== ECM governance: governed vs ungoverned service (MEM-class) ===");
    let shard_threads = 2usize;
    let svc_n = (llc / 4) as usize + (1 << 18); // 2 f32 streams => 2*LLC + 2 MiB: MEM class
    let svc_clients = 2usize;
    let svc_reqs = if smoke { 6 } else { 20 };
    let mem_cap = (verdict.sat_cores[0][2].max(1) as usize).min(shard_threads - 1).max(1);
    let mut caps = [[usize::MAX; 3]; 2];
    caps[0][2] = mem_cap;
    caps[1][2] = mem_cap;
    let open_engine: &'static ShardedEngine = leak_sharded(shard_threads);
    let governed_engine: &'static mut ShardedEngine = leak_sharded(shard_threads);
    governed_engine.set_worker_caps(caps);
    let governed_engine: &'static ShardedEngine = governed_engine;
    let (svc_rps_uncapped, svc_capped_ungoverned) =
        run_service_scenario(open_engine, "off", svc_n, svc_clients, svc_reqs);
    let (svc_rps_capped, svc_capped_governed) =
        run_service_scenario(governed_engine, "on", svc_n, svc_clients, svc_reqs);
    println!(
        "governed {svc_rps_capped:.1} req/s ({svc_capped_governed} capped) vs \
         ungoverned {svc_rps_uncapped:.1} req/s ({svc_capped_ungoverned} capped)"
    );
    if svc_rps_capped < svc_rps_uncapped {
        eprintln!(
            "WARNING: governed service throughput {svc_rps_capped:.1} req/s is below \
             ungoverned {svc_rps_uncapped:.1} req/s (recorded in {json_path})"
        );
    }

    // Feed mispredictions back into the autotuner's dispatch table as a
    // correction factor (rel error beyond 25% stores observed/predicted).
    // This runs AFTER the service comparison so the correction cannot
    // retroactively open the governed scenario's explicit caps.
    for &(_, pi, class, pred, obs) in &sat_results {
        if pred > 0 {
            let prec = if pi == 0 { Precision::Sp } else { Precision::Dp };
            table.note_saturation(prec, class, pred, obs, 0.25);
        }
    }

    // --- Measured-calibration profile: cold-start parity + split gain ---
    //
    // Snapshot the calibration profile AFTER the saturation feedback above
    // so the persisted corrections include what this run observed, write
    // it as the PROFILE artifact CI uploads, and close two loops:
    //
    // * cold-start parity: a dispatch table seeded purely from the profile
    //   (`DispatchTable::from_profile` — what a cold process starts with)
    //   must select a MEM-class Kahan winner within a few percent of the
    //   live-calibrated table's (`calib_cold_start_ratio >= 0.95`).
    // * split gain: a sharded engine whose split threshold auto-derives
    //   from the measured crossover must not serve a MEM-class dot
    //   materially slower than one pinned to the built-in 4 MiB constant
    //   (`calib_split_gain = t_const / t_calibrated`, lenient >= 0.8).
    println!("\n=== measured-calibration profile ===");
    let profile = kahan_ecm::engine::CalibrationProfile::measure();
    let _ = kahan_ecm::engine::install_host_profile(profile.clone());
    let profile_path = "PROFILE_calibration.json";
    profile.save(std::path::Path::new(profile_path)).expect("write calibration profile");
    println!(
        "measured: {:.1} GB/s load bw, split fixed {:.1} us, MEM kahan/naive {:.2}",
        profile.mem_bw_gbs, profile.split_fixed_us, profile.kahan_vs_naive[2]
    );
    println!("wrote {profile_path}");
    let cold_table =
        kahan_ecm::engine::DispatchTable::from_profile(&profile).expect("profile round-trip");
    let calib_cold_start_ratio = {
        let n = (mem_ws / 8).max(1024) as usize;
        let class = SizeClass::of(2 * n as u64 * 4);
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let time_winner = |t: &kahan_ecm::engine::DispatchTable| {
            let f = match t.select(Precision::Sp, Accuracy::Kahan, class).f {
                KernelFn::F32(f) => f,
                KernelFn::F64(_) => unreachable!(),
            };
            std::hint::black_box(f(&a, &b));
            median_us(reps, || f(&a, &b))
        };
        let warm_us = time_winner(table);
        let cold_us = time_winner(&cold_table);
        warm_us / cold_us
    };
    println!(
        "cold-start parity: profile-seeded winner at {:.2}x of live-calibrated (>= 0.95 \
         means a cold process starts warmed up)",
        calib_cold_start_ratio
    );
    if calib_cold_start_ratio < 0.95 {
        eprintln!(
            "WARNING: profile-seeded dispatch is {calib_cold_start_ratio:.2}x of \
             live-calibrated (< 0.95) — recorded in {json_path}"
        );
    }
    let calib_split_gain = {
        let mk = |split_min_bytes: usize| -> &'static ShardedEngine {
            Box::leak(Box::new(ShardedEngine::from_topology(
                &Topology::fake_even(2),
                ShardedConfig {
                    engine: EngineConfig {
                        threads: 2,
                        governance: false,
                        ..EngineConfig::default()
                    },
                    split_min_bytes,
                    chunks: 0,
                },
            )))
        };
        let const_engine = mk(kahan_ecm::engine::DEFAULT_SPLIT_MIN_BYTES);
        let calib_engine = mk(0); // auto: derive from the installed profile
        println!(
            "split threshold: constant {} vs auto {} [{}]",
            kahan_ecm::util::fmt::bytes(const_engine.config().split_min_bytes as u64),
            kahan_ecm::util::fmt::bytes(calib_engine.config().split_min_bytes as u64),
            calib_engine.split_min_source()
        );
        let n = (mem_ws / 8).max(1024) as usize;
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        std::hint::black_box(const_engine.dot_f32(Accuracy::Kahan, &a, &b));
        std::hint::black_box(calib_engine.dot_f32(Accuracy::Kahan, &a, &b));
        let t_const = median_us(reps, || const_engine.dot_f32(Accuracy::Kahan, &a, &b));
        let t_calib = median_us(reps, || calib_engine.dot_f32(Accuracy::Kahan, &a, &b));
        t_const / t_calib
    };
    println!(
        "split gain: calibrated threshold serves the MEM-class dot at {:.2}x of the \
         4 MiB constant (>= 1 = measured crossover wins or ties)",
        calib_split_gain
    );
    if calib_split_gain < 0.8 {
        eprintln!(
            "WARNING: calibrated split threshold is {calib_split_gain:.2}x of the \
             constant (< 0.8) — recorded in {json_path}"
        );
    }

    // --- BENCH_engine.json ---
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"bench_engine\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str(&format!("  \"threads\": {threads},\n"));
    json.push_str(&format!("  \"llc_bytes\": {llc},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"class\": \"{}\", \"ws_bytes\": {}, \"spawn_us\": {}, \"engine_us\": {}, \"engine_pooled_us\": {}, \"speedup\": {}, \"speedup_pooled\": {}}}{}\n",
            r.label,
            r.class.name(),
            r.ws_bytes,
            json_escape_free(r.spawn_us),
            json_escape_free(r.engine_us),
            json_escape_free(r.engine_pooled_us),
            json_escape_free(r.spawn_us / r.engine_us),
            json_escape_free(r.spawn_us / r.engine_pooled_us),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"memory_speedup\": {},\n", json_escape_free(memory_speedup)));
    json.push_str(&format!(
        "  \"memory_speedup_pooled\": {},\n",
        json_escape_free(memory_speedup_pooled)
    ));
    for &(suffix, _, _, pred, obs) in &sat_results {
        json.push_str(&format!("  \"ecm_pred_sat_{suffix}\": {pred},\n"));
        json.push_str(&format!("  \"ecm_obs_sat_{suffix}\": {obs},\n"));
    }
    json.push_str(&format!("  \"svc_rps_uncapped\": {},\n", json_escape_free(svc_rps_uncapped)));
    json.push_str(&format!("  \"svc_rps_capped\": {},\n", json_escape_free(svc_rps_capped)));
    json.push_str(&format!(
        "  \"svc_capped_requests_ungoverned\": {svc_capped_ungoverned},\n"
    ));
    json.push_str(&format!("  \"svc_capped_requests_governed\": {svc_capped_governed},\n"));
    for (suffix, _, ratios, names) in &ladder {
        json.push_str(&format!(
            "  \"kahan_vs_naive_{suffix}\": {},\n",
            json_escape_free(ratios[1])
        ));
        json.push_str(&format!(
            "  \"dot2_vs_naive_{suffix}\": {},\n",
            json_escape_free(ratios[2])
        ));
        json.push_str(&format!("  \"winner_naive_{suffix}\": \"{}\",\n", names[0]));
        json.push_str(&format!("  \"winner_kahan_{suffix}\": \"{}\",\n", names[1]));
        json.push_str(&format!("  \"winner_dot2_{suffix}\": \"{}\",\n", names[2]));
    }
    json.push_str(&format!("  \"dot2_mem_free\": {dot2_mem_free},\n"));
    for (suffix, _, ratios) in &ladder_f64 {
        json.push_str(&format!(
            "  \"kahan_vs_naive_f64_{suffix}\": {},\n",
            json_escape_free(ratios[1])
        ));
        json.push_str(&format!(
            "  \"dot2_vs_naive_f64_{suffix}\": {},\n",
            json_escape_free(ratios[2])
        ));
    }
    json.push_str(&format!("  \"dot2_mem_free_f64\": {dot2_mem_free_f64},\n"));
    json.push_str(&format!(
        "  \"calib_cold_start_ratio\": {},\n",
        json_escape_free(calib_cold_start_ratio)
    ));
    json.push_str(&format!(
        "  \"calib_split_gain\": {},\n",
        json_escape_free(calib_split_gain)
    ));
    json.push_str(&format!("  \"meets_2x\": {}\n", memory_speedup >= 2.0));
    json.push_str("}\n");
    std::fs::write(&json_path, &json).expect("write BENCH_engine.json");
    println!("wrote {json_path}");

    if memory_speedup < 2.0 {
        eprintln!(
            "WARNING: memory-resident speedup {memory_speedup:.2}x is below the 2x target \
             (recorded in {json_path})"
        );
    }
    println!("bench_engine: OK");
}
