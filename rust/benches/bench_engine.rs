//! Bench ENGINE: the persistent parallel dot engine vs the old
//! spawn-per-call request path, at LLC- and memory-resident sizes.
//!
//! Baseline ("spawn"): what the pre-engine code did per request — clone
//! both streams into fresh (unaligned, cold-page) `Vec`s, spawn + pin a
//! thread per chunk, join, fold. Engine ("engine"): admit into recycled
//! 64-byte-aligned pooled buffers and run on the persistent pinned worker
//! pool; "engine-pooled" is the zero-copy steady state (streams already
//! admitted, e.g. a server holding hot vectors).
//!
//! Emits `BENCH_engine.json` (path overridable with `--json P`; `--smoke`
//! shrinks sizes/reps for CI). The acceptance line is `memory_speedup`:
//! engine vs spawn-per-call at the memory-resident size.

use kahan_ecm::bench::kernels::{compensated_fold_f32, KernelFn};
use kahan_ecm::bench::threads::pin_to_cpu;
use kahan_ecm::engine::{dispatch, DotEngine, EngineConfig, SizeClass};
use kahan_ecm::isa::{Precision, Variant};
use kahan_ecm::machine::detect::detect_host_cached;
use kahan_ecm::util::{stats, Rng, Table};
use std::sync::Arc;
use std::time::Instant;

/// The old request path, verbatim in spirit: fresh clones, fresh threads.
fn spawn_per_call_dot(
    threads: usize,
    f: fn(&[f32], &[f32]) -> f32,
    a: &[f32],
    b: &[f32],
) -> f32 {
    let a: Arc<Vec<f32>> = Arc::new(a.to_vec());
    let b: Arc<Vec<f32>> = Arc::new(b.to_vec());
    let n = a.len();
    let chunk = (n + threads - 1) / threads;
    let mut handles = Vec::with_capacity(threads);
    for t in 0..threads {
        let a = Arc::clone(&a);
        let b = Arc::clone(&b);
        handles.push(std::thread::spawn(move || {
            pin_to_cpu(t);
            let lo = (t * chunk).min(n);
            let hi = ((t + 1) * chunk).min(n);
            f(&a[lo..hi], &b[lo..hi])
        }));
    }
    let sums: Vec<f32> = handles.into_iter().map(|h| h.join().expect("spawned chunk")).collect();
    let comps = vec![0.0f32; sums.len()];
    compensated_fold_f32(&sums, &comps)
}

fn median_us<F: FnMut() -> f32>(reps: usize, mut f: F) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    stats::median(&samples)
}

struct Row {
    label: &'static str,
    ws_bytes: u64,
    class: SizeClass,
    spawn_us: f64,
    engine_us: f64,
    engine_pooled_us: f64,
}

fn json_escape_free(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let mut smoke = false;
    let mut json_path = "BENCH_engine.json".to_string();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--smoke" => smoke = true,
            "--json" => json_path = args.next().unwrap_or(json_path),
            "--bench" => {} // cargo bench passes this through
            other => eprintln!("ignoring unknown arg `{other}`"),
        }
    }

    println!("=== bench_engine: persistent engine vs spawn-per-call ===\n");
    let m = detect_host_cached();
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let llc = m.caches[2].size_bytes;
    let mem_ws = if smoke {
        (2 * llc).min(32 << 20).max(llc + (4 << 20))
    } else {
        (2 * llc).min(64 << 20).max(llc + (8 << 20))
    };
    let sizes: Vec<(&'static str, u64)> = vec![
        ("L2-resident", (m.caches[1].size_bytes / 2).max(128 << 10)),
        ("LLC-resident", llc / 2),
        ("memory-resident", mem_ws),
    ];
    let reps = if smoke { 7 } else { 15 };

    println!("host: {} | {} threads | LLC {}", m.name, threads, kahan_ecm::util::fmt::bytes(llc));
    println!("calibrating autotuned dispatch (one-time)...");
    let table = dispatch();
    println!("{}", table.render().render());

    let engine = DotEngine::new(EngineConfig::default());
    let mut rng = Rng::new(2025);
    let mut rows: Vec<Row> = Vec::new();

    for &(label, ws) in &sizes {
        let n = (ws / 8).max(1024) as usize; // two f32 streams
        let class = SizeClass::of(2 * n as u64 * 4);
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        let f = match table.select(Precision::Sp, Variant::Kahan, class).f {
            KernelFn::F32(f) => f,
            KernelFn::F64(_) => unreachable!(),
        };

        // warm-up both paths (page in sources, fill the pool, calibrate)
        std::hint::black_box(engine.dot_f32(Variant::Kahan, &a, &b));
        std::hint::black_box(spawn_per_call_dot(threads, f, &a, &b));

        let spawn_us = median_us(reps, || spawn_per_call_dot(threads, f, &a, &b));
        let engine_us = median_us(reps, || engine.dot_f32(Variant::Kahan, &a, &b));
        let pa = engine.admit_f32(&a);
        let pb = engine.admit_f32(&b);
        let engine_pooled_us =
            median_us(reps, || engine.dot_pooled_f32(Variant::Kahan, &pa, &pb));

        rows.push(Row {
            label,
            ws_bytes: 2 * n as u64 * 4,
            class,
            spawn_us,
            engine_us,
            engine_pooled_us,
        });
    }

    let mut t = Table::new("per-call wall clock (median, us; lower is better)").headers([
        "working set",
        "class",
        "spawn/call",
        "engine",
        "engine (pooled)",
        "speedup",
        "speedup (pooled)",
    ]);
    for r in &rows {
        t.row([
            format!("{} ({})", r.label, kahan_ecm::util::fmt::bytes(r.ws_bytes)),
            r.class.name().to_string(),
            format!("{:.1}", r.spawn_us),
            format!("{:.1}", r.engine_us),
            format!("{:.1}", r.engine_pooled_us),
            format!("{:.2}x", r.spawn_us / r.engine_us),
            format!("{:.2}x", r.spawn_us / r.engine_pooled_us),
        ]);
    }
    println!("{}", t.render());

    let mem_row = rows.last().expect("memory row");
    let memory_speedup = mem_row.spawn_us / mem_row.engine_us;
    let memory_speedup_pooled = mem_row.spawn_us / mem_row.engine_pooled_us;
    let es = engine.stats();
    println!(
        "memory-resident: engine {:.2}x, pooled {:.2}x over spawn-per-call",
        memory_speedup, memory_speedup_pooled
    );
    println!(
        "engine stats: {} requests, {} parallel, pool hits/misses {}/{}",
        es.requests, es.parallel, es.pool.hits, es.pool.misses
    );

    // --- BENCH_engine.json ---
    let mut json = String::new();
    json.push_str("{\n");
    json.push_str("  \"bench\": \"bench_engine\",\n");
    json.push_str(&format!("  \"smoke\": {smoke},\n"));
    json.push_str(&format!("  \"threads\": {threads},\n"));
    json.push_str(&format!("  \"llc_bytes\": {llc},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"label\": \"{}\", \"class\": \"{}\", \"ws_bytes\": {}, \"spawn_us\": {}, \"engine_us\": {}, \"engine_pooled_us\": {}, \"speedup\": {}, \"speedup_pooled\": {}}}{}\n",
            r.label,
            r.class.name(),
            r.ws_bytes,
            json_escape_free(r.spawn_us),
            json_escape_free(r.engine_us),
            json_escape_free(r.engine_pooled_us),
            json_escape_free(r.spawn_us / r.engine_us),
            json_escape_free(r.spawn_us / r.engine_pooled_us),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n");
    json.push_str(&format!("  \"memory_speedup\": {},\n", json_escape_free(memory_speedup)));
    json.push_str(&format!(
        "  \"memory_speedup_pooled\": {},\n",
        json_escape_free(memory_speedup_pooled)
    ));
    json.push_str(&format!("  \"meets_2x\": {}\n", memory_speedup >= 2.0));
    json.push_str("}\n");
    std::fs::write(&json_path, &json).expect("write BENCH_engine.json");
    println!("wrote {json_path}");

    if memory_speedup < 2.0 {
        eprintln!(
            "WARNING: memory-resident speedup {memory_speedup:.2}x is below the 2x target \
             (recorded in {json_path})"
        );
    }
    println!("bench_engine: OK");
}
