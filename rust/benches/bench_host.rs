//! Bench HOST: the real-silicon microbenchmark — every available SIMD
//! kernel at representative L1/L2/LLC/memory working sets, cycles per CL
//! and GUP/s, plus the "Kahan for free" ratio on this machine.

use kahan_ecm::bench::{kernels, run_sweep};
use kahan_ecm::isa::{Precision, Variant};
use kahan_ecm::util::Table;
use std::time::Instant;

fn main() {
    println!("=== bench_host: SIMD kernels on this machine (TSC cycles/CL) ===\n");
    let m = kahan_ecm::machine::detect::detect_host();
    println!(
        "host: {} | L1 {} | L2 {} | LLC {}\n",
        m.name,
        kahan_ecm::util::fmt::bytes(m.caches[0].size_bytes),
        kahan_ecm::util::fmt::bytes(m.caches[1].size_bytes),
        kahan_ecm::util::fmt::bytes(m.caches[2].size_bytes),
    );
    // representative sizes: half-L1, half-L2, half-LLC, 2x LLC
    let sizes = vec![
        m.caches[0].size_bytes / 2,
        m.caches[1].size_bytes / 2,
        m.caches[2].size_bytes / 2,
        2 * m.caches[2].size_bytes,
    ];
    let labels = ["L1/2", "L2/2", "LLC/2", "2xLLC"];

    let t0 = Instant::now();
    let mut t = Table::new("cycles per cache line (lower is better)")
        .headers(["kernel", labels[0], labels[1], labels[2], labels[3]]);
    let mut results = Vec::new();
    for k in kernels::registry().into_iter().filter(|k| k.available) {
        let pts = run_sweep(&k, &sizes, 7, 11);
        let mut row = vec![k.name.to_string()];
        row.extend(pts.iter().map(|p| format!("{:.2}", p.cy_per_cl)));
        t.row(row);
        results.push((k, pts));
    }
    println!("{}", t.render());

    // headline on real silicon (SP, AVX2): free beyond L1
    let find = |v: Variant, name: &str| {
        results
            .iter()
            .find(|(k, _)| k.variant == v && k.prec == Precision::Sp && k.name.contains(name))
            .map(|(_, p)| p.clone())
    };
    if let (Some(n), Some(ka)) = (find(Variant::Naive, "AVX2"), find(Variant::Kahan, "AVX2")) {
        let mem_ratio = ka[3].cy_per_cl / n[3].cy_per_cl;
        let l1_ratio = ka[0].cy_per_cl / n[0].cy_per_cl;
        println!("kahan-AVX2/naive-AVX2: L1 {l1_ratio:.2}x, memory {mem_ratio:.2}x");
        assert!(
            mem_ratio < 1.35,
            "memory-bound Kahan should be (nearly) free, got {mem_ratio:.2}x"
        );
        assert!(l1_ratio > 1.2, "L1-bound Kahan must cost extra, got {l1_ratio:.2}x");
    }
    println!("bench_host: swept {} kernels in {:.1} s — OK", results.len(), t0.elapsed().as_secs_f64());
}
