//! Bench RT: PJRT execution latency of the AOT artifacts — the request-path
//! cost the serving example pays per call (compile once, execute many).

use kahan_ecm::runtime::Runtime;
use kahan_ecm::util::{stats, Rng};
use std::time::Instant;

fn time_us<F: FnMut()>(reps: usize, mut f: F) -> (f64, f64) {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    (stats::median(&samples), stats::min(&samples))
}

fn main() -> anyhow::Result<()> {
    println!("=== bench_runtime: PJRT execute latency (per call) ===\n");
    if !kahan_ecm::runtime::artifacts_dir().join("manifest.tsv").exists() {
        println!("SKIP: no artifacts (run `make artifacts`)");
        return Ok(());
    }
    let mut rt = Runtime::new()?;
    let mut rng = Rng::new(3);

    for name in [
        "dot_naive_f32_n4096",
        "dot_kahan_f32_n4096",
        "dot_kahan_f32_n65536",
        "dot_kahan_f64_n65536",
        "dot_kahan_f32_n1048576",
    ] {
        let meta = rt.manifest().get(name).expect("artifact").clone();
        let tc = Instant::now();
        rt.load(name)?;
        let compile_ms = tc.elapsed().as_secs_f64() * 1e3;
        let (med, min) = if meta.dtype == "f32" {
            let a = rng.normal_f32_vec(meta.n);
            let b = rng.normal_f32_vec(meta.n);
            time_us(15, || {
                rt.dot_f32(name, &a, &b).unwrap();
            })
        } else {
            let a = rng.normal_f64_vec(meta.n);
            let b = rng.normal_f64_vec(meta.n);
            time_us(15, || {
                rt.dot_f64(name, &a, &b).unwrap();
            })
        };
        println!(
            "{name:32} compile {compile_ms:8.1} ms | execute median {med:9.1} us (min {min:9.1}) | {:.1} Melem/s",
            meta.n as f64 / (min * 1e-6) / 1e6
        );
    }

    // batched throughput vs sequential singles
    let bname = "batched_dot_kahan_f32_b8_n16384";
    let meta = rt.manifest().get(bname).expect("batched artifact").clone();
    rt.load(bname)?;
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..meta.batch)
        .map(|_| (rng.normal_f32_vec(meta.n), rng.normal_f32_vec(meta.n)))
        .collect();
    let (med_b, _) = time_us(15, || {
        rt.batched_dot_f32(bname, &pairs).unwrap();
    });
    let single = "dot_kahan_f32_n65536";
    let a = rng.normal_f32_vec(meta.n);
    let b = rng.normal_f32_vec(meta.n);
    rt.load(single)?;
    let (med_s, _) = time_us(15, || {
        rt.dot_f32(single, &a, &b).unwrap();
    });
    println!(
        "\nbatched (8x16384) {med_b:.1} us vs 8 singles {:.1} us -> batching gain {:.2}x",
        8.0 * med_s,
        8.0 * med_s / med_b
    );
    println!("bench_runtime: OK");
    Ok(())
}
