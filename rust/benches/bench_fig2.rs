//! Bench F2: regenerate Fig. 2 (single-core cy/CL vs working set, IVB, SP)
//! on the virtual testbed, print the series the paper plots, and check the
//! shape constraints the paper reports.

use kahan_ecm::coordinator::experiments;
use kahan_ecm::isa::Precision;
use kahan_ecm::machine::presets::ivb;
use std::time::Instant;

fn main() {
    println!("=== bench_fig2: single-core working-set sweep (IVB, SP) ===\n");
    let m = ivb();
    let sizes: Vec<u64> = vec![
        8 << 10,
        16 << 10,
        24 << 10,
        32 << 10,
        48 << 10,
        64 << 10,
        128 << 10,
        256 << 10,
        512 << 10,
        1 << 20,
        4 << 20,
        12 << 20,
        16 << 20,
        64 << 20,
        256 << 20,
        512 << 20,
    ];
    let t0 = Instant::now();
    let series = experiments::fig2(&m, Precision::Sp, &sizes);
    let elapsed = t0.elapsed();
    println!("{}", experiments::fig2_table(&m, &series).render());

    // paper shape checks
    let get = |name: &str| series.iter().find(|s| s.kernel.contains(name)).unwrap();
    let avx = get("kahan-AVX");
    let naive = get("naive-AVX");
    let scalar = get("kahan-scalar");
    let last = sizes.len() - 1;
    let ratio_mem = avx.points[last].cy_per_cl / naive.points[last].cy_per_cl;
    assert!((0.95..=1.05).contains(&ratio_mem), "in-memory Kahan==naive: {ratio_mem}");
    let flat = scalar.points[last].cy_per_cl / scalar.points[0].cy_per_cl;
    assert!((0.9..=1.1).contains(&flat), "scalar flat across hierarchy: {flat}");
    println!(
        "bench_fig2: {} sizes x 4 kernels in {:.2} s — shape checks OK",
        sizes.len(),
        elapsed.as_secs_f64()
    );
}
