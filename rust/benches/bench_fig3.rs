//! Bench F3a/F3b: regenerate the in-memory multicore scaling figures on the
//! virtual IVB testbed (SP and DP) and check the saturation behaviour the
//! paper reports.

use kahan_ecm::coordinator::experiments;
use kahan_ecm::isa::Precision;
use kahan_ecm::machine::presets::ivb;
use std::time::Instant;

fn main() {
    let m = ivb();
    let t0 = Instant::now();
    for p in [Precision::Sp, Precision::Dp] {
        println!(
            "=== bench_fig3{}: in-memory scaling (IVB, {}) ===\n",
            if p == Precision::Sp { "a" } else { "b" },
            p.name()
        );
        let series = experiments::fig3(&m, p);
        println!("{}", experiments::fig3_table(&m, p, &series).render());

        let get = |name: &str| series.iter().find(|s| s.kernel.contains(name)).unwrap();
        let avx = get("kahan-AVX");
        let sat = avx.sim.iter().position(|pt| pt.bw_utilization >= 1.0).map(|i| i + 1);
        match p {
            Precision::Sp => {
                assert!(sat.unwrap_or(99) <= 5, "SP AVX saturates by ~4 cores: {sat:?}");
                let scalar = get("kahan-scalar");
                assert!(
                    scalar.sim.last().unwrap().bw_utilization < 1.0,
                    "SP scalar must NOT saturate on 10 cores"
                );
            }
            Precision::Dp => {
                let scalar = get("kahan-scalar");
                let ssat = scalar.sim.iter().position(|pt| pt.bw_utilization >= 1.0).map(|i| i + 1);
                assert!(
                    (5..=7).contains(&ssat.unwrap_or(99)),
                    "DP scalar saturates at ~6 cores: {ssat:?}"
                );
            }
        }
        // the compiler variant stays clearly below the saturated vectorized
        // kernels in both precisions (in DP the gap narrows: 8 iters/CL and
        // the same 12-cy chain leave it at ~1.8 vs 2.88 GUP/s)
        let comp = get("compiler");
        let frac = comp.sim.last().unwrap().gups / get("kahan-AVX").sim.last().unwrap().gups;
        assert!(frac < 0.75, "compiler variant at {frac:.2} of AVX");
    }
    println!("bench_fig3: both figures in {:.2} s — saturation checks OK", t0.elapsed().as_secs_f64());
}
