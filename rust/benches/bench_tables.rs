//! Bench T1/T2/EQ2: regenerate Table 1, Table 2 and the §3 model zoo, and
//! verify the paper strings appear. (criterion is unavailable offline; each
//! bench is a standalone harness that prints the paper's rows and wall
//! times.)

use kahan_ecm::coordinator::experiments;
use kahan_ecm::isa::Precision;
use kahan_ecm::machine::all_presets;
use std::time::Instant;

fn main() {
    println!("=== bench_tables: Table 1 / Table 2 / §3 models ===\n");

    let t0 = Instant::now();
    let t1 = experiments::table1();
    println!("{}", t1.render());

    let t2 = experiments::table2();
    println!("{}", t2.render());

    for m in all_presets() {
        println!("{}", experiments::models_table(&m, Precision::Sp).render());
    }
    println!("{}", experiments::models_table(&kahan_ecm::machine::presets::ivb(), Precision::Dp).render());

    let elapsed = t0.elapsed();
    // sanity: the flagship strings must be present
    let rendered = t2.render();
    assert!(rendered.contains("{4.40 | 4.40 | 2.93 | 1.68}"), "IVB row");
    assert!(rendered.contains("{3.60 | 3.60 | 3.60 | 1.80}"), "BDW row");
    println!("bench_tables: regenerated all tables in {:.1} ms — OK", elapsed.as_secs_f64() * 1e3);
}
