//! Layer-3 coordinator: the experiment registry that regenerates every
//! table/figure of the paper, the validation harness that compares against
//! the paper's published numbers, reporting, and the batched-dot service
//! that executes PJRT artifacts (the end-to-end driver's engine).

pub mod ablation;
pub mod cli;
pub mod experiments;
pub mod report;
pub mod service;
pub mod validate;

pub use cli::cli_main;
pub use service::{
    Backend, DotClient, DotRequest, DotResponse, DotService, LaneStats, RetryBudget,
    ServiceConfig, ServiceError, ServiceStats,
};
