//! Validation harness: every number the paper prints, compared against what
//! this reproduction generates — the machine-checkable form of
//! EXPERIMENTS.md.

use crate::ecm;
use crate::isa::{generate, Precision, Simd, Variant};
use crate::machine::presets::*;
use crate::sim;

/// One validation check.
#[derive(Clone, Debug)]
pub struct Check {
    pub name: String,
    pub expected: f64,
    pub got: f64,
    /// relative tolerance
    pub tol: f64,
}

impl Check {
    pub fn pass(&self) -> bool {
        if self.expected == 0.0 {
            return self.got.abs() <= self.tol;
        }
        ((self.got - self.expected) / self.expected).abs() <= self.tol
    }
}

fn check(name: impl Into<String>, expected: f64, got: f64, tol: f64) -> Check {
    Check { name: name.into(), expected, got, tol }
}

/// Run every paper-number validation; returns all checks (pass/fail).
pub fn run_all() -> Vec<Check> {
    let mut cs: Vec<Check> = Vec::new();

    // ---- Eq. 2: naive AVX SP on IVB ----
    let m = ivb();
    let naive = generate(Variant::Naive, Simd::Avx, Precision::Sp, 0);
    let e = ecm::build(&m, &naive, true);
    for (i, want) in [8.80, 4.40, 2.93, 1.68].iter().enumerate() {
        cs.push(check(format!("Eq2 naive-AVX IVB perf level {i}"), *want, e.perf_gups(i), 0.01));
    }
    cs.push(check("naive IVB n_S", 4.0, e.saturation_cores() as f64, 0.0));
    cs.push(check("naive IVB roofline P_BW", 5.76, e.roofline_gups(), 0.01));

    // ---- §3 scalar/SSE predictions on IVB ----
    let scalar = generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0);
    let e = ecm::build(&m, &scalar, true);
    cs.push(check("kahan-scalar IVB flat cycles", 64.0, e.prediction(3), 0.001));
    cs.push(check("kahan-scalar IVB perf", 0.55, e.perf_gups(0), 0.01));
    cs.push(check("kahan-scalar IVB n_S", 11.0, e.saturation_cores() as f64, 0.0));
    let sse = generate(Variant::Kahan, Simd::Sse, Precision::Sp, 0);
    let e = ecm::build(&m, &sse, true);
    cs.push(check("kahan-SSE IVB L1..L3 cycles", 16.0, e.prediction(2), 0.001));
    cs.push(check("kahan-SSE IVB perf L1", 2.20, e.perf_gups(0), 0.01));

    // ---- DP scalar on IVB ----
    let dp = generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0);
    let e = ecm::build(&m, &dp, true);
    cs.push(check("kahan-scalar DP IVB cycles", 32.0, e.prediction(3), 0.001));
    cs.push(check("kahan-scalar DP IVB n_S", 6.0, e.saturation_cores() as f64, 0.0));
    cs.push(check("DP roofline", 2.88, e.roofline_gups(), 0.01));

    // ---- Table 2: AVX Kahan across machines ----
    let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    let rows: [(&str, crate::machine::Machine, [f64; 4], [f64; 4]); 4] = [
        ("SNB", snb(), [8.0, 8.0, 12.0, 25.0], [5.40, 5.40, 3.60, 1.73]),
        ("IVB", ivb(), [8.0, 8.0, 12.0, 21.0], [4.40, 4.40, 2.93, 1.68]),
        ("HSW", hsw(), [8.0, 8.0, 9.54, 25.54], [4.60, 4.60, 3.86, 1.44]),
        ("BDW", bdw(), [8.0, 8.0, 8.0, 16.0], [3.60, 3.60, 3.60, 1.80]),
    ];
    for (name, mach, cy, perf) in rows {
        let e = ecm::build(&mach, &k, true);
        for i in 0..4 {
            cs.push(check(format!("T2 {name} cycles level {i}"), cy[i], e.prediction(i), 0.01));
            cs.push(check(format!("T2 {name} perf level {i}"), perf[i], e.perf_gups(i), 0.01));
        }
    }

    // ---- §4 FMA claim: ~20% in L1, none beyond (model) ----
    let mh = hsw();
    let add = ecm::build(&mh, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), true);
    let fma = ecm::build(&mh, &generate(Variant::KahanFma, Simd::Avx, Precision::Sp, 0), true);
    cs.push(check("FMA L1 speedup (HSW)", 1.20, add.prediction(0) / fma.prediction(0), 0.05));
    cs.push(check("FMA mem speedup (HSW)", 1.00, add.prediction(3) / fma.prediction(3), 0.02));

    // ---- headline (simulated measurement): Kahan AVX / naive AVX ----
    let kavx = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    let l2 = 128 * 1024u64;
    let mem = 512 * 1024 * 1024u64;
    let l1 = 16 * 1024u64;
    let r = |kern: &crate::isa::KernelDesc, ws: u64| {
        sim::simulate_working_set(&m, kern, ws / kern.bytes_per_iter(), true).cy_per_cl
    };
    cs.push(check("headline: kahan/naive in L2", 1.0, r(&kavx, l2) / r(&naive, l2), 0.08));
    cs.push(check("headline: kahan/naive in mem", 1.0, r(&kavx, mem) / r(&naive, mem), 0.05));
    cs.push(check("headline: kahan/naive in L1", 2.0, r(&kavx, l1) / r(&naive, l1), 0.15));

    // ---- scaling (simulated): saturation points ----
    let elems = 64 * 1024 * 1024u64;
    let pts = sim::simulate_scaling(&m, &kavx, elems, m.cores);
    cs.push(check(
        "Fig3a AVX observed saturation cores",
        4.0,
        sim::multicore::observed_saturation(&pts) as f64,
        0.3,
    ));
    cs.push(check("Fig3a AVX saturated GUP/s", 5.76, pts.last().unwrap().gups, 0.05));
    let dp_pts = sim::simulate_scaling(&m, &dp, elems, m.cores);
    cs.push(check(
        "Fig3b DP scalar observed saturation",
        6.0,
        sim::multicore::observed_saturation(&dp_pts) as f64,
        0.2,
    ));

    cs
}

/// Render the checks as a report table; returns (table, all_passed).
pub fn report() -> (crate::util::Table, bool) {
    let checks = run_all();
    let mut t = crate::util::Table::new("Validation: paper-published numbers vs this reproduction")
        .headers(["check", "paper", "ours", "rel.err", "ok"]);
    let mut all = true;
    for c in &checks {
        let rel = if c.expected != 0.0 { (c.got - c.expected) / c.expected } else { c.got };
        all &= c.pass();
        t.row([
            c.name.clone(),
            format!("{:.4}", c.expected),
            format!("{:.4}", c.got),
            format!("{:+.2}%", rel * 100.0),
            if c.pass() { "PASS".into() } else { "FAIL".to_string() },
        ]);
    }
    (t, all)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The single most important test in the repository: every number the
    /// paper publishes is reproduced within tolerance.
    #[test]
    fn all_paper_numbers_validate() {
        let checks = run_all();
        assert!(checks.len() > 50, "expected a thorough check list, got {}", checks.len());
        let failed: Vec<String> = checks
            .iter()
            .filter(|c| !c.pass())
            .map(|c| format!("{}: want {} got {:.4}", c.name, c.expected, c.got))
            .collect();
        assert!(failed.is_empty(), "failed checks:\n{}", failed.join("\n"));
    }

    #[test]
    fn check_pass_logic() {
        assert!(check("x", 1.0, 1.005, 0.01).pass());
        assert!(!check("x", 1.0, 1.02, 0.01).pass());
        assert!(check("zero", 0.0, 0.0005, 0.001).pass());
    }
}
