//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **Unrolling** — the paper asserts "proper modulo unrolling for best
//!    pipeline utilization" everywhere. Sweep the unroll factor and watch
//!    the ADD-latency chain dominate until enough accumulator slots exist
//!    (and the FMA variant hit the register wall).
//! 2. **Miss-handling overheads** — the simulator's only non-Table-1
//!    constants (`sim::params`). Zeroing them collapses simulation onto the
//!    analytic model, demonstrating they are what reproduces the paper's
//!    measured-vs-model gaps (and nothing else is fitted).
//! 3. **Batching window** — the serving-side knob: PJRT-call reduction as a
//!    function of max batch size.

use crate::ecm;
use crate::isa::{generate_ext, KernelDesc, Precision, Simd, Variant};
use crate::machine::Machine;
use crate::sim;
use crate::util::Table;

/// Unroll ablation: ECM L1 prediction and scoreboard steady state vs the
/// unroll factor, for the Kahan AVX and Kahan-FMA kernels.
pub fn unroll_ablation(machine: &Machine, prec: Precision) -> Table {
    let mut t = Table::new(&format!(
        "Ablation: unroll factor vs in-core cy/unit on {} ({})",
        machine.shorthand,
        prec.name()
    ))
    .headers(["unroll (units)", "kahan-AVX model", "kahan-AVX scoreboard", "kahan-FMA model", "kahan-FMA scoreboard", "slots (AVX/FMA)"]);
    for unroll in 1..=8usize {
        let ka = generate_ext(Variant::Kahan, Simd::Avx, prec, unroll, None);
        let kf = generate_ext(Variant::KahanFma, Simd::Avx, prec, unroll, None);
        let ea = ecm::build(machine, &ka, true).prediction(0);
        let ef = ecm::build(machine, &kf, true).prediction(0);
        let sa = sim::core::steady_state_cycles_per_unit(&machine.core, &ka);
        let sf = sim::core::steady_state_cycles_per_unit(&machine.core, &kf);
        t.row([
            unroll.to_string(),
            format!("{ea:.2}"),
            format!("{sa:.2}"),
            format!("{ef:.2}"),
            format!("{sf:.2}"),
            format!("{}/{}", ka.slots, kf.slots),
        ]);
    }
    t
}

/// Miss-overhead ablation: simulated cy/CL with the per-socket overheads
/// vs. with them zeroed, against the pure model — at the L2/L3 working
/// sets where the paper's measurements deviate from prediction.
pub fn overhead_ablation(machine: &Machine, kernel: &KernelDesc) -> Table {
    let mut t = Table::new(&format!(
        "Ablation: miss-handling overheads on {} ({})",
        machine.shorthand, kernel.name
    ))
    .headers(["WS", "model cy/CL", "sim (overheads on)", "sim (overheads off)"]);
    let e = ecm::build(machine, kernel, true);
    let cls = kernel.cls_per_unit() as f64;
    let ws = [
        (machine.caches[0].size_bytes / 2, 0usize),
        (machine.caches[1].size_bytes / 2, 1),
        (machine.caches[2].size_bytes / 2, 2),
        (8 * machine.llc_bytes(), 3),
    ];
    for (bytes, level) in ws {
        let elems = bytes / kernel.bytes_per_iter();
        let on = sim::simulate_working_set(machine, kernel, elems, true);
        let off = sim::engine::simulate_working_set_no_overhead(machine, kernel, elems, true);
        t.row([
            crate::util::fmt::bytes(bytes),
            format!("{:.2}", e.prediction(level) / cls),
            format!("{:.2}", on.cy_per_cl),
            format!("{:.2}", off.cy_per_cl),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::generate;
    use crate::machine::presets::{hsw, ivb};

    #[test]
    fn unroll_ablation_shows_latency_hiding() {
        let t = unroll_ablation(&ivb(), Precision::Sp);
        assert_eq!(t.n_rows(), 8);
        let r = t.render();
        // unroll 1 (2 slots) is chain-bound at 12 cy; >= 2 units reaches the
        // ADD-port bound of 8 cy
        assert!(r.contains("12"), "chain-bound row missing:\n{r}");
        assert!(r.contains("8.00") || r.contains(" 8 "), "port-bound rows missing:\n{r}");
    }

    #[test]
    fn fma_never_beats_port_bound_beyond_register_wall() {
        // on HSW the FMA variant is capped at 6 slots: more unroll must not
        // help below the 20-cy-chain/6-slot floor
        let m = hsw();
        let k6 = generate_ext(Variant::KahanFma, Simd::Avx, Precision::Sp, 3, None);
        let k8 = generate_ext(Variant::KahanFma, Simd::Avx, Precision::Sp, 8, None);
        let e6 = ecm::build(&m, &k6, true).prediction(0);
        let e8 = ecm::build(&m, &k8, true).prediction(0);
        assert!((e6 - e8).abs() < 1e-9, "register wall: {e6} vs {e8}");
        assert_eq!(k8.slots, 6);
    }

    #[test]
    fn overhead_ablation_collapses_onto_model() {
        let m = ivb();
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        let e = ecm::build(&m, &k, true);
        let cls = k.cls_per_unit() as f64;
        // with overheads off, the L2 point sits on the model prediction
        let elems = m.caches[1].size_bytes / 2 / k.bytes_per_iter();
        let off = sim::engine::simulate_working_set_no_overhead(&m, &k, elems, true);
        let pred = e.prediction(1) / cls;
        assert!(
            (off.cy_per_cl - pred).abs() / pred < 0.05,
            "no-overhead sim {} vs model {pred}",
            off.cy_per_cl
        );
        // with overheads on, it sits visibly above (the paper's gap)
        let on = sim::simulate_working_set(&m, &k, elems, true);
        assert!(on.cy_per_cl > pred * 1.05);
    }
}
