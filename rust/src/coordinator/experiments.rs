//! The experiment registry: one function per paper artifact (tables,
//! figures, discussion claims), each producing render-ready data.
//!
//! Experiment index (DESIGN.md §4): T1, EQ2/MODELS, T2, F2, F3a/b, F4a/b,
//! FMA, ACC, HOST.

use crate::ecm::{self, notation};
use crate::isa::{self, compiler_kahan, generate, KernelDesc, Precision, Simd, Variant};
use crate::machine::{all_presets, Machine};
use crate::sim;
use crate::util::{fmt, Table};

/// Table 1: the testbed description, straight from the machine models.
pub fn table1() -> Table {
    let machines = all_presets();
    let mut t = Table::new("Table 1: Test machine specifications (one socket)")
        .headers(["Microarchitecture", "SNB", "IVB", "HSW", "BDW"]);
    let row = |label: &str, f: &dyn Fn(&Machine) -> String| {
        let mut cells = vec![label.to_string()];
        cells.extend(machines.iter().map(|m| f(m)));
        cells
    };
    t.row(row("Xeon model", &|m| m.xeon_model.to_string()));
    t.row(row("Year", &|m| m.year.to_string()));
    t.row(row("Clock (fixed)", &|m| format!("{} GHz", m.clock_ghz)));
    t.row(row("Cores/Threads", &|m| format!("{}/{}", m.cores, m.threads)));
    t.row(row("L1 load ports", &|m| {
        format!("{}x{} B", m.core.load_ports, m.core.load_port_bytes)
    }));
    t.row(row("ADD throughput", &|m| format!("{} / cy", m.core.add_ports)));
    t.row(row("MUL throughput", &|m| format!("{} / cy", m.core.mul_ports)));
    t.row(row("FMA throughput", &|m| {
        if m.core.fma_ports == 0 { "n/a".into() } else { format!("{} / cy", m.core.fma_ports) }
    }));
    t.row(row("L2-L1 bus", &|m| format!("{} B/cy", m.caches[1].bytes_per_cy_to_inner)));
    t.row(row("L3-L2 bus", &|m| format!("{} B/cy", m.caches[2].bytes_per_cy_to_inner)));
    t.row(row("LLC size", &|m| fmt::bytes(m.llc_bytes())));
    t.row(row("Main memory", &|m| m.dram.to_string()));
    t.row(row("Peak BW", &|m| format!("{} GB/s", m.memory.peak_bw_gbs)));
    t.row(row("Load-only BW", &|m| format!("{} GB/s", m.memory.load_bw_gbs)));
    t.row(row("T_L3Mem per CL", &|m| format!("{} cy", fmt::cy(m.t_l3mem_per_cl()))));
    t
}

/// The §3 kernel set for one precision, including the FMA variant when the
/// machine has FMA pipes.
pub fn kernel_set(machine: &Machine, prec: Precision) -> Vec<KernelDesc> {
    let mut ks = isa::paper_kernels(prec);
    ks.push(compiler_kahan(prec));
    if machine.core.fma_ports > 0 {
        ks.push(generate(Variant::KahanFma, Simd::Avx, prec, 0));
    }
    ks
}

/// §3 / Eq. 2: full ECM models for every kernel variant on one machine.
pub fn models_table(machine: &Machine, prec: Precision) -> Table {
    let mut t = Table::new(&format!(
        "ECM models on {} ({}, single core)",
        machine.shorthand,
        prec.name()
    ))
    .headers(["Kernel", "ECM model [cy]", "Prediction [cy]", "Perf [GUP/s]", "n_S", "P_BW [GUP/s]"]);
    for k in kernel_set(machine, prec) {
        let e = ecm::build(machine, &k, true);
        t.row([
            k.name.clone(),
            notation::format_model(&e),
            notation::format_prediction(&e),
            notation::format_perf(&e),
            e.saturation_cores().to_string(),
            fmt::perf(e.roofline_gups()),
        ]);
    }
    t
}

/// Table 2: the AVX Kahan model across all four sockets.
pub fn table2() -> Table {
    let mut t = Table::new("Table 2: ECM models for the AVX Kahan dot (SP) across Xeons")
        .headers(["", "ECM model [cy]", "Prediction [cy/CL-pair]", "Pred. perf [GUP/s]", "n_S"]);
    let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
    for m in all_presets() {
        let e = ecm::build(&m, &k, true);
        t.row([
            m.shorthand.to_string(),
            notation::format_model(&e),
            notation::format_prediction(&e),
            notation::format_perf(&e),
            e.saturation_cores().to_string(),
        ]);
    }
    t
}

/// One Fig. 2 series: the simulated single-core sweep for one kernel.
pub struct SweepSeries {
    pub kernel: String,
    pub points: Vec<sim::SweepPoint>,
    /// ECM cycle-per-CL predictions per residence level (horizontal lines)
    pub model_cy_per_cl: [f64; 4],
}

/// Fig. 2: single-core cycles/CL vs data-set size on one machine.
pub fn fig2(machine: &Machine, prec: Precision, sizes: &[u64]) -> Vec<SweepSeries> {
    let kernels = [
        generate(Variant::Naive, Simd::Avx, prec, 0),
        generate(Variant::Kahan, Simd::Scalar, prec, 0),
        generate(Variant::Kahan, Simd::Sse, prec, 0),
        generate(Variant::Kahan, Simd::Avx, prec, 0),
    ];
    kernels
        .into_iter()
        .map(|k| {
            let e = ecm::build(machine, &k, true);
            let cls = k.cls_per_unit() as f64;
            let model = [
                e.prediction(0) / cls,
                e.prediction(1) / cls,
                e.prediction(2) / cls,
                e.prediction(3) / cls,
            ];
            SweepSeries {
                kernel: k.name.clone(),
                points: sim::simulate_sweep(machine, &k, sizes, true),
                model_cy_per_cl: model,
            }
        })
        .collect()
}

/// Render a Fig. 2 result as a table (one row per size, one column per
/// kernel).
pub fn fig2_table(machine: &Machine, series: &[SweepSeries]) -> Table {
    let mut t = Table::new(&format!(
        "Fig. 2: single-core cy/CL vs working set on {} (sim | model-L1..Mem in header)",
        machine.shorthand
    ));
    let mut headers = vec!["WS".to_string()];
    for s in series {
        headers.push(format!(
            "{} (model {} | {} | {} | {})",
            s.kernel,
            fmt::cy(s.model_cy_per_cl[0]),
            fmt::cy(s.model_cy_per_cl[1]),
            fmt::cy(s.model_cy_per_cl[2]),
            fmt::cy(s.model_cy_per_cl[3])
        ));
    }
    let mut t2 = std::mem::replace(&mut t, Table::new("")).headers(headers);
    if let Some(first) = series.first() {
        for (i, p) in first.points.iter().enumerate() {
            let mut row = vec![fmt::bytes(p.ws_bytes)];
            for s in series {
                row.push(format!("{:.2}", s.points[i].cy_per_cl));
            }
            t2.row(row);
        }
    }
    t2
}

/// One Fig. 3 series: simulated multicore scaling plus the model curve.
pub struct ScalingSeries {
    pub kernel: String,
    pub sim: Vec<sim::multicore::ScalePoint>,
    pub model: Vec<ecm::scaling::ScalingPoint>,
    pub model_saturation: u32,
}

/// Figs. 3a/3b: in-memory scaling on one machine for the Kahan variants
/// (scalar / SSE / AVX / compiler) plus naive AVX.
pub fn fig3(machine: &Machine, prec: Precision) -> Vec<ScalingSeries> {
    let elems_mem = (8 * machine.llc_bytes() / prec.elem_bytes() as u64).max(1 << 24);
    let mut kernels = vec![
        generate(Variant::Naive, Simd::Avx, prec, 0),
        generate(Variant::Kahan, Simd::Scalar, prec, 0),
        generate(Variant::Kahan, Simd::Sse, prec, 0),
        generate(Variant::Kahan, Simd::Avx, prec, 0),
        compiler_kahan(prec),
    ];
    kernels
        .drain(..)
        .map(|k| {
            let e = ecm::build(machine, &k, false);
            ScalingSeries {
                kernel: k.name.clone(),
                sim: sim::simulate_scaling(machine, &k, elems_mem, machine.cores),
                model: ecm::scaling::curve(&e, machine.cores).points,
                model_saturation: e.saturation_cores(),
            }
        })
        .collect()
}

pub fn fig3_table(machine: &Machine, prec: Precision, series: &[ScalingSeries]) -> Table {
    let mut headers = vec!["cores".to_string()];
    for s in series {
        headers.push(format!("{} sim", s.kernel));
        headers.push(format!("{} model", s.kernel));
    }
    let mut t = Table::new(&format!(
        "Fig. 3{}: in-memory scaling on {} [GUP/s]",
        if prec == Precision::Sp { "a (SP)" } else { "b (DP)" },
        machine.shorthand
    ))
    .headers(headers);
    for n in 0..machine.cores as usize {
        let mut row = vec![(n + 1).to_string()];
        for s in series {
            row.push(fmt::perf(s.sim[n].gups));
            row.push(fmt::perf(s.model[n].gups));
        }
        t.row(row);
    }
    t
}

/// Fig. 4a: single-core cycles/CL per memory level for the AVX Kahan kernel
/// on every socket, with the saturation point annotation.
pub struct Fig4aRow {
    pub arch: &'static str,
    /// simulated cy/CL at representative L1/L2/L3/Mem working sets
    pub sim_cy_per_cl: [f64; 4],
    /// ECM model cy/CL
    pub model_cy_per_cl: [f64; 4],
    pub n_s: u32,
}

pub fn fig4a(prec: Precision) -> Vec<Fig4aRow> {
    let k = generate(Variant::Kahan, Simd::Avx, prec, 0);
    all_presets()
        .into_iter()
        .map(|m| {
            let e = ecm::build(&m, &k, true);
            let cls = k.cls_per_unit() as f64;
            // representative working sets per level: half of L1, half of L2,
            // half of L3, 8x LLC
            let ws = [
                m.caches[0].size_bytes / 2,
                m.caches[1].size_bytes / 2,
                m.caches[2].size_bytes / 2,
                8 * m.llc_bytes(),
            ];
            let mut sim_vals = [0.0f64; 4];
            for (i, w) in ws.iter().enumerate() {
                let elems = w / k.bytes_per_iter();
                sim_vals[i] = sim::simulate_working_set(&m, &k, elems, true).cy_per_cl;
            }
            Fig4aRow {
                arch: m.shorthand,
                sim_cy_per_cl: sim_vals,
                model_cy_per_cl: [
                    e.prediction(0) / cls,
                    e.prediction(1) / cls,
                    e.prediction(2) / cls,
                    e.prediction(3) / cls,
                ],
                n_s: e.saturation_cores(),
            }
        })
        .collect()
}

pub fn fig4a_table(rows: &[Fig4aRow]) -> Table {
    let mut t = Table::new("Fig. 4a: AVX Kahan (SP) single-core cy/CL per level, sim (model)")
        .headers(["Arch", "L1", "L2", "L3", "Mem", "n_S"]);
    for r in rows {
        t.row([
            r.arch.to_string(),
            format!("{:.2} ({})", r.sim_cy_per_cl[0], fmt::cy(r.model_cy_per_cl[0])),
            format!("{:.2} ({})", r.sim_cy_per_cl[1], fmt::cy(r.model_cy_per_cl[1])),
            format!("{:.2} ({})", r.sim_cy_per_cl[2], fmt::cy(r.model_cy_per_cl[2])),
            format!("{:.2} ({})", r.sim_cy_per_cl[3], fmt::cy(r.model_cy_per_cl[3])),
            r.n_s.to_string(),
        ]);
    }
    t
}

/// Fig. 4b: in-memory scaling of AVX Kahan (SP) on all four sockets.
pub fn fig4b(prec: Precision) -> Vec<(String, Vec<sim::multicore::ScalePoint>)> {
    let k = generate(Variant::Kahan, Simd::Avx, prec, 0);
    all_presets()
        .into_iter()
        .map(|m| {
            let elems = (8 * m.llc_bytes() / prec.elem_bytes() as u64).max(1 << 24);
            let pts = sim::simulate_scaling(&m, &k, elems, m.cores);
            (m.shorthand.to_string(), pts)
        })
        .collect()
}

pub fn fig4b_table(series: &[(String, Vec<sim::multicore::ScalePoint>)]) -> Table {
    let max_cores = series.iter().map(|(_, p)| p.len()).max().unwrap_or(0);
    let mut headers = vec!["cores".to_string()];
    headers.extend(series.iter().map(|(n, _)| n.clone()));
    let mut t =
        Table::new("Fig. 4b: in-memory scaling, AVX Kahan SP [GUP/s]").headers(headers);
    for n in 0..max_cores {
        let mut row = vec![(n + 1).to_string()];
        for (_, pts) in series {
            row.push(pts.get(n).map(|p| fmt::perf(p.gups)).unwrap_or_default());
        }
        t.row(row);
    }
    t
}

/// §4 FMA study: Kahan-ADD vs Kahan-FMA on the FMA-capable sockets.
pub fn fma_study(prec: Precision) -> Table {
    let mut t = Table::new("FMA variant study (Kahan AVX vs Kahan FMA): cy/CL sim (model)")
        .headers(["Arch", "Level", "kahan-AVX", "kahan-FMA", "speedup"]);
    for m in all_presets().into_iter().filter(|m| m.core.fma_ports > 0) {
        let add = generate(Variant::Kahan, Simd::Avx, prec, 0);
        let fma = generate(Variant::KahanFma, Simd::Avx, prec, 0);
        let ws = [
            m.caches[0].size_bytes / 2,
            m.caches[1].size_bytes / 2,
            m.caches[2].size_bytes / 2,
            8 * m.llc_bytes(),
        ];
        for (level, w) in ["L1", "L2", "L3", "Mem"].iter().zip(ws) {
            let ea = sim::simulate_working_set(&m, &add, w / add.bytes_per_iter(), true);
            let ef = sim::simulate_working_set(&m, &fma, w / fma.bytes_per_iter(), true);
            t.row([
                m.shorthand.to_string(),
                level.to_string(),
                format!("{:.2}", ea.cy_per_cl),
                format!("{:.2}", ef.cy_per_cl),
                format!("{:.2}x", ea.cy_per_cl / ef.cy_per_cl),
            ]);
        }
    }
    t
}

/// ACC: the accuracy experiment (error vs condition number).
pub fn accuracy_table(n: usize, trials: usize) -> Table {
    let conds = [1e1, 1e4, 1e7, 1e10, 1e13];
    let rows = crate::accuracy::error_sweep(n, &conds, trials, 2024);
    let mut t = Table::new(&format!(
        "Accuracy: median relative error vs condition number (n={n}, {trials} trials, f32)"
    ))
    .headers(["algorithm", "cond 1e1", "cond 1e4", "cond 1e7", "cond 1e10", "cond 1e13"]);
    for (name, _) in crate::accuracy::analysis::algorithm_list() {
        let mut row = vec![name.to_string()];
        for &c in &conds {
            let r = rows
                .iter()
                .find(|r| r.algo == name && r.target_cond == c)
                .expect("row");
            row.push(format!("{:.2e}", r.median_rel_err));
        }
        t.row(row);
    }
    t
}

/// HOST: sweep the host kernels (likwid-bench analog on this machine).
pub fn host_sweep_table(reps: usize, quick: bool) -> Table {
    let sizes = if quick {
        vec![16 * 1024, 256 * 1024, 4 * 1024 * 1024, 48 * 1024 * 1024]
    } else {
        crate::bench::sweep::default_sizes()
    };
    let kernels: Vec<_> = crate::bench::registry()
        .into_iter()
        .filter(|k| k.available && k.prec == Precision::Sp)
        .collect();
    let mut t = Table::new("Host sweep: cycles per cache line (TSC cycles)");
    let mut headers = vec!["WS".to_string()];
    headers.extend(kernels.iter().map(|k| k.name.to_string()));
    let mut t2 = std::mem::replace(&mut t, Table::new("")).headers(headers);
    let series: Vec<Vec<crate::bench::HostSweepPoint>> = kernels
        .iter()
        .map(|k| crate::bench::run_sweep(k, &sizes, reps, 7))
        .collect();
    for (i, &ws) in sizes.iter().enumerate() {
        let mut row = vec![fmt::bytes(ws)];
        for s in &series {
            row.push(format!("{:.2}", s[i].cy_per_cl));
        }
        t2.row(row);
    }
    t2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::presets::ivb;

    #[test]
    fn table1_shape() {
        let t = table1();
        let r = t.render();
        assert!(r.contains("E5-2690 v2"));
        assert!(r.contains("D-1540"));
        assert!(r.contains("3.96")); // SNB T_L3Mem per CL
    }

    #[test]
    fn table2_contains_paper_strings() {
        let r = table2().render();
        assert!(r.contains("{8 || 4 | 4 | 4 |"), "{r}");
        assert!(r.contains("{4.40 | 4.40 | 2.93 | 1.68}"), "{r}");
        assert!(r.contains("{3.60 | 3.60 | 3.60 | 1.80}"), "{r}"); // BDW
    }

    #[test]
    fn models_table_has_all_variants() {
        let r = models_table(&ivb(), Precision::Sp).render();
        for name in ["naive-AVX-SP", "kahan-scalar-SP", "kahan-SSE-SP", "kahan-AVX-SP", "kahan-compiler-SP"] {
            assert!(r.contains(name), "missing {name} in\n{r}");
        }
        // IVB has no FMA ports -> no FMA row
        assert!(!r.contains("kahan-fma"));
    }

    #[test]
    fn fig2_series_and_table() {
        let m = ivb();
        let sizes = vec![16 * 1024, 256 * 1024, 4 * 1024 * 1024];
        let s = fig2(&m, Precision::Sp, &sizes);
        assert_eq!(s.len(), 4);
        let t = fig2_table(&m, &s);
        assert_eq!(t.n_rows(), sizes.len());
    }

    #[test]
    fn fig4a_rows_have_saturation_points() {
        let rows = fig4a(Precision::Sp);
        assert_eq!(rows.len(), 4);
        let ivb_row = rows.iter().find(|r| r.arch == "IVB").unwrap();
        assert_eq!(ivb_row.n_s, 4);
        // L1 is ADD-bound everywhere: all four archs show 4 cy/CL
        for r in &rows {
            assert!((r.sim_cy_per_cl[0] - 4.0).abs() < 0.5, "{}: {:?}", r.arch, r.sim_cy_per_cl);
        }
    }

    #[test]
    fn fma_study_l1_speedup_present() {
        let t = fma_study(Precision::Sp).render();
        assert!(t.contains("HSW"));
        assert!(t.contains("BDW"));
    }
}
