//! Batched dot service: the request-path component that executes AOT
//! artifacts via PJRT with dynamic batching — the engine behind the
//! end-to-end example (`examples/e2e_serve.rs`).
//!
//! Architecture (std-only; the offline container has no tokio):
//! * callers submit `DotRequest`s over an mpsc channel and receive their
//!   `DotResponse` on a per-request return channel;
//! * one worker thread owns the PJRT `Runtime` (executables are not shared
//!   across threads), drains the queue with a batching window, groups
//!   compatible requests (same variant, fits the batched artifact), and
//!   executes them in one PJRT call when possible;
//! * Python is never involved: this is the "self-contained rust binary"
//!   property of the three-layer design.

use crate::runtime::Runtime;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Message to the worker: a request or an explicit shutdown (needed
/// because `DotClient` clones keep the channel alive — dropping the
/// service's own sender alone would never disconnect the worker).
enum Msg {
    Req(DotRequest),
    Shutdown,
}

/// A dot-product request.
pub struct DotRequest {
    pub id: u64,
    /// "kahan" or "naive"
    pub variant: &'static str,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    reply: mpsc::Sender<DotResponse>,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct DotResponse {
    pub id: u64,
    pub value: Result<f32, String>,
    /// how many requests shared the PJRT call that served this one
    pub batch_size: usize,
    /// queue + execute time
    pub latency: Duration,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// max requests fused into one batched execute
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub window: Duration,
    /// name of the batched artifact to use (must exist in the manifest)
    pub batched_artifact_kahan: String,
    pub batched_artifact_naive: String,
    /// single-request fallback artifacts
    pub single_artifact_kahan: String,
    pub single_artifact_naive: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            max_batch: 8,
            window: Duration::from_millis(2),
            batched_artifact_kahan: "batched_dot_kahan_f32_b8_n16384".into(),
            batched_artifact_naive: "batched_dot_naive_f32_b8_n16384".into(),
            single_artifact_kahan: "dot_kahan_f32_n65536".into(),
            single_artifact_naive: "dot_naive_f32_n65536".into(),
        }
    }
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    pub pjrt_calls: u64,
    pub batched_calls: u64,
    pub errors: u64,
}

/// Handle to a running service.
pub struct DotService {
    tx: Option<mpsc::Sender<Msg>>,
    worker: Option<std::thread::JoinHandle<ServiceStats>>,
}

/// Client-side handle for submitting requests.
#[derive(Clone)]
pub struct DotClient {
    tx: mpsc::Sender<Msg>,
}

impl DotClient {
    /// Submit a request; returns the receiver for its response.
    pub fn submit(
        &self,
        id: u64,
        variant: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        let req = DotRequest { id, variant, a, b, reply };
        // a send error means the service stopped; the caller sees it as a
        // disconnected receiver
        let _ = self.tx.send(Msg::Req(req));
        rx
    }

    /// Convenience: blocking round-trip.
    pub fn dot_blocking(&self, variant: &'static str, a: Vec<f32>, b: Vec<f32>) -> Result<f32, String> {
        let rx = self.submit(0, variant, a, b);
        match rx.recv() {
            Ok(resp) => resp.value,
            Err(_) => Err("service stopped".into()),
        }
    }
}

impl DotService {
    /// Start the worker thread with its own PJRT runtime.
    ///
    /// PJRT handles are not `Send`, so the `Runtime` must be constructed
    /// *inside* the worker thread; startup errors are relayed back through a
    /// one-shot channel so callers still see them synchronously.
    pub fn start(config: ServiceConfig) -> anyhow::Result<(Self, DotClient)> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let worker = std::thread::spawn(move || match Runtime::new() {
            Ok(rt) => {
                let _ = ready_tx.send(Ok(()));
                worker_loop(rt, rx, config)
            }
            Err(e) => {
                let _ = ready_tx.send(Err(e.to_string()));
                ServiceStats::default()
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = worker.join();
                anyhow::bail!("service startup: {e}");
            }
            Err(_) => {
                let _ = worker.join();
                anyhow::bail!("service worker died during startup");
            }
        }
        let client = DotClient { tx: tx.clone() };
        Ok((DotService { tx: Some(tx), worker: Some(worker) }, client))
    }

    /// Stop the service and return its statistics.
    pub fn stop(mut self) -> ServiceStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        self.worker.take().map(|w| w.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for DotService {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

struct Pending {
    req: DotRequest,
    arrived: Instant,
}

fn worker_loop(
    mut rt: Runtime,
    rx: mpsc::Receiver<Msg>,
    cfg: ServiceConfig,
) -> ServiceStats {
    let mut shutdown = false;
    let mut stats = ServiceStats::default();
    let batched_max_n = rt
        .manifest()
        .get(&cfg.batched_artifact_kahan)
        .map(|m| m.n)
        .unwrap_or(0);

    while !shutdown {
        // block for the first request
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
        };
        let mut queue = vec![Pending { req: first, arrived: Instant::now() }];
        // batching window: gather more requests
        let deadline = Instant::now() + cfg.window;
        while queue.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => queue.push(Pending { req: r, arrived: Instant::now() }),
                Ok(Msg::Shutdown) => {
                    // serve what we already accepted, then exit
                    shutdown = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // group by variant; batch-execute groups where every request fits
        for variant in ["kahan", "naive"] {
            let group: Vec<Pending> = {
                let mut g = Vec::new();
                let mut rest = Vec::new();
                for p in queue.drain(..) {
                    if p.req.variant == variant {
                        g.push(p);
                    } else {
                        rest.push(p);
                    }
                }
                queue = rest;
                g
            };
            if group.is_empty() {
                continue;
            }
            let (batched_name, single_name) = if variant == "kahan" {
                (&cfg.batched_artifact_kahan, &cfg.single_artifact_kahan)
            } else {
                (&cfg.batched_artifact_naive, &cfg.single_artifact_naive)
            };

            let fits = group.len() >= 2
                && batched_max_n > 0
                && group.iter().all(|p| p.req.a.len() <= batched_max_n);
            if fits {
                stats.pjrt_calls += 1;
                stats.batched_calls += 1;
                let pairs: Vec<(Vec<f32>, Vec<f32>)> =
                    group.iter().map(|p| (p.req.a.clone(), p.req.b.clone())).collect();
                match rt.batched_dot_f32(batched_name, &pairs) {
                    Ok(values) => {
                        let bsz = group.len();
                        for (p, v) in group.into_iter().zip(values) {
                            stats.requests += 1;
                            let _ = p.req.reply.send(DotResponse {
                                id: p.req.id,
                                value: Ok(v),
                                batch_size: bsz,
                                latency: p.arrived.elapsed(),
                            });
                        }
                    }
                    Err(e) => {
                        stats.errors += 1;
                        for p in group {
                            stats.requests += 1;
                            let _ = p.req.reply.send(DotResponse {
                                id: p.req.id,
                                value: Err(format!("batched execute: {e}")),
                                batch_size: 0,
                                latency: p.arrived.elapsed(),
                            });
                        }
                    }
                }
            } else {
                for p in group {
                    stats.requests += 1;
                    stats.pjrt_calls += 1;
                    let value = rt
                        .dot_f32(single_name, &p.req.a, &p.req.b)
                        .map_err(|e| e.to_string());
                    if value.is_err() {
                        stats.errors += 1;
                    }
                    let _ = p.req.reply.send(DotResponse {
                        id: p.req.id,
                        value,
                        batch_size: 1,
                        latency: p.arrived.elapsed(),
                    });
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    fn artifacts_present() -> bool {
        crate::runtime::artifacts_dir().join("manifest.tsv").exists()
    }

    #[test]
    fn service_round_trip_and_batching() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(5);
        let n = 2048;
        // submit a burst so the batcher can fuse them
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            expected.push(exact_dot_f32(&a, &b));
            rxs.push(client.submit(i, "kahan", a, b));
        }
        let mut batched_seen = false;
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, i as u64);
            let v = resp.value.expect("value") as f64;
            assert!((v - expected[i]).abs() < 1e-2, "req {i}: {v} vs {}", expected[i]);
            batched_seen |= resp.batch_size > 1;
        }
        let stats = svc.stop();
        assert_eq!(stats.requests, 6);
        assert!(stats.errors == 0);
        assert!(batched_seen, "burst of 6 should have batched at least once");
        assert!(stats.pjrt_calls < 6, "batching must reduce PJRT calls: {stats:?}");
    }

    #[test]
    fn naive_and_kahan_variants_route_correctly() {
        if !artifacts_present() {
            return;
        }
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let a = vec![1.0f32; 100];
        let b = vec![2.0f32; 100];
        let vk = client.dot_blocking("kahan", a.clone(), b.clone()).unwrap();
        let vn = client.dot_blocking("naive", a, b).unwrap();
        assert_eq!(vk, 200.0);
        assert_eq!(vn, 200.0);
        svc.stop();
    }

    #[test]
    fn oversized_request_errors_cleanly() {
        if !artifacts_present() {
            return;
        }
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let big = vec![0.0f32; 1 << 21]; // 2M > 65536 and > batched n
        let r = client.dot_blocking("kahan", big.clone(), big);
        assert!(r.is_err());
        svc.stop();
    }
}
