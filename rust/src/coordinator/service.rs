//! Batched dot service: the request-path component behind the end-to-end
//! example (`examples/e2e_serve.rs`).
//!
//! Two backends share one client API:
//!
//! * [`Backend::Host`] (default) — requests execute on the NUMA-sharded
//!   serving tier (`crate::engine::ShardedEngine`): one pinned worker pool
//!   + recycling 64-byte-aligned buffer pool per memory domain, autotuned
//!   SIMD kernel dispatch, and a shard router keyed on **admission
//!   locality** — streams admitted via [`DotClient::admit_blocking`]
//!   remember their home shard and every later pooled dot executes there
//!   (the data is already domain-local); fresh one-shot requests
//!   round-robin across shards, and very large ones split across every
//!   shard with a compensated cross-shard merge. Single-node hosts
//!   degrade to one shard. Works anywhere, no artifacts needed.
//! * [`Backend::Pjrt`] — the original PJRT path: one worker thread owns
//!   the `Runtime` (executables are not shared across threads), drains the
//!   queue with a batching window, groups compatible requests, and
//!   executes them in one PJRT call when possible. Needs AOT artifacts and
//!   the `pjrt` cargo feature.
//!
//! Architecture (std-only; the offline container has no tokio): callers
//! submit `DotRequest`s over an mpsc channel and receive their
//! `DotResponse` on a per-request return channel.

use crate::engine::{HomedSlice, ShardedEngine};
use crate::isa::Variant;
use crate::runtime::Runtime;
use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Message to the worker: a request, stream admission/release, or an
/// explicit shutdown (needed because `DotClient` clones keep the channel
/// alive — dropping the service's own sender alone would never disconnect
/// the worker).
enum Msg {
    Req(DotRequest),
    /// Admit a stream into the sharded engine's pooled storage; replies
    /// with the stream handle (Host backend only). `near` co-locates the
    /// stream on the home shard of an existing handle.
    Admit { data: Vec<f32>, near: Option<u64>, reply: mpsc::Sender<Result<u64, String>> },
    /// Dot two admitted streams on the home shard of `a` (Host backend
    /// only).
    ReqPooled {
        id: u64,
        variant: &'static str,
        a: u64,
        b: u64,
        reply: mpsc::Sender<DotResponse>,
        submitted: Instant,
    },
    /// Drop an admitted stream, returning its buffer to the shard pool.
    Release { handle: u64 },
    Shutdown,
}

/// Which execution path serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// persistent host engine (pooled buffers + pinned workers)
    #[default]
    Host,
    /// PJRT execution of the AOT artifacts (requires the `pjrt` feature)
    Pjrt,
}

/// A dot-product request.
pub struct DotRequest {
    pub id: u64,
    /// "kahan" or "naive"
    pub variant: &'static str,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    reply: mpsc::Sender<DotResponse>,
    /// stamped in `DotClient::submit`, so reported latency includes the
    /// time spent queued in the channel, not just the execute time
    submitted: Instant,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct DotResponse {
    pub id: u64,
    pub value: Result<f32, String>,
    /// how many requests shared the backend call that served this one
    pub batch_size: usize,
    /// queue + execute time
    pub latency: Duration,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub backend: Backend,
    /// max requests fused into one batched execute (Pjrt backend)
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch (Pjrt backend)
    pub window: Duration,
    /// name of the batched artifact to use (must exist in the manifest)
    pub batched_artifact_kahan: String,
    pub batched_artifact_naive: String,
    /// single-request fallback artifacts
    pub single_artifact_kahan: String,
    pub single_artifact_naive: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            backend: Backend::Host,
            max_batch: 8,
            window: Duration::from_millis(2),
            batched_artifact_kahan: "batched_dot_kahan_f32_b8_n16384".into(),
            batched_artifact_naive: "batched_dot_naive_f32_b8_n16384".into(),
            single_artifact_kahan: "dot_kahan_f32_n65536".into(),
            single_artifact_naive: "dot_naive_f32_n65536".into(),
        }
    }
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    /// engine executions (Host backend)
    pub engine_calls: u64,
    /// streams admitted into shard-local pooled storage (Host backend)
    pub admitted: u64,
    /// dots served over already-admitted streams on their home shard.
    /// (Cross-shard split counts live in `ShardedEngine::stats` — the
    /// engine is process-global, so a per-service delta would misattribute
    /// splits whenever two services or a direct engine user coexist.)
    pub pooled_calls: u64,
    pub pjrt_calls: u64,
    pub batched_calls: u64,
    pub errors: u64,
}

/// Handle to a running service.
pub struct DotService {
    tx: Option<mpsc::Sender<Msg>>,
    worker: Option<std::thread::JoinHandle<ServiceStats>>,
}

/// Client-side handle for submitting requests.
#[derive(Clone)]
pub struct DotClient {
    tx: mpsc::Sender<Msg>,
}

impl DotClient {
    /// Submit a request; returns the receiver for its response.
    pub fn submit(
        &self,
        id: u64,
        variant: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        let req = DotRequest { id, variant, a, b, reply, submitted: Instant::now() };
        // a send error means the service stopped; the caller sees it as a
        // disconnected receiver
        let _ = self.tx.send(Msg::Req(req));
        rx
    }

    /// Convenience: blocking round-trip.
    pub fn dot_blocking(&self, variant: &'static str, a: Vec<f32>, b: Vec<f32>) -> Result<f32, String> {
        let rx = self.submit(0, variant, a, b);
        match rx.recv() {
            Ok(resp) => resp.value,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Admit a stream into the serving tier's pooled shard-local storage
    /// and get back its handle. The stream's home shard is fixed at
    /// admission; every later [`DotClient::dot_pooled_blocking`] over it
    /// executes there (Host backend only — the PJRT worker rejects it).
    pub fn admit_blocking(&self, data: Vec<f32>) -> Result<u64, String> {
        self.admit_near_blocking(data, None)
    }

    /// Like [`DotClient::admit_blocking`], but co-locate the stream on the
    /// home shard of `near` (an earlier handle) — the placement for
    /// streams that will be dotted against each other, so the pair never
    /// crosses a NUMA domain. A `near` that no longer exists falls back to
    /// round-robin placement.
    pub fn admit_near_blocking(&self, data: Vec<f32>, near: Option<u64>) -> Result<u64, String> {
        let (reply, rx) = mpsc::channel();
        if self.tx.send(Msg::Admit { data, near, reply }).is_err() {
            return Err("service stopped".into());
        }
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Submit a dot over two admitted streams; returns the response
    /// receiver.
    pub fn submit_pooled(
        &self,
        id: u64,
        variant: &'static str,
        a: u64,
        b: u64,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Msg::ReqPooled { id, variant, a, b, reply, submitted: Instant::now() });
        rx
    }

    /// Convenience: blocking dot over two admitted streams.
    pub fn dot_pooled_blocking(&self, variant: &'static str, a: u64, b: u64) -> Result<f32, String> {
        let rx = self.submit_pooled(0, variant, a, b);
        match rx.recv() {
            Ok(resp) => resp.value,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Release an admitted stream (its buffer recycles into the home
    /// shard's pool). Unknown handles are ignored.
    pub fn release(&self, handle: u64) {
        let _ = self.tx.send(Msg::Release { handle });
    }
}

impl DotService {
    /// Start the worker thread for the configured backend.
    ///
    /// Host backend: the worker borrows the process-wide sharded engine
    /// (`ShardedEngine::global()`), so startup is immediate and cannot
    /// fail.
    ///
    /// Pjrt backend: PJRT handles are not `Send`, so the `Runtime` must be
    /// constructed *inside* the worker thread; startup errors are relayed
    /// back through a one-shot channel so callers still see them
    /// synchronously.
    pub fn start(config: ServiceConfig) -> anyhow::Result<(Self, DotClient)> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let worker = match config.backend {
            Backend::Host => std::thread::spawn(move || worker_loop_host(rx)),
            Backend::Pjrt => {
                let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
                let worker = std::thread::spawn(move || match Runtime::new() {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop_pjrt(rt, rx, config)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        ServiceStats::default()
                    }
                });
                match ready_rx.recv() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        let _ = worker.join();
                        anyhow::bail!("service startup: {e}");
                    }
                    Err(_) => {
                        let _ = worker.join();
                        anyhow::bail!("service worker died during startup");
                    }
                }
                worker
            }
        };
        let client = DotClient { tx: tx.clone() };
        Ok((DotService { tx: Some(tx), worker: Some(worker) }, client))
    }

    /// Stop the service and return its statistics.
    pub fn stop(mut self) -> ServiceStats {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        self.worker.take().map(|w| w.join().unwrap_or_default()).unwrap_or_default()
    }
}

impl Drop for DotService {
    fn drop(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn parse_variant(s: &str) -> Result<Variant, String> {
    match s {
        "kahan" => Ok(Variant::Kahan),
        "naive" => Ok(Variant::Naive),
        other => Err(format!("unknown variant `{other}`")),
    }
}

/// Host backend: the shard router. Every request runs on the NUMA-sharded
/// engine — fresh requests round-robin across shards (the engine splits
/// very large ones across all of them), admitted streams execute on their
/// home shard. No batching window — the engine parallelizes *within* a
/// dot, so queueing requests to fuse them would only add latency.
///
/// Length mismatches are rejected HERE, before the engine: the engine's
/// documented policy is debug-assert + truncate (see the engine module's
/// "Length policy"), so the service is the layer that turns a mismatch
/// into a client-visible error.
fn worker_loop_host(rx: mpsc::Receiver<Msg>) -> ServiceStats {
    let engine = ShardedEngine::global();
    // calibrate the dispatch table now, not on the first request
    let _ = crate::engine::dispatch();
    let mut stats = ServiceStats::default();
    // admitted streams: handle -> home-shard slice
    let mut streams: HashMap<u64, HomedSlice<f32>> = HashMap::new();
    let mut next_handle: u64 = 1;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Req(req) => {
                stats.requests += 1;
                let value = if req.a.len() != req.b.len() {
                    Err(format!("length mismatch {} vs {}", req.a.len(), req.b.len()))
                } else {
                    // no per-request heap churn: the engine reads the
                    // request's own vectors (small dots run on them in
                    // place; large dots pay one admission copy into the
                    // target shard's recycled aligned pool buffers)
                    parse_variant(req.variant).map(|v| {
                        stats.engine_calls += 1;
                        engine.dot_f32(v, &req.a, &req.b)
                    })
                };
                if value.is_err() {
                    stats.errors += 1;
                }
                let _ = req.reply.send(DotResponse {
                    id: req.id,
                    value,
                    batch_size: 1,
                    latency: req.submitted.elapsed(),
                });
            }
            Msg::Admit { data, near, reply } => {
                let handle = next_handle;
                next_handle += 1;
                let homed = match near.and_then(|h| streams.get(&h)) {
                    Some(neighbor) => engine.admit_to_f32(neighbor.shard, &data),
                    None => engine.admit_f32(&data),
                };
                streams.insert(handle, homed);
                stats.admitted += 1;
                let _ = reply.send(Ok(handle));
            }
            Msg::ReqPooled { id, variant, a, b, reply, submitted } => {
                stats.requests += 1;
                let value = match (streams.get(&a), streams.get(&b)) {
                    (Some(sa), Some(sb)) if sa.len() == sb.len() => {
                        parse_variant(variant).map(|v| {
                            stats.engine_calls += 1;
                            stats.pooled_calls += 1;
                            engine.dot_homed_f32(v, sa, sb)
                        })
                    }
                    (Some(sa), Some(sb)) => {
                        Err(format!("length mismatch {} vs {}", sa.len(), sb.len()))
                    }
                    _ => Err(format!("unknown stream handle {}", if streams.contains_key(&a) { b } else { a })),
                };
                if value.is_err() {
                    stats.errors += 1;
                }
                let _ = reply.send(DotResponse {
                    id,
                    value,
                    batch_size: 1,
                    latency: submitted.elapsed(),
                });
            }
            Msg::Release { handle } => {
                streams.remove(&handle);
            }
        }
    }
    stats
}

fn worker_loop_pjrt(
    mut rt: Runtime,
    rx: mpsc::Receiver<Msg>,
    cfg: ServiceConfig,
) -> ServiceStats {
    let mut shutdown = false;
    let mut stats = ServiceStats::default();
    let batched_max_n = rt
        .manifest()
        .get(&cfg.batched_artifact_kahan)
        .map(|m| m.n)
        .unwrap_or(0);

    // pooled-stream admission is a Host-backend feature: the PJRT worker
    // rejects it synchronously rather than pretending to hold streams
    let reject_pooled = |msg: Msg| match msg {
        Msg::Admit { reply, .. } => {
            let _ = reply.send(Err("stream admission requires the Host backend".into()));
        }
        Msg::ReqPooled { id, reply, submitted, .. } => {
            let _ = reply.send(DotResponse {
                id,
                value: Err("pooled dots require the Host backend".into()),
                batch_size: 0,
                latency: submitted.elapsed(),
            });
        }
        _ => {}
    };

    while !shutdown {
        // block for the first request
        let first = match rx.recv() {
            Ok(Msg::Req(r)) => r,
            Ok(Msg::Shutdown) | Err(_) => break,
            Ok(other) => {
                reject_pooled(other);
                continue;
            }
        };
        let mut queue = vec![first];
        // batching window: gather more requests
        let deadline = Instant::now() + cfg.window;
        while queue.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Msg::Req(r)) => queue.push(r),
                Ok(Msg::Shutdown) => {
                    // serve what we already accepted, then exit
                    shutdown = true;
                    break;
                }
                Ok(other) => reject_pooled(other),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // group by variant; batch-execute groups where every request fits
        for variant in ["kahan", "naive"] {
            let group: Vec<DotRequest> = {
                let mut g = Vec::new();
                let mut rest = Vec::new();
                for p in queue.drain(..) {
                    if p.variant == variant {
                        g.push(p);
                    } else {
                        rest.push(p);
                    }
                }
                queue = rest;
                g
            };
            if group.is_empty() {
                continue;
            }
            let (batched_name, single_name) = if variant == "kahan" {
                (&cfg.batched_artifact_kahan, &cfg.single_artifact_kahan)
            } else {
                (&cfg.batched_artifact_naive, &cfg.single_artifact_naive)
            };

            let fits = group.len() >= 2
                && batched_max_n > 0
                && group.iter().all(|p| p.a.len() <= batched_max_n);
            if fits {
                stats.pjrt_calls += 1;
                stats.batched_calls += 1;
                let pairs: Vec<(Vec<f32>, Vec<f32>)> =
                    group.iter().map(|p| (p.a.clone(), p.b.clone())).collect();
                match rt.batched_dot_f32(batched_name, &pairs) {
                    Ok(values) => {
                        let bsz = group.len();
                        for (p, v) in group.into_iter().zip(values) {
                            stats.requests += 1;
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Ok(v),
                                batch_size: bsz,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                    Err(e) => {
                        stats.errors += 1;
                        for p in group {
                            stats.requests += 1;
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Err(format!("batched execute: {e}")),
                                batch_size: 0,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                }
            } else {
                for p in group {
                    stats.requests += 1;
                    stats.pjrt_calls += 1;
                    let value = rt
                        .dot_f32(single_name, &p.a, &p.b)
                        .map_err(|e| e.to_string());
                    if value.is_err() {
                        stats.errors += 1;
                    }
                    let _ = p.reply.send(DotResponse {
                        id: p.id,
                        value,
                        batch_size: 1,
                        latency: p.submitted.elapsed(),
                    });
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::accuracy::gen_dot_f32;
    use crate::util::Rng;

    fn artifacts_present() -> bool {
        // the stub Runtime (no `pjrt` feature) fails closed, so the PJRT
        // tests must skip even when artifacts exist on disk
        cfg!(feature = "pjrt")
            && crate::runtime::artifacts_dir().join("manifest.tsv").exists()
    }

    fn pjrt_config() -> ServiceConfig {
        ServiceConfig { backend: Backend::Pjrt, ..ServiceConfig::default() }
    }

    // ---- Host backend (default): no artifacts needed ----

    #[test]
    fn host_backend_round_trip_matches_exact() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(5);
        let mut rxs = Vec::new();
        let mut expected = Vec::new();
        let mut scales = Vec::new();
        // mixed sizes: inline path and chunked-parallel path
        for (i, n) in [1000usize, 2048, 400_000].iter().enumerate() {
            let a = rng.normal_f32_vec(*n);
            let b = rng.normal_f32_vec(*n);
            expected.push(exact_dot_f32(&a, &b));
            scales.push(
                a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30),
            );
            rxs.push(client.submit(i as u64, if i == 1 { "naive" } else { "kahan" }, a, b));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, i as u64);
            let v = resp.value.expect("value") as f64;
            assert!(
                (v - expected[i]).abs() / scales[i] < 1e-4,
                "req {i}: {v} vs {}",
                expected[i]
            );
        }
        let stats = svc.stop();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.engine_calls, 3);
        assert_eq!(stats.pjrt_calls, 0);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn host_backend_kahan_survives_ill_conditioned_input() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(9);
        let (a, b, exact, _cond) = gen_dot_f32(4096, 1e6, &mut rng);
        let absdot: f64 =
            a.iter().zip(&b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum::<f64>().max(1e-30);
        let v = client.dot_blocking("kahan", a, b).unwrap() as f64;
        assert!(
            (v - exact).abs() / absdot < 1e-5,
            "kahan service result must stay within the Kahan bound: {v} vs {exact}"
        );
        svc.stop();
    }

    #[test]
    fn host_backend_rejects_length_mismatch() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let r = client.dot_blocking("kahan", vec![0.0; 10], vec![0.0; 11]);
        assert!(r.is_err());
        let stats = svc.stop();
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn host_backend_pooled_streams_round_trip_on_home_shard() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(21);
        let n = 50_000;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);

        let ha = client.admit_blocking(av).expect("admit a");
        // co-locate b with a so the steady-state pair shares a home shard
        let hb = client.admit_near_blocking(bv, Some(ha)).expect("admit b");
        assert_ne!(ha, hb);
        // admit once, dot many: the steady-state serving pattern
        let first = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
        assert!((first as f64 - exact).abs() / scale < 1e-6);
        for _ in 0..3 {
            let again = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
            assert_eq!(first.to_bits(), again.to_bits(), "home-shard dots are bit-stable");
        }
        // unknown handles and released handles are clean errors, not hangs
        assert!(client.dot_pooled_blocking("kahan", ha, 999).is_err());
        client.release(hb);
        assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err());

        let stats = svc.stop();
        assert_eq!(stats.admitted, 2);
        assert_eq!(stats.pooled_calls, 4);
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.requests, 6);
    }

    #[test]
    fn host_backend_pooled_rejects_length_mismatch() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let ha = client.admit_blocking(vec![1.0; 100]).unwrap();
        let hb = client.admit_blocking(vec![1.0; 101]).unwrap();
        assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err());
        let stats = svc.stop();
        assert_eq!(stats.errors, 1);
    }

    // ---- Pjrt backend: skipped without artifacts ----

    #[test]
    fn service_round_trip_and_batching() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (svc, client) = DotService::start(pjrt_config()).unwrap();
        let mut rng = Rng::new(5);
        let n = 2048;
        // submit a burst so the batcher can fuse them
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            expected.push(exact_dot_f32(&a, &b));
            rxs.push(client.submit(i, "kahan", a, b));
        }
        let mut batched_seen = false;
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, i as u64);
            let v = resp.value.expect("value") as f64;
            assert!((v - expected[i]).abs() < 1e-2, "req {i}: {v} vs {}", expected[i]);
            batched_seen |= resp.batch_size > 1;
        }
        let stats = svc.stop();
        assert_eq!(stats.requests, 6);
        assert!(stats.errors == 0);
        assert!(batched_seen, "burst of 6 should have batched at least once");
        assert!(stats.pjrt_calls < 6, "batching must reduce PJRT calls: {stats:?}");
    }

    #[test]
    fn naive_and_kahan_variants_route_correctly() {
        if !artifacts_present() {
            return;
        }
        let (svc, client) = DotService::start(pjrt_config()).unwrap();
        let a = vec![1.0f32; 100];
        let b = vec![2.0f32; 100];
        let vk = client.dot_blocking("kahan", a.clone(), b.clone()).unwrap();
        let vn = client.dot_blocking("naive", a, b).unwrap();
        assert_eq!(vk, 200.0);
        assert_eq!(vn, 200.0);
        svc.stop();
    }

    #[test]
    fn oversized_request_errors_cleanly() {
        if !artifacts_present() {
            return;
        }
        let (svc, client) = DotService::start(pjrt_config()).unwrap();
        let big = vec![0.0f32; 1 << 21]; // 2M > 65536 and > batched n
        let r = client.dot_blocking("kahan", big.clone(), big);
        assert!(r.is_err());
        svc.stop();
    }
}
