//! Batched dot service: the request-path component behind the end-to-end
//! example (`examples/e2e_serve.rs`).
//!
//! Two backends share one client API:
//!
//! * [`Backend::Host`] (default) — requests execute on the NUMA-sharded
//!   serving tier (`crate::engine::ShardedEngine`) through a **router
//!   pool**: one submitter thread per shard, each fed by its own bounded
//!   queue. The client routes messages itself (no central router thread to
//!   serialize behind): pooled streams go to the submitter of their home
//!   shard, fresh requests round-robin across submitters, and each
//!   submitter executes on *its* shard — so two small independent requests
//!   run concurrently on different shards. Submitters drain their queue
//!   **greedily**: a wake-up that finds k ≥ 2 queued small dots executes
//!   them as one engine batch (`ServiceConfig::max_batch` caps the fuse;
//!   results are bit-identical to serial execution — the engine module's
//!   "Batching invariant"), and a burst of admissions to one shard
//!   coalesces into a single worker pass (`Msg::AdmitPair` admits a
//!   co-located pair in one message). Runs never cross a message of a
//!   different kind, so each lane keeps exact FIFO order. Very large dots
//!   still fan out
//!   across every shard with the flat compensated cross-shard merge (the
//!   submitter only initiates the split), which keeps the sequential Kahan
//!   bound and 1-vs-N-shard bit-identity intact. Queues are bounded
//!   (`ServiceConfig::router_queue_depth`): when a lane is full the
//!   client's send blocks — back-pressure instead of unbounded queue
//!   growth — and the stall is counted in
//!   [`ServiceStats::queue_full_stalls`]. Shutdown is graceful: each
//!   submitter drains and serves everything already queued behind the
//!   shutdown marker before exiting (see `submitter_loop`).
//! * [`Backend::Pjrt`] — the original PJRT path: one worker thread owns
//!   the `Runtime` (executables are not shared across threads), drains the
//!   queue with a batching window, groups compatible requests, and
//!   executes them in one PJRT call when possible. Needs AOT artifacts and
//!   the `pjrt` cargo feature.
//!
//! Ordering: each lane is FIFO, and pooled-dot operands are resolved at
//! *submit* time in the caller's program order while `release` removes the
//! stream-table entry synchronously on the caller's thread. One client
//! therefore keeps exactly the old single-router FIFO semantics — a
//! `release` after `submit_pooled` never invalidates the in-flight dot
//! (the message holds the resolved `Arc`s), and a `release` before a
//! submit is always visible to it. Concurrent clients racing a release
//! against a submit get one outcome or the other, never a dangling read.
//!
//! Architecture (std-only; the offline container has no tokio): callers
//! submit `DotRequest`s over per-shard bounded channels and receive their
//! `DotResponse` on a per-request return channel.

use crate::engine::parallel::panic_message;
use crate::engine::{HomedSlice, ShardedEngine};
use crate::isa::Variant;
use crate::runtime::Runtime;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, Instant};

/// Message to a submitter (Host) or the worker (Pjrt): a request, stream
/// admission/release, or an explicit shutdown marker (needed because
/// `DotClient` clones keep the channels alive — dropping the service's own
/// senders alone would never disconnect the receivers).
enum Msg {
    Req(DotRequest),
    /// Admit a stream into the sharded engine's pooled storage; replies
    /// with the stream handle (Host backend only). Placement is the lane
    /// the message was routed to: the client resolves `near` co-location
    /// *before* sending, so the admission copy always runs on the target
    /// shard's own workers.
    Admit { data: Vec<f32>, reply: mpsc::Sender<Result<u64, String>> },
    /// Dot two admitted streams on the home shard of `a` (Host backend
    /// only). The operands are resolved from the stream table at *submit*
    /// time on the client thread — program order of one client therefore
    /// decides what a dot sees (exactly the old single-router FIFO
    /// semantics): a `release` after `submit_pooled` can never invalidate
    /// an in-flight dot (the message holds the slices alive), and a
    /// `release` before it is always visible (`sa`/`sb` arrive `None`).
    ReqPooled {
        id: u64,
        variant: &'static str,
        a: u64,
        b: u64,
        sa: Option<HomedSlice<f32>>,
        sb: Option<HomedSlice<f32>>,
        reply: mpsc::Sender<DotResponse>,
        submitted: Instant,
    },
    /// Admit a stream pair in ONE message (Host backend only): both
    /// streams land on the same shard in a single worker pass — the
    /// co-located placement `admit_near` needed two routing round-trips
    /// for.
    AdmitPair {
        a: Vec<f32>,
        b: Vec<f32>,
        reply: mpsc::Sender<Result<(u64, u64), String>>,
    },
    /// Drop an admitted stream (Pjrt path only — the Host client removes
    /// it from the shared stream table synchronously instead).
    Release { handle: u64 },
    Shutdown,
}

/// Discriminant for run-grouping in the submitter's greedy drain: only
/// consecutive messages of the same kind coalesce, so each lane keeps its
/// exact FIFO execution order.
fn msg_kind(m: &Msg) -> u8 {
    match m {
        Msg::Req(_) => 0,
        Msg::ReqPooled { .. } => 1,
        Msg::Admit { .. } => 2,
        Msg::AdmitPair { .. } => 3,
        Msg::Release { .. } => 4,
        Msg::Shutdown => 5,
    }
}

/// Which execution path serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// persistent host engine (pooled buffers + pinned workers)
    #[default]
    Host,
    /// PJRT execution of the AOT artifacts (requires the `pjrt` feature)
    Pjrt,
}

/// A dot-product request.
pub struct DotRequest {
    pub id: u64,
    /// "kahan" or "naive"
    pub variant: &'static str,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    reply: mpsc::Sender<DotResponse>,
    /// stamped in `DotClient::submit`, so reported latency includes the
    /// time spent queued in the channel, not just the execute time
    submitted: Instant,
}

/// The service's answer.
#[derive(Clone, Debug)]
pub struct DotResponse {
    pub id: u64,
    pub value: Result<f32, String>,
    /// how many requests shared the backend call that served this one
    pub batch_size: usize,
    /// queue + execute time
    pub latency: Duration,
}

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub backend: Backend,
    /// Host backend: per-shard submitter queue depth. When a lane holds
    /// this many undelivered messages the next send *blocks* the caller
    /// (back-pressure: admission copies must not pile up behind a busy
    /// shard and starve compute), and the stall is counted in
    /// [`ServiceStats::queue_full_stalls`].
    pub router_queue_depth: usize,
    /// Max requests fused into one batched execute. Host backend: a
    /// submitter that wakes up with k ≥ 2 queued small dots executes them
    /// as ONE engine batch (chunks of at most `max_batch`; bit-identical
    /// to serial execution — see the engine module's batching invariant),
    /// and bursts of admissions coalesce into one worker pass the same
    /// way. `max_batch = 1` disables coalescing. Pjrt backend: the batch
    /// window size, as before.
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch (Pjrt backend)
    pub window: Duration,
    /// name of the batched artifact to use (must exist in the manifest)
    pub batched_artifact_kahan: String,
    pub batched_artifact_naive: String,
    /// single-request fallback artifacts
    pub single_artifact_kahan: String,
    pub single_artifact_naive: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            backend: Backend::Host,
            router_queue_depth: 64,
            max_batch: 16,
            window: Duration::from_millis(2),
            batched_artifact_kahan: "batched_dot_kahan_f32_b8_n16384".into(),
            batched_artifact_naive: "batched_dot_naive_f32_b8_n16384".into(),
            single_artifact_kahan: "dot_kahan_f32_n65536".into(),
            single_artifact_naive: "dot_naive_f32_n65536".into(),
        }
    }
}

/// Per-submitter-lane counters (Host backend; lane index == shard index).
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneStats {
    /// messages accepted into this lane's queue. Sends rejected by a
    /// stopped lane are not counted; a send that wins the race into the
    /// queue just as the submitter exits is counted but never served
    /// (its client sees a disconnect), so during a shutdown race this
    /// may exceed the lane's served total by the few in-flight sends.
    pub routed: u64,
    /// dots (fresh + pooled) executed by this lane's submitter
    pub executed: u64,
    /// sends that found this lane's queue full and had to block
    pub queue_full_stalls: u64,
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    /// engine executions (Host backend)
    pub engine_calls: u64,
    /// streams admitted into shard-local pooled storage (Host backend)
    pub admitted: u64,
    /// dots served over already-admitted streams on their home shard.
    /// (Cross-shard split counts live in `ShardedEngine::stats` — the
    /// engine is process-global, so a per-service delta would misattribute
    /// splits whenever two services or a direct engine user coexist.)
    pub pooled_calls: u64,
    pub pjrt_calls: u64,
    pub batched_calls: u64,
    /// Host backend: engine batch calls that fused ≥ 2 queued dots into
    /// one execution (each also counts once in `engine_calls`)
    pub batches: u64,
    /// Host backend: dots served inside those batches
    pub batched_requests: u64,
    /// Host backend: admission bursts coalesced into one worker pass
    pub admit_batches: u64,
    pub errors: u64,
    /// total sends that hit a full lane queue and blocked (back-pressure)
    pub queue_full_stalls: u64,
    /// messages served during the shutdown drain (they were queued behind
    /// the shutdown marker and would have been dropped without the drain)
    pub drained: u64,
    /// per-shard router lanes (empty for the Pjrt backend)
    pub lanes: Vec<LaneStats>,
}

/// One submitter lane's live counters.
#[derive(Default)]
struct LaneCounters {
    routed: AtomicU64,
    executed: AtomicU64,
    queue_full_stalls: AtomicU64,
}

/// Shared state of the Host router pool: the per-shard bounded queues,
/// the admitted-stream table, and every counter. Clients route against it
/// directly — there is no central router thread.
struct HostRouter {
    engine: &'static ShardedEngine,
    /// coalescing cap per engine batch (`ServiceConfig::max_batch`, ≥ 1)
    max_batch: usize,
    /// bounded hand-off to each shard's submitter (index == shard)
    queues: Vec<mpsc::SyncSender<Msg>>,
    /// admitted streams: handle -> home-shard slice. Inserted by the
    /// owning submitter at admission, removed by *client* threads in
    /// `DotClient::release` (synchronously — that is what makes a release
    /// ordered against the same client's later submits), and read by
    /// clients at submit time to resolve pooled operands.
    streams: RwLock<HashMap<u64, HomedSlice<f32>>>,
    next_handle: AtomicU64,
    /// round-robin cursor for fresh (un-homed) messages
    rr: AtomicUsize,
    lanes: Vec<LaneCounters>,
    requests: AtomicU64,
    engine_calls: AtomicU64,
    admitted: AtomicU64,
    pooled_calls: AtomicU64,
    batches: AtomicU64,
    batched_requests: AtomicU64,
    admit_batches: AtomicU64,
    errors: AtomicU64,
    drained: AtomicU64,
}

impl HostRouter {
    /// Lane for the next fresh (un-homed) message.
    fn route_fresh(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed) % self.queues.len()
    }

    /// Home shard of an admitted stream, if it is still live.
    fn shard_of(&self, handle: u64) -> Option<usize> {
        self.streams.read().unwrap().get(&handle).map(|h| h.shard)
    }

    /// Hand `msg` to shard `s`'s submitter. The queue is bounded: a full
    /// lane counts a stall and then *blocks* until the submitter catches
    /// up — back-pressure, not unbounded growth. A send after shutdown is
    /// dropped; the caller observes it as a disconnected reply channel.
    fn send_to(&self, s: usize, msg: Msg) {
        match self.queues[s].try_send(msg) {
            Ok(()) => {
                self.lanes[s].routed.fetch_add(1, Ordering::Relaxed);
            }
            Err(mpsc::TrySendError::Full(msg)) => {
                self.lanes[s].queue_full_stalls.fetch_add(1, Ordering::Relaxed);
                // count only accepted messages — a *rejected* send must
                // not inflate `routed` (acceptance can still race the
                // submitter's exit; see the `LaneStats::routed` doc)
                if self.queues[s].send(msg).is_ok() {
                    self.lanes[s].routed.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {}
        }
    }

    /// Shared tail of both dot arms: bump the execution counters, run the
    /// engine call with panic isolation, and turn an unwind into the
    /// request's own error (the client must see the real panic text).
    fn execute(
        &self,
        s: usize,
        variant: &'static str,
        pooled: bool,
        dot: impl FnOnce(Variant) -> f32,
    ) -> Result<f32, String> {
        parse_variant(variant).and_then(|v| {
            self.engine_calls.fetch_add(1, Ordering::Relaxed);
            if pooled {
                self.pooled_calls.fetch_add(1, Ordering::Relaxed);
            }
            self.lanes[s].executed.fetch_add(1, Ordering::Relaxed);
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| dot(v)))
                .map_err(|e| format!("engine panic: {}", panic_message(e)))
        })
    }

    /// Execute one message on lane `s`'s submitter thread.
    ///
    /// Length mismatches are rejected HERE, before the engine: the
    /// engine's documented policy is debug-assert + truncate (see the
    /// engine module's "Length policy"), so the service is the layer that
    /// turns a mismatch into a client-visible error.
    fn serve(&self, s: usize, msg: Msg) {
        match msg {
            Msg::Shutdown => {}
            Msg::Req(req) => {
                self.requests.fetch_add(1, Ordering::Relaxed);
                let value = if req.a.len() != req.b.len() {
                    Err(format!("length mismatch {} vs {}", req.a.len(), req.b.len()))
                } else {
                    // no per-request heap churn: the engine reads the
                    // request's own vectors (small dots run on them in
                    // place; large dots pay one admission copy into the
                    // target shard's recycled aligned pool buffers).
                    // Executes on THIS lane's shard (routing already
                    // balanced fresh requests round-robin); the engine
                    // keeps the split-vs-route threshold and fans very
                    // large dots out across every shard
                    self.execute(s, req.variant, false, |v| {
                        self.engine.dot_on_f32(s, v, &req.a, &req.b)
                    })
                };
                if value.is_err() {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = req.reply.send(DotResponse {
                    id: req.id,
                    value,
                    batch_size: 1,
                    latency: req.submitted.elapsed(),
                });
            }
            Msg::Admit { data, reply } => {
                // the copy runs on shard `s`'s own pinned workers, so
                // fresh pages first-touch in-domain
                let homed = self.engine.admit_to_f32(s, &data);
                let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                self.streams.write().unwrap().insert(handle, homed);
                self.admitted.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Ok(handle));
            }
            Msg::ReqPooled { id, variant, a, b, sa, sb, reply, submitted } => {
                self.requests.fetch_add(1, Ordering::Relaxed);
                let value = match (sa, sb) {
                    (Some(sa), Some(sb)) if sa.len() == sb.len() => {
                        self.execute(s, variant, true, |v| self.engine.dot_homed_f32(v, &sa, &sb))
                    }
                    (Some(sa), Some(sb)) => {
                        Err(format!("length mismatch {} vs {}", sa.len(), sb.len()))
                    }
                    (sa, _) => Err(format!(
                        "unknown stream handle {}",
                        if sa.is_some() { b } else { a }
                    )),
                };
                if value.is_err() {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = reply.send(DotResponse {
                    id,
                    value,
                    batch_size: 1,
                    latency: submitted.elapsed(),
                });
            }
            Msg::AdmitPair { a, b, reply } => {
                // one message, one worker pass, one shard for both streams
                // — the steady-state pair placement without the second
                // routing round-trip `admit_near` paid
                let homed = self.engine.admit_many_to_f32(s, &[&a, &b]);
                let mut handles = homed.into_iter().map(|h| {
                    let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                    self.streams.write().unwrap().insert(handle, h);
                    handle
                });
                let ha = handles.next().expect("pair admission");
                let hb = handles.next().expect("pair admission");
                self.admitted.fetch_add(2, Ordering::Relaxed);
                let _ = reply.send(Ok((ha, hb)));
            }
            Msg::Release { handle } => {
                // unreachable on the Host path (the client releases
                // synchronously); kept for match exhaustiveness
                self.streams.write().unwrap().remove(&handle);
            }
        }
    }

    /// Serve a coalesced run of fresh dot requests: validate each, then
    /// execute same-variant chunks of ≥ 2 as ONE engine batch on this
    /// lane's shard (bit-identical to per-request execution). On a batch
    /// panic the chunk falls back to per-request serves, so only the
    /// culprit request errors.
    fn serve_req_batch(&self, s: usize, reqs: Vec<DotRequest>) {
        self.requests.fetch_add(reqs.len() as u64, Ordering::Relaxed);
        let mut kahan: Vec<DotRequest> = Vec::new();
        let mut naive: Vec<DotRequest> = Vec::new();
        for req in reqs {
            match parse_variant(req.variant) {
                Err(e) => {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Err(e),
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
                Ok(_) if req.a.len() != req.b.len() => {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Err(format!(
                            "length mismatch {} vs {}",
                            req.a.len(),
                            req.b.len()
                        )),
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
                Ok(Variant::Naive) => naive.push(req),
                Ok(_) => kahan.push(req),
            }
        }
        for (v, mut group) in [(Variant::Kahan, kahan), (Variant::Naive, naive)] {
            while !group.is_empty() {
                let take = group.len().min(self.max_batch);
                let chunk: Vec<DotRequest> = group.drain(..take).collect();
                self.serve_req_chunk(s, v, chunk);
            }
        }
    }

    /// One engine batch call for a same-variant chunk of validated fresh
    /// requests (or the plain single-request path for a chunk of one).
    fn serve_req_chunk(&self, s: usize, v: Variant, chunk: Vec<DotRequest>) {
        if chunk.len() == 1 {
            // mirror of the Msg::Req single path, minus the re-validation
            let req = &chunk[0];
            let value = self.execute(s, req.variant, false, |var| {
                self.engine.dot_on_f32(s, var, &req.a, &req.b)
            });
            if value.is_err() {
                self.errors.fetch_add(1, Ordering::Relaxed);
            }
            let req = chunk.into_iter().next().expect("chunk of one");
            let _ = req.reply.send(DotResponse {
                id: req.id,
                value,
                batch_size: 1,
                latency: req.submitted.elapsed(),
            });
            return;
        }
        let pairs: Vec<(&[f32], &[f32])> =
            chunk.iter().map(|r| (r.a.as_slice(), r.b.as_slice())).collect();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.engine.dot_batch_on_f32(s, v, &pairs)
        }));
        drop(pairs);
        match r {
            Ok(vals) => {
                let bsz = chunk.len();
                // counted only on success: the panic fallback below routes
                // every request through `execute`, which does its own
                // counting — counting both would break the
                // `engine_calls - batches + batched_requests == served`
                // identity the e2e driver asserts
                self.engine_calls.fetch_add(1, Ordering::Relaxed);
                self.batches.fetch_add(1, Ordering::Relaxed);
                self.batched_requests.fetch_add(bsz as u64, Ordering::Relaxed);
                self.lanes[s].executed.fetch_add(bsz as u64, Ordering::Relaxed);
                for (req, val) in chunk.into_iter().zip(vals) {
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Ok(val),
                        batch_size: bsz,
                        latency: req.submitted.elapsed(),
                    });
                }
            }
            Err(_) => {
                // the batch died (a kernel panicked): fall back to
                // per-request execution so only the culprit errors
                self.errors.fetch_add(1, Ordering::Relaxed);
                for req in chunk {
                    let value = self.execute(s, req.variant, false, |var| {
                        self.engine.dot_on_f32(s, var, &req.a, &req.b)
                    });
                    if value.is_err() {
                        self.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value,
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
            }
        }
    }

    /// Serve a coalesced run of pooled dots: operands were resolved at
    /// submit time, so validation here is presence + length; valid
    /// same-variant chunks of ≥ 2 execute as one homed engine batch on
    /// the pairs' home shards.
    fn serve_pooled_batch(&self, s: usize, msgs: Vec<Msg>) {
        struct Pooled {
            id: u64,
            variant: &'static str,
            sa: HomedSlice<f32>,
            sb: HomedSlice<f32>,
            reply: mpsc::Sender<DotResponse>,
            submitted: Instant,
        }
        self.requests.fetch_add(msgs.len() as u64, Ordering::Relaxed);
        let mut kahan: Vec<Pooled> = Vec::new();
        let mut naive: Vec<Pooled> = Vec::new();
        for msg in msgs {
            let Msg::ReqPooled { id, variant, a, b, sa, sb, reply, submitted } = msg else {
                unreachable!("serve_pooled_batch takes ReqPooled runs only");
            };
            let validated: Result<Variant, String> = match (parse_variant(variant), &sa, &sb) {
                (Err(e), _, _) => Err(e),
                (Ok(v), Some(sa), Some(sb)) if sa.len() == sb.len() => Ok(v),
                (Ok(_), Some(sa), Some(sb)) => {
                    Err(format!("length mismatch {} vs {}", sa.len(), sb.len()))
                }
                (Ok(_), sa, _) => Err(format!(
                    "unknown stream handle {}",
                    if sa.is_some() { b } else { a }
                )),
            };
            let v = match validated {
                Err(e) => {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(DotResponse {
                        id,
                        value: Err(e),
                        batch_size: 1,
                        latency: submitted.elapsed(),
                    });
                    continue;
                }
                Ok(v) => v,
            };
            let p = Pooled {
                id,
                variant,
                sa: sa.expect("validated"),
                sb: sb.expect("validated"),
                reply,
                submitted,
            };
            if v == Variant::Naive {
                naive.push(p);
            } else {
                kahan.push(p);
            }
        }
        for (v, mut group) in [(Variant::Kahan, kahan), (Variant::Naive, naive)] {
            while !group.is_empty() {
                let take = group.len().min(self.max_batch);
                let chunk: Vec<Pooled> = group.drain(..take).collect();
                if chunk.len() == 1 {
                    let p = &chunk[0];
                    let value = self.execute(s, p.variant, true, |var| {
                        self.engine.dot_homed_f32(var, &p.sa, &p.sb)
                    });
                    if value.is_err() {
                        self.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    let p = chunk.into_iter().next().expect("chunk of one");
                    let _ = p.reply.send(DotResponse {
                        id: p.id,
                        value,
                        batch_size: 1,
                        latency: p.submitted.elapsed(),
                    });
                    continue;
                }
                let pairs: Vec<(&HomedSlice<f32>, &HomedSlice<f32>)> =
                    chunk.iter().map(|p| (&p.sa, &p.sb)).collect();
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.engine.dot_batch_homed_f32(v, &pairs)
                }));
                drop(pairs);
                match r {
                    Ok(vals) => {
                        // success-only counting, as in `serve_req_chunk`:
                        // the panic fallback's `execute` calls count for
                        // themselves
                        let bsz = chunk.len();
                        self.engine_calls.fetch_add(1, Ordering::Relaxed);
                        self.pooled_calls.fetch_add(bsz as u64, Ordering::Relaxed);
                        self.batches.fetch_add(1, Ordering::Relaxed);
                        self.batched_requests.fetch_add(bsz as u64, Ordering::Relaxed);
                        self.lanes[s].executed.fetch_add(bsz as u64, Ordering::Relaxed);
                        for (p, val) in chunk.into_iter().zip(vals) {
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Ok(val),
                                batch_size: bsz,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                    Err(_) => {
                        self.errors.fetch_add(1, Ordering::Relaxed);
                        for p in chunk {
                            let value = self.execute(s, p.variant, true, |var| {
                                self.engine.dot_homed_f32(var, &p.sa, &p.sb)
                            });
                            if value.is_err() {
                                self.errors.fetch_add(1, Ordering::Relaxed);
                            }
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value,
                                batch_size: 1,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                }
            }
        }
    }

    /// Serve a coalesced run of admissions: one worker pass copies up to
    /// `max_batch` streams into shard `s`'s pool (the ROADMAP's
    /// admission-coalescing item), then handles are minted and replied in
    /// order. `max_batch = 1` degrades to the per-message path, as the
    /// config documents.
    fn serve_admit_batch(&self, s: usize, mut msgs: Vec<Msg>) {
        while !msgs.is_empty() {
            let take = msgs.len().min(self.max_batch);
            let rest = msgs.split_off(take);
            let group = std::mem::replace(&mut msgs, rest);
            if group.len() == 1 {
                for m in group {
                    self.serve(s, m);
                }
                continue;
            }
            let mut datas: Vec<Vec<f32>> = Vec::with_capacity(group.len());
            let mut replies: Vec<mpsc::Sender<Result<u64, String>>> =
                Vec::with_capacity(group.len());
            for msg in group {
                let Msg::Admit { data, reply } = msg else {
                    unreachable!("serve_admit_batch takes Admit runs only");
                };
                datas.push(data);
                replies.push(reply);
            }
            let views: Vec<&[f32]> = datas.iter().map(|d| d.as_slice()).collect();
            let homed = self.engine.admit_many_to_f32(s, &views);
            self.admit_batches.fetch_add(1, Ordering::Relaxed);
            for (h, reply) in homed.into_iter().zip(replies) {
                let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                self.streams.write().unwrap().insert(handle, h);
                self.admitted.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Ok(handle));
            }
        }
    }

    fn snapshot(&self) -> ServiceStats {
        let lanes: Vec<LaneStats> = self
            .lanes
            .iter()
            .map(|l| LaneStats {
                routed: l.routed.load(Ordering::Relaxed),
                executed: l.executed.load(Ordering::Relaxed),
                queue_full_stalls: l.queue_full_stalls.load(Ordering::Relaxed),
            })
            .collect();
        ServiceStats {
            requests: self.requests.load(Ordering::Relaxed),
            engine_calls: self.engine_calls.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            pooled_calls: self.pooled_calls.load(Ordering::Relaxed),
            pjrt_calls: 0,
            batched_calls: 0,
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            admit_batches: self.admit_batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            queue_full_stalls: lanes.iter().map(|l| l.queue_full_stalls).sum(),
            drained: self.drained.load(Ordering::Relaxed),
            lanes,
        }
    }
}

/// One shard's submitter: drain the lane queue GREEDILY in FIFO order.
/// Each wake-up takes everything already queued (capped), then serves it
/// as runs — consecutive small dots become one engine batch, consecutive
/// admissions one worker pass — so a burst pays one handoff instead of
/// one per request, without reordering anything (runs never cross a
/// message of a different kind). On the shutdown marker, everything
/// already queued behind it is *served* (not dropped) before the thread
/// exits — the old single-router loop broke out of `recv` on shutdown and
/// silently dropped queued requests, leaving their clients with a
/// disconnected reply channel.
fn submitter_loop(router: &HostRouter, shard: usize, rx: mpsc::Receiver<Msg>) {
    // calibrate the dispatch table before the first request, on a worker
    // thread so `DotService::start` stays non-blocking (the OnceLock makes
    // one submitter calibrate while its peers wait)
    let _ = crate::engine::dispatch();
    // bound one wake-up's gather so a firehose producer cannot starve the
    // executions it is waiting on
    let gather_cap = router.max_batch.max(1) * 4;
    let mut shutdown = false;
    loop {
        let first = if shutdown {
            match rx.try_recv() {
                Ok(m) => m,
                Err(_) => return,
            }
        } else {
            match rx.recv() {
                Ok(m) => m,
                Err(_) => return,
            }
        };
        let mut pending: Vec<Msg> = Vec::new();
        match first {
            Msg::Shutdown => shutdown = true,
            m => {
                if shutdown {
                    router.drained.fetch_add(1, Ordering::Relaxed);
                }
                pending.push(m);
            }
        }
        while pending.len() < gather_cap {
            match rx.try_recv() {
                Ok(Msg::Shutdown) => shutdown = true,
                Ok(m) => {
                    // messages gathered behind the marker are the drain set
                    if shutdown {
                        router.drained.fetch_add(1, Ordering::Relaxed);
                    }
                    pending.push(m);
                }
                Err(_) => break,
            }
        }
        serve_pending(router, shard, pending);
    }
}

/// Serve one wake-up's gathered messages as maximal same-kind runs, in
/// arrival order.
fn serve_pending(router: &HostRouter, shard: usize, msgs: Vec<Msg>) {
    let mut run: Vec<Msg> = Vec::new();
    for m in msgs {
        if !run.is_empty() && msg_kind(&run[0]) != msg_kind(&m) {
            serve_run(router, shard, std::mem::take(&mut run));
        }
        run.push(m);
    }
    if !run.is_empty() {
        serve_run(router, shard, run);
    }
}

/// Execute one same-kind run: dot and admission runs of ≥ 2 take the
/// coalesced paths, everything else the per-message path. Panic isolation
/// as for `serve_caught` — a dead lane would silently blackhole its shard.
fn serve_run(router: &HostRouter, shard: usize, mut run: Vec<Msg>) {
    if run.len() == 1 {
        serve_caught(router, shard, run.pop().expect("run of one"));
        return;
    }
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match msg_kind(&run[0]) {
        0 => {
            let reqs: Vec<DotRequest> = run
                .into_iter()
                .map(|m| match m {
                    Msg::Req(r) => r,
                    _ => unreachable!("mixed run"),
                })
                .collect();
            router.serve_req_batch(shard, reqs);
        }
        1 => router.serve_pooled_batch(shard, run),
        2 => router.serve_admit_batch(shard, run),
        _ => {
            for m in run {
                router.serve(shard, m);
            }
        }
    }));
    if r.is_err() {
        router.errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// `serve`, but a panic (realistically: a chunk kernel panic that
/// `collect_partials` re-raises in the caller — here, this submitter)
/// must not kill the lane: a dead submitter would silently blackhole
/// every future message routed to its shard (`send_to` swallows
/// disconnects) while `ServiceStats` stays clean — a partial, invisible
/// outage. The panicking request's reply sender unwinds with the frame,
/// so its client sees a disconnect; the failure is counted and the lane
/// lives on. (The engine's worker pool survives job panics by the same
/// policy, so the next request finds it healthy.)
fn serve_caught(router: &HostRouter, shard: usize, msg: Msg) {
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| router.serve(shard, msg)));
    if r.is_err() {
        router.errors.fetch_add(1, Ordering::Relaxed);
    }
}

enum ServiceInner {
    Host {
        router: Arc<HostRouter>,
        submitters: Vec<std::thread::JoinHandle<()>>,
    },
    Pjrt {
        tx: Option<mpsc::Sender<Msg>>,
        worker: Option<std::thread::JoinHandle<ServiceStats>>,
    },
}

/// Handle to a running service.
pub struct DotService {
    inner: ServiceInner,
}

#[derive(Clone)]
enum ClientInner {
    Host(Arc<HostRouter>),
    Pjrt(mpsc::Sender<Msg>),
}

/// Client-side handle for submitting requests. Cloneable and `Send`: on
/// the Host backend every clone routes directly against the shared router
/// state, so N client threads submit to N shard lanes concurrently.
#[derive(Clone)]
pub struct DotClient {
    inner: ClientInner,
}

impl DotClient {
    /// Submit a request; returns the receiver for its response. Fresh
    /// requests round-robin across the shard lanes; a full lane blocks
    /// (back-pressure).
    pub fn submit(
        &self,
        id: u64,
        variant: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        let req = DotRequest { id, variant, a, b, reply, submitted: Instant::now() };
        match &self.inner {
            ClientInner::Host(r) => {
                let s = r.route_fresh();
                r.send_to(s, Msg::Req(req));
            }
            // a send error means the service stopped; the caller sees it
            // as a disconnected receiver
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::Req(req));
            }
        }
        rx
    }

    /// Convenience: blocking round-trip.
    pub fn dot_blocking(&self, variant: &'static str, a: Vec<f32>, b: Vec<f32>) -> Result<f32, String> {
        let rx = self.submit(0, variant, a, b);
        match rx.recv() {
            Ok(resp) => resp.value,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Admit a stream into the serving tier's pooled shard-local storage
    /// and get back its handle. The stream's home shard is fixed at
    /// admission; every later [`DotClient::dot_pooled_blocking`] over it
    /// executes there (Host backend only — the PJRT worker rejects it).
    pub fn admit_blocking(&self, data: Vec<f32>) -> Result<u64, String> {
        self.admit_near_blocking(data, None)
    }

    /// Admit a stream PAIR in one message: both streams land on the same
    /// shard in a single worker pass — the co-located steady-state
    /// placement (`admit_near`) without the second routing round-trip.
    /// Host backend only.
    pub fn admit_pair_blocking(
        &self,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Result<(u64, u64), String> {
        let (reply, rx) = mpsc::channel();
        match &self.inner {
            ClientInner::Host(r) => {
                let s = r.route_fresh();
                r.send_to(s, Msg::AdmitPair { a, b, reply });
            }
            ClientInner::Pjrt(tx) => {
                if tx.send(Msg::AdmitPair { a, b, reply }).is_err() {
                    return Err("service stopped".into());
                }
            }
        }
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Like [`DotClient::admit_blocking`], but co-locate the stream on the
    /// home shard of `near` (an earlier handle) — the placement for
    /// streams that will be dotted against each other, so the pair never
    /// crosses a NUMA domain. A `near` that no longer exists falls back to
    /// round-robin placement.
    pub fn admit_near_blocking(&self, data: Vec<f32>, near: Option<u64>) -> Result<u64, String> {
        let (reply, rx) = mpsc::channel();
        match &self.inner {
            ClientInner::Host(r) => {
                let s = near.and_then(|h| r.shard_of(h)).unwrap_or_else(|| r.route_fresh());
                r.send_to(s, Msg::Admit { data, reply });
            }
            ClientInner::Pjrt(tx) => {
                if tx.send(Msg::Admit { data, reply }).is_err() {
                    return Err("service stopped".into());
                }
            }
        }
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Submit a dot over two admitted streams; returns the response
    /// receiver. Routed to the home shard of `a` (admission locality).
    /// The operands are resolved here, in the caller's program order —
    /// see `Msg::ReqPooled` for why that makes `release` safe to call
    /// right after submitting.
    pub fn submit_pooled(
        &self,
        id: u64,
        variant: &'static str,
        a: u64,
        b: u64,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        match &self.inner {
            ClientInner::Host(r) => {
                let (sa, sb) = {
                    let m = r.streams.read().unwrap();
                    (m.get(&a).cloned(), m.get(&b).cloned())
                };
                // an unknown handle still travels a lane so the submitter
                // reports it as a per-request error, not a silent drop
                let s = sa.as_ref().map(|h| h.shard).unwrap_or_else(|| r.route_fresh());
                r.send_to(s, Msg::ReqPooled { id, variant, a, b, sa, sb, reply, submitted: Instant::now() });
            }
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::ReqPooled {
                    id,
                    variant,
                    a,
                    b,
                    sa: None,
                    sb: None,
                    reply,
                    submitted: Instant::now(),
                });
            }
        }
        rx
    }

    /// Convenience: blocking dot over two admitted streams.
    pub fn dot_pooled_blocking(&self, variant: &'static str, a: u64, b: u64) -> Result<f32, String> {
        let rx = self.submit_pooled(0, variant, a, b);
        match rx.recv() {
            Ok(resp) => resp.value,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Release an admitted stream. Takes effect immediately (the entry is
    /// removed from the stream table on the caller's thread): later dots
    /// from this client see it gone, while dots already submitted keep
    /// their resolved operands and finish normally. The buffer recycles
    /// into the home shard's pool once the last in-flight reference
    /// drops. Unknown handles are ignored.
    pub fn release(&self, handle: u64) {
        match &self.inner {
            ClientInner::Host(r) => {
                r.streams.write().unwrap().remove(&handle);
            }
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::Release { handle });
            }
        }
    }
}

impl DotService {
    /// Start the configured backend.
    ///
    /// Host backend: a router pool over the process-wide sharded engine
    /// (`ShardedEngine::global()`) — one submitter thread per shard;
    /// startup is immediate and cannot fail.
    ///
    /// Pjrt backend: PJRT handles are not `Send`, so the `Runtime` must be
    /// constructed *inside* the worker thread; startup errors are relayed
    /// back through a one-shot channel so callers still see them
    /// synchronously.
    pub fn start(config: ServiceConfig) -> anyhow::Result<(Self, DotClient)> {
        match config.backend {
            Backend::Host => Ok(Self::start_on(config, ShardedEngine::global())),
            Backend::Pjrt => {
                let (tx, rx) = mpsc::channel::<Msg>();
                let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
                let worker = std::thread::spawn(move || match Runtime::new() {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        worker_loop_pjrt(rt, rx, config)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        ServiceStats::default()
                    }
                });
                match ready_rx.recv() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        let _ = worker.join();
                        anyhow::bail!("service startup: {e}");
                    }
                    Err(_) => {
                        let _ = worker.join();
                        anyhow::bail!("service worker died during startup");
                    }
                }
                let client = DotClient { inner: ClientInner::Pjrt(tx.clone()) };
                Ok((
                    DotService { inner: ServiceInner::Pjrt { tx: Some(tx), worker: Some(worker) } },
                    client,
                ))
            }
        }
    }

    /// Start a Host-backend router pool on an explicit engine (tests and
    /// benches hand in a leaked `ShardedEngine` over a synthetic
    /// `Topology::fake_even` layout to exercise multi-shard routing on
    /// single-node hosts). `config.backend` is ignored: this is always the
    /// host path.
    pub fn start_on(config: ServiceConfig, engine: &'static ShardedEngine) -> (Self, DotClient) {
        let depth = config.router_queue_depth.max(1);
        let shards = engine.shards();
        let mut queues = Vec::with_capacity(shards);
        let mut receivers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<Msg>(depth);
            queues.push(tx);
            receivers.push(rx);
        }
        let router = Arc::new(HostRouter {
            engine,
            max_batch: config.max_batch.max(1),
            queues,
            streams: RwLock::new(HashMap::new()),
            next_handle: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            lanes: (0..shards).map(|_| LaneCounters::default()).collect(),
            requests: AtomicU64::new(0),
            engine_calls: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            pooled_calls: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            admit_batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            drained: AtomicU64::new(0),
        });
        let submitters = receivers
            .into_iter()
            .enumerate()
            .map(|(s, rx)| {
                let r = Arc::clone(&router);
                std::thread::Builder::new()
                    .name(format!("dot-submitter-{s}"))
                    .spawn(move || submitter_loop(&r, s, rx))
                    .expect("spawn dot submitter")
            })
            .collect();
        let client = DotClient { inner: ClientInner::Host(Arc::clone(&router)) };
        (DotService { inner: ServiceInner::Host { router, submitters } }, client)
    }

    /// Stop the service and return its statistics. Host backend: every
    /// lane gets a shutdown marker, each submitter serves what is already
    /// queued (in-flight requests are drained, not dropped), then joins.
    pub fn stop(mut self) -> ServiceStats {
        self.shutdown()
    }

    fn shutdown(&mut self) -> ServiceStats {
        match &mut self.inner {
            ServiceInner::Host { router, submitters } => {
                if !submitters.is_empty() {
                    for q in &router.queues {
                        let _ = q.send(Msg::Shutdown);
                    }
                    for h in submitters.drain(..) {
                        let _ = h.join();
                    }
                }
                router.snapshot()
            }
            ServiceInner::Pjrt { tx, worker } => {
                if let Some(tx) = tx.take() {
                    let _ = tx.send(Msg::Shutdown);
                }
                worker.take().map(|w| w.join().unwrap_or_default()).unwrap_or_default()
            }
        }
    }
}

impl Drop for DotService {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

fn parse_variant(s: &str) -> Result<Variant, String> {
    match s {
        "kahan" => Ok(Variant::Kahan),
        "naive" => Ok(Variant::Naive),
        other => Err(format!("unknown variant `{other}`")),
    }
}

fn worker_loop_pjrt(
    mut rt: Runtime,
    rx: mpsc::Receiver<Msg>,
    cfg: ServiceConfig,
) -> ServiceStats {
    let mut shutdown = false;
    let mut stats = ServiceStats::default();
    let batched_max_n = rt
        .manifest()
        .get(&cfg.batched_artifact_kahan)
        .map(|m| m.n)
        .unwrap_or(0);

    // pooled-stream admission is a Host-backend feature: the PJRT worker
    // rejects it synchronously rather than pretending to hold streams
    let reject_pooled = |msg: Msg| match msg {
        Msg::Admit { reply, .. } => {
            let _ = reply.send(Err("stream admission requires the Host backend".into()));
        }
        Msg::AdmitPair { reply, .. } => {
            let _ = reply.send(Err("stream admission requires the Host backend".into()));
        }
        Msg::ReqPooled { id, reply, submitted, .. } => {
            let _ = reply.send(DotResponse {
                id,
                value: Err("pooled dots require the Host backend".into()),
                batch_size: 0,
                latency: submitted.elapsed(),
            });
        }
        _ => {}
    };

    loop {
        // block for the first request; after the shutdown marker, keep
        // draining whatever is already queued (serving, not dropping it)
        // and exit once the channel is empty
        let first = if shutdown {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => {
                    stats.drained += 1;
                    r
                }
                Ok(Msg::Shutdown) => continue,
                Ok(other) => {
                    reject_pooled(other);
                    continue;
                }
                Err(_) => break,
            }
        } else {
            match rx.recv() {
                Ok(Msg::Req(r)) => r,
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    continue;
                }
                Ok(other) => {
                    reject_pooled(other);
                    continue;
                }
                Err(_) => break,
            }
        };
        let mut queue = vec![first];
        if !shutdown {
            // batching window: gather more requests
            let deadline = Instant::now() + cfg.window;
            while queue.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Req(r)) => queue.push(r),
                    Ok(Msg::Shutdown) => {
                        // serve what we already accepted; the outer loop
                        // then drains the rest of the channel
                        shutdown = true;
                        break;
                    }
                    Ok(other) => reject_pooled(other),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        // group by variant; batch-execute groups where every request fits
        for variant in ["kahan", "naive"] {
            let group: Vec<DotRequest> = {
                let mut g = Vec::new();
                let mut rest = Vec::new();
                for p in queue.drain(..) {
                    if p.variant == variant {
                        g.push(p);
                    } else {
                        rest.push(p);
                    }
                }
                queue = rest;
                g
            };
            if group.is_empty() {
                continue;
            }
            let (batched_name, single_name) = if variant == "kahan" {
                (&cfg.batched_artifact_kahan, &cfg.single_artifact_kahan)
            } else {
                (&cfg.batched_artifact_naive, &cfg.single_artifact_naive)
            };

            let fits = group.len() >= 2
                && batched_max_n > 0
                && group.iter().all(|p| p.a.len() <= batched_max_n);
            if fits {
                stats.pjrt_calls += 1;
                stats.batched_calls += 1;
                let pairs: Vec<(Vec<f32>, Vec<f32>)> =
                    group.iter().map(|p| (p.a.clone(), p.b.clone())).collect();
                match rt.batched_dot_f32(batched_name, &pairs) {
                    Ok(values) => {
                        let bsz = group.len();
                        for (p, v) in group.into_iter().zip(values) {
                            stats.requests += 1;
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Ok(v),
                                batch_size: bsz,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                    Err(e) => {
                        stats.errors += 1;
                        for p in group {
                            stats.requests += 1;
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Err(format!("batched execute: {e}")),
                                batch_size: 0,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                }
            } else {
                for p in group {
                    stats.requests += 1;
                    stats.pjrt_calls += 1;
                    let value = rt
                        .dot_f32(single_name, &p.a, &p.b)
                        .map_err(|e| e.to_string());
                    if value.is_err() {
                        stats.errors += 1;
                    }
                    let _ = p.reply.send(DotResponse {
                        id: p.id,
                        value,
                        batch_size: 1,
                        latency: p.submitted.elapsed(),
                    });
                }
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::accuracy::gen_dot_f32;
    use crate::engine::{EngineConfig, ShardedConfig, Topology};
    use crate::util::Rng;
    use std::sync::{Condvar, Mutex};

    fn artifacts_present() -> bool {
        // the stub Runtime (no `pjrt` feature) fails closed, so the PJRT
        // tests must skip even when artifacts exist on disk
        cfg!(feature = "pjrt")
            && crate::runtime::artifacts_dir().join("manifest.tsv").exists()
    }

    fn pjrt_config() -> ServiceConfig {
        ServiceConfig { backend: Backend::Pjrt, ..ServiceConfig::default() }
    }

    /// A private pinned engine for router tests (leaked: submitter threads
    /// need `'static`, and the process exits with the test binary).
    fn leak_engine(topo: &Topology, threads: usize) -> &'static ShardedEngine {
        Box::leak(Box::new(ShardedEngine::from_topology(
            topo,
            ShardedConfig {
                engine: EngineConfig { threads, ..EngineConfig::default() },
                ..ShardedConfig::default()
            },
        )))
    }

    /// Occupy every worker of `shard` until `open` is called: lets a test
    /// hold a submitter *inside* a parallel-path dot deterministically.
    struct Gate(Arc<(Mutex<bool>, Condvar)>);

    impl Gate {
        fn close(engine: &ShardedEngine, shard: usize) -> Gate {
            let gate = Arc::new((Mutex::new(false), Condvar::new()));
            for w in 0..engine.shard(shard).threads() {
                let g = Arc::clone(&gate);
                engine.shard(shard).workers().submit_to(
                    w,
                    Box::new(move || {
                        let (m, cv) = &*g;
                        let mut open = m.lock().unwrap();
                        while !*open {
                            open = cv.wait(open).unwrap();
                        }
                    }),
                );
            }
            Gate(gate)
        }

        fn open(&self) {
            let (m, cv) = &*self.0;
            *m.lock().unwrap() = true;
            cv.notify_all();
        }
    }

    impl Drop for Gate {
        /// A test that panics with the gate still closed would otherwise
        /// deadlock: unwinding drops the `DotService`, whose shutdown
        /// joins a submitter blocked behind the gate jobs — the failure
        /// message would be masked by a CI timeout. Opening on drop makes
        /// every panic path unwind cleanly.
        fn drop(&mut self) {
            self.open();
        }
    }

    // ---- Host backend (default): no artifacts needed ----

    #[test]
    fn host_backend_round_trip_matches_exact() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(5);
        let mut rxs = Vec::new();
        let mut expected = Vec::new();
        let mut scales = Vec::new();
        // mixed sizes: inline path and chunked-parallel path
        for (i, n) in [1000usize, 2048, 400_000].iter().enumerate() {
            let a = rng.normal_f32_vec(*n);
            let b = rng.normal_f32_vec(*n);
            expected.push(exact_dot_f32(&a, &b));
            scales.push(
                a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30),
            );
            rxs.push(client.submit(i as u64, if i == 1 { "naive" } else { "kahan" }, a, b));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, i as u64);
            let v = resp.value.expect("value") as f64;
            assert!(
                (v - expected[i]).abs() / scales[i] < 1e-4,
                "req {i}: {v} vs {}",
                expected[i]
            );
        }
        let stats = svc.stop();
        assert_eq!(stats.requests, 3);
        // a burst may coalesce into engine batches (timing-dependent), but
        // singles + batched requests must account for every request
        assert!(stats.engine_calls >= 1 && stats.engine_calls <= 3, "{stats:?}");
        assert_eq!(
            (stats.engine_calls - stats.batches) + stats.batched_requests,
            3,
            "{stats:?}"
        );
        assert_eq!(stats.pjrt_calls, 0);
        assert_eq!(stats.errors, 0);
        // every fresh request was routed to and executed by some lane
        assert_eq!(stats.lanes.iter().map(|l| l.executed).sum::<u64>(), 3);
    }

    #[test]
    fn host_backend_kahan_survives_ill_conditioned_input() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(9);
        let (a, b, exact, _cond) = gen_dot_f32(4096, 1e6, &mut rng);
        let absdot: f64 =
            a.iter().zip(&b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum::<f64>().max(1e-30);
        let v = client.dot_blocking("kahan", a, b).unwrap() as f64;
        assert!(
            (v - exact).abs() / absdot < 1e-5,
            "kahan service result must stay within the Kahan bound: {v} vs {exact}"
        );
        svc.stop();
    }

    #[test]
    fn host_backend_rejects_length_mismatch() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let r = client.dot_blocking("kahan", vec![0.0; 10], vec![0.0; 11]);
        assert!(r.is_err());
        let stats = svc.stop();
        assert_eq!(stats.errors, 1);
    }

    #[test]
    fn host_backend_pooled_streams_round_trip_on_home_shard() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let mut rng = Rng::new(21);
        let n = 50_000;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);

        let ha = client.admit_blocking(av).expect("admit a");
        // co-locate b with a so the steady-state pair shares a home shard
        let hb = client.admit_near_blocking(bv, Some(ha)).expect("admit b");
        assert_ne!(ha, hb);
        // admit once, dot many: the steady-state serving pattern
        let first = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
        assert!((first as f64 - exact).abs() / scale < 1e-6);
        for _ in 0..3 {
            let again = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
            assert_eq!(first.to_bits(), again.to_bits(), "home-shard dots are bit-stable");
        }
        // unknown handles and released handles are clean errors, not hangs
        assert!(client.dot_pooled_blocking("kahan", ha, 999).is_err());
        client.release(hb);
        assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err());

        let stats = svc.stop();
        assert_eq!(stats.admitted, 2);
        assert_eq!(stats.pooled_calls, 4);
        assert_eq!(stats.errors, 2);
        assert_eq!(stats.requests, 6);
    }

    #[test]
    fn host_backend_pooled_rejects_length_mismatch() {
        let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
        let ha = client.admit_blocking(vec![1.0; 100]).unwrap();
        let hb = client.admit_blocking(vec![1.0; 101]).unwrap();
        assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err());
        let stats = svc.stop();
        assert_eq!(stats.errors, 1);
    }

    /// Regression for the lane-race the router pool introduced: with the
    /// pair on *different* shards (plain round-robin admission), a
    /// strictly sequential `submit_pooled(a, b)` → `release(b)` must
    /// behave like the old single-router FIFO — the in-flight dot keeps
    /// its operands, and only *later* submits see the release.
    #[test]
    fn release_after_submit_never_invalidates_inflight_cross_shard_dot() {
        let engine = leak_engine(&Topology::fake_even(2), 1);
        let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
        let mut rng = Rng::new(41);
        let n = 4096;
        let av = rng.normal_f32_vec(n);
        let bv = rng.normal_f32_vec(n);
        let exact = exact_dot_f32(&av, &bv);
        let scale: f64 =
            av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
        for round in 0..20 {
            let ha = client.admit_blocking(av.clone()).unwrap();
            let hb = client.admit_blocking(bv.clone()).unwrap();
            let rx = client.submit_pooled(round, "kahan", ha, hb);
            client.release(hb);
            client.release(ha);
            let v = rx
                .recv()
                .expect("reply")
                .value
                .expect("release-after-submit must not invalidate the in-flight dot")
                as f64;
            assert!((v - exact).abs() / scale < 1e-6, "round {round}");
            // ...while a dot submitted after the release cleanly errors
            assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err(), "round {round}");
        }
        let stats = svc.stop();
        assert_eq!(stats.admitted, 40);
        assert_eq!(stats.pooled_calls, 20);
        assert_eq!(stats.errors, 20);
        assert_eq!(stats.requests, 40);
    }

    // ---- router pool: concurrency, back-pressure, shutdown drain ----

    /// Two independent requests must NOT serialize behind one router
    /// thread: with shard 0's workers gated (its submitter is stuck inside
    /// a parallel-path dot), a small request routed to shard 1 completes
    /// while the first is still blocked.
    #[test]
    fn independent_requests_do_not_serialize_behind_one_router() {
        let engine = leak_engine(&Topology::fake_even(2), 2);
        let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
        let gate = Gate::close(engine, 0);

        let mut rng = Rng::new(31);
        let n = 200_000; // 1.6 MB total: parallel path, blocks on the gate
        let rx1 = client.submit(1, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
        // fresh requests round-robin: request 2 lands on shard 1
        let a2 = rng.normal_f32_vec(1000);
        let b2 = rng.normal_f32_vec(1000);
        let exact2 = exact_dot_f32(&a2, &b2);
        let rx2 = client.submit(2, "kahan", a2, b2);

        // shard 1 serves its request while shard 0 is still blocked
        let resp2 = rx2
            .recv_timeout(Duration::from_secs(30))
            .expect("request on the free shard must not queue behind the blocked one");
        let v2 = resp2.value.expect("value") as f64;
        assert!((v2 - exact2).abs() < 1e-2 * exact2.abs().max(1.0));
        assert!(
            matches!(rx1.try_recv(), Err(mpsc::TryRecvError::Empty)),
            "gated request cannot have completed"
        );

        gate.open();
        assert!(rx1.recv_timeout(Duration::from_secs(30)).expect("gated reply").value.is_ok());
        let stats = svc.stop();
        assert_eq!(stats.lanes.len(), 2);
        assert_eq!(stats.lanes[0].executed, 1, "{stats:?}");
        assert_eq!(stats.lanes[1].executed, 1, "{stats:?}");
    }

    /// Bounded lanes: with queue depth 1 and the only shard's workers
    /// stalled, a burst of requests blocks the producer instead of growing
    /// the queue, and the stall counter advances.
    #[test]
    fn backpressure_blocks_producer_and_counts_stalls() {
        let engine = leak_engine(&Topology::single_node(), 2);
        let (svc, client) = DotService::start_on(
            ServiceConfig { router_queue_depth: 1, ..ServiceConfig::default() },
            engine,
        );
        let gate = Gate::close(engine, 0);

        let accepted = Arc::new(AtomicU64::new(0));
        let (rx_tx, rx_rx) = mpsc::channel();
        let producer = {
            let client = client.clone();
            let accepted = Arc::clone(&accepted);
            std::thread::spawn(move || {
                let mut rng = Rng::new(33);
                // first request takes the parallel path and blocks on the
                // gate; the rest are small
                let sizes = [200_000usize, 64, 64, 64, 64];
                for (i, n) in sizes.iter().enumerate() {
                    let rx = client.submit(
                        i as u64,
                        "kahan",
                        rng.normal_f32_vec(*n),
                        rng.normal_f32_vec(*n),
                    );
                    accepted.fetch_add(1, Ordering::SeqCst);
                    rx_tx.send(rx).unwrap();
                }
            })
        };

        // the producer can hand over at most 2 requests while the gate is
        // closed: one executing (blocked), one in the depth-1 queue; the
        // third send blocks. Wait for that steady state, then verify it
        // holds — the queue must not keep growing.
        let t0 = Instant::now();
        while accepted.load(Ordering::SeqCst) < 2 && t0.elapsed() < Duration::from_secs(30) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(accepted.load(Ordering::SeqCst), 2);
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(
            accepted.load(Ordering::SeqCst),
            2,
            "producer must be blocked by back-pressure, not queueing unboundedly"
        );

        gate.open();
        producer.join().unwrap();
        for rx in rx_rx.iter() {
            assert!(rx.recv().expect("reply").value.is_ok());
        }
        let stats = svc.stop();
        assert_eq!(stats.requests, 5);
        assert_eq!(stats.errors, 0);
        assert!(
            stats.queue_full_stalls >= 1,
            "blocked sends must be visible in stats: {stats:?}"
        );
    }

    /// Regression (shutdown-drop bug): requests queued behind the shutdown
    /// marker must be served during the drain, not dropped with a
    /// disconnected reply channel.
    #[test]
    fn shutdown_drains_queued_requests_instead_of_dropping() {
        let engine = leak_engine(&Topology::single_node(), 2);
        let (svc, client) =
            DotService::start_on(ServiceConfig { router_queue_depth: 8, ..Default::default() }, engine);
        let gate = Gate::close(engine, 0);

        let mut rng = Rng::new(37);
        let n = 200_000;
        // the submitter picks this up and blocks inside the gated engine
        let rx1 = client.submit(1, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
        // inject the shutdown marker *ahead* of two more requests: without
        // the drain, the submitter would exit at the marker and drop them
        let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
        router.queues[0].send(Msg::Shutdown).unwrap();
        let rx2 = client.submit(2, "kahan", vec![1.0; 64], vec![2.0; 64]);
        let rx3 = client.submit(3, "kahan", vec![1.0; 64], vec![3.0; 64]);

        gate.open();
        let stats = svc.stop();
        assert!(rx1.recv().expect("pre-shutdown reply").value.is_ok());
        assert_eq!(rx2.recv().expect("drained reply 2").value.expect("value"), 128.0);
        assert_eq!(rx3.recv().expect("drained reply 3").value.expect("value"), 192.0);
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.drained, 2, "{stats:?}");
        assert_eq!(stats.errors, 0);
    }

    // ---- lane batching: coalescing, admission batching, controls ----

    /// Wait until shard 0's engine has started executing at least `n`
    /// requests (the submitter is then *inside* the engine, so everything
    /// submitted next queues up behind it deterministically).
    fn wait_engine_requests(engine: &ShardedEngine, n: u64) {
        let t0 = Instant::now();
        while engine.shard(0).stats().requests < n {
            assert!(t0.elapsed() < Duration::from_secs(30), "engine never started request {n}");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// THE tentpole behavior, deterministically: a lane that wakes up with
    /// k ≥ 2 queued small dots executes them as ONE engine batch, with
    /// bit-identical results to serial re-submission.
    #[test]
    fn lane_coalesces_queued_small_dots_into_one_engine_batch() {
        let engine = leak_engine(&Topology::single_node(), 2);
        let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
        let gate = Gate::close(engine, 0);

        let mut rng = Rng::new(61);
        let n_big = 200_000; // 1.6 MB: parallel path, blocks on the gate
        let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
        // the submitter must be INSIDE the big dot before the burst is
        // queued, so the burst becomes exactly one wake-up's gather
        wait_engine_requests(engine, 1);

        let smalls: Vec<(Vec<f32>, Vec<f32>)> = [512usize, 1024, 700, 2048, 64, 4096]
            .iter()
            .map(|&n| (rng.normal_f32_vec(n), rng.normal_f32_vec(n)))
            .collect();
        let rxs: Vec<_> = smalls
            .iter()
            .enumerate()
            .map(|(i, (a, b))| client.submit(1 + i as u64, "kahan", a.clone(), b.clone()))
            .collect();

        gate.open();
        assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
        let batched: Vec<f32> = rxs
            .into_iter()
            .map(|rx| {
                let resp = rx.recv_timeout(Duration::from_secs(30)).expect("batched reply");
                assert_eq!(resp.batch_size, 6, "all six queued smalls must share one batch");
                resp.value.expect("batched value")
            })
            .collect();
        // serial re-submission (blocking ⇒ no coalescing) must be
        // bit-identical: batching never changes bits
        for (i, (a, b)) in smalls.iter().enumerate() {
            let serial = client.dot_blocking("kahan", a.clone(), b.clone()).expect("serial");
            assert_eq!(
                serial.to_bits(),
                batched[i].to_bits(),
                "req {i}: batched vs serial bits differ"
            );
        }

        let stats = svc.stop();
        assert_eq!(stats.batches, 1, "{stats:?}");
        assert_eq!(stats.batched_requests, 6, "{stats:?}");
        assert_eq!(stats.requests, 13, "{stats:?}");
        assert_eq!(stats.errors, 0, "{stats:?}");
        // one batch call + the big dot + 6 serial singles
        assert_eq!(stats.engine_calls, 8, "{stats:?}");
        assert_eq!(stats.lanes[0].executed, 13, "{stats:?}");
        let est = engine.stats();
        assert_eq!(est.batched, 6, "engine must see the 6 batched dots: {est:?}");
    }

    /// `max_batch = 1` is the unbatched control: the identical burst
    /// executes per-request.
    #[test]
    fn max_batch_one_disables_coalescing() {
        let engine = leak_engine(&Topology::single_node(), 2);
        let (svc, client) = DotService::start_on(
            ServiceConfig { max_batch: 1, ..ServiceConfig::default() },
            engine,
        );
        let gate = Gate::close(engine, 0);
        let mut rng = Rng::new(63);
        let n_big = 200_000;
        let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
        wait_engine_requests(engine, 1);
        let rxs: Vec<_> = (0..4)
            .map(|i| {
                client.submit(1 + i, "kahan", rng.normal_f32_vec(256), rng.normal_f32_vec(256))
            })
            .collect();
        gate.open();
        assert!(rx_big.recv().expect("big").value.is_ok());
        for rx in rxs {
            let resp = rx.recv().expect("reply");
            assert_eq!(resp.batch_size, 1);
            assert!(resp.value.is_ok());
        }
        let stats = svc.stop();
        assert_eq!(stats.batches, 0, "{stats:?}");
        assert_eq!(stats.batched_requests, 0, "{stats:?}");
        assert_eq!(stats.engine_calls, 5, "{stats:?}");
    }

    /// The ROADMAP item, deterministically: a burst of admissions to one
    /// shard coalesces into ONE worker pass.
    #[test]
    fn admit_burst_coalesces_into_one_worker_pass() {
        let engine = leak_engine(&Topology::single_node(), 2);
        let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
        let gate = Gate::close(engine, 0);
        let mut rng = Rng::new(67);
        let n_big = 200_000;
        let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
        wait_engine_requests(engine, 1);

        // queue three admissions behind the blocked submitter (send the
        // raw messages: the blocking client API would deadlock here)
        let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
        let n = 4096;
        let va = rng.normal_f32_vec(n);
        let vb = rng.normal_f32_vec(n);
        let vc = rng.normal_f32_vec(n);
        let mut replies = Vec::new();
        for v in [&va, &vb, &vc] {
            let (reply, rx) = mpsc::channel();
            router.send_to(0, Msg::Admit { data: v.clone(), reply });
            replies.push(rx);
        }

        gate.open();
        assert!(rx_big.recv().expect("big").value.is_ok());
        let handles: Vec<u64> = replies
            .into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(30)).expect("admit reply").expect("handle"))
            .collect();
        assert_eq!(handles.len(), 3);

        // the admitted streams are live and dot correctly
        let got = client.dot_pooled_blocking("kahan", handles[0], handles[1]).expect("pooled");
        let want = client.dot_blocking("kahan", va.clone(), vb.clone()).expect("direct");
        assert_eq!(got.to_bits(), want.to_bits());

        let stats = svc.stop();
        assert_eq!(stats.admitted, 3, "{stats:?}");
        assert_eq!(stats.admit_batches, 1, "burst must be one worker pass: {stats:?}");
        assert_eq!(stats.errors, 0, "{stats:?}");
    }

    /// `admit_pair` admits a co-located stream pair in a single message.
    #[test]
    fn admit_pair_places_both_streams_on_one_shard_in_one_message() {
        let engine = leak_engine(&Topology::fake_even(2), 1);
        let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
        let mut rng = Rng::new(71);
        let n = 8192;
        let va = rng.normal_f32_vec(n);
        let vb = rng.normal_f32_vec(n);
        let (ha, hb) = client.admit_pair_blocking(va.clone(), vb.clone()).expect("pair");
        assert_ne!(ha, hb);
        let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
        {
            let streams = router.streams.read().unwrap();
            assert_eq!(
                streams[&ha].shard, streams[&hb].shard,
                "pair must share one home shard"
            );
        }
        let got = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
        let want = client.dot_blocking("kahan", va, vb).expect("direct dot");
        assert_eq!(got.to_bits(), want.to_bits(), "co-located pair must not change bits");
        let stats = svc.stop();
        assert_eq!(stats.admitted, 2, "{stats:?}");
        assert_eq!(stats.errors, 0, "{stats:?}");
    }

    // ---- Pjrt backend: skipped without artifacts ----

    #[test]
    fn service_round_trip_and_batching() {
        if !artifacts_present() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let (svc, client) = DotService::start(pjrt_config()).unwrap();
        let mut rng = Rng::new(5);
        let n = 2048;
        // submit a burst so the batcher can fuse them
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..6u64 {
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            expected.push(exact_dot_f32(&a, &b));
            rxs.push(client.submit(i, "kahan", a, b));
        }
        let mut batched_seen = false;
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().expect("response");
            assert_eq!(resp.id, i as u64);
            let v = resp.value.expect("value") as f64;
            assert!((v - expected[i]).abs() < 1e-2, "req {i}: {v} vs {}", expected[i]);
            batched_seen |= resp.batch_size > 1;
        }
        let stats = svc.stop();
        assert_eq!(stats.requests, 6);
        assert!(stats.errors == 0);
        assert!(batched_seen, "burst of 6 should have batched at least once");
        assert!(stats.pjrt_calls < 6, "batching must reduce PJRT calls: {stats:?}");
    }

    #[test]
    fn naive_and_kahan_variants_route_correctly() {
        if !artifacts_present() {
            return;
        }
        let (svc, client) = DotService::start(pjrt_config()).unwrap();
        let a = vec![1.0f32; 100];
        let b = vec![2.0f32; 100];
        let vk = client.dot_blocking("kahan", a.clone(), b.clone()).unwrap();
        let vn = client.dot_blocking("naive", a, b).unwrap();
        assert_eq!(vk, 200.0);
        assert_eq!(vn, 200.0);
        svc.stop();
    }

    #[test]
    fn oversized_request_errors_cleanly() {
        if !artifacts_present() {
            return;
        }
        let (svc, client) = DotService::start(pjrt_config()).unwrap();
        let big = vec![0.0f32; 1 << 21]; // 2M > 65536 and > batched n
        let r = client.dot_blocking("kahan", big.clone(), big);
        assert!(r.is_err());
        svc.stop();
    }
}
