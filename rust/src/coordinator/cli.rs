//! The `repro` CLI: regenerate every paper artifact from the command line.

use super::{experiments, report, validate};
use crate::isa::Precision;
use crate::machine::{detect, preset, PresetId};
use crate::sim;
use crate::util::cli::Args;
use std::path::PathBuf;

const HELP: &str = "\
repro — reproduce 'Performance analysis of the Kahan-enhanced scalar product'

USAGE: repro <command> [options]

Paper artifacts (virtual testbed + ECM model):
  table1                Table 1: testbed specification
  table2                Table 2: ECM models for AVX Kahan across sockets
  models [--arch A] [--dtype sp|dp]
                        §3/Eq.2: full ECM model zoo for one socket
  fig2   [--arch A] [--dtype sp|dp] [--full]
                        Fig. 2: single-core cy/CL vs working-set sweep
  fig3   [--arch A] [--dtype sp|dp]
                        Figs. 3a/3b: in-memory multicore scaling
  fig4a                 Fig. 4a: per-level cy/CL across sockets
  fig4b                 Fig. 4b: in-memory scaling across sockets
  fma                   §4: Kahan-FMA study on HSW/BDW
  ablation [--arch A] [--dtype sp|dp]
                        design ablations: unroll sweep, miss-overhead on/off
  validate              compare every paper number against this build
  all                   run everything above and write out/ reports

Host silicon (likwid-bench analog):
  host-info             detected machine model + SIMD features
  host-sweep [--reps N] [--full]
                        sweep real SIMD kernels on this machine
  host-scaling [--threads N]
                        thread scaling on this machine
  engine-info           persistent dot engine: autotuned kernel dispatch
                        table, worker/pool state, smoke dot
  calibrate [--write] [--path P]
                        measure the calibration profile (split bandwidth,
                        kernel throughput, accuracy-tier ratios) and print
                        every threshold it derives; --write persists it so
                        future starts plan on measured numbers
  plan --len N [--precision f32|f64] [--batch K] [--accuracy A] [--window-us U]
       [--deadline-us D] [--queued Q] [--est-service-us E]
                        explain the planner's decision for one request:
                        route, size class, the split threshold and its
                        provenance (measured vs default), the accuracy
                        tier's chosen kernel and any free upgrade, fuse
                        cutoff (A: naive|kahan|dot2|exact), and — given a
                        deadline D and a lane with Q queued messages —
                        the admission gate's shed verdict
  accuracy [--n N] [--trials T]
                        error vs condition number (algorithm zoo)

Options:
  --arch snb|ivb|hsw|bdw   target socket (default ivb)
  --dtype sp|dp            precision (default sp)
  --out DIR                report directory (default out/)
  --csv                    also write CSV series
";

fn parse_arch(args: &Args) -> Result<crate::machine::Machine, String> {
    let a = args.opt("arch", "ivb");
    PresetId::parse(&a).map(preset).ok_or_else(|| format!("unknown arch `{a}`"))
}

fn parse_prec(args: &Args) -> Result<Precision, String> {
    let d = args.opt("dtype", "sp");
    Precision::parse(&d).ok_or_else(|| format!("unknown dtype `{d}`"))
}

/// Print the host ECM governance verdict: which machine model produced it
/// (detected host vs Table-1 preset fallback), the predicted saturation
/// cores per (precision, size class), and the worker cap the given policy
/// actually applies (autotuner-corrected; `policy` may be ungoverned, in
/// which case every class prints uncapped).
fn print_ecm_verdict(policy: &crate::engine::PlanPolicy) {
    let v = crate::ecm::governance::host_verdict();
    let table = crate::engine::dispatch();
    println!("ecm governance: model from {}", v.source.describe());
    for (pi, prec) in [Precision::Sp, Precision::Dp].into_iter().enumerate() {
        for class in crate::engine::SizeClass::ALL.iter() {
            let sat = v.sat_cores[pi][class.index()];
            let pred = if sat == 0 {
                "no shared-bandwidth ceiling predicted".to_string()
            } else {
                format!("predicted saturation at {sat} core(s)")
            };
            let cap = table.corrected_sat(prec, *class, policy.worker_cap(prec, *class));
            let applied = if cap == usize::MAX {
                "fan-out uncapped".to_string()
            } else {
                format!("worker cap {cap} (clamped to each shard's worker count)")
            };
            println!(
                "  {} {:<3}: {pred} -> {applied}",
                crate::ecm::governance::PREC_NAMES[pi],
                class.name()
            );
        }
    }
}

/// Entry point; returns the process exit code.
pub fn cli_main() -> i32 {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    }
}

/// Dispatch a parsed command (separated from `cli_main` for tests).
pub fn run(args: &Args) -> Result<(), String> {
    let out: PathBuf = args.opt("out", "out").into();
    let csv = args.flag("csv");
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".into());

    match cmd.as_str() {
        "help" | "--help" => {
            println!("{HELP}");
        }
        "table1" => println!("{}", experiments::table1().render()),
        "table2" => println!("{}", experiments::table2().render()),
        "models" => {
            let m = parse_arch(args)?;
            let p = parse_prec(args)?;
            println!("{}", experiments::models_table(&m, p).render());
        }
        "fig2" => {
            let m = parse_arch(args)?;
            let p = parse_prec(args)?;
            let sizes = if args.flag("full") {
                sim::engine::default_sweep_sizes()
            } else {
                vec![
                    8 << 10,
                    16 << 10,
                    32 << 10,
                    64 << 10,
                    128 << 10,
                    256 << 10,
                    1 << 20,
                    4 << 20,
                    16 << 20,
                    64 << 20,
                    256 << 20,
                ]
            };
            let series = experiments::fig2(&m, p, &sizes);
            println!("{}", experiments::fig2_table(&m, &series).render());
            if csv {
                report::save_sweep_csv(&out, &format!("fig2_{}", m.shorthand), &series)
                    .map_err(|e| e.to_string())?;
                println!("wrote {}/fig2_{}.csv", out.display(), m.shorthand);
            }
        }
        "fig3" => {
            let m = parse_arch(args)?;
            let p = parse_prec(args)?;
            let series = experiments::fig3(&m, p);
            println!("{}", experiments::fig3_table(&m, p, &series).render());
            if csv {
                let name = format!(
                    "fig3{}_{}",
                    if p == Precision::Sp { "a" } else { "b" },
                    m.shorthand
                );
                report::save_scaling_csv(&out, &name, &series).map_err(|e| e.to_string())?;
                println!("wrote {}/{name}.csv", out.display());
            }
        }
        "fig4a" => {
            let rows = experiments::fig4a(Precision::Sp);
            println!("{}", experiments::fig4a_table(&rows).render());
        }
        "fig4b" => {
            let series = experiments::fig4b(Precision::Sp);
            println!("{}", experiments::fig4b_table(&series).render());
        }
        "fma" => println!("{}", experiments::fma_study(Precision::Sp).render()),
        "ablation" => {
            let m = parse_arch(args)?;
            let p = parse_prec(args)?;
            println!("{}", super::ablation::unroll_ablation(&m, p).render());
            let k = crate::isa::generate(crate::isa::Variant::Kahan, crate::isa::Simd::Avx, p, 0);
            println!("{}", super::ablation::overhead_ablation(&m, &k).render());
        }
        "validate" => {
            let (t, ok) = validate::report();
            println!("{}", t.render());
            if !ok {
                return Err("validation FAILED".into());
            }
            println!("all paper numbers reproduced within tolerance");
        }
        "all" => {
            run_all_reports(&out)?;
        }
        "host-info" => {
            let m = detect::detect_host();
            println!("host: {} ({} cores, {:.2} GHz tsc)", m.name, m.cores, m.clock_ghz);
            let simd = detect::host_simd();
            println!(
                "simd: sse={} avx2={} fma={} avx512f={}",
                simd.sse, simd.avx2, simd.fma, simd.avx512f
            );
            let topo = crate::engine::topology_cached();
            println!("numa: {} domain(s) [{}]", topo.nodes.len(), topo.render());
            for c in &m.caches {
                println!("{}: {}", c.name, crate::util::fmt::bytes(c.size_bytes));
            }
            println!(
                "measured load bandwidth: {:.1} GB/s",
                crate::bench::sweep::measure_load_bandwidth()
            );
        }
        "host-sweep" => {
            let reps = args.num("reps", 5usize).map_err(|e| e.to_string())?;
            let quick = !args.flag("full");
            println!("{}", experiments::host_sweep_table(reps, quick).render());
        }
        "host-scaling" => {
            let threads = args.num("threads", 0u32).map_err(|e| e.to_string())?;
            let max = if threads == 0 {
                std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(1)
            } else {
                threads
            };
            let k = crate::bench::kernels::by_name("kahan-AVX2-SP").ok_or("no kernel")?;
            let pts = crate::bench::threads::scaling_curve(&k, max, 1 << 22, 150);
            let mut t = crate::util::Table::new("Host thread scaling (kahan-AVX2-SP, in-memory)")
                .headers(["threads", "GUP/s", "imbalance"]);
            for p in pts {
                t.row([
                    p.threads.to_string(),
                    format!("{:.3}", p.gups),
                    format!("{:.2}", p.imbalance),
                ]);
            }
            println!("{}", t.render());
        }
        "engine-info" => {
            println!("calibrating kernel dispatch (first use only)...");
            let table = crate::engine::dispatch();
            println!("{}", table.render().render());
            let topo = crate::engine::topology_cached();
            println!("numa topology: {} domain(s) [{}]", topo.nodes.len(), topo.render());
            let e = crate::engine::ShardedEngine::global();
            println!(
                "sharded engine: {} shard(s), {} workers total (pinned per-domain), \
                 split threshold {}",
                e.shards(),
                e.total_workers(),
                crate::util::fmt::bytes(e.config().split_min_bytes as u64)
            );
            print_ecm_verdict(e.policy());
            let svc_cfg = crate::coordinator::ServiceConfig::default();
            println!(
                "service router pool: {} submitter(s) (one per shard), default per-shard \
                 queue depth {} (configurable; senders block when full, stalls counted \
                 in ServiceStats)",
                e.shards(),
                svc_cfg.router_queue_depth
            );
            println!(
                "lane batching: up to {} queued small dots fuse into one engine batch \
                 per wake-up (bit-identical to serial; admission bursts coalesce into \
                 one worker pass)",
                svc_cfg.max_batch
            );
            println!(
                "adaptive window: batch_window_us = {} (0 = opportunistic only; when set, \
                 lanes wait only where the planner says the fused kernel wins — see \
                 `repro plan`)",
                svc_cfg.batch_window_us
            );
            for (s, es) in e.stats_per_shard().iter().enumerate() {
                println!(
                    "  shard {s}: {} workers, pin failures {}, respawns {} \
                     (respawn pin failures {}){}",
                    e.shard(s).threads(),
                    es.pin_failures,
                    es.respawns,
                    es.respawn_pin_failures,
                    if e.is_quarantined(s) { "  [QUARANTINED]" } else { "" }
                );
            }
            let mut rng = crate::util::Rng::new(1);
            let n = 1 << 20;
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let exact = crate::accuracy::exact::exact_dot_f32(&a, &b);
            let got = e.dot_f32(crate::isa::Accuracy::Kahan, &a, &b) as f64;
            let s = e.stats();
            println!("smoke dot (n = {n}): engine {got:.6e}, exact {exact:.6e}");
            println!(
                "engine stats: {} requests, {} parallel, {} batched, {} split, {} capped, \
                 pool hits/misses {}/{}",
                s.requests,
                s.parallel,
                s.batched,
                s.split_dots,
                s.capped_requests,
                s.pool.hits,
                s.pool.misses
            );
            // degraded-health warnings: a respawn means a worker died or
            // wedged and was replaced; a pin failure (startup or respawn)
            // means a worker runs unpinned and the NUMA placement story
            // no longer holds for it
            if s.respawns > 0 {
                println!(
                    "WARNING: {} worker respawn(s) — workers died or wedged and were \
                     replaced (results stay bit-exact; investigate the host)",
                    s.respawns
                );
            }
            if s.pin_failures > 0 || s.respawn_pin_failures > 0 {
                println!(
                    "WARNING: {} pin failure(s) + {} respawn pin failure(s) — some \
                     workers run unpinned; per-domain bandwidth isolation is degraded",
                    s.pin_failures, s.respawn_pin_failures
                );
            }
        }
        "plan" => {
            let len = args.num("len", 0usize).map_err(|e| e.to_string())?;
            let prec_s = args.opt("precision", "f32");
            let acc_s = args.opt("accuracy", "kahan");
            let batch = args.num("batch", 1usize).map_err(|e| e.to_string())?;
            let window_us = args.num("window-us", 0u64).map_err(|e| e.to_string())?;
            let deadline_us = args.num("deadline-us", 0u64).map_err(|e| e.to_string())?;
            let queued = args.num("queued", 0usize).map_err(|e| e.to_string())?;
            let est_us_flag = args.num("est-service-us", 0u64).map_err(|e| e.to_string())?;
            if len == 0 {
                return Err("plan: --len N (elements per stream) is required".into());
            }
            let prec = match prec_s.as_str() {
                "f32" | "sp" => Precision::Sp,
                "f64" | "dp" => Precision::Dp,
                other => return Err(format!("unknown precision `{other}` (f32|f64)")),
            };
            let accuracy = crate::isa::Accuracy::parse(&acc_s).ok_or_else(|| {
                format!("unknown accuracy tier `{acc_s}` (naive|kahan|dot2|exact)")
            })?;
            let batch = batch.max(1);
            let elem: u64 = if prec == Precision::Sp { 4 } else { 8 };
            let total_bytes = 2 * len as u64 * elem;

            println!("calibrating kernel dispatch (first use only)...");
            let table = crate::engine::dispatch();
            let engine = crate::engine::ShardedEngine::global();
            // the exact policy the serving stack routes by: the engine
            // tier's thresholds plus the requested service knobs (and the
            // default service's lane depth for the shed verdict below)
            let svc_defaults = super::ServiceConfig::default();
            let policy = engine
                .policy()
                .clone()
                .with_service(batch, window_us)
                .with_admission(svc_defaults.router_queue_depth, svc_defaults.per_client_inflight)
                .with_upgrade(svc_defaults.auto_upgrade_accuracy);
            let plan = policy.plan_dot(0, accuracy, total_bytes);
            let kernel = table.select(prec, accuracy, plan.class);
            let fused = crate::engine::plan::batch_exec(table, prec, accuracy, plan.class, batch);
            let bytes = crate::util::fmt::bytes;

            println!();
            println!("plan for one {acc_s} {prec_s} dot, n = {len} per stream:");
            println!(
                "  working set : {} (both streams) -> size class {}",
                bytes(plan.total_bytes),
                plan.class.name()
            );
            println!("  route       : {}", plan.route.name());
            use crate::engine::DotRoute;
            if accuracy == crate::isa::Accuracy::Exact {
                println!(
                    "    why: the exact tier always routes Inline on one worker — scalar \
                     expansion arithmetic has no partial-merge story, so routing never \
                     touches its bits"
                );
            } else {
                match plan.route {
                DotRoute::Inline => println!(
                    "    why: {} < parallel cutoff {} — a worker handoff would cost more \
                     than it amortizes, so the dot runs on the submitting thread",
                    bytes(plan.total_bytes),
                    bytes(policy.parallel_cutoff_bytes as u64)
                ),
                DotRoute::Parallel => println!(
                    "    why: {} >= parallel cutoff {} but < split threshold {} — chunked \
                     compensated reduction across shard {}'s {} worker(s)",
                    bytes(plan.total_bytes),
                    bytes(policy.parallel_cutoff_bytes as u64),
                    bytes(policy.split_min_bytes as u64),
                    plan.shard,
                    policy.shard_workers[plan.shard]
                ),
                DotRoute::Split => {
                    let chunks = policy.split_chunk_count();
                    println!(
                        "    why: {} >= split threshold {} — weighted split across all {} \
                         shard(s), {} global chunks, flat compensated merge",
                        bytes(plan.total_bytes),
                        bytes(policy.split_min_bytes as u64),
                        policy.shards(),
                        chunks
                    );
                    for (s, lo, hi) in policy.split_blocks(chunks) {
                        println!(
                            "      shard {s}: chunks {lo}..{hi} ({} worker(s))",
                            policy.shard_workers[s]
                        );
                    }
                }
                }
            }
            println!(
                "  split min   : {} [{}]",
                bytes(policy.split_min_bytes as u64),
                engine.split_min_source()
            );
            println!(
                "  shard route : {} shard(s); fresh requests round-robin (this plan assumed \
                 shard {}), pooled streams execute on their home shard",
                policy.shards(),
                plan.shard
            );
            // the free-upgrade verdict for this request (the tier it is
            // actually served at under the default service config)
            let (_, up_ratio) = policy.upgrade_accuracy(accuracy, total_bytes);
            match up_ratio {
                Some(r) => println!(
                    "  accuracy    : naive requested, served at kahan — FREE upgrade \
                     (measured kahan/naive {r:.2} >= {:.2} for class {}; strictly more \
                     accurate at measured-equal speed; ServiceConfig::auto_upgrade_accuracy \
                     disables)",
                    crate::engine::plan::FREE_UPGRADE_RATIO,
                    plan.class.name()
                ),
                None if accuracy == crate::isa::Accuracy::Naive => println!(
                    "  accuracy    : naive served as requested ({})",
                    if policy.calibration.is_none() {
                        "no calibration profile — run `repro calibrate --write` to enable \
                         free upgrades"
                    } else if !policy.auto_upgrade {
                        "auto-upgrade disabled"
                    } else {
                        "measured kahan/naive ratio below the free-upgrade threshold for \
                         this class"
                    }
                ),
                None => {}
            }
            // the governance verdict behind the fan-out this plan realizes
            print_ecm_verdict(&policy);
            {
                let cap = table.corrected_sat(prec, plan.class, policy.worker_cap(prec, plan.class));
                let workers = policy.shard_workers[plan.shard];
                if cap < workers {
                    println!(
                        "  governance  : this request's fan-out is capped at {cap} of shard \
                         {}'s {workers} worker(s) — chunk geometry (and therefore bits) is \
                         unchanged; the freed workers serve other lanes concurrently",
                        plan.shard
                    );
                } else {
                    println!(
                        "  governance  : cap does not bind for this request (full fan-out on \
                         shard {}'s {workers} worker(s))",
                        plan.shard
                    );
                }
            }
            if accuracy == crate::isa::Accuracy::Exact {
                println!(
                    "  kernel      : {} (never timed at calibration: correctly rounded \
                     scalar expansion)",
                    kernel.name
                );
            } else {
                println!(
                    "  kernel      : {} ({:.0} cy at calibration probe)",
                    kernel.name,
                    table.choice(prec, plan.class).probe_cy(accuracy)
                );
            }
            if plan.route != DotRoute::Inline {
                println!(
                    "  batch of {batch}: serial — {} requests take the per-request path at \
                     any batch size (only inline-route dots fuse)",
                    plan.route.name()
                );
            } else {
                match fused {
                    Some(bk) => println!(
                        "  batch of {batch}: FUSE via {} (multi-dot twin of {}; bit-identical \
                         per request)",
                        bk.name, bk.matches
                    ),
                    None if batch < 2 => {
                        println!("  batch of {batch}: serial (a single request has nothing to fuse)")
                    }
                    None => println!(
                        "  batch of {batch}: serial loop of {} (calibration kept no fused twin \
                         for this cell)",
                        kernel.name
                    ),
                }
            }
            // the calibrated fuse cutoff for this (precision, tier) row
            let cutoff: Vec<&str> = crate::engine::SizeClass::ALL
                .iter()
                .filter(|&&c| table.select_batch(prec, accuracy, c).is_some())
                .map(|c| c.name())
                .collect();
            println!(
                "  fuse cutoff : fused kernels kept for classes [{}] (monotone; MEM always \
                 serial)",
                cutoff.join(", ")
            );
            // mirror the lane's actual decision: only inline-route dots
            // with a winning fused kernel may ever hold a window open
            let fused_wins = plan.route == DotRoute::Inline && fused.is_some();
            match policy.batch_window(1, fused_wins) {
                Some(w) => println!(
                    "  window      : a lane holding a short run may wait up to {} us for \
                     more requests (planner-approved: fusion wins at batch {batch})",
                    w.as_micros()
                ),
                None if window_us == 0 => println!(
                    "  window      : 0 us — purely opportunistic coalescing (zero added \
                     latency)"
                ),
                None if batch < 2 => println!(
                    "  window      : configured {window_us} us but max_batch = {batch} — \
                     there is no fuse to grow, so lanes never wait"
                ),
                None if plan.route != DotRoute::Inline => println!(
                    "  window      : configured {window_us} us but {} requests never \
                     wait — waiting cannot grow a fuse they will not join",
                    plan.route.name()
                ),
                None => println!(
                    "  window      : configured {window_us} us but the planner vetoes the \
                     wait for this request (calibration kept no winning fused kernel for \
                     this cell)"
                ),
            }
            // the admission gate's shed verdict, computed by the SAME pure
            // method the service lanes call (`PlanPolicy::shed`). A live
            // lane estimates per-message service time from its
            // service-time histogram mean; here the estimate is a flag,
            // defaulting to a ~10 GB/s streaming guess for this working
            // set so the verdict is still meaningful without a service.
            let est_service_us =
                if est_us_flag > 0 { est_us_flag } else { (plan.total_bytes / 10_000).max(1) };
            if deadline_us == 0 {
                println!(
                    "  admission   : no deadline — a full lane BLOCKS this sender \
                     (back-pressure); pass --deadline-us D [--queued Q] to see the shed \
                     verdict the service would reach"
                );
            } else {
                match policy.shed(deadline_us, queued, est_service_us) {
                    Some(v) if v.queue_full => println!(
                        "  admission   : SHED — the lane is full ({} queued >= depth {}); \
                         the reply is an immediate clean `shed:` error and the sender never \
                         blocks (the deadline contract)",
                        v.queued, policy.lane_depth
                    ),
                    Some(v) => println!(
                        "  admission   : SHED — projected queue wait {} us ({} queued x \
                         {est_service_us} us est. service) exceeds the {} us deadline",
                        v.projected_wait_us, v.queued, v.deadline_us
                    ),
                    None => println!(
                        "  admission   : ADMIT — projected queue wait {} us ({queued} queued \
                         x {est_service_us} us est. service) fits the {deadline_us} us \
                         deadline (lane depth {}); an admitted request whose deadline expires \
                         while it waits is still shed at serve time",
                        (queued as u64).saturating_mul(est_service_us),
                        policy.lane_depth
                    ),
                }
            }
        }
        "calibrate" => {
            let write = args.flag("write");
            let path_s = args.opt("path", "");
            println!("calibrating kernel dispatch (first use only)...");
            let p = crate::engine::CalibrationProfile::measure();
            // install so anything else this process plans (engine-info
            // style follow-ups, the global engine) uses the fresh numbers
            let _ = crate::engine::install_host_profile(p.clone());
            let bytes = crate::util::fmt::bytes;
            println!();
            println!("calibration profile (schema v{}):", p.version);
            println!(
                "  machine      : {} ({} thread(s), {} shard(s))",
                p.machine, p.threads, p.shards
            );
            println!("  load bw      : {:.1} GB/s streaming", p.mem_bw_gbs);
            println!(
                "  split fixed  : {:.1} us fan-out + merge per chunked parallel dot",
                p.split_fixed_us
            );
            for (pi, pn) in crate::ecm::governance::PREC_NAMES.iter().enumerate() {
                let g = p.kernel_gbs[pi];
                println!(
                    "  {pn} kernels  : L1 {:.1} / LLC {:.1} / MEM {:.1} GB/s single-core \
                     (kahan winner)",
                    g[0], g[1], g[2]
                );
            }
            println!(
                "  kahan/naive  : L1 {:.2} / LLC {:.2} / MEM {:.2} (>= {:.2} means the \
                 compensated tier is FREE there — naive requests auto-upgrade)",
                p.kahan_vs_naive[0],
                p.kahan_vs_naive[1],
                p.kahan_vs_naive[2],
                crate::engine::plan::FREE_UPGRADE_RATIO
            );
            println!(
                "  dot2/naive   : L1 {:.2} / LLC {:.2} / MEM {:.2}",
                p.dot2_vs_naive[0], p.dot2_vs_naive[1], p.dot2_vs_naive[2]
            );
            let topo = crate::engine::topology_cached();
            let workers: Vec<usize> = topo.nodes.iter().map(|n| n.cpus.len().max(1)).collect();
            match p.derived_split_min_bytes(&workers) {
                Some(b) => println!(
                    "  split min    : {} — measured crossover where the cross-shard \
                     split's fixed cost amortizes",
                    bytes(b)
                ),
                None => println!(
                    "  split min    : no measured crossover (single shard or no split \
                     headroom) — engines keep the built-in {} default",
                    bytes(crate::engine::DEFAULT_SPLIT_MIN_BYTES as u64)
                ),
            }
            let ww = p.worker_wedge_default_us();
            if ww > 0 {
                println!(
                    "  wedge        : worker {} ms / lane {} ms calibrated defaults \
                     (projected worst-case chunk service time x {:.0} safety)",
                    ww / 1000,
                    p.lane_wedge_default_us() / 1000,
                    crate::engine::profile::WEDGE_SAFETY_FACTOR
                );
            } else {
                println!("  wedge        : off (no usable throughput figure)");
            }
            let dest = if path_s.is_empty() {
                crate::engine::profile::resolved_path()
            } else {
                Some(PathBuf::from(&path_s))
            };
            if write || !path_s.is_empty() {
                let path = dest
                    .ok_or("profiles disabled (REPRO_PROFILE=off); pass --path P to write")?;
                p.save(&path)?;
                println!(
                    "wrote {} — future starts derive their thresholds from these \
                     measured numbers",
                    path.display()
                );
            } else {
                match dest {
                    Some(d) => {
                        println!("(dry run — pass --write to persist to {})", d.display())
                    }
                    None => println!(
                        "(profiles disabled via REPRO_PROFILE; pass --path P to write anyway)"
                    ),
                }
            }
        }
        "accuracy" => {
            let n = args.num("n", 2048usize).map_err(|e| e.to_string())?;
            let trials = args.num("trials", 7usize).map_err(|e| e.to_string())?;
            println!("{}", experiments::accuracy_table(n, trials).render());
        }
        other => return Err(format!("unknown command `{other}` (try `repro help`)")),
    }
    args.finish().map_err(|e| e.to_string())
}

/// `repro all`: write every report into `out/`.
fn run_all_reports(out: &PathBuf) -> Result<(), String> {
    let save =
        |name: &str, t: &crate::util::Table| report::save_table(out, name, t).map_err(|e| e.to_string());
    println!("writing reports to {}", out.display());

    save("table1", &experiments::table1())?;
    save("table2", &experiments::table2())?;
    for (id, m) in [
        (PresetId::Snb, "snb"),
        (PresetId::Ivb, "ivb"),
        (PresetId::Hsw, "hsw"),
        (PresetId::Bdw, "bdw"),
    ] {
        let mach = preset(id);
        save(&format!("models_{m}_sp"), &experiments::models_table(&mach, Precision::Sp))?;
    }
    let sizes = vec![
        8 << 10,
        16 << 10,
        32 << 10,
        64 << 10,
        128 << 10,
        256 << 10,
        1 << 20,
        4 << 20,
        16 << 20,
        64 << 20,
        256 << 20,
    ];
    let ivb = preset(PresetId::Ivb);
    let f2 = experiments::fig2(&ivb, Precision::Sp, &sizes);
    save("fig2_ivb", &experiments::fig2_table(&ivb, &f2))?;
    report::save_sweep_csv(out, "fig2_ivb", &f2).map_err(|e| e.to_string())?;
    for p in [Precision::Sp, Precision::Dp] {
        let s = experiments::fig3(&ivb, p);
        let name = format!("fig3{}_ivb", if p == Precision::Sp { "a" } else { "b" });
        save(&name, &experiments::fig3_table(&ivb, p, &s))?;
        report::save_scaling_csv(out, &name, &s).map_err(|e| e.to_string())?;
    }
    save("fig4a", &experiments::fig4a_table(&experiments::fig4a(Precision::Sp)))?;
    save("fig4b", &experiments::fig4b_table(&experiments::fig4b(Precision::Sp)))?;
    save("fma", &experiments::fma_study(Precision::Sp))?;
    save("ablation_unroll", &super::ablation::unroll_ablation(&ivb, Precision::Sp))?;
    let kavx = crate::isa::generate(
        crate::isa::Variant::Kahan,
        crate::isa::Simd::Avx,
        Precision::Sp,
        0,
    );
    save("ablation_overheads", &super::ablation::overhead_ablation(&ivb, &kavx))?;
    save("accuracy", &experiments::accuracy_table(2048, 7))?;
    let (vt, ok) = validate::report();
    save("validate", &vt)?;
    println!("validation: {}", if ok { "PASS" } else { "FAIL" });
    if !ok {
        return Err("validation failed".into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn table_commands_run() {
        run(&args(&["table1"])).unwrap();
        run(&args(&["table2"])).unwrap();
        run(&args(&["models", "--arch", "hsw"])).unwrap();
        run(&args(&["fma"])).unwrap();
    }

    #[test]
    fn fig2_quick_runs() {
        run(&args(&["fig2", "--arch", "ivb"])).unwrap();
    }

    #[test]
    fn validate_passes() {
        run(&args(&["validate"])).unwrap();
    }

    #[test]
    fn bad_inputs_are_errors() {
        assert!(run(&args(&["models", "--arch", "z80"])).is_err());
        assert!(run(&args(&["frobnicate"])).is_err());
        assert!(run(&args(&["table1", "--bogus", "1"])).is_err());
    }

    /// `repro plan` explains a decision for every route without erroring
    /// (exact routes depend on the host; the planner property tests pin
    /// them down — this is the CLI surface).
    #[test]
    fn plan_command_runs_and_validates_inputs() {
        run(&args(&["plan", "--len", "1000"])).unwrap();
        run(&args(&["plan", "--len", "4096", "--precision", "f64", "--batch", "4"])).unwrap();
        run(&args(&[
            "plan",
            "--len",
            "1000000",
            "--accuracy",
            "naive",
            "--window-us",
            "100",
        ]))
        .unwrap();
        // every tier is a valid request dimension now — including exact,
        // which must explain its unconditional Inline route at any size
        run(&args(&["plan", "--len", "4096", "--accuracy", "dot2", "--batch", "4"])).unwrap();
        run(&args(&["plan", "--len", "1000000", "--accuracy", "exact"])).unwrap();
        // the admission gate's shed verdict: a projected-wait SHED
        // (8 queued x 50 us >> 100 us), a comfortable ADMIT, and a
        // full-lane SHED (queued >= default depth)
        run(&args(&[
            "plan",
            "--len",
            "1000",
            "--deadline-us",
            "100",
            "--queued",
            "8",
            "--est-service-us",
            "50",
        ]))
        .unwrap();
        run(&args(&["plan", "--len", "1000", "--deadline-us", "1000000", "--queued", "1"]))
            .unwrap();
        run(&args(&["plan", "--len", "64", "--deadline-us", "10", "--queued", "64"])).unwrap();
        assert!(run(&args(&["plan"])).is_err(), "--len is required");
        assert!(run(&args(&["plan", "--len", "10", "--precision", "f16"])).is_err());
        assert!(run(&args(&["plan", "--len", "10", "--accuracy", "fast"])).is_err());
    }
}
