//! Report output: write experiment tables and CSV series to an output
//! directory (`out/` by default), mirroring what the paper's plots consume.

use crate::util::csv::write_csv;
use crate::util::Table;
use std::path::Path;

/// Write a rendered table to `<dir>/<name>.txt` and markdown to `.md`.
pub fn save_table(dir: &Path, name: &str, table: &Table) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.txt")), table.render())?;
    std::fs::write(dir.join(format!("{name}.md")), table.render_markdown())?;
    Ok(())
}

/// Write Fig. 2-style sweep series as CSV: ws_bytes, then one column per
/// series.
pub fn save_sweep_csv(
    dir: &Path,
    name: &str,
    series: &[super::experiments::SweepSeries],
) -> std::io::Result<()> {
    let mut header = vec!["ws_bytes".to_string()];
    header.extend(series.iter().map(|s| s.kernel.clone()));
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let n = series.first().map(|s| s.points.len()).unwrap_or(0);
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let mut row = vec![series[0].points[i].ws_bytes.to_string()];
        for s in series {
            row.push(format!("{:.4}", s.points[i].cy_per_cl));
        }
        rows.push(row);
    }
    write_csv(dir.join(format!("{name}.csv")), &header_refs, &rows)
}

/// Write scaling series (Fig. 3 / 4b) as CSV: cores, then sim and model
/// columns per kernel.
pub fn save_scaling_csv(
    dir: &Path,
    name: &str,
    series: &[super::experiments::ScalingSeries],
) -> std::io::Result<()> {
    let mut header = vec!["cores".to_string()];
    for s in series {
        header.push(format!("{}_sim", s.kernel));
        header.push(format!("{}_model", s.kernel));
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let n = series.first().map(|s| s.sim.len()).unwrap_or(0);
    let mut rows = Vec::with_capacity(n);
    for i in 0..n {
        let mut row = vec![(i + 1).to_string()];
        for s in series {
            row.push(format!("{:.4}", s.sim[i].gups));
            row.push(format!("{:.4}", s.model[i].gups));
        }
        rows.push(row);
    }
    write_csv(dir.join(format!("{name}.csv")), &header_refs, &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Precision;
    use crate::machine::presets::ivb;

    #[test]
    fn save_table_writes_both_formats() {
        let dir = std::env::temp_dir().join("kahan_ecm_report_test");
        let mut t = Table::new("t").headers(["a"]);
        t.row(["1"]);
        save_table(&dir, "x", &t).unwrap();
        assert!(dir.join("x.txt").exists());
        assert!(dir.join("x.md").exists());
    }

    #[test]
    fn sweep_csv_roundtrip() {
        let dir = std::env::temp_dir().join("kahan_ecm_report_sweep");
        let m = ivb();
        let series =
            super::super::experiments::fig2(&m, Precision::Sp, &[16 * 1024, 64 * 1024]);
        save_sweep_csv(&dir, "fig2", &series).unwrap();
        let text = std::fs::read_to_string(dir.join("fig2.csv")).unwrap();
        assert!(text.starts_with("ws_bytes,"));
        assert_eq!(text.lines().count(), 3);
    }
}
