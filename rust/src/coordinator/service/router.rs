//! The Host backend's shared router state and the client's routing.
//!
//! There is no central router thread: every [`DotClient`] clone routes
//! messages itself against the shared [`HostRouter`] — pooled dots to the
//! home-shard lane, fresh messages round-robin — and each shard's
//! submitter (`super::lane`) executes on *its* shard. Routing decisions
//! that depend on a threshold (split vs route, fuse vs serial, wait vs
//! serve) are never made here: they flow through the engine's plan layer
//! (`crate::engine::plan`), which the router carries as its
//! [`PlanPolicy`].

use super::stats::LaneCounters;
use super::{parse_accuracy, DotRequest, DotResponse, Msg};
use crate::engine::parallel::panic_message;
use crate::engine::{HomedSlice, PlanPolicy, ShardedEngine};
use crate::isa::Accuracy;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::time::Instant;

/// Shared state of the Host router pool: the per-shard bounded queues,
/// the admitted-stream table, and every counter. Clients route against it
/// directly — there is no central router thread.
pub(super) struct HostRouter {
    pub(super) engine: &'static ShardedEngine,
    /// the compiled routing policy: the engine tier's thresholds plus the
    /// service's batching knobs (`max_batch`, `batch_window_us`) — every
    /// coalescing and window decision in the lanes goes through it
    pub(super) policy: PlanPolicy,
    /// tier served when a request's `accuracy` string is empty
    /// (`ServiceConfig::default_accuracy`, validated at start)
    pub(super) default_accuracy: Accuracy,
    /// bounded hand-off to each shard's submitter (index == shard)
    pub(super) queues: Vec<mpsc::SyncSender<Msg>>,
    /// admitted streams: handle -> home-shard slice. Inserted by the
    /// owning submitter at admission, removed by *client* threads in
    /// `DotClient::release` (synchronously — that is what makes a release
    /// ordered against the same client's later submits), and read by
    /// clients at submit time to resolve pooled operands.
    pub(super) streams: RwLock<HashMap<u64, HomedSlice<f32>>>,
    pub(super) next_handle: AtomicU64,
    /// round-robin cursor for fresh (un-homed) messages
    pub(super) rr: AtomicUsize,
    pub(super) lanes: Vec<LaneCounters>,
    pub(super) requests: AtomicU64,
    pub(super) engine_calls: AtomicU64,
    pub(super) admitted: AtomicU64,
    pub(super) pooled_calls: AtomicU64,
    pub(super) batches: AtomicU64,
    pub(super) batched_requests: AtomicU64,
    pub(super) admit_batches: AtomicU64,
    pub(super) errors: AtomicU64,
    pub(super) drained: AtomicU64,
}

impl HostRouter {
    /// Fresh router state plus the receiving half of every lane queue
    /// (one bounded channel per shard; the caller spawns the submitters).
    pub(super) fn new(
        engine: &'static ShardedEngine,
        policy: PlanPolicy,
        queue_depth: usize,
        default_accuracy: Accuracy,
    ) -> (Arc<HostRouter>, Vec<mpsc::Receiver<Msg>>) {
        let shards = engine.shards();
        let mut queues = Vec::with_capacity(shards);
        let mut receivers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<Msg>(queue_depth);
            queues.push(tx);
            receivers.push(rx);
        }
        let router = Arc::new(HostRouter {
            engine,
            policy,
            default_accuracy,
            queues,
            streams: RwLock::new(HashMap::new()),
            next_handle: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            lanes: (0..shards).map(|_| LaneCounters::default()).collect(),
            requests: AtomicU64::new(0),
            engine_calls: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            pooled_calls: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            admit_batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            drained: AtomicU64::new(0),
        });
        (router, receivers)
    }

    /// Lane for the next fresh (un-homed) message.
    pub(super) fn route_fresh(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed) % self.queues.len()
    }

    /// Hand `msg` to shard `s`'s submitter. The queue is bounded: a full
    /// lane counts a stall and then *blocks* until the submitter catches
    /// up — back-pressure, not unbounded growth. A send after shutdown is
    /// dropped; the caller observes it as a disconnected reply channel.
    pub(super) fn send_to(&self, s: usize, msg: Msg) {
        match self.queues[s].try_send(msg) {
            Ok(()) => {
                self.lanes[s].routed.fetch_add(1, Ordering::Relaxed);
            }
            Err(mpsc::TrySendError::Full(msg)) => {
                self.lanes[s].queue_full_stalls.fetch_add(1, Ordering::Relaxed);
                // count only accepted messages — a *rejected* send must
                // not inflate `routed` (acceptance can still race the
                // submitter's exit; see the `LaneStats::routed` doc)
                if self.queues[s].send(msg).is_ok() {
                    self.lanes[s].routed.fetch_add(1, Ordering::Relaxed);
                }
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {}
        }
    }

    /// Shared tail of both dot arms: bump the execution counters, run the
    /// engine call with panic isolation, and turn an unwind into the
    /// request's own error (the client must see the real panic text).
    pub(super) fn execute(
        &self,
        s: usize,
        accuracy: &'static str,
        pooled: bool,
        dot: impl FnOnce(Accuracy) -> f32,
    ) -> Result<f32, String> {
        self.req_accuracy(accuracy).and_then(|acc| {
            self.engine_calls.fetch_add(1, Ordering::Relaxed);
            if pooled {
                self.pooled_calls.fetch_add(1, Ordering::Relaxed);
            }
            self.lanes[s].executed.fetch_add(1, Ordering::Relaxed);
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| dot(acc)))
                .map_err(|e| format!("engine panic: {}", panic_message(e)))
        })
    }

    /// Resolve a request's accuracy string: empty means the service's
    /// validated default tier, anything else must parse.
    pub(super) fn req_accuracy(&self, accuracy: &str) -> Result<Accuracy, String> {
        if accuracy.is_empty() {
            return Ok(self.default_accuracy);
        }
        parse_accuracy(accuracy)
    }

    /// Execute one message on lane `s`'s submitter thread.
    ///
    /// Length mismatches are rejected HERE, before the engine: the
    /// engine's documented policy is debug-assert + truncate (see the
    /// plan module's "Length policy"), so the service is the layer that
    /// turns a mismatch into a client-visible error.
    pub(super) fn serve(&self, s: usize, msg: Msg) {
        match msg {
            Msg::Shutdown => {}
            Msg::Req(req) => {
                self.requests.fetch_add(1, Ordering::Relaxed);
                let value = if req.a.len() != req.b.len() {
                    Err(format!("length mismatch {} vs {}", req.a.len(), req.b.len()))
                } else {
                    // no per-request heap churn: the engine reads the
                    // request's own vectors (small dots run on them in
                    // place; large dots pay one admission copy into the
                    // target shard's recycled aligned pool buffers).
                    // Executes on THIS lane's shard (routing already
                    // balanced fresh requests round-robin); the engine
                    // consumes the planner's route and fans very large
                    // dots out across every shard
                    self.execute(s, req.accuracy, false, |acc| {
                        self.engine.dot_on_f32(s, acc, &req.a, &req.b)
                    })
                };
                if value.is_err() {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = req.reply.send(DotResponse {
                    id: req.id,
                    value,
                    batch_size: 1,
                    latency: req.submitted.elapsed(),
                });
            }
            Msg::Admit { data, reply } => {
                // the copy runs on shard `s`'s own pinned workers, so
                // fresh pages first-touch in-domain
                let homed = self.engine.admit_to_f32(s, &data);
                let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                self.streams.write().unwrap().insert(handle, homed);
                self.admitted.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Ok(handle));
            }
            Msg::ReqPooled { id, accuracy, a, b, sa, sb, reply, submitted } => {
                self.requests.fetch_add(1, Ordering::Relaxed);
                let value = match (sa, sb) {
                    (Some(sa), Some(sb)) if sa.len() == sb.len() => {
                        self.execute(s, accuracy, true, |acc| {
                            self.engine.dot_homed_f32(acc, &sa, &sb)
                        })
                    }
                    (Some(sa), Some(sb)) => {
                        Err(format!("length mismatch {} vs {}", sa.len(), sb.len()))
                    }
                    (sa, _) => Err(format!(
                        "unknown stream handle {}",
                        if sa.is_some() { b } else { a }
                    )),
                };
                if value.is_err() {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = reply.send(DotResponse {
                    id,
                    value,
                    batch_size: 1,
                    latency: submitted.elapsed(),
                });
            }
            Msg::AdmitPair { a, b, reply } => {
                // one message, one worker pass, one shard for both streams
                // — the steady-state pair placement without the second
                // routing round-trip `admit_near` paid
                let homed = self.engine.admit_many_to_f32(s, &[&a, &b]);
                let mut handles = homed.into_iter().map(|h| {
                    let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                    self.streams.write().unwrap().insert(handle, h);
                    handle
                });
                let ha = handles.next().expect("pair admission");
                let hb = handles.next().expect("pair admission");
                self.admitted.fetch_add(2, Ordering::Relaxed);
                let _ = reply.send(Ok((ha, hb)));
            }
            Msg::Release { handle } => {
                // unreachable on the Host path (the client releases
                // synchronously); kept for match exhaustiveness
                self.streams.write().unwrap().remove(&handle);
            }
        }
    }
}

#[derive(Clone)]
pub(super) enum ClientInner {
    Host(Arc<HostRouter>),
    Pjrt(mpsc::Sender<Msg>),
}

/// Client-side handle for submitting requests. Cloneable and `Send`: on
/// the Host backend every clone routes directly against the shared router
/// state, so N client threads submit to N shard lanes concurrently.
#[derive(Clone)]
pub struct DotClient {
    pub(super) inner: ClientInner,
}

impl DotClient {
    /// Submit a request; returns the receiver for its response. Fresh
    /// requests round-robin across the shard lanes; a full lane blocks
    /// (back-pressure).
    pub fn submit(
        &self,
        id: u64,
        accuracy: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        let req = DotRequest { id, accuracy, a, b, reply, submitted: Instant::now() };
        match &self.inner {
            ClientInner::Host(r) => {
                let s = r.route_fresh();
                r.send_to(s, Msg::Req(req));
            }
            // a send error means the service stopped; the caller sees it
            // as a disconnected receiver
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::Req(req));
            }
        }
        rx
    }

    /// Convenience: blocking round-trip.
    pub fn dot_blocking(
        &self,
        accuracy: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Result<f32, String> {
        let rx = self.submit(0, accuracy, a, b);
        match rx.recv() {
            Ok(resp) => resp.value,
            Err(_) => Err("service stopped".into()),
        }
    }
}
