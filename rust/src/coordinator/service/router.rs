//! The Host backend's shared router state and the client's routing.
//!
//! There is no central router thread: every [`DotClient`] clone routes
//! messages itself against the shared [`HostRouter`] — pooled dots to the
//! home-shard lane, fresh messages round-robin — and each shard's
//! submitter (`super::lane`) executes on *its* shard. Routing decisions
//! that depend on a threshold (split vs route, fuse vs serial, wait vs
//! serve) are never made here: they flow through the engine's plan layer
//! (`crate::engine::plan`), which the router carries as its
//! [`PlanPolicy`].

use super::stats::LaneCounters;
use super::{msg_client, msg_deadline, parse_accuracy, DotRequest, DotResponse, Msg, ServiceError};
use crate::engine::parallel::panic_message;
use crate::engine::{HomedSlice, PlanPolicy, ShardedEngine};
use crate::isa::Accuracy;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, RwLock};
use std::time::{Duration, Instant};

/// Shared state of the Host router pool: the per-shard bounded queues,
/// the admitted-stream table, and every counter. Clients route against it
/// directly — there is no central router thread.
pub(super) struct HostRouter {
    pub(super) engine: &'static ShardedEngine,
    /// the compiled routing policy: the engine tier's thresholds plus the
    /// service's batching knobs (`max_batch`, `batch_window_us`) — every
    /// coalescing and window decision in the lanes goes through it
    pub(super) policy: PlanPolicy,
    /// tier served when a request's `accuracy` string is empty
    /// (`ServiceConfig::default_accuracy`, validated at start)
    pub(super) default_accuracy: Accuracy,
    /// bounded hand-off to each shard's submitter (index == shard)
    pub(super) queues: Vec<mpsc::SyncSender<Msg>>,
    /// admitted streams: handle -> home-shard slice. Inserted by the
    /// owning submitter at admission, removed by *client* threads in
    /// `DotClient::release` (synchronously — that is what makes a release
    /// ordered against the same client's later submits), and read by
    /// clients at submit time to resolve pooled operands.
    pub(super) streams: RwLock<HashMap<u64, HomedSlice<f32>>>,
    pub(super) next_handle: AtomicU64,
    /// round-robin cursor for fresh (un-homed) messages
    pub(super) rr: AtomicUsize,
    pub(super) lanes: Vec<LaneCounters>,
    pub(super) requests: AtomicU64,
    pub(super) engine_calls: AtomicU64,
    pub(super) admitted: AtomicU64,
    pub(super) pooled_calls: AtomicU64,
    pub(super) batches: AtomicU64,
    pub(super) batched_requests: AtomicU64,
    pub(super) admit_batches: AtomicU64,
    pub(super) errors: AtomicU64,
    /// naive requests served at kahan because the calibration profile's
    /// measured class ratio said compensation is free
    /// ([`PlanPolicy::upgrade_accuracy`]; `ServiceConfig::auto_upgrade_accuracy`)
    pub(super) accuracy_upgrades: AtomicU64,
    pub(super) release_misses: AtomicU64,
    pub(super) drained: AtomicU64,
    /// dead or wedged lane submitters replaced by the supervisor
    pub(super) lane_restarts: AtomicU64,
    /// shards pulled from fresh routing after exhausting their respawn
    /// budget (probe-based reinstatement does not decrement this)
    pub(super) quarantines: AtomicU64,
}

impl HostRouter {
    /// Fresh router state plus the receiving half of every lane queue
    /// (one bounded channel per shard; the caller spawns the submitters).
    pub(super) fn new(
        engine: &'static ShardedEngine,
        policy: PlanPolicy,
        queue_depth: usize,
        default_accuracy: Accuracy,
    ) -> (Arc<HostRouter>, Vec<mpsc::Receiver<Msg>>) {
        let shards = engine.shards();
        let mut queues = Vec::with_capacity(shards);
        let mut receivers = Vec::with_capacity(shards);
        for _ in 0..shards {
            let (tx, rx) = mpsc::sync_channel::<Msg>(queue_depth);
            queues.push(tx);
            receivers.push(rx);
        }
        let router = Arc::new(HostRouter {
            engine,
            policy,
            default_accuracy,
            queues,
            streams: RwLock::new(HashMap::new()),
            next_handle: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            lanes: (0..shards).map(|_| LaneCounters::default()).collect(),
            requests: AtomicU64::new(0),
            engine_calls: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            pooled_calls: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batched_requests: AtomicU64::new(0),
            admit_batches: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            accuracy_upgrades: AtomicU64::new(0),
            release_misses: AtomicU64::new(0),
            drained: AtomicU64::new(0),
            lane_restarts: AtomicU64::new(0),
            quarantines: AtomicU64::new(0),
        });
        (router, receivers)
    }

    /// Lane for the next fresh (un-homed) message. Skips lanes whose
    /// shard is quarantined by the supervisor — routing never changes
    /// bits, so rerouting is always safe. When EVERY shard is
    /// quarantined the filter is ignored: degraded service beats
    /// refusing to serve (mirrors `ShardedEngine::route`).
    pub(super) fn route_fresh(&self) -> usize {
        let n = self.queues.len();
        for _ in 0..n {
            let s = self.rr.fetch_add(1, Ordering::Relaxed) % n;
            if !self.engine.is_quarantined(s) {
                return s;
            }
        }
        self.rr.fetch_add(1, Ordering::Relaxed) % n
    }

    /// Hand `msg` to shard `s`'s submitter. The queue is bounded: a full
    /// lane counts a stall and then *blocks* until the submitter catches
    /// up — back-pressure, not unbounded growth — UNLESS the message
    /// carries an admission deadline, in which case it is shed instead
    /// (the priority-inversion fix: a deadlined request never blocks its
    /// sender; the admission gate races the queue, so this is the
    /// authoritative full-lane check). A send after shutdown is dropped;
    /// the caller observes it as a disconnected reply channel.
    pub(super) fn send_to(&self, s: usize, msg: Msg) {
        match self.queues[s].try_send(msg) {
            Ok(()) => {
                self.lanes[s].routed.fetch_add(1, Ordering::Relaxed);
                self.lanes[s].queued.fetch_add(1, Ordering::Relaxed);
            }
            Err(mpsc::TrySendError::Full(msg)) => {
                let deadline_us = msg_deadline(&msg);
                if deadline_us > 0 {
                    self.lanes[s].shed.fetch_add(1, Ordering::Relaxed);
                    self.client_done_for(s, &msg);
                    self.reject(
                        msg,
                        ServiceError::ShedQueueFull {
                            lane: s,
                            queued: None,
                            deadline_us,
                            // the channel itself rejected the send, so no
                            // verdict hint exists — one service time is
                            // the earliest a slot can plausibly free up
                            retry_after_us: self.lanes[s].est_service_us().max(1),
                        },
                    );
                    return;
                }
                self.lanes[s].queue_full_stalls.fetch_add(1, Ordering::Relaxed);
                let stall_start = Instant::now();
                // count only accepted messages — a *rejected* send must
                // not inflate `routed` (acceptance can still race the
                // submitter's exit; see the `LaneStats::routed` doc)
                if self.queues[s].send(msg).is_ok() {
                    self.lanes[s].routed.fetch_add(1, Ordering::Relaxed);
                    self.lanes[s].queued.fetch_add(1, Ordering::Relaxed);
                }
                let stalled = stall_start.elapsed().as_micros() as u64;
                self.lanes[s].stalled_us.fetch_add(stalled, Ordering::Relaxed);
                // fold the stall into the queue-wait attribution: a
                // blocked sender IS queue wait, just paid before the
                // message entered the lane
                self.lanes[s].record_wait_us(stalled);
            }
            Err(mpsc::TrySendError::Disconnected(msg)) => {
                self.client_done_for(s, &msg);
            }
        }
    }

    /// The overload admission gate for dot messages (`Msg::Req` /
    /// `Msg::ReqPooled`), run on the CLIENT thread before the queue:
    /// deadline shed first (pure [`PlanPolicy::shed`] over the lane's
    /// live depth gauge and its histogram-derived service-time estimate),
    /// then per-client fair admission, then the normal send. Sheds reply
    /// `Err("shed: …")` immediately — they are clean rejects, counted in
    /// `shed`/`fair_sheds` but never in `requests` or `errors`, and they
    /// never reach an engine.
    pub(super) fn admit_or_shed(&self, s: usize, msg: Msg) {
        let deadline_us = msg_deadline(&msg);
        if deadline_us > 0 {
            let queued = self.lanes[s].queued.load(Ordering::Relaxed) as usize;
            let est = self.lanes[s].est_service_us();
            if let Some(v) = self.policy.shed(deadline_us, queued, est) {
                self.lanes[s].shed.fetch_add(1, Ordering::Relaxed);
                let why = if v.queue_full {
                    ServiceError::ShedQueueFull {
                        lane: s,
                        queued: Some(v.queued),
                        deadline_us: v.deadline_us,
                        retry_after_us: v.retry_after_us,
                    }
                } else {
                    ServiceError::ShedProjected {
                        lane: s,
                        projected_wait_us: v.projected_wait_us,
                        deadline_us: v.deadline_us,
                        queued: v.queued,
                        retry_after_us: v.retry_after_us,
                    }
                };
                self.reject(msg, why);
                return;
            }
        }
        if self.policy.per_client_inflight > 0 {
            if let Some(client) = msg_client(&msg) {
                if !self.client_admit(s, client) {
                    self.lanes[s].fair_sheds.fetch_add(1, Ordering::Relaxed);
                    self.reject(
                        msg,
                        ServiceError::ShedFairness {
                            client,
                            cap: self.policy.per_client_inflight,
                            lane: s,
                        },
                    );
                    return;
                }
            }
        }
        self.send_to(s, msg);
    }

    /// Reply to a shed dot message without serving it.
    fn reject(&self, msg: Msg, why: ServiceError) {
        match msg {
            Msg::Req(req) => {
                let _ = req.reply.send(DotResponse {
                    id: req.id,
                    value: Err(why),
                    batch_size: 1,
                    latency: req.submitted.elapsed(),
                });
            }
            Msg::ReqPooled { id, reply, submitted, .. } => {
                let _ = reply.send(DotResponse {
                    id,
                    value: Err(why),
                    batch_size: 1,
                    latency: submitted.elapsed(),
                });
            }
            // only dot requests carry deadlines or client tokens
            _ => {}
        }
    }

    /// Serve-time deadline check: a request whose deadline expired while
    /// it sat in the queue is shed HERE, before any engine work — the
    /// admission projection is an estimate, this is the ground truth.
    /// Counts the shed and returns the reply text; `None` = serve it.
    pub(super) fn shed_expired(
        &self,
        s: usize,
        deadline_us: u64,
        submitted: Instant,
    ) -> Option<ServiceError> {
        if deadline_us == 0 {
            return None;
        }
        let waited = submitted.elapsed().as_micros() as u64;
        if waited < deadline_us {
            return None;
        }
        self.lanes[s].shed.fetch_add(1, Ordering::Relaxed);
        Some(ServiceError::ShedExpired { deadline_us, waited_us: waited })
    }

    /// Bookkeeping when a submitter picks a message off its lane queue:
    /// the live depth gauge drops, and the sending client's fair-admission
    /// slot is returned. Shutdown markers bypass `send_to`, so they must
    /// bypass this too (the lane loop only calls it for real messages).
    pub(super) fn note_dequeued(&self, s: usize, msg: &Msg) {
        let _ = self.lanes[s].queued.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| {
            v.checked_sub(1)
        });
        self.client_done_for(s, msg);
    }

    /// Record a dot request's queue wait (submit → serve start) into lane
    /// `s`'s histogram.
    pub(super) fn note_wait(&self, s: usize, submitted: Instant) {
        self.lanes[s].record_wait_us(submitted.elapsed().as_micros() as u64);
    }

    /// Record one engine execution's duration into lane `s`'s
    /// service-time histogram, once per request it served (every request
    /// in a coalesced batch waited on the whole batch).
    pub(super) fn note_service(&self, s: usize, started: Instant, requests: u64) {
        self.lanes[s].record_service_us_n(started.elapsed().as_micros() as u64, requests);
    }

    /// Take one fair-admission slot for `client` on lane `s` if it is
    /// under the cap.
    fn client_admit(&self, s: usize, client: u64) -> bool {
        let mut m = self.lanes[s].inflight.lock().unwrap();
        let n = m.entry(client).or_insert(0);
        if !self.policy.admits_client(*n as usize) {
            return false;
        }
        *n += 1;
        true
    }

    /// Return a dot message's fair-admission slot (dequeue, or a send
    /// that shed/dropped after the gate admitted it).
    fn client_done_for(&self, s: usize, msg: &Msg) {
        if self.policy.per_client_inflight == 0 {
            return;
        }
        let Some(client) = msg_client(msg) else { return };
        let mut m = self.lanes[s].inflight.lock().unwrap();
        if let Some(n) = m.get_mut(&client) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                m.remove(&client);
            }
        }
    }

    /// Shared tail of both dot arms: bump the execution counters, run the
    /// engine call with panic isolation, and turn an unwind into the
    /// request's own error (the client must see the real panic text).
    pub(super) fn execute(
        &self,
        s: usize,
        accuracy: &'static str,
        total_bytes: u64,
        pooled: bool,
        dot: impl FnOnce(Accuracy) -> f32,
    ) -> Result<f32, ServiceError> {
        self.resolved_accuracy(accuracy, total_bytes)
            .and_then(|acc| self.execute_resolved(s, acc, pooled, dot))
    }

    /// [`HostRouter::execute`] for a tier that was already resolved (and
    /// upgrade-counted) at batch-grouping time — the lane's chunk paths
    /// use this so a request never counts its upgrade twice.
    pub(super) fn execute_resolved(
        &self,
        s: usize,
        acc: Accuracy,
        pooled: bool,
        dot: impl FnOnce(Accuracy) -> f32,
    ) -> Result<f32, ServiceError> {
        self.engine_calls.fetch_add(1, Ordering::Relaxed);
        if pooled {
            self.pooled_calls.fetch_add(1, Ordering::Relaxed);
        }
        self.lanes[s].executed.fetch_add(1, Ordering::Relaxed);
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| dot(acc)))
            .map_err(|e| ServiceError::EnginePanic(panic_message(e)))
    }

    /// Resolve a request's accuracy string: empty means the service's
    /// validated default tier, anything else must parse.
    pub(super) fn req_accuracy(&self, accuracy: &str) -> Result<Accuracy, ServiceError> {
        if accuracy.is_empty() {
            return Ok(self.default_accuracy);
        }
        parse_accuracy(accuracy)
    }

    /// [`HostRouter::req_accuracy`] plus the free-upgrade pass: a naive
    /// request whose size class the calibration profile measured as
    /// compensation-free (kahan ≥ 0.95× naive) is served at kahan —
    /// strictly more accurate at measured-equal speed, counted in
    /// `accuracy_upgrades`. Inert without a calibration or with
    /// `auto_upgrade_accuracy = false` (the planner gates both).
    pub(super) fn resolved_accuracy(
        &self,
        accuracy: &str,
        total_bytes: u64,
    ) -> Result<Accuracy, ServiceError> {
        let acc = self.req_accuracy(accuracy)?;
        let (acc, upgraded) = self.policy.upgrade_accuracy(acc, total_bytes);
        if upgraded.is_some() {
            self.accuracy_upgrades.fetch_add(1, Ordering::Relaxed);
        }
        Ok(acc)
    }

    /// Execute one message on lane `s`'s submitter thread.
    ///
    /// Length mismatches are rejected HERE, before the engine: the
    /// engine's documented policy is debug-assert + truncate (see the
    /// plan module's "Length policy"), so the service is the layer that
    /// turns a mismatch into a client-visible error.
    pub(super) fn serve(&self, s: usize, msg: Msg) {
        match msg {
            Msg::Shutdown => {}
            Msg::Req(req) => {
                // deadline ground truth before any engine work; an
                // expired request is a shed, not a served request or an
                // error
                if let Some(why) = self.shed_expired(s, req.deadline_us, req.submitted) {
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Err(why),
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                    return;
                }
                self.requests.fetch_add(1, Ordering::Relaxed);
                self.note_wait(s, req.submitted);
                let value = if req.a.len() != req.b.len() {
                    Err(ServiceError::LengthMismatch { a: req.a.len(), b: req.b.len() })
                } else {
                    // no per-request heap churn: the engine reads the
                    // request's own vectors (small dots run on them in
                    // place; large dots pay one admission copy into the
                    // target shard's recycled aligned pool buffers).
                    // Executes on THIS lane's shard (routing already
                    // balanced fresh requests round-robin); the engine
                    // consumes the planner's route and fans very large
                    // dots out across every shard. The request's deadline
                    // rides into the planner: a calibrated projection may
                    // promote the route to Split (bit-identical, counted
                    // in `ShardedStats::deadline_splits`)
                    let started = Instant::now();
                    let total = (2 * req.a.len() * std::mem::size_of::<f32>()) as u64;
                    let v = self.execute(s, req.accuracy, total, false, |acc| {
                        self.engine.dot_on_deadline_f32(s, acc, req.deadline_us, &req.a, &req.b)
                    });
                    self.note_service(s, started, 1);
                    v
                };
                if value.is_err() {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = req.reply.send(DotResponse {
                    id: req.id,
                    value,
                    batch_size: 1,
                    latency: req.submitted.elapsed(),
                });
            }
            Msg::Admit { data, reply } => {
                // the copy runs on shard `s`'s own pinned workers, so
                // fresh pages first-touch in-domain
                let homed = self.engine.admit_to_f32(s, &data);
                let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                self.streams.write().unwrap().insert(handle, homed);
                self.admitted.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Ok(handle));
            }
            Msg::ReqPooled { id, accuracy, a, b, sa, sb, deadline_us, client: _, reply, submitted } => {
                if let Some(why) = self.shed_expired(s, deadline_us, submitted) {
                    let _ = reply.send(DotResponse {
                        id,
                        value: Err(why),
                        batch_size: 1,
                        latency: submitted.elapsed(),
                    });
                    return;
                }
                self.requests.fetch_add(1, Ordering::Relaxed);
                self.note_wait(s, submitted);
                let value = match (sa, sb) {
                    (Some(sa), Some(sb)) if sa.len() == sb.len() => {
                        let started = Instant::now();
                        let total = (2 * sa.len() * std::mem::size_of::<f32>()) as u64;
                        let v = self.execute(s, accuracy, total, true, |acc| {
                            self.engine.dot_homed_f32(acc, &sa, &sb)
                        });
                        self.note_service(s, started, 1);
                        v
                    }
                    (Some(sa), Some(sb)) => {
                        Err(ServiceError::LengthMismatch { a: sa.len(), b: sb.len() })
                    }
                    // the handle was either never admitted or released —
                    // possibly by another client racing this dot, which
                    // is a clean outcome, not a confusing internal error
                    (sa, _) => Err(ServiceError::StreamReleased {
                        handle: if sa.is_some() { b } else { a },
                    }),
                };
                if value.is_err() {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                }
                let _ = reply.send(DotResponse {
                    id,
                    value,
                    batch_size: 1,
                    latency: submitted.elapsed(),
                });
            }
            Msg::AdmitPair { a, b, reply } => {
                // one message, one worker pass, one shard for both streams
                // — the steady-state pair placement without the second
                // routing round-trip `admit_near` paid
                let homed = self.engine.admit_many_to_f32(s, &[&a, &b]);
                let mut handles = homed.into_iter().map(|h| {
                    let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                    self.streams.write().unwrap().insert(handle, h);
                    handle
                });
                let ha = handles.next().expect("pair admission");
                let hb = handles.next().expect("pair admission");
                self.admitted.fetch_add(2, Ordering::Relaxed);
                let _ = reply.send(Ok((ha, hb)));
            }
            Msg::Release { handle } => {
                // unreachable on the Host path (the client releases
                // synchronously); kept for match exhaustiveness
                self.streams.write().unwrap().remove(&handle);
            }
        }
    }
}

#[derive(Clone)]
pub(super) enum ClientInner {
    Host(Arc<HostRouter>),
    Pjrt(mpsc::Sender<Msg>),
}

/// Client-side handle for submitting requests. Cloneable and `Send`: on
/// the Host backend every clone routes directly against the shared router
/// state, so N client threads submit to N shard lanes concurrently.
#[derive(Clone)]
pub struct DotClient {
    pub(super) inner: ClientInner,
    /// fair-admission token stamped on every dot this handle submits
    /// (0 = anonymous; see [`DotClient::for_client`])
    pub(super) client: u64,
}

impl DotClient {
    /// A handle that stamps `client` on every dot it submits, for
    /// per-client fair admission: with
    /// `ServiceConfig::per_client_inflight` set, each client token gets
    /// its own in-flight budget per lane, so one heavy client saturating
    /// a lane is shed while its neighbors keep being admitted. Shares the
    /// underlying service with `self`.
    pub fn for_client(&self, client: u64) -> DotClient {
        DotClient { inner: self.inner.clone(), client }
    }

    /// Submit a request; returns the receiver for its response. Fresh
    /// requests round-robin across the shard lanes; a full lane blocks
    /// (back-pressure). No admission deadline: this path never sheds.
    pub fn submit(
        &self,
        id: u64,
        accuracy: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> mpsc::Receiver<DotResponse> {
        self.submit_with_deadline(id, accuracy, a, b, 0)
    }

    /// [`DotClient::submit`] with an admission deadline (µs; 0 = none).
    /// A deadlined request is never blocked behind a full or slow lane:
    /// if the lane's projected queue wait exceeds the deadline, the lane
    /// is full, or the deadline expires while queued, the request is SHED
    /// with a clean `Err` reply whose text starts with `"shed: "` —
    /// overload protection instead of the blocking-admission priority
    /// inversion. Served requests are bit-identical to an undeadlined
    /// resubmission; sheds never reach an engine.
    pub fn submit_with_deadline(
        &self,
        id: u64,
        accuracy: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
        deadline_us: u64,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        let req = DotRequest {
            id,
            accuracy,
            a,
            b,
            deadline_us,
            client: self.client,
            reply,
            submitted: Instant::now(),
        };
        match &self.inner {
            ClientInner::Host(r) => {
                let s = r.route_fresh();
                r.admit_or_shed(s, Msg::Req(req));
            }
            // a send error means the service stopped; the caller sees it
            // as a disconnected receiver (the Pjrt worker serves FIFO
            // with no admission gate — deadlines are Host-backend)
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::Req(req));
            }
        }
        rx
    }

    /// Convenience: blocking round-trip. Keeps the string-error surface
    /// for callers that only print; the typed error is on
    /// [`DotResponse::value`].
    pub fn dot_blocking(
        &self,
        accuracy: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Result<f32, String> {
        let rx = self.submit(0, accuracy, a, b);
        match rx.recv() {
            Ok(resp) => resp.value.map_err(|e| e.to_string()),
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Blocking submit that retries *infrastructure* failures — sheds and
    /// dead lanes ([`ServiceError::is_retryable`]) — with capped
    /// exponential backoff under a per-request retry budget. Validation
    /// errors (length, accuracy, released stream) and engine panics are
    /// deterministic and return immediately: retrying them burns budget
    /// to fail identically. The backoff honors the shed projection's
    /// retry-after hint ([`ServiceError::retry_after_us`]) — when the
    /// lane said "the queue drains in ~N µs", sleeping less than N is a
    /// guaranteed re-shed. Served retries are bit-identical to a first-try
    /// serve (sheds never reach an engine, and routing never changes
    /// bits). Returns the final response plus the number of attempts.
    pub fn submit_with_retry(
        &self,
        id: u64,
        accuracy: &'static str,
        a: Vec<f32>,
        b: Vec<f32>,
        deadline_us: u64,
        budget: &RetryBudget,
    ) -> (DotResponse, u32) {
        let start = Instant::now();
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            let rx = self.submit_with_deadline(id, accuracy, a.clone(), b.clone(), deadline_us);
            let resp = match rx.recv() {
                Ok(r) => r,
                // the reply channel disconnected without a response: the
                // serving lane died mid-request (or the service stopped).
                // Typed as LaneDead — retryable, because the supervisor
                // restarts dead lanes
                Err(_) => DotResponse {
                    id,
                    value: Err(ServiceError::LaneDead),
                    batch_size: 0,
                    latency: start.elapsed(),
                },
            };
            let retryable = resp.value.as_ref().err().is_some_and(|e| e.is_retryable());
            if !retryable || attempt >= budget.max_attempts.max(1) {
                return (resp, attempt);
            }
            let hint =
                resp.value.as_ref().err().and_then(|e| e.retry_after_us()).unwrap_or(0);
            let exp = budget
                .base_backoff_us
                .saturating_mul(1u64 << (attempt - 1).min(20) as u64);
            let backoff = exp.max(hint).min(budget.max_backoff_us.max(1));
            let spent = start.elapsed().as_micros() as u64;
            if spent.saturating_add(backoff) >= budget.budget_us {
                // the budget cannot fund the wait — the caller gets the
                // last real outcome instead of a late guaranteed re-shed
                return (resp, attempt);
            }
            std::thread::sleep(Duration::from_micros(backoff));
        }
    }
}

/// Retry policy for [`DotClient::submit_with_retry`]: at most
/// `max_attempts` tries, exponential backoff from `base_backoff_us`
/// doubling per attempt and capped at `max_backoff_us`, the whole dance
/// (waits included) bounded by `budget_us` of wall clock.
#[derive(Clone, Copy, Debug)]
pub struct RetryBudget {
    pub max_attempts: u32,
    /// total wall-clock budget (µs) across all attempts and backoffs
    pub budget_us: u64,
    pub base_backoff_us: u64,
    pub max_backoff_us: u64,
}

impl Default for RetryBudget {
    fn default() -> Self {
        RetryBudget {
            max_attempts: 4,
            budget_us: 1_000_000,
            base_backoff_us: 100,
            max_backoff_us: 100_000,
        }
    }
}
