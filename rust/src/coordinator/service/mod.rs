//! Batched dot service: the request-path component behind the end-to-end
//! example (`examples/e2e_serve.rs`).
//!
//! Two backends share one client API:
//!
//! * [`Backend::Host`] (default) — requests execute on the NUMA-sharded
//!   serving tier (`crate::engine::ShardedEngine`) through a **router
//!   pool**: one submitter thread per shard, each fed by its own bounded
//!   queue. The client routes messages itself (no central router thread to
//!   serialize behind): pooled streams go to the submitter of their home
//!   shard, fresh requests round-robin across submitters, and each
//!   submitter executes on *its* shard — so two small independent requests
//!   run concurrently on different shards. Submitters drain their queue
//!   **greedily**: a wake-up that finds k ≥ 2 queued small dots executes
//!   them as one engine batch (`ServiceConfig::max_batch` caps the fuse;
//!   results are bit-identical to serial execution — the engine plan
//!   module's "Batching invariant"), and a burst of admissions to one
//!   shard coalesces into a single worker pass (`Msg::AdmitPair` admits a
//!   co-located pair in one message). Runs never cross a message of a
//!   different kind, so each lane keeps exact FIFO order. With
//!   [`ServiceConfig::batch_window_us`] set, a lane holding a short dot
//!   run may additionally wait a bounded window for more requests — but
//!   only when the planner ([`crate::engine::PlanPolicy::batch_window`])
//!   says the fused kernel wins at the projected batch size; the default
//!   of 0 keeps the purely opportunistic, zero-added-latency behavior.
//!   Very large dots still fan out across every shard with the flat
//!   compensated cross-shard merge (the submitter only initiates the
//!   split), which keeps the sequential Kahan bound and 1-vs-N-shard
//!   bit-identity intact. Queues are bounded
//!   (`ServiceConfig::router_queue_depth`): a deadline-less send to a
//!   full lane blocks — back-pressure instead of unbounded queue growth —
//!   with the stall counted in [`ServiceStats::queue_full_stalls`] and
//!   its duration in [`ServiceStats::stalled_us`]. **Overload
//!   protection** (opt-in per request) replaces that blocking with
//!   shedding: a request carrying a `deadline_us` is rejected with a
//!   clean `Err("shed: …")` reply — never a blocked sender — when the
//!   planner's pure shed policy ([`crate::engine::PlanPolicy::shed`])
//!   projects the lane queue wait past the deadline or finds the lane
//!   full, and again at serve time if the deadline expired while queued.
//!   [`ServiceConfig::per_client_inflight`] adds per-client fair
//!   admission on top ([`DotClient::for_client`] tags requests): one
//!   heavy client at its cap is shed instead of occupying the whole
//!   lane. Sheds never reach an engine, so every served request stays
//!   bit-identical to serial resubmission; per-lane log-bucketed
//!   queue-wait and service-time histograms
//!   ([`crate::coordinator::service::LatencyHist`]) feed both the shed
//!   projection and the tail-latency accounting in [`ServiceStats`].
//!   Shutdown is graceful: each submitter drains and serves everything
//!   already queued behind the shutdown marker before exiting (see
//!   `lane::submitter_loop`).
//! * [`Backend::Pjrt`] — the original PJRT path: one worker thread owns
//!   the `Runtime` (executables are not shared across threads), drains the
//!   queue with a batching window, groups compatible requests, and
//!   executes them in one PJRT call when possible. Needs AOT artifacts and
//!   the `pjrt` cargo feature.
//!
//! Ordering: each lane is FIFO, and pooled-dot operands are resolved at
//! *submit* time in the caller's program order while `release` removes the
//! stream-table entry synchronously on the caller's thread. One client
//! therefore keeps exactly the old single-router FIFO semantics — a
//! `release` after `submit_pooled` never invalidates the in-flight dot
//! (the message holds the resolved `Arc`s), and a `release` before a
//! submit is always visible to it. Concurrent clients racing a release
//! against a submit get one outcome or the other, never a dangling read.
//!
//! Architecture (std-only; the offline container has no tokio): callers
//! submit `DotRequest`s over per-shard bounded channels and receive their
//! `DotResponse` on a per-request return channel.
//!
//! Module map (each file stays well under ~700 lines):
//!
//! * `mod.rs` — message/request/response types, [`ServiceConfig`]
//!   (validated at service start), [`DotService`] lifecycle;
//! * `router` — the shared [`Backend::Host`] router state (`HostRouter`)
//!   and the client's routing ([`DotClient`]);
//! * `lane` — the per-shard submitter loop: greedy drain, same-kind run
//!   coalescing, the planner-gated adaptive batching window, and the
//!   batched serve paths;
//! * `streams` — the admitted-stream surface: admission, co-location,
//!   pooled dots, release;
//! * `stats` — [`ServiceStats`]/[`LaneStats`] and the snapshot;
//! * `pjrt` — the [`Backend::Pjrt`] worker loop.

mod error;
mod lane;
mod pjrt;
mod router;
mod stats;
mod streams;
mod supervise;
#[cfg(test)]
mod tests;
#[cfg(test)]
mod tests_accuracy;
#[cfg(test)]
mod tests_window;

pub use error::ServiceError;
pub use router::{DotClient, RetryBudget};
pub use stats::{LaneStats, LatencyHist, ServiceStats, HIST_BUCKETS};

use crate::engine::{HomedSlice, ShardedEngine};
use crate::isa::Accuracy;
use crate::runtime::Runtime;
use router::{ClientInner, HostRouter};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};
use supervise::LaneSlot;

/// Message to a submitter (Host) or the worker (Pjrt): a request, stream
/// admission/release, or an explicit shutdown marker (needed because
/// `DotClient` clones keep the channels alive — dropping the service's own
/// senders alone would never disconnect the receivers).
enum Msg {
    Req(DotRequest),
    /// Admit a stream into the sharded engine's pooled storage; replies
    /// with the stream handle (Host backend only). Placement is the lane
    /// the message was routed to: the client resolves `near` co-location
    /// *before* sending, so the admission copy always runs on the target
    /// shard's own workers.
    Admit { data: Vec<f32>, reply: mpsc::Sender<Result<u64, String>> },
    /// Dot two admitted streams on the home shard of `a` (Host backend
    /// only). The operands are resolved from the stream table at *submit*
    /// time on the client thread — program order of one client therefore
    /// decides what a dot sees (exactly the old single-router FIFO
    /// semantics): a `release` after `submit_pooled` can never invalidate
    /// an in-flight dot (the message holds the slices alive), and a
    /// `release` before it is always visible (`sa`/`sb` arrive `None`).
    ReqPooled {
        id: u64,
        accuracy: &'static str,
        a: u64,
        b: u64,
        sa: Option<HomedSlice<f32>>,
        sb: Option<HomedSlice<f32>>,
        /// admission deadline (µs, 0 = none) — same shed semantics as
        /// [`DotRequest::deadline_us`]
        deadline_us: u64,
        /// fair-admission client token — same semantics as
        /// [`DotRequest::client`]
        client: u64,
        reply: mpsc::Sender<DotResponse>,
        submitted: Instant,
    },
    /// Admit a stream pair in ONE message (Host backend only): both
    /// streams land on the same shard in a single worker pass — the
    /// co-located placement `admit_near` needed two routing round-trips
    /// for.
    AdmitPair {
        a: Vec<f32>,
        b: Vec<f32>,
        reply: mpsc::Sender<Result<(u64, u64), String>>,
    },
    /// Drop an admitted stream (Pjrt path only — the Host client removes
    /// it from the shared stream table synchronously instead).
    Release { handle: u64 },
    Shutdown,
}

/// Discriminant for run-grouping in the submitter's greedy drain: only
/// consecutive messages of the same kind coalesce, so each lane keeps its
/// exact FIFO execution order.
fn msg_kind(m: &Msg) -> u8 {
    match m {
        Msg::Req(_) => 0,
        Msg::ReqPooled { .. } => 1,
        Msg::Admit { .. } => 2,
        Msg::AdmitPair { .. } => 3,
        Msg::Release { .. } => 4,
        Msg::Shutdown => 5,
    }
}

/// Admission deadline a message carries (dot requests only; everything
/// else is 0 = "no deadline" and keeps blocking back-pressure).
fn msg_deadline(m: &Msg) -> u64 {
    match m {
        Msg::Req(r) => r.deadline_us,
        Msg::ReqPooled { deadline_us, .. } => *deadline_us,
        _ => 0,
    }
}

/// Fair-admission client token a message carries (dot requests only —
/// admissions and releases are not subject to the per-client cap).
fn msg_client(m: &Msg) -> Option<u64> {
    match m {
        Msg::Req(r) => Some(r.client),
        Msg::ReqPooled { client, .. } => Some(*client),
        _ => None,
    }
}

/// Which execution path serves requests.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// persistent host engine (pooled buffers + pinned workers)
    #[default]
    Host,
    /// PJRT execution of the AOT artifacts (requires the `pjrt` feature)
    Pjrt,
}

/// A dot-product request.
pub struct DotRequest {
    pub id: u64,
    /// requested accuracy tier: "naive", "kahan", "dot2" or "exact"
    /// (empty = the service's validated `default_accuracy`)
    pub accuracy: &'static str,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    /// admission deadline in microseconds; 0 (the [`DotClient::submit`]
    /// default) = no deadline, keep blocking back-pressure. With a
    /// deadline set the request is SHED — a clean `Err("shed: …")` reply,
    /// never a blocked sender — when the lane's projected queue wait or a
    /// full queue means it cannot be served in time
    /// ([`crate::engine::PlanPolicy::shed`]), or when the deadline has
    /// already expired by the time a submitter picks it up.
    pub deadline_us: u64,
    /// fair-admission client token ([`DotClient::for_client`]; 0 =
    /// anonymous). With [`ServiceConfig::per_client_inflight`] set, a
    /// client already holding that many queue slots on the target lane is
    /// shed instead of admitted.
    pub client: u64,
    reply: mpsc::Sender<DotResponse>,
    /// stamped in `DotClient::submit`, so reported latency includes the
    /// time spent queued in the channel, not just the execute time
    submitted: Instant,
}

/// The service's answer. Failures are typed ([`ServiceError`]) so
/// clients branch on variants — shed vs validation vs dead lane — and
/// the retry client reads retryability off the error; `to_string()`
/// reproduces the string era's stable texts.
#[derive(Clone, Debug)]
pub struct DotResponse {
    pub id: u64,
    pub value: Result<f32, ServiceError>,
    /// how many requests shared the backend call that served this one
    pub batch_size: usize,
    /// queue + execute time
    pub latency: Duration,
}

/// Cap on [`ServiceConfig::batch_window_us`]: a window is a per-wake-up
/// latency budget, so anything beyond 10 s is a configuration bug (and a
/// huge value could overflow the lane's deadline arithmetic) — validation
/// rejects it instead of wedging every lane.
pub const MAX_BATCH_WINDOW_US: u64 = 10_000_000;

/// Sentinel default for [`ServiceConfig::worker_wedge_us`] /
/// [`ServiceConfig::lane_wedge_us`]: resolve the threshold from the
/// calibration profile's projected worst-case chunk service time × a
/// safety factor ([`crate::engine::CalibrationProfile::worker_wedge_default_us`]),
/// so stall detection is ON by default wherever a profile says what
/// "stalled" means — and OFF (the safe pre-calibration behavior) where
/// none does. An explicit `0` still means "off", an explicit value still
/// wins: the sentinel only marks "the deployment didn't say".
pub const WEDGE_AUTO: u64 = u64::MAX;

/// Service configuration.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub backend: Backend,
    /// Host backend: per-shard submitter queue depth. When a lane holds
    /// this many undelivered messages the next send *blocks* the caller
    /// (back-pressure: admission copies must not pile up behind a busy
    /// shard and starve compute), and the stall is counted in
    /// [`ServiceStats::queue_full_stalls`]. Must be ≥ 1 (validated at
    /// service start).
    pub router_queue_depth: usize,
    /// Max requests fused into one batched execute. Host backend: a
    /// submitter that wakes up with k ≥ 2 queued small dots executes them
    /// as ONE engine batch (chunks of at most `max_batch`; bit-identical
    /// to serial execution — see the engine plan module's batching
    /// invariant), and bursts of admissions coalesce into one worker pass
    /// the same way. `max_batch = 1` disables coalescing; 0 is rejected at
    /// service start. Pjrt backend: the batch window size, as before.
    pub max_batch: usize,
    /// Host backend: latency-aware adaptive batching. When a lane wakes up
    /// holding fewer than `max_batch` coalescible dots AND the planner
    /// says the fused kernel wins at the projected batch size
    /// ([`crate::engine::PlanPolicy::batch_window`]), it waits up to this
    /// many microseconds for more requests before executing — trading a
    /// bounded slice of p50 latency for bigger fuses under light load.
    /// `0` (default) keeps the purely opportunistic coalescing with zero
    /// added latency. Capped by [`MAX_BATCH_WINDOW_US`] (validated at
    /// service start).
    pub batch_window_us: u64,
    /// Accuracy tier served when a request's `accuracy` string is empty:
    /// "naive", "kahan" (default), "dot2" or "exact" (validated at
    /// service start).
    pub default_accuracy: String,
    /// Host backend: per-client in-flight cap per lane (fair admission).
    /// A client already holding this many slots of a lane's queue has its
    /// next request shed (`Err("shed: client …")`) instead of admitted,
    /// so one heavy client cannot occupy a whole lane and starve its
    /// neighbors ([`crate::engine::PlanPolicy::admits_client`]). `0`
    /// (default) = unlimited, the pre-fairness behavior.
    pub per_client_inflight: usize,
    /// Host backend: ECM worker governance. `"on"` (default) keeps the
    /// engine tier's governed plan policy — MEM-class fan-out is capped at
    /// the host ECM verdict's predicted saturation cores, freeing workers
    /// for other lanes' concurrent requests (concurrency only, never
    /// bits). `"off"` serves every request with the full worker fan-out
    /// (the pre-governance behaviour). Anything else is rejected at
    /// service start.
    pub ecm_governance: String,
    /// Host backend: microseconds between self-healing supervision sweeps
    /// (worker respawns, shard quarantine verdicts + probes, lane
    /// restarts — see the `supervise` module). `0` disables the
    /// supervisor thread entirely (the pre-supervision behavior: a dead
    /// lane silently blackholes its shard's queue until shutdown drains
    /// it). Default 10 000 (10 ms).
    pub supervise_interval_us: u64,
    /// Engine-worker wedge threshold (µs): a worker whose heartbeat shows
    /// it busy on one job longer than this is abandoned and replaced on
    /// the next sweep. `0` disables wedge detection — dead workers are
    /// still respawned. The default [`WEDGE_AUTO`] calibrates the
    /// threshold from the profile's projected worst-case chunk service
    /// time × a safety factor (off when no profile loaded) — a threshold
    /// shorter than the longest legitimate chunk would shoot healthy
    /// workers, which is exactly why it needs a *measured* floor.
    pub worker_wedge_us: u64,
    /// Lane-submitter wedge threshold (µs), same contract as
    /// [`ServiceConfig::worker_wedge_us`] but for the per-shard submitter
    /// threads (lanes legitimately run whole batches, so the calibrated
    /// default is a multiple of the worker one). [`WEDGE_AUTO`] (default)
    /// = calibrate from the profile; `0` = off; dead submitters are
    /// always replaced.
    pub lane_wedge_us: u64,
    /// Calibration-profile path. Empty (default): no lazy measurement —
    /// the engine still *loads* a profile from `REPRO_PROFILE` (or the
    /// temp-dir default path) if one exists, but never writes one. Set to
    /// a path: the service ensures a profile exists there at startup —
    /// loading it when valid, else running the one-shot measurement pass
    /// and caching the result — and installs it process-wide before
    /// serving, so the dispatch table, split threshold, deadline routing
    /// and wedge defaults all start calibrated (the
    /// `calib_cold_start_ratio` claim).
    pub profile_path: String,
    /// Free accuracy upgrades: when `true` (default) and the calibration
    /// profile's measured per-class ratio says the compensated kernel
    /// runs at ≥ 0.95× naive throughput, requests asking for "naive" are
    /// served at "kahan" — a strictly more accurate answer at measured-
    /// equal speed (the paper's thesis, enforced at the planner:
    /// [`crate::engine::PlanPolicy::upgrade_accuracy`]). This is the ONE
    /// routing decision allowed to change bits, because the caller's
    /// tier changes; set `false` to always serve exactly the requested
    /// tier.
    pub auto_upgrade_accuracy: bool,
    /// Worker respawns a shard may burn through between sweeps before it
    /// is **quarantined**: pulled from fresh routing and split chunk
    /// *assignment* (never chunk geometry — bits are unchanged; see
    /// `ShardedEngine::quarantine`) until a probe proves every worker
    /// healthy again. Must be ≥ 1 (validated at service start). Default
    /// 8.
    pub shard_respawn_budget: u64,
    /// how long the batcher waits to fill a batch (Pjrt backend)
    pub window: Duration,
    /// name of the batched artifact to use (must exist in the manifest)
    pub batched_artifact_kahan: String,
    pub batched_artifact_naive: String,
    /// single-request fallback artifacts
    pub single_artifact_kahan: String,
    pub single_artifact_naive: String,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            backend: Backend::Host,
            router_queue_depth: 64,
            max_batch: 16,
            batch_window_us: 0,
            default_accuracy: "kahan".into(),
            per_client_inflight: 0,
            ecm_governance: "on".into(),
            supervise_interval_us: 10_000,
            worker_wedge_us: WEDGE_AUTO,
            lane_wedge_us: WEDGE_AUTO,
            profile_path: String::new(),
            auto_upgrade_accuracy: true,
            shard_respawn_budget: 8,
            window: Duration::from_millis(2),
            batched_artifact_kahan: "batched_dot_kahan_f32_b8_n16384".into(),
            batched_artifact_naive: "batched_dot_naive_f32_b8_n16384".into(),
            single_artifact_kahan: "dot_kahan_f32_n65536".into(),
            single_artifact_naive: "dot_naive_f32_n65536".into(),
        }
    }
}

impl ServiceConfig {
    /// Validate the configuration. Run at every service start so a bad
    /// config is a clean error, not a panic deep in a lane or a silently
    /// wedged queue: `max_batch == 0` would make every coalescing chunk
    /// empty, `router_queue_depth == 0` can never accept a message
    /// (rendezvous channels would deadlock the blocking client), and an
    /// oversized `batch_window_us` would stall lanes for minutes per
    /// wake-up.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err(
                "ServiceConfig::max_batch must be >= 1 (use 1 to disable coalescing)".into()
            );
        }
        if self.router_queue_depth == 0 {
            return Err(
                "ServiceConfig::router_queue_depth must be >= 1 (a depth-0 lane can never \
                 accept a message)"
                    .into(),
            );
        }
        if self.batch_window_us > MAX_BATCH_WINDOW_US {
            return Err(format!(
                "ServiceConfig::batch_window_us = {} exceeds the {} us ({} s) cap — a window \
                 is a per-wake-up latency budget, not a schedule",
                self.batch_window_us,
                MAX_BATCH_WINDOW_US,
                MAX_BATCH_WINDOW_US / 1_000_000
            ));
        }
        if let Err(e) = parse_accuracy(&self.default_accuracy) {
            return Err(format!("ServiceConfig::default_accuracy: {e}"));
        }
        if self.ecm_governance != "on" && self.ecm_governance != "off" {
            return Err(format!(
                "ServiceConfig::ecm_governance = {:?} — must be \"on\" or \"off\"",
                self.ecm_governance
            ));
        }
        if self.shard_respawn_budget == 0 {
            return Err(
                "ServiceConfig::shard_respawn_budget must be >= 1 (a budget of 0 would \
                 quarantine every shard on the first sweep)"
                    .into(),
            );
        }
        Ok(())
    }
}

enum ServiceInner {
    Host {
        router: Arc<HostRouter>,
        /// per-shard lane slots: each owns its queue receiver and the
        /// current submitter incarnation's join handle (the supervisor
        /// replaces dead/wedged incarnations in place)
        lanes: Arc<Vec<LaneSlot>>,
        supervisor: Option<std::thread::JoinHandle<()>>,
        /// set once by shutdown; read by the supervisor between sweep
        /// slices so stop() is never blocked a full interval
        stopping: Arc<AtomicBool>,
    },
    Pjrt {
        tx: Option<mpsc::Sender<Msg>>,
        worker: Option<std::thread::JoinHandle<ServiceStats>>,
    },
}

/// Handle to a running service.
pub struct DotService {
    inner: ServiceInner,
}

impl DotService {
    /// Start the configured backend. The configuration is validated first
    /// — an invalid one is returned as an error, never a wedged lane.
    ///
    /// Host backend: a router pool over the process-wide sharded engine
    /// (`ShardedEngine::global()`) — one submitter thread per shard.
    ///
    /// Pjrt backend: PJRT handles are not `Send`, so the `Runtime` must be
    /// constructed *inside* the worker thread; startup errors are relayed
    /// back through a one-shot channel so callers still see them
    /// synchronously.
    pub fn start(config: ServiceConfig) -> anyhow::Result<(Self, DotClient)> {
        config.validate().map_err(|e| anyhow::anyhow!("service config: {e}"))?;
        match config.backend {
            Backend::Host => {
                // resolve the calibration profile BEFORE the global engine
                // exists: the dispatch table is seeded and the split
                // threshold derived at engine construction, so a profile
                // installed later would arrive too late to matter
                Self::ensure_profile(&config);
                Self::try_start_on(config, ShardedEngine::global())
                    .map_err(|e| anyhow::anyhow!("service config: {e}"))
            }
            Backend::Pjrt => {
                let (tx, rx) = mpsc::channel::<Msg>();
                let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
                let worker = std::thread::spawn(move || match Runtime::new() {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        pjrt::worker_loop_pjrt(rt, rx, config)
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.to_string()));
                        ServiceStats::default()
                    }
                });
                match ready_rx.recv() {
                    Ok(Ok(())) => {}
                    Ok(Err(e)) => {
                        let _ = worker.join();
                        anyhow::bail!("service startup: {e}");
                    }
                    Err(_) => {
                        let _ = worker.join();
                        anyhow::bail!("service worker died during startup");
                    }
                }
                let client = DotClient { inner: ClientInner::Pjrt(tx.clone()), client: 0 };
                Ok((
                    DotService { inner: ServiceInner::Pjrt { tx: Some(tx), worker: Some(worker) } },
                    client,
                ))
            }
        }
    }

    /// Start a Host-backend router pool on an explicit engine (tests and
    /// benches hand in a leaked `ShardedEngine` over a synthetic
    /// `Topology::fake_even` layout to exercise multi-shard routing on
    /// single-node hosts). `config.backend` is ignored: this is always the
    /// host path. Panics on an invalid configuration — callers that want
    /// the error instead use [`DotService::try_start_on`].
    pub fn start_on(config: ServiceConfig, engine: &'static ShardedEngine) -> (Self, DotClient) {
        match Self::try_start_on(config, engine) {
            Ok(pair) => pair,
            Err(e) => panic!("service config: {e}"),
        }
    }

    /// Lazy profile bootstrap for [`ServiceConfig::profile_path`]: load
    /// the profile cached there, or — when the file is missing, corrupt,
    /// or stale (rejections are counted in
    /// [`ServiceStats::profile_rejected`]) — run the one-shot measurement
    /// pass and cache the result, then install it process-wide. An empty
    /// path keeps the load-only default (`REPRO_PROFILE` / temp dir, no
    /// measurement ever). Idempotent per process: once a profile is
    /// installed, later calls change nothing.
    fn ensure_profile(config: &ServiceConfig) {
        use crate::engine::profile::{install_host_profile, CalibrationProfile};
        if config.profile_path.is_empty() {
            return;
        }
        let path = std::path::Path::new(&config.profile_path);
        let p = match CalibrationProfile::load(path) {
            Ok(p) => p,
            Err(_) => {
                let p = CalibrationProfile::measure();
                // caching is best-effort: an unwritable path costs the
                // next start its warm seed, never this one its profile
                let _ = p.save(path);
                p
            }
        };
        let _ = install_host_profile(p);
    }

    /// Resolve one wedge threshold: [`WEDGE_AUTO`] becomes the profile's
    /// calibrated default (off when no profile loaded); explicit values —
    /// including the 0 = off override — pass through untouched.
    fn resolve_wedge(configured: u64, calibrated: Option<u64>) -> u64 {
        if configured == WEDGE_AUTO {
            calibrated.unwrap_or(0)
        } else {
            configured
        }
    }

    /// [`DotService::start_on`], but an invalid configuration comes back
    /// as a `Result` (what [`DotService::start`] uses under the hood).
    pub fn try_start_on(
        config: ServiceConfig,
        engine: &'static ShardedEngine,
    ) -> Result<(Self, DotClient), String> {
        config.validate()?;
        // the service's routing policy is the engine tier's compiled plan
        // policy plus the service's batching knobs — one planner, layered.
        // `ecm_governance = "off"` opens the policy's worker caps (the
        // shard engines the service executes on must be built ungoverned
        // too for a fully open path — see the bench's paired scenarios)
        let mut policy = engine
            .policy()
            .clone()
            .with_service(config.max_batch, config.batch_window_us)
            .with_admission(config.router_queue_depth, config.per_client_inflight)
            .with_upgrade(config.auto_upgrade_accuracy);
        if config.ecm_governance == "off" {
            policy = policy.ungoverned();
        }
        let default_accuracy =
            parse_accuracy(&config.default_accuracy).expect("validated above");
        let (router, receivers) =
            HostRouter::new(engine, policy, config.router_queue_depth, default_accuracy);
        // the lane slots own the queue receivers, so a dead submitter
        // never disconnects its channel: queued requests wait for (and
        // are served by) the supervisor's replacement
        let lanes: Arc<Vec<LaneSlot>> = Arc::new(
            receivers
                .into_iter()
                .map(|rx| LaneSlot { rx: Mutex::new(rx), join: Mutex::new(None) })
                .collect(),
        );
        for (s, slot) in lanes.iter().enumerate() {
            let h = supervise::spawn_submitter(&router, &lanes, s, 0);
            *slot.join.lock().expect("fresh lane slot") = Some(h);
        }
        let stopping = Arc::new(AtomicBool::new(false));
        let supervisor = if config.supervise_interval_us > 0 {
            let r = Arc::clone(&router);
            let l = Arc::clone(&lanes);
            let st = Arc::clone(&stopping);
            // WEDGE_AUTO resolves against the calibration profile here,
            // at the one place the thresholds are consumed: a measured
            // worst-case chunk time (× safety factor) is the only sane
            // default — without one, auto stays off and only explicit
            // thresholds shoot wedged threads
            let profile = crate::engine::profile::host_profile();
            let sc = supervise::SuperviseCfg {
                interval_us: config.supervise_interval_us,
                worker_wedge_us: Self::resolve_wedge(
                    config.worker_wedge_us,
                    profile.map(|p| p.worker_wedge_default_us()),
                ),
                lane_wedge_us: Self::resolve_wedge(
                    config.lane_wedge_us,
                    profile.map(|p| p.lane_wedge_default_us()),
                ),
                respawn_budget: config.shard_respawn_budget,
            };
            Some(
                std::thread::Builder::new()
                    .name("dot-supervisor".into())
                    .spawn(move || supervise::supervisor_loop(r, l, sc, st))
                    .expect("spawn dot supervisor"),
            )
        } else {
            None
        };
        let client = DotClient { inner: ClientInner::Host(Arc::clone(&router)), client: 0 };
        Ok((DotService { inner: ServiceInner::Host { router, lanes, supervisor, stopping } }, client))
    }

    /// Stop the service and return its statistics. Host backend: every
    /// lane gets a shutdown marker, each submitter serves what is already
    /// queued (in-flight requests are drained, not dropped), then joins.
    pub fn stop(mut self) -> ServiceStats {
        self.shutdown()
    }

    fn shutdown(&mut self) -> ServiceStats {
        match &mut self.inner {
            ServiceInner::Host { router, lanes, supervisor, stopping } => {
                if !stopping.swap(true, Ordering::Relaxed) {
                    // supervisor FIRST: it must not resurrect lanes the
                    // shutdown is in the middle of retiring
                    if let Some(h) = supervisor.take() {
                        let _ = h.join();
                    }
                    for (s, q) in router.queues.iter().enumerate() {
                        // best-effort marker (a full queue must not block
                        // shutdown) + an epoch bump, which stops even a
                        // submitter that never sees the marker at its
                        // next loop-top (≤ one bounded recv later)
                        let _ = q.try_send(Msg::Shutdown);
                        router.lanes[s].epoch.fetch_add(1, Ordering::Relaxed);
                    }
                    for slot in lanes.iter() {
                        let h = slot.join.lock().unwrap_or_else(|p| p.into_inner()).take();
                        if let Some(h) = h {
                            let _ = h.join();
                        }
                    }
                    // final inline drain: anything a retired (or dead)
                    // lane left queued is served HERE — the drain
                    // guarantee does not depend on any lane's health
                    for (s, slot) in lanes.iter().enumerate() {
                        let rx = slot.rx.lock().unwrap_or_else(|p| p.into_inner());
                        while let Ok(m) = rx.try_recv() {
                            if matches!(m, Msg::Shutdown) {
                                continue;
                            }
                            router.note_dequeued(s, &m);
                            router.drained.fetch_add(1, Ordering::Relaxed);
                            let r = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| router.serve(s, m)),
                            );
                            if r.is_err() {
                                router.errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                router.snapshot()
            }
            ServiceInner::Pjrt { tx, worker } => {
                if let Some(tx) = tx.take() {
                    let _ = tx.send(Msg::Shutdown);
                }
                worker.take().map(|w| w.join().unwrap_or_default()).unwrap_or_default()
            }
        }
    }
}

impl Drop for DotService {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

/// Parse a request's accuracy-tier string ("naive" / "kahan" / "dot2" /
/// "exact", plus the aliases `Accuracy::parse` accepts). The service
/// rejects unknown tiers per request instead of panicking in a lane.
fn parse_accuracy(s: &str) -> Result<Accuracy, ServiceError> {
    Accuracy::parse(s).ok_or_else(|| ServiceError::UnknownAccuracy(s.to_string()))
}
