//! The admitted-stream surface: admission (single, co-located, and
//! paired), pooled dots over admitted streams, and release.
//!
//! The stream table itself lives on `HostRouter` (`streams`): inserted by
//! the owning submitter at admission, *read* by client threads at submit
//! time to resolve pooled operands, and *removed* by client threads in
//! [`DotClient::release`] — synchronously, which is what keeps a release
//! ordered against the same client's later submits (the old
//! single-router FIFO semantics; see the module doc's "Ordering"
//! paragraph and the `release_after_submit_never_invalidates_...`
//! regression test).

use super::router::{ClientInner, DotClient};
use super::{DotResponse, Msg};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::time::Instant;

impl super::router::HostRouter {
    /// Home shard of an admitted stream, if it is still live.
    pub(super) fn shard_of(&self, handle: u64) -> Option<usize> {
        self.streams.read().unwrap().get(&handle).map(|h| h.shard)
    }
}

impl DotClient {
    /// Admit a stream into the serving tier's pooled shard-local storage
    /// and get back its handle. The stream's home shard is fixed at
    /// admission; every later [`DotClient::dot_pooled_blocking`] over it
    /// executes there (Host backend only — the PJRT worker rejects it).
    pub fn admit_blocking(&self, data: Vec<f32>) -> Result<u64, String> {
        self.admit_near_blocking(data, None)
    }

    /// Admit a stream PAIR in one message: both streams land on the same
    /// shard in a single worker pass — the co-located steady-state
    /// placement (`admit_near`) without the second routing round-trip.
    /// Host backend only.
    pub fn admit_pair_blocking(
        &self,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Result<(u64, u64), String> {
        let (reply, rx) = mpsc::channel();
        match &self.inner {
            ClientInner::Host(r) => {
                let s = r.route_fresh();
                r.send_to(s, Msg::AdmitPair { a, b, reply });
            }
            ClientInner::Pjrt(tx) => {
                if tx.send(Msg::AdmitPair { a, b, reply }).is_err() {
                    return Err("service stopped".into());
                }
            }
        }
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Like [`DotClient::admit_blocking`], but co-locate the stream on the
    /// home shard of `near` (an earlier handle) — the placement for
    /// streams that will be dotted against each other, so the pair never
    /// crosses a NUMA domain. A `near` that no longer exists falls back to
    /// round-robin placement.
    pub fn admit_near_blocking(&self, data: Vec<f32>, near: Option<u64>) -> Result<u64, String> {
        let (reply, rx) = mpsc::channel();
        match &self.inner {
            ClientInner::Host(r) => {
                let s = near.and_then(|h| r.shard_of(h)).unwrap_or_else(|| r.route_fresh());
                r.send_to(s, Msg::Admit { data, reply });
            }
            ClientInner::Pjrt(tx) => {
                if tx.send(Msg::Admit { data, reply }).is_err() {
                    return Err("service stopped".into());
                }
            }
        }
        match rx.recv() {
            Ok(r) => r,
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Submit a dot over two admitted streams; returns the response
    /// receiver. Routed to the home shard of `a` (admission locality).
    /// The operands are resolved here, in the caller's program order —
    /// see `Msg::ReqPooled` for why that makes `release` safe to call
    /// right after submitting.
    pub fn submit_pooled(
        &self,
        id: u64,
        accuracy: &'static str,
        a: u64,
        b: u64,
    ) -> mpsc::Receiver<DotResponse> {
        self.submit_pooled_with_deadline(id, accuracy, a, b, 0)
    }

    /// [`DotClient::submit_pooled`] with an admission deadline (µs; 0 =
    /// none) — the same shed-instead-of-block semantics as
    /// [`DotClient::submit_with_deadline`], on the home-shard lane.
    pub fn submit_pooled_with_deadline(
        &self,
        id: u64,
        accuracy: &'static str,
        a: u64,
        b: u64,
        deadline_us: u64,
    ) -> mpsc::Receiver<DotResponse> {
        let (reply, rx) = mpsc::channel();
        match &self.inner {
            ClientInner::Host(r) => {
                let (sa, sb) = {
                    let m = r.streams.read().unwrap();
                    (m.get(&a).cloned(), m.get(&b).cloned())
                };
                // an unknown handle still travels a lane so the submitter
                // reports it as a per-request error, not a silent drop
                let s = sa.as_ref().map(|h| h.shard).unwrap_or_else(|| r.route_fresh());
                r.admit_or_shed(
                    s,
                    Msg::ReqPooled {
                        id,
                        accuracy,
                        a,
                        b,
                        sa,
                        sb,
                        deadline_us,
                        client: self.client,
                        reply,
                        submitted: Instant::now(),
                    },
                );
            }
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::ReqPooled {
                    id,
                    accuracy,
                    a,
                    b,
                    sa: None,
                    sb: None,
                    deadline_us,
                    client: self.client,
                    reply,
                    submitted: Instant::now(),
                });
            }
        }
        rx
    }

    /// Convenience: blocking dot over two admitted streams.
    pub fn dot_pooled_blocking(
        &self,
        accuracy: &'static str,
        a: u64,
        b: u64,
    ) -> Result<f32, String> {
        let rx = self.submit_pooled(0, accuracy, a, b);
        match rx.recv() {
            Ok(resp) => resp.value.map_err(|e| e.to_string()),
            Err(_) => Err("service stopped".into()),
        }
    }

    /// Release an admitted stream. Takes effect immediately (the entry is
    /// removed from the stream table on the caller's thread): later dots
    /// from this client see it gone, while dots already submitted keep
    /// their resolved operands and finish normally. The buffer recycles
    /// into the home shard's pool once the last in-flight reference
    /// drops. Releasing an unknown or already-released handle is a clean
    /// no-op, counted in [`super::ServiceStats::release_misses`] instead
    /// of silently swallowed (a double release, or two clients racing a
    /// release of the same stream).
    pub fn release(&self, handle: u64) {
        match &self.inner {
            ClientInner::Host(r) => {
                if r.streams.write().unwrap().remove(&handle).is_none() {
                    r.release_misses.fetch_add(1, Ordering::Relaxed);
                }
            }
            ClientInner::Pjrt(tx) => {
                let _ = tx.send(Msg::Release { handle });
            }
        }
    }
}
