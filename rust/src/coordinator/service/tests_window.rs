//! PR 5 service tests: `ServiceConfig` validation at service start and
//! the latency-aware adaptive batching window (`batch_window_us`). The
//! pre-PR-5 tests live, unmodified, in `tests.rs`; the shared `Gate` /
//! `leak_engine` / `wait_engine_requests` helpers are reused from there.

use super::tests::{leak_engine, wait_engine_requests, Gate};
use super::*;
use crate::accuracy::exact::exact_dot_f32;
use crate::engine::Topology;
use crate::util::Rng;
use std::time::Duration;

/// Satellite: an invalid configuration is a clean startup error — from
/// `start` and `try_start_on` alike — never a panic deep in a lane or a
/// silently wedged queue.
#[test]
fn invalid_config_is_a_start_error() {
    let bad_batch = ServiceConfig { max_batch: 0, ..ServiceConfig::default() };
    assert!(bad_batch.validate().is_err());
    assert!(DotService::start(bad_batch).is_err());

    let bad_depth = ServiceConfig { router_queue_depth: 0, ..ServiceConfig::default() };
    assert!(bad_depth.validate().is_err());
    assert!(DotService::start(bad_depth).is_err());

    let bad_window = ServiceConfig {
        batch_window_us: MAX_BATCH_WINDOW_US + 1,
        ..ServiceConfig::default()
    };
    assert!(bad_window.validate().is_err());
    assert!(DotService::start(bad_window).is_err());

    // the explicit-engine path reports the same errors as a Result
    let engine = leak_engine(&Topology::single_node(), 1);
    assert!(DotService::try_start_on(
        ServiceConfig { max_batch: 0, ..ServiceConfig::default() },
        engine
    )
    .is_err());
    // ...and a valid config still starts
    let (svc, client) =
        DotService::try_start_on(ServiceConfig::default(), engine).expect("valid config");
    assert_eq!(client.dot_blocking("kahan", vec![1.0; 8], vec![2.0; 8]), Ok(16.0));
    svc.stop();
}

/// The adaptive window must never wedge a lane: a lone blocking request
/// against a windowed service completes (the wait is bounded), results
/// are unchanged, and shutdown drains promptly with requests queued
/// behind the marker.
#[test]
fn batch_window_bounded_wait_serves_singles_and_drains() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        // 2 ms window: long enough to be real, short enough for tests
        ServiceConfig { batch_window_us: 2_000, ..ServiceConfig::default() },
        engine,
    );
    let mut rng = Rng::new(83);
    // sequential blocking round-trips: each wake-up holds ONE dot, so a
    // planner-approved lane waits the full window and must still answer
    for round in 0..3 {
        let a = rng.normal_f32_vec(1024);
        let b = rng.normal_f32_vec(1024);
        let exact = exact_dot_f32(&a, &b);
        let v = client
            .dot_blocking("kahan", a, b)
            .expect("windowed lane must serve a lone request") as f64;
        assert!((v - exact).abs() < 1e-2 * exact.abs().max(1.0), "round {round}");
    }
    // shutdown with work queued behind the marker still drains
    let gate = Gate::close(engine, 0);
    let n_big = 200_000;
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
    wait_engine_requests(engine, 4);
    let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
    router.queues[0].send(Msg::Shutdown).unwrap();
    let rx2 = client.submit(4, "kahan", vec![1.0; 64], vec![2.0; 64]);
    gate.open();
    let stats = svc.stop();
    assert!(rx_big.recv().expect("pre-shutdown reply").value.is_ok());
    assert_eq!(rx2.recv().expect("drained reply").value.expect("value"), 128.0);
    assert_eq!(stats.errors, 0, "{stats:?}");
    assert_eq!(stats.requests, 5, "{stats:?}");
}

/// A windowed lane coalesces a queued burst exactly like the
/// opportunistic lane does (the window only ever ADDS gather time) and
/// stays bit-identical to serial execution.
#[test]
fn batch_window_burst_still_coalesces_bit_identically() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        ServiceConfig { batch_window_us: 1_000, ..ServiceConfig::default() },
        engine,
    );
    let gate = Gate::close(engine, 0);
    let mut rng = Rng::new(89);
    let n_big = 200_000;
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
    wait_engine_requests(engine, 1);
    let smalls: Vec<(Vec<f32>, Vec<f32>)> = [512usize, 1024, 2048, 64]
        .iter()
        .map(|&n| (rng.normal_f32_vec(n), rng.normal_f32_vec(n)))
        .collect();
    let rxs: Vec<_> = smalls
        .iter()
        .enumerate()
        .map(|(i, (a, b))| client.submit(1 + i as u64, "kahan", a.clone(), b.clone()))
        .collect();
    gate.open();
    assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
    let batched: Vec<f32> = rxs
        .into_iter()
        .map(|rx| {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("batched reply");
            assert_eq!(resp.batch_size, 4, "the queued burst must share one batch");
            resp.value.expect("batched value")
        })
        .collect();
    for (i, (a, b)) in smalls.iter().enumerate() {
        let serial = client.dot_blocking("kahan", a.clone(), b.clone()).expect("serial");
        assert_eq!(serial.to_bits(), batched[i].to_bits(), "req {i}: window changed bits");
    }
    let stats = svc.stop();
    assert_eq!(stats.batches, 1, "{stats:?}");
    assert_eq!(stats.batched_requests, 4, "{stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
}
