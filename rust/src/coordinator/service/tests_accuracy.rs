//! Accuracy-tier service tests: requests carry an `accuracy` tier end to
//! end (`naive` / `kahan` / `dot2` / `exact`), the empty string resolves
//! to the configured default, and a mixed-accuracy burst splits into
//! per-tier chunks — tiers with a fused twin fuse, Dot2/Exact
//! serial-loop — with bits identical to serial resubmission either way.
//! The shared `Gate` / `leak_engine` / `wait_engine_requests` helpers
//! come from `tests.rs`.

use super::tests::{leak_engine, wait_engine_requests, Gate};
use super::*;
use crate::accuracy::exact::exact_dot_f32;
use crate::accuracy::gen_dot_f32;
use crate::engine::plan::batch_exec;
use crate::engine::{dispatch, fused_dots_total, SizeClass, Topology};
use crate::isa::Precision;
use crate::util::Rng;
use std::time::Duration;

/// Satellite: a lane wake-up holding a MIXED-accuracy burst splits it
/// into per-tier chunks; the Kahan chunk goes through the fused batch
/// kernel (when the calibrated cutoff approves), the Dot2 chunk — whose
/// tier has no fused twin by construction — serial-loops inside its
/// engine batch call. Both are bit-identical to serial resubmission.
#[test]
fn mixed_accuracy_burst_fuses_kahan_and_serial_loops_dot2() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    let gate = Gate::close(engine, 0);

    let mut rng = Rng::new(97);
    let n_big = 200_000; // parallel path: blocks on the gate
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
    wait_engine_requests(engine, 1);

    // the queued burst: three kahan + two dot2, interleaved
    let specs: [(&'static str, usize); 5] =
        [("kahan", 1024), ("dot2", 1024), ("kahan", 512), ("dot2", 2048), ("kahan", 1024)];
    let reqs: Vec<(&'static str, Vec<f32>, Vec<f32>)> = specs
        .iter()
        .map(|&(acc, n)| (acc, rng.normal_f32_vec(n), rng.normal_f32_vec(n)))
        .collect();
    let rxs: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, &(acc, ref a, ref b))| client.submit(1 + i as u64, acc, a.clone(), b.clone()))
        .collect();

    let fused_before = fused_dots_total();
    gate.open();
    assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
    let mut batched = Vec::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("batched reply");
        let want_bsz: usize = if reqs[i].0 == "kahan" { 3 } else { 2 };
        assert_eq!(resp.batch_size, want_bsz, "req {i}: per-tier chunk size");
        batched.push(resp.value.expect("batched value"));
    }

    // serial resubmission (blocking ⇒ no coalescing) must be
    // bit-identical per tier: neither fusing nor looping changes bits
    for (i, &(acc, ref a, ref b)) in reqs.iter().enumerate() {
        let serial = client.dot_blocking(acc, a.clone(), b.clone()).expect("serial");
        assert_eq!(
            serial.to_bits(),
            batched[i].to_bits(),
            "req {i} ({acc}): batched vs serial bits differ"
        );
    }

    // dot2 has no fused twin in ANY cell — its serial loop is the
    // planner's decision, not a lucky cutoff
    for class in [SizeClass::L1, SizeClass::Llc, SizeClass::Mem] {
        for k in [2usize, 8, 64] {
            assert!(
                batch_exec(dispatch(), Precision::Sp, crate::isa::Accuracy::Dot2, class, k)
                    .is_none(),
                "dot2 must never fuse ({class:?}, k={k})"
            );
            assert!(
                batch_exec(dispatch(), Precision::Sp, crate::isa::Accuracy::Exact, class, k)
                    .is_none(),
                "exact must never fuse ({class:?}, k={k})"
            );
        }
    }
    // ...while the kahan run fused iff its cell's calibrated cutoff
    // approves a run of 3 (the counter is process-global, so only the
    // ≥ direction is race-free to assert)
    let kahan_class = SizeClass::of((2 * 1024 * std::mem::size_of::<f32>()) as u64);
    if batch_exec(dispatch(), Precision::Sp, crate::isa::Accuracy::Kahan, kahan_class, 3).is_some()
    {
        assert!(
            fused_dots_total() - fused_before >= 3,
            "the kahan chunk must go through the fused kernel"
        );
    }

    let stats = svc.stop();
    // one engine batch call per tier chunk, every burst request in one
    assert_eq!(stats.batches, 2, "{stats:?}");
    assert_eq!(stats.batched_requests, 5, "{stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
    assert_eq!(stats.requests, 11, "{stats:?}");
}

/// The two new tiers round-trip end to end: Dot2 holds its error bound
/// where Kahan-grade accuracy is the floor, Exact returns the correctly
/// rounded dot even at chunked-parallel sizes (it always routes Inline),
/// and the pooled-stream path accepts tier names and aliases.
#[test]
fn dot2_and_exact_tiers_round_trip_through_the_service() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let mut rng = Rng::new(101);

    // ill-conditioned input: dot2 must stay at full working accuracy
    let (a, b, exact, _cond) = gen_dot_f32(4096, 1e6, &mut rng);
    let absdot: f64 =
        a.iter().zip(&b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum::<f64>().max(1e-30);
    let v = client.dot_blocking("dot2", a.clone(), b.clone()).unwrap() as f64;
    assert!(
        (v - exact).abs() / absdot < 1e-6,
        "dot2 service result must stay within the Dot2 bound: {v} vs {exact}"
    );

    // exact: bit-equal to the correctly rounded reference, including at
    // a size the other tiers would serve chunked-parallel
    let n = 300_000;
    let xa = rng.normal_f32_vec(n);
    let xb = rng.normal_f32_vec(n);
    let want = exact_dot_f32(&xa, &xb) as f32;
    let got = client.dot_blocking("exact", xa.clone(), xb.clone()).unwrap();
    assert_eq!(got.to_bits(), want.to_bits(), "exact tier must be correctly rounded");

    // pooled streams take tiers (and parse aliases) too
    let (ha, hb) = client.admit_pair_blocking(a, b).expect("pair");
    let p1 = client.dot_pooled_blocking("dot2", ha, hb).expect("pooled dot2");
    let p2 = client.dot_pooled_blocking("oro", ha, hb).expect("alias oro = dot2");
    assert_eq!(p1.to_bits(), p2.to_bits(), "alias must hit the same tier");
    assert!((p1 as f64 - exact).abs() / absdot < 1e-6);

    let stats = svc.stop();
    assert_eq!(stats.errors, 0, "{stats:?}");
}

/// An empty accuracy string resolves to `ServiceConfig::default_accuracy`
/// (bit-identical to naming the tier explicitly), a bad default is a
/// clean startup error, and an unknown per-request tier is a per-request
/// error — counted, never a hang or a silent drop.
#[test]
fn empty_accuracy_resolves_to_configured_default() {
    let mut rng = Rng::new(103);
    let a = rng.normal_f32_vec(2048);
    let b = rng.normal_f32_vec(2048);

    // default default: kahan
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let vd = client.dot_blocking("", a.clone(), b.clone()).unwrap();
    let vk = client.dot_blocking("kahan", a.clone(), b.clone()).unwrap();
    assert_eq!(vd.to_bits(), vk.to_bits(), "\"\" must be the configured default tier");
    assert!(client.dot_blocking("fast", a.clone(), b.clone()).is_err());
    let stats = svc.stop();
    assert_eq!(stats.errors, 1, "{stats:?}");

    // a reconfigured default changes what "" means
    let cfg = ServiceConfig { default_accuracy: "dot2".into(), ..ServiceConfig::default() };
    let (svc, client) = DotService::start(cfg).unwrap();
    let vd = client.dot_blocking("", a.clone(), b.clone()).unwrap();
    let v2 = client.dot_blocking("dot2", a.clone(), b.clone()).unwrap();
    assert_eq!(vd.to_bits(), v2.to_bits());
    svc.stop();

    // a bad default is caught at startup, not deep in a lane
    let bad = ServiceConfig { default_accuracy: "fastest".into(), ..ServiceConfig::default() };
    assert!(bad.validate().is_err());
    assert!(DotService::start(bad).is_err());
}
