//! Per-shard submitter lanes: the greedy FIFO drain, same-kind run
//! coalescing, the planner-gated adaptive batching window, and the
//! batched serve paths.
//!
//! Each wake-up takes everything already queued (capped), then serves it
//! as runs — consecutive small dots become one engine batch, consecutive
//! admissions one worker pass — so a burst pays one handoff instead of
//! one per request, without reordering anything (runs never cross a
//! message of a different kind). When `ServiceConfig::batch_window_us` is
//! set, a wake-up whose trailing fuse-eligible dot run is shorter than a
//! full batch may additionally wait — but only when the planner
//! ([`crate::engine::PlanPolicy::batch_window`]) confirms the fused
//! kernel wins at the projected batch size; where fusion lost the
//! calibration probe, added latency buys nothing and the lane serves
//! immediately. Before waiting, everything queued AHEAD of the growable
//! run (admissions, other-tier or parallel/split-route dots) is served
//! — the window may only ever delay requests that stand to gain from it.

use super::router::HostRouter;
use super::{msg_kind, DotRequest, DotResponse, Msg, ServiceError};
use crate::engine::autotune::acc_index;
use crate::engine::plan::batch_exec;
use crate::engine::{dispatch, DotRoute, HomedSlice};
use crate::isa::{Accuracy, Precision};
use crate::util::faults;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// Upper bound on one wake-up's blocking first-`recv`: the receiver lock
/// must come up for air this often so (a) a supervisor-spawned
/// replacement can take over the lane, and (b) a stale-epoch incarnation
/// notices it was replaced and exits.
const LANE_RECV_SLICE: Duration = Duration::from_millis(50);

/// One shard's submitter: drain the lane queue GREEDILY in FIFO order.
/// On the shutdown marker, everything already queued behind it is
/// *served* (not dropped) before the thread exits — the old single-router
/// loop broke out of `recv` on shutdown and silently dropped queued
/// requests, leaving their clients with a disconnected reply channel.
///
/// Supervision contract: the queue receiver is borrowed from the lane's
/// `LaneSlot` per wake-up (never owned — a dead incarnation must not
/// disconnect the channel), every gather happens under that lock with
/// bounded waits, and serving happens OUTSIDE it, so a submitter wedged
/// mid-execute never blocks its replacement's gathers. `my_epoch` is the
/// incarnation's generation: the loop top exits on a stale epoch, which
/// is how a wedged-then-recovered incarnation retires without ever
/// double-serving (it finishes the messages it already dequeued — they
/// are served exactly once, by it — and takes no more).
pub(super) fn submitter_loop(
    router: &HostRouter,
    shard: usize,
    rx: &Mutex<mpsc::Receiver<Msg>>,
    my_epoch: usize,
) {
    // calibrate the dispatch table before the first request, on a worker
    // thread so `DotService::start` stays non-blocking (the OnceLock makes
    // one submitter calibrate while its peers wait)
    let _ = crate::engine::dispatch();
    // bound one wake-up's gather so a firehose producer cannot starve the
    // executions it is waiting on (max_batch >= 1 is validated at start)
    let gather_cap = router.policy.max_batch * 4;
    let mut shutdown = false;
    loop {
        if router.lanes[shard].epoch.load(Ordering::Relaxed) != my_epoch {
            // replaced (wedge recovery) or retired (shutdown epoch bump)
            return;
        }
        let mut pending: Vec<Msg> = Vec::new();
        {
            // a poisoned lock means a predecessor panicked mid-gather;
            // the receiver itself is fine — recover and keep serving
            let rx = rx.lock().unwrap_or_else(|p| p.into_inner());
            let first = if shutdown {
                match rx.try_recv() {
                    Ok(m) => m,
                    Err(_) => return,
                }
            } else {
                match rx.recv_timeout(LANE_RECV_SLICE) {
                    Ok(m) => m,
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            };
            router.lanes[shard].hb.busy();
            match first {
                Msg::Shutdown => shutdown = true,
                m => {
                    // depth gauge + fair-admission slot return (shutdown
                    // markers bypass `send_to`, so they bypass this too)
                    router.note_dequeued(shard, &m);
                    if shutdown {
                        router.drained.fetch_add(1, Ordering::Relaxed);
                    }
                    pending.push(m);
                }
            }
            while pending.len() < gather_cap {
                match rx.try_recv() {
                    Ok(Msg::Shutdown) => shutdown = true,
                    Ok(m) => {
                        router.note_dequeued(shard, &m);
                        // messages gathered behind the marker are the drain set
                        if shutdown {
                            router.drained.fetch_add(1, Ordering::Relaxed);
                        }
                        pending.push(m);
                    }
                    Err(_) => break,
                }
            }
            // latency-aware adaptive batching: the greedy gather came up
            // short of a full batch — if (and only if) the planner approves,
            // trade a bounded wait for a bigger fuse. Never during shutdown:
            // the drain must finish promptly.
            if !shutdown && pending.len() < gather_cap {
                if let Some((window, run, kind, accuracy)) = router.plan_window(shard, &pending) {
                    router.lanes[shard].window_waits.fetch_add(1, Ordering::Relaxed);
                    // serve everything AHEAD of the growable run first:
                    // admissions, pooled releases, and parallel/split-route or
                    // other-tier dots can never join this fuse, so holding
                    // them through the window would be pure added latency
                    // (FIFO order is preserved — they were queued earlier)
                    let head = pending.len() - run;
                    if head > 0 {
                        let rest = pending.split_off(head);
                        serve_pending(router, shard, std::mem::replace(&mut pending, rest));
                    }
                    let deadline = Instant::now() + window;
                    while pending.len() < router.policy.max_batch && pending.len() < gather_cap {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(Msg::Shutdown) => {
                                shutdown = true;
                                break;
                            }
                            Ok(m) => {
                                router.note_dequeued(shard, &m);
                                let grew = router.grows_fuse(shard, &m, kind, accuracy);
                                pending.push(m);
                                if !grew {
                                    // a message that can't join the fuse ended
                                    // the run — more waiting can't grow it, and
                                    // would only delay this arrival, so serve
                                    break;
                                }
                            }
                            Err(_) => break,
                        }
                    }
                }
            }
        }
        // the "lane" fault site sits between gather and serve, outside
        // the receiver lock: Die drops `pending` on the floor (their
        // clients see a disconnected reply channel — LaneDead on the
        // retry path) and the supervisor restarts the lane; Stall here is
        // a wedge the heartbeat exposes without poisoning the lock
        if faults::act(faults::check("lane", shard)) {
            return;
        }
        serve_pending(router, shard, pending);
        router.lanes[shard].hb.idle();
    }
}

/// Serve one wake-up's gathered messages as maximal same-kind runs, in
/// arrival order.
fn serve_pending(router: &HostRouter, shard: usize, msgs: Vec<Msg>) {
    let mut run: Vec<Msg> = Vec::new();
    for m in msgs {
        if !run.is_empty() && msg_kind(&run[0]) != msg_kind(&m) {
            serve_run(router, shard, std::mem::take(&mut run));
        }
        run.push(m);
    }
    if !run.is_empty() {
        serve_run(router, shard, run);
    }
}

/// Execute one same-kind run: dot and admission runs of ≥ 2 take the
/// coalesced paths, everything else the per-message path. Panic isolation
/// as for `serve_caught` — a dead lane would silently blackhole its shard.
fn serve_run(router: &HostRouter, shard: usize, mut run: Vec<Msg>) {
    if run.len() == 1 {
        serve_caught(router, shard, run.pop().expect("run of one"));
        return;
    }
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| match msg_kind(&run[0]) {
        0 => {
            let reqs: Vec<DotRequest> = run
                .into_iter()
                .map(|m| match m {
                    Msg::Req(r) => r,
                    _ => unreachable!("mixed run"),
                })
                .collect();
            router.serve_req_batch(shard, reqs);
        }
        1 => router.serve_pooled_batch(shard, run),
        2 => router.serve_admit_batch(shard, run),
        _ => {
            for m in run {
                router.serve(shard, m);
            }
        }
    }));
    if r.is_err() {
        router.errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// `serve`, but a panic (realistically: a chunk kernel panic that
/// `collect_partials` re-raises in the caller — here, this submitter)
/// must not kill the lane: a dead submitter would silently blackhole
/// every future message routed to its shard (`send_to` swallows
/// disconnects) while `ServiceStats` stays clean — a partial, invisible
/// outage. The panicking request's reply sender unwinds with the frame,
/// so its client sees a disconnect; the failure is counted and the lane
/// lives on. (The engine's worker pool survives job panics by the same
/// policy, so the next request finds it healthy.)
fn serve_caught(router: &HostRouter, shard: usize, msg: Msg) {
    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| router.serve(shard, msg)));
    if r.is_err() {
        router.errors.fetch_add(1, Ordering::Relaxed);
    }
}

impl HostRouter {
    /// Can `m` join the fuse being grown — same message kind and accuracy
    /// tier as the run's head, and itself inline-route? Anything else
    /// takes the serial path regardless of batch size, so waiting on its
    /// account (or making it wait) would be pure added latency.
    fn grows_fuse(&self, shard: usize, m: &Msg, kind: u8, accuracy: &'static str) -> bool {
        if msg_kind(m) != kind {
            return false;
        }
        let (a, n) = match m {
            Msg::Req(r) => (r.accuracy, r.a.len().min(r.b.len())),
            Msg::ReqPooled { accuracy, sa: Some(sa), sb: Some(sb), .. } => {
                (*accuracy, sa.len().min(sb.len()))
            }
            _ => return false,
        };
        if a != accuracy {
            return false;
        }
        let Ok(acc) = self.req_accuracy(a) else { return false };
        let total_bytes = (2 * n * std::mem::size_of::<f32>()) as u64;
        // plan on the tier the request will actually EXECUTE at (free
        // upgrades included); speculative, so no upgrade counting here
        let (acc, _) = self.policy.upgrade_accuracy(acc, total_bytes);
        self.policy.plan_dot(shard, acc, total_bytes).route == DotRoute::Inline
    }

    /// The planner's wait-for-k decision for one wake-up's gather: `Some`
    /// only when the gather ENDS in a coalescible inline-route dot run
    /// whose dispatch cell kept a fused kernel at the projected batch
    /// size (`PlanPolicy::batch_window` holds the full condition list).
    /// Returns the window, the length of the growable trailing run (only
    /// messages that [`HostRouter::grows_fuse`] accepts count — the
    /// caller serves everything ahead of that run before waiting), and
    /// the run's kind/tier identity for growth checks during the wait.
    fn plan_window(
        &self,
        shard: usize,
        pending: &[Msg],
    ) -> Option<(Duration, usize, u8, &'static str)> {
        if self.policy.batch_window_us == 0 {
            // the default: purely opportunistic, zero added latency
            return None;
        }
        let last = pending.last()?;
        let (accuracy, n) = match last {
            Msg::Req(r) => (r.accuracy, r.a.len().min(r.b.len())),
            Msg::ReqPooled { accuracy, sa: Some(sa), sb: Some(sb), .. } => {
                (*accuracy, sa.len().min(sb.len()))
            }
            // only dot runs grow by waiting; admissions and invalid
            // pooled operands serve immediately
            _ => return None,
        };
        let acc = self.req_accuracy(accuracy).ok()?;
        let total_bytes = (2 * n * std::mem::size_of::<f32>()) as u64;
        // window economics are judged at the executed tier — an upgraded
        // naive run fuses (or not) as kahan (speculative; not counted)
        let (acc, _) = self.policy.upgrade_accuracy(acc, total_bytes);
        // only inline-class dots ever fuse: a parallel- or split-route
        // request takes the serial path at any batch size, so waiting
        // would be pure added latency
        let plan = self.policy.plan_dot(shard, acc, total_bytes);
        if plan.route != DotRoute::Inline {
            return None;
        }
        // fuse-or-loop: tiers without a fused twin (dot2, exact) never
        // justify added window latency — the planner returns None for them
        let fused_wins =
            batch_exec(dispatch(), Precision::Sp, acc, plan.class, self.policy.max_batch).is_some();
        let kind = msg_kind(last);
        let run = pending
            .iter()
            .rev()
            .take_while(|m| self.grows_fuse(shard, m, kind, accuracy))
            .count();
        self.policy.batch_window(run, fused_wins).map(|w| (w, run, kind, accuracy))
    }

    /// Serve a coalesced run of fresh dot requests: validate each, then
    /// execute same-tier chunks of ≥ 2 as ONE engine batch on this
    /// lane's shard (bit-identical to per-request execution — tiers with
    /// a fused twin fuse, Dot2/Exact serial-loop inside the engine batch,
    /// bits never change either way). On a batch panic the chunk falls
    /// back to per-request serves, so only the culprit request errors.
    fn serve_req_batch(&self, s: usize, reqs: Vec<DotRequest>) {
        // deadline ground truth first: expired requests are shed — they
        // never reach an engine, never count as requests or errors, and
        // their removal cannot change any other request's bits (batching
        // is bit-identical at every batch size)
        let mut live: Vec<DotRequest> = Vec::with_capacity(reqs.len());
        for req in reqs {
            match self.shed_expired(s, req.deadline_us, req.submitted) {
                Some(why) => {
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Err(why),
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
                None => {
                    self.note_wait(s, req.submitted);
                    live.push(req);
                }
            }
        }
        self.requests.fetch_add(live.len() as u64, Ordering::Relaxed);
        // one group per accuracy tier, indexed like the dispatch table.
        // Grouping keys on the RESOLVED tier — the free-upgrade pass
        // applies per request here, exactly as on the serial path, so a
        // request upgrades identically whether or not it coalesced
        // (batched and single serves stay bit-identical)
        let mut groups: [Vec<DotRequest>; 4] = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
        for req in live {
            match self.req_accuracy(req.accuracy) {
                Err(e) => {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Err(e),
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
                Ok(_) if req.a.len() != req.b.len() => {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Err(ServiceError::LengthMismatch {
                            a: req.a.len(),
                            b: req.b.len(),
                        }),
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
                Ok(acc) => {
                    let total = (2 * req.a.len() * std::mem::size_of::<f32>()) as u64;
                    let (acc, upgraded) = self.policy.upgrade_accuracy(acc, total);
                    if upgraded.is_some() {
                        self.accuracy_upgrades.fetch_add(1, Ordering::Relaxed);
                    }
                    groups[acc_index(acc)].push(req)
                }
            }
        }
        for (acc, mut group) in Accuracy::ALL.into_iter().zip(groups) {
            while !group.is_empty() {
                let take = group.len().min(self.policy.max_batch);
                let chunk: Vec<DotRequest> = group.drain(..take).collect();
                self.serve_req_chunk(s, acc, chunk);
            }
        }
    }

    /// One engine batch call for a same-tier chunk of validated fresh
    /// requests (or the plain single-request path for a chunk of one).
    fn serve_req_chunk(&self, s: usize, acc: Accuracy, chunk: Vec<DotRequest>) {
        if chunk.len() == 1 {
            // mirror of the Msg::Req single path, minus the re-validation
            // (the tier was resolved — upgrades included — at grouping);
            // the deadline rides into the planner exactly as it does there
            let req = &chunk[0];
            let started = Instant::now();
            let value = self.execute_resolved(s, acc, false, |a| {
                self.engine.dot_on_deadline_f32(s, a, req.deadline_us, &req.a, &req.b)
            });
            self.note_service(s, started, 1);
            if value.is_err() {
                self.errors.fetch_add(1, Ordering::Relaxed);
            }
            let req = chunk.into_iter().next().expect("chunk of one");
            let _ = req.reply.send(DotResponse {
                id: req.id,
                value,
                batch_size: 1,
                latency: req.submitted.elapsed(),
            });
            return;
        }
        let pairs: Vec<(&[f32], &[f32])> =
            chunk.iter().map(|r| (r.a.as_slice(), r.b.as_slice())).collect();
        let started = Instant::now();
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.engine.dot_batch_on_f32(s, acc, &pairs)
        }));
        drop(pairs);
        match r {
            Ok(vals) => {
                let bsz = chunk.len();
                // every request in the batch waited on the whole batch
                self.note_service(s, started, bsz as u64);
                // counted only on success: the panic fallback below routes
                // every request through `execute`, which does its own
                // counting — counting both would break the
                // `engine_calls - batches + batched_requests == served`
                // identity the e2e driver asserts
                self.engine_calls.fetch_add(1, Ordering::Relaxed);
                self.batches.fetch_add(1, Ordering::Relaxed);
                self.batched_requests.fetch_add(bsz as u64, Ordering::Relaxed);
                self.lanes[s].executed.fetch_add(bsz as u64, Ordering::Relaxed);
                for (req, val) in chunk.into_iter().zip(vals) {
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value: Ok(val),
                        batch_size: bsz,
                        latency: req.submitted.elapsed(),
                    });
                }
            }
            Err(_) => {
                // the batch died (a kernel panicked): fall back to
                // per-request execution so only the culprit errors (tier
                // already resolved at grouping — no upgrade re-count)
                self.errors.fetch_add(1, Ordering::Relaxed);
                for req in chunk {
                    let value = self.execute_resolved(s, acc, false, |a| {
                        self.engine.dot_on_deadline_f32(s, a, req.deadline_us, &req.a, &req.b)
                    });
                    if value.is_err() {
                        self.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    let _ = req.reply.send(DotResponse {
                        id: req.id,
                        value,
                        batch_size: 1,
                        latency: req.submitted.elapsed(),
                    });
                }
            }
        }
    }

    /// Serve a coalesced run of pooled dots: operands were resolved at
    /// submit time, so validation here is presence + length; valid
    /// same-tier chunks of ≥ 2 execute as one homed engine batch on
    /// the pairs' home shards.
    fn serve_pooled_batch(&self, s: usize, msgs: Vec<Msg>) {
        struct Pooled {
            id: u64,
            sa: HomedSlice<f32>,
            sb: HomedSlice<f32>,
            reply: mpsc::Sender<DotResponse>,
            submitted: Instant,
        }
        let mut groups: [Vec<Pooled>; 4] = [Vec::new(), Vec::new(), Vec::new(), Vec::new()];
        for msg in msgs {
            let Msg::ReqPooled { id, accuracy, a, b, sa, sb, deadline_us, client: _, reply, submitted } =
                msg
            else {
                unreachable!("serve_pooled_batch takes ReqPooled runs only");
            };
            // expired deadline = shed (clean reject, not a request or an
            // error), exactly as in the fresh-request batch path
            if let Some(why) = self.shed_expired(s, deadline_us, submitted) {
                let _ = reply.send(DotResponse {
                    id,
                    value: Err(why),
                    batch_size: 1,
                    latency: submitted.elapsed(),
                });
                continue;
            }
            self.requests.fetch_add(1, Ordering::Relaxed);
            self.note_wait(s, submitted);
            let validated: Result<Accuracy, ServiceError> =
                match (self.req_accuracy(accuracy), &sa, &sb) {
                    (Err(e), _, _) => Err(e),
                    (Ok(acc), Some(sa), Some(sb)) if sa.len() == sb.len() => {
                        // resolved tier keys the group (see the fresh-batch
                        // path): the free-upgrade pass applies per request,
                        // identically to its serial serve
                        let total = (2 * sa.len() * std::mem::size_of::<f32>()) as u64;
                        let (acc, upgraded) = self.policy.upgrade_accuracy(acc, total);
                        if upgraded.is_some() {
                            self.accuracy_upgrades.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(acc)
                    }
                    (Ok(_), Some(sa), Some(sb)) => {
                        Err(ServiceError::LengthMismatch { a: sa.len(), b: sb.len() })
                    }
                    // typed "stream released", as in the serial arm
                    (Ok(_), sa, _) => Err(ServiceError::StreamReleased {
                        handle: if sa.is_some() { b } else { a },
                    }),
                };
            let acc = match validated {
                Err(e) => {
                    self.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = reply.send(DotResponse {
                        id,
                        value: Err(e),
                        batch_size: 1,
                        latency: submitted.elapsed(),
                    });
                    continue;
                }
                Ok(acc) => acc,
            };
            groups[acc_index(acc)].push(Pooled {
                id,
                sa: sa.expect("validated"),
                sb: sb.expect("validated"),
                reply,
                submitted,
            });
        }
        for (acc, mut group) in Accuracy::ALL.into_iter().zip(groups) {
            while !group.is_empty() {
                let take = group.len().min(self.policy.max_batch);
                let chunk: Vec<Pooled> = group.drain(..take).collect();
                if chunk.len() == 1 {
                    let p = &chunk[0];
                    let started = Instant::now();
                    let value = self.execute_resolved(s, acc, true, |a| {
                        self.engine.dot_homed_f32(a, &p.sa, &p.sb)
                    });
                    self.note_service(s, started, 1);
                    if value.is_err() {
                        self.errors.fetch_add(1, Ordering::Relaxed);
                    }
                    let p = chunk.into_iter().next().expect("chunk of one");
                    let _ = p.reply.send(DotResponse {
                        id: p.id,
                        value,
                        batch_size: 1,
                        latency: p.submitted.elapsed(),
                    });
                    continue;
                }
                let pairs: Vec<(&HomedSlice<f32>, &HomedSlice<f32>)> =
                    chunk.iter().map(|p| (&p.sa, &p.sb)).collect();
                let started = Instant::now();
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.engine.dot_batch_homed_f32(acc, &pairs)
                }));
                drop(pairs);
                match r {
                    Ok(vals) => {
                        // success-only counting, as in `serve_req_chunk`:
                        // the panic fallback's `execute` calls count for
                        // themselves
                        let bsz = chunk.len();
                        self.note_service(s, started, bsz as u64);
                        self.engine_calls.fetch_add(1, Ordering::Relaxed);
                        self.pooled_calls.fetch_add(bsz as u64, Ordering::Relaxed);
                        self.batches.fetch_add(1, Ordering::Relaxed);
                        self.batched_requests.fetch_add(bsz as u64, Ordering::Relaxed);
                        self.lanes[s].executed.fetch_add(bsz as u64, Ordering::Relaxed);
                        for (p, val) in chunk.into_iter().zip(vals) {
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Ok(val),
                                batch_size: bsz,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                    Err(_) => {
                        self.errors.fetch_add(1, Ordering::Relaxed);
                        for p in chunk {
                            let value = self.execute_resolved(s, acc, true, |a| {
                                self.engine.dot_homed_f32(a, &p.sa, &p.sb)
                            });
                            if value.is_err() {
                                self.errors.fetch_add(1, Ordering::Relaxed);
                            }
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value,
                                batch_size: 1,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                }
            }
        }
    }

    /// Serve a coalesced run of admissions: one worker pass copies up to
    /// `max_batch` streams into shard `s`'s pool (the ROADMAP's
    /// admission-coalescing item), then handles are minted and replied in
    /// order. `max_batch = 1` degrades to the per-message path, as the
    /// config documents.
    fn serve_admit_batch(&self, s: usize, mut msgs: Vec<Msg>) {
        while !msgs.is_empty() {
            let take = msgs.len().min(self.policy.max_batch);
            let rest = msgs.split_off(take);
            let group = std::mem::replace(&mut msgs, rest);
            if group.len() == 1 {
                for m in group {
                    self.serve(s, m);
                }
                continue;
            }
            let mut datas: Vec<Vec<f32>> = Vec::with_capacity(group.len());
            let mut replies: Vec<mpsc::Sender<Result<u64, String>>> =
                Vec::with_capacity(group.len());
            for msg in group {
                let Msg::Admit { data, reply } = msg else {
                    unreachable!("serve_admit_batch takes Admit runs only");
                };
                datas.push(data);
                replies.push(reply);
            }
            let views: Vec<&[f32]> = datas.iter().map(|d| d.as_slice()).collect();
            let homed = self.engine.admit_many_to_f32(s, &views);
            self.admit_batches.fetch_add(1, Ordering::Relaxed);
            for (h, reply) in homed.into_iter().zip(replies) {
                let handle = self.next_handle.fetch_add(1, Ordering::Relaxed);
                self.streams.write().unwrap().insert(handle, h);
                self.admitted.fetch_add(1, Ordering::Relaxed);
                let _ = reply.send(Ok(handle));
            }
        }
    }
}
