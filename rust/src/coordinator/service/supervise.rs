//! Service-tier self-healing: lane slots (the queue halves that outlive
//! any one submitter incarnation), the supervisor thread, and the
//! quarantined-shard probe.
//!
//! The fault-domain layering (see the engine module's diagram):
//!
//! * **workers** — each sweep calls [`ShardedEngine::supervise`]: dead or
//!   wedged engine workers are respawned re-pinned by the pool itself
//!   (`crate::engine::parallel::WorkerPool::supervise`).
//! * **shards** — a shard burning through its respawn budget between
//!   sweeps is structurally sick (bad core, poisoned allocator …): it is
//!   **quarantined** — dropped from fresh routing and from split
//!   chunk-block *assignment* (never from chunk *geometry*, so bits are
//!   unchanged; see `ShardedEngine::quarantine`) — and probed each sweep
//!   with a no-op round-trip per worker until it proves healthy again.
//! * **lanes** — a dead submitter (panic or injected death) or a wedged
//!   one (heartbeat older than `lane_wedge_us`) is replaced. The lane's
//!   queue receiver lives in its [`LaneSlot`], NOT the thread, so queued
//!   requests survive the death and are served by the replacement; only
//!   the dead incarnation's in-hand messages drop, which their clients
//!   observe as a disconnected reply channel
//!   ([`super::ServiceError::LaneDead`] on the retry path). A wedged
//!   incarnation is abandoned (never joined — that would block on the
//!   wedge) and exits on its own at the next loop-top epoch check.

use super::router::HostRouter;
use super::{lane, Msg};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One lane's supervised state. The receiver is owned HERE, not by the
/// submitter thread: a dead submitter never disconnects the channel, so
/// clients' queued messages wait for the replacement instead of erroring,
/// and `send_to` keeps accepting during the gap (bounded back-pressure).
pub(super) struct LaneSlot {
    pub(super) rx: Mutex<mpsc::Receiver<Msg>>,
    /// current incarnation's join handle; replaced on restart (a wedged
    /// incarnation's handle is simply overwritten — joining it would
    /// block the supervisor on the wedge itself)
    pub(super) join: Mutex<Option<JoinHandle<()>>>,
}

/// The supervisor's knobs, copied out of `ServiceConfig` at start.
#[derive(Clone, Copy)]
pub(super) struct SuperviseCfg {
    pub(super) interval_us: u64,
    pub(super) worker_wedge_us: u64,
    pub(super) lane_wedge_us: u64,
    pub(super) respawn_budget: u64,
}

/// Spawn lane `shard`'s submitter at `epoch`. The thread borrows the
/// receiver from the slot per wake-up (bounded `recv_timeout` holds, so a
/// replacement can always take the lock over a dead incarnation).
pub(super) fn spawn_submitter(
    router: &Arc<HostRouter>,
    lanes: &Arc<Vec<LaneSlot>>,
    shard: usize,
    epoch: usize,
) -> JoinHandle<()> {
    let r = Arc::clone(router);
    let l = Arc::clone(lanes);
    std::thread::Builder::new()
        .name(format!("dot-submitter-{shard}"))
        .spawn(move || lane::submitter_loop(&r, shard, &l[shard].rx, epoch))
        .expect("spawn dot submitter")
}

/// The supervision loop: sweep workers, shards and lanes every
/// `interval_us` until `stopping`. Sleeps in ≤ 20 ms slices so
/// [`super::DotService::stop`] is never blocked a full interval.
pub(super) fn supervisor_loop(
    router: Arc<HostRouter>,
    lanes: Arc<Vec<LaneSlot>>,
    cfg: SuperviseCfg,
    stopping: Arc<AtomicBool>,
) {
    let shards = router.engine.shards();
    // per-shard respawn baselines: the quarantine budget counts respawns
    // SINCE the last verdict, not lifetime totals
    let mut baseline: Vec<u64> =
        (0..shards).map(|s| router.engine.shard(s).stats().respawns).collect();
    loop {
        let mut left = cfg.interval_us.max(1);
        while left > 0 && !stopping.load(Ordering::Relaxed) {
            let step = left.min(20_000);
            std::thread::sleep(Duration::from_micros(step));
            left -= step;
        }
        if stopping.load(Ordering::Relaxed) {
            return;
        }
        // 1) worker sweep: the pool joins dead workers and respawns them
        //    re-pinned; wedged ones (heartbeat older than the threshold)
        //    are abandoned and replaced
        router.engine.supervise(cfg.worker_wedge_us);
        // 2) shard verdicts: quarantine on an exhausted respawn budget,
        //    probe-reinstate once every worker round-trips again
        for s in 0..shards {
            let respawns = router.engine.shard(s).stats().respawns;
            if router.engine.is_quarantined(s) {
                let healthy = probe_shard(&router, s);
                if healthy {
                    router.engine.reinstate(s);
                    baseline[s] = router.engine.shard(s).stats().respawns;
                }
            } else if respawns.saturating_sub(baseline[s]) >= cfg.respawn_budget {
                router.engine.quarantine(s);
                router.quarantines.fetch_add(1, Ordering::Relaxed);
                baseline[s] = respawns;
            }
        }
        // 3) lane sweep: replace dead or wedged submitters
        for (s, slot) in lanes.iter().enumerate() {
            let dead = {
                let mut j = slot.join.lock().unwrap_or_else(|p| p.into_inner());
                match j.as_ref() {
                    None => true,
                    Some(h) if h.is_finished() => {
                        // reap the exited thread; its panic (if any) was
                        // already isolated per-serve
                        let _ = j.take().map(|h| h.join());
                        true
                    }
                    Some(_) => false,
                }
            };
            let wedged = !dead && router.lanes[s].hb.wedged(cfg.lane_wedge_us);
            if !dead && !wedged {
                continue;
            }
            // epoch first: a wedged incarnation that later wakes sees a
            // stale epoch at its loop top and exits instead of
            // double-serving the lane
            let epoch = router.lanes[s].epoch.fetch_add(1, Ordering::Relaxed) + 1;
            router.lanes[s].hb.idle();
            let h = spawn_submitter(&router, &lanes, s, epoch);
            *slot.join.lock().unwrap_or_else(|p| p.into_inner()) = Some(h);
            router.lane_restarts.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Health probe for a quarantined shard: a no-op job to every worker,
/// each replying on a channel — the shard is healthy only when all of
/// them round-trip within the timeout. Runs ONLY while quarantined, so
/// probes never perturb healthy-path statistics, and never computes a
/// dot, so reinstatement cannot change any request's bits.
fn probe_shard(router: &HostRouter, s: usize) -> bool {
    let engine = router.engine.shard(s);
    let n = engine.threads();
    let (tx, rx) = mpsc::channel();
    for w in 0..n {
        let tx = tx.clone();
        engine.workers().submit_to(w, Box::new(move || {
            let _ = tx.send(w);
        }));
    }
    drop(tx);
    for _ in 0..n {
        if rx.recv_timeout(Duration::from_millis(20)).is_err() {
            return false;
        }
    }
    true
}
