//! The [`super::Backend::Pjrt`] worker loop: one thread owns the
//! `Runtime` (PJRT executables are not shared across threads), drains the
//! queue with a batching window, groups compatible requests by accuracy
//! tier, and executes them in one PJRT call when possible. Only the
//! naive and kahan tiers have compiled artifacts — dot2/exact requests
//! are rejected per-request. Pooled-stream messages are a Host-backend
//! feature and are rejected synchronously.

use super::stats::ServiceStats;
use super::{DotRequest, DotResponse, Msg, ServiceConfig, ServiceError};
use crate::runtime::Runtime;
use std::sync::mpsc;
use std::time::Instant;

pub(super) fn worker_loop_pjrt(
    mut rt: Runtime,
    rx: mpsc::Receiver<Msg>,
    cfg: ServiceConfig,
) -> ServiceStats {
    let mut shutdown = false;
    let mut stats = ServiceStats::default();
    let batched_max_n = rt
        .manifest()
        .get(&cfg.batched_artifact_kahan)
        .map(|m| m.n)
        .unwrap_or(0);

    // pooled-stream admission is a Host-backend feature: the PJRT worker
    // rejects it synchronously rather than pretending to hold streams
    let reject_pooled = |msg: Msg| match msg {
        Msg::Admit { reply, .. } => {
            let _ = reply.send(Err("stream admission requires the Host backend".into()));
        }
        Msg::AdmitPair { reply, .. } => {
            let _ = reply.send(Err("stream admission requires the Host backend".into()));
        }
        Msg::ReqPooled { id, reply, submitted, .. } => {
            let _ = reply.send(DotResponse {
                id,
                value: Err(ServiceError::Unsupported(
                    "pooled dots require the Host backend".into(),
                )),
                batch_size: 0,
                latency: submitted.elapsed(),
            });
        }
        _ => {}
    };

    loop {
        // block for the first request; after the shutdown marker, keep
        // draining whatever is already queued (serving, not dropping it)
        // and exit once the channel is empty
        let first = if shutdown {
            match rx.try_recv() {
                Ok(Msg::Req(r)) => {
                    stats.drained += 1;
                    r
                }
                Ok(Msg::Shutdown) => continue,
                Ok(other) => {
                    reject_pooled(other);
                    continue;
                }
                Err(_) => break,
            }
        } else {
            match rx.recv() {
                Ok(Msg::Req(r)) => r,
                Ok(Msg::Shutdown) => {
                    shutdown = true;
                    continue;
                }
                Ok(other) => {
                    reject_pooled(other);
                    continue;
                }
                Err(_) => break,
            }
        };
        let mut queue = vec![first];
        if !shutdown {
            // batching window: gather more requests
            let deadline = Instant::now() + cfg.window;
            while queue.len() < cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(Msg::Req(r)) => queue.push(r),
                    Ok(Msg::Shutdown) => {
                        // serve what we already accepted; the outer loop
                        // then drains the rest of the channel
                        shutdown = true;
                        break;
                    }
                    Ok(other) => reject_pooled(other),
                    Err(mpsc::RecvTimeoutError::Timeout) => break,
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
        }

        // group by accuracy tier; batch-execute groups where every
        // request fits. The empty string resolves to the configured
        // default, mirroring the Host router.
        for accuracy in ["kahan", "naive"] {
            let group: Vec<DotRequest> = {
                let mut g = Vec::new();
                let mut rest = Vec::new();
                for p in queue.drain(..) {
                    let resolved =
                        if p.accuracy.is_empty() { cfg.default_accuracy.as_str() } else { p.accuracy };
                    if resolved == accuracy {
                        g.push(p);
                    } else {
                        rest.push(p);
                    }
                }
                queue = rest;
                g
            };
            if group.is_empty() {
                continue;
            }
            let (batched_name, single_name) = if accuracy == "kahan" {
                (&cfg.batched_artifact_kahan, &cfg.single_artifact_kahan)
            } else {
                (&cfg.batched_artifact_naive, &cfg.single_artifact_naive)
            };

            let fits = group.len() >= 2
                && batched_max_n > 0
                && group.iter().all(|p| p.a.len() <= batched_max_n);
            if fits {
                stats.pjrt_calls += 1;
                stats.batched_calls += 1;
                let pairs: Vec<(Vec<f32>, Vec<f32>)> =
                    group.iter().map(|p| (p.a.clone(), p.b.clone())).collect();
                match rt.batched_dot_f32(batched_name, &pairs) {
                    Ok(values) => {
                        let bsz = group.len();
                        for (p, v) in group.into_iter().zip(values) {
                            stats.requests += 1;
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Ok(v),
                                batch_size: bsz,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                    Err(e) => {
                        stats.errors += 1;
                        for p in group {
                            stats.requests += 1;
                            let _ = p.reply.send(DotResponse {
                                id: p.id,
                                value: Err(ServiceError::Unsupported(format!(
                                    "batched execute: {e}"
                                ))),
                                batch_size: 0,
                                latency: p.submitted.elapsed(),
                            });
                        }
                    }
                }
            } else {
                for p in group {
                    stats.requests += 1;
                    stats.pjrt_calls += 1;
                    let value = rt
                        .dot_f32(single_name, &p.a, &p.b)
                        .map_err(|e| ServiceError::Unsupported(e.to_string()));
                    if value.is_err() {
                        stats.errors += 1;
                    }
                    let _ = p.reply.send(DotResponse {
                        id: p.id,
                        value,
                        batch_size: 1,
                        latency: p.submitted.elapsed(),
                    });
                }
            }
        }
        // tiers without a compiled PJRT artifact (dot2, exact) and
        // unknown strings: per-request error, never a silent drop
        for p in queue.drain(..) {
            stats.requests += 1;
            stats.errors += 1;
            let _ = p.reply.send(DotResponse {
                id: p.id,
                value: Err(ServiceError::Unsupported(format!(
                    "accuracy tier `{}` requires the Host backend",
                    p.accuracy
                ))),
                batch_size: 0,
                latency: p.submitted.elapsed(),
            });
        }
    }
    stats
}
