//! Service counters: per-lane live counters, the public snapshot types,
//! and the aggregation that `DotService::stop` returns.

use super::router::HostRouter;
use std::sync::atomic::{AtomicU64, Ordering};

/// Per-submitter-lane counters (Host backend; lane index == shard index).
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneStats {
    /// messages accepted into this lane's queue. Sends rejected by a
    /// stopped lane are not counted; a send that wins the race into the
    /// queue just as the submitter exits is counted but never served
    /// (its client sees a disconnect), so during a shutdown race this
    /// may exceed the lane's served total by the few in-flight sends.
    pub routed: u64,
    /// dots (fresh + pooled) executed by this lane's submitter
    pub executed: u64,
    /// sends that found this lane's queue full and had to block
    pub queue_full_stalls: u64,
    /// wake-ups where this lane entered a planner-approved adaptive
    /// batching window (waited up to `ServiceConfig::batch_window_us` for
    /// more requests); always 0 with the default window of 0
    pub window_waits: u64,
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    /// engine executions (Host backend)
    pub engine_calls: u64,
    /// streams admitted into shard-local pooled storage (Host backend)
    pub admitted: u64,
    /// dots served over already-admitted streams on their home shard.
    /// (Cross-shard split counts live in `ShardedEngine::stats` — the
    /// engine is process-global, so a per-service delta would misattribute
    /// splits whenever two services or a direct engine user coexist.)
    pub pooled_calls: u64,
    pub pjrt_calls: u64,
    pub batched_calls: u64,
    /// Host backend: engine batch calls that fused ≥ 2 queued dots into
    /// one execution (each also counts once in `engine_calls`)
    pub batches: u64,
    /// Host backend: dots served inside those batches
    pub batched_requests: u64,
    /// Host backend: admission bursts coalesced into one worker pass
    pub admit_batches: u64,
    pub errors: u64,
    /// Host backend: dots whose fan-out the ECM governance layer capped
    /// below the realized worker count, snapshotted from the backing
    /// engine's counters ([`crate::engine::ShardedStats::capped_requests`]).
    /// Like the split counts, this is engine-level: two services sharing
    /// one engine both see the engine's total.
    pub capped_requests: u64,
    /// total sends that hit a full lane queue and blocked (back-pressure)
    pub queue_full_stalls: u64,
    /// messages served during the shutdown drain (they were queued behind
    /// the shutdown marker and would have been dropped without the drain)
    pub drained: u64,
    /// lane wake-ups that entered an adaptive batching window (sum of
    /// [`LaneStats::window_waits`])
    pub window_waits: u64,
    /// per-shard router lanes (empty for the Pjrt backend)
    pub lanes: Vec<LaneStats>,
}

/// One submitter lane's live counters.
#[derive(Default)]
pub(super) struct LaneCounters {
    pub(super) routed: AtomicU64,
    pub(super) executed: AtomicU64,
    pub(super) queue_full_stalls: AtomicU64,
    pub(super) window_waits: AtomicU64,
}

impl HostRouter {
    pub(super) fn snapshot(&self) -> ServiceStats {
        let lanes: Vec<LaneStats> = self
            .lanes
            .iter()
            .map(|l| LaneStats {
                routed: l.routed.load(Ordering::Relaxed),
                executed: l.executed.load(Ordering::Relaxed),
                queue_full_stalls: l.queue_full_stalls.load(Ordering::Relaxed),
                window_waits: l.window_waits.load(Ordering::Relaxed),
            })
            .collect();
        ServiceStats {
            requests: self.requests.load(Ordering::Relaxed),
            engine_calls: self.engine_calls.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            pooled_calls: self.pooled_calls.load(Ordering::Relaxed),
            pjrt_calls: 0,
            batched_calls: 0,
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            admit_batches: self.admit_batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            capped_requests: self.engine.stats().capped_requests,
            queue_full_stalls: lanes.iter().map(|l| l.queue_full_stalls).sum(),
            drained: self.drained.load(Ordering::Relaxed),
            window_waits: lanes.iter().map(|l| l.window_waits).sum(),
            lanes,
        }
    }
}
