//! Service counters: per-lane live counters, the log-bucketed latency
//! histograms, the public snapshot types, and the aggregation that
//! `DotService::stop` returns.

use super::router::HostRouter;
use crate::util::faults::Heartbeat;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Bucket count of a [`LatencyHist`]: one power-of-two bucket per `u64`
/// microsecond magnitude, so recording is a single shift + atomic add and
/// the whole histogram is a fixed 512-byte array — cheap enough to keep
/// two per lane (queue wait and service time) on the hot path.
pub const HIST_BUCKETS: usize = 64;

/// A log-bucketed latency histogram snapshot. Bucket 0 counts
/// sub-microsecond samples; bucket `b ≥ 1` counts samples in
/// `[2^(b-1), 2^b)` µs. The ~2× bucket resolution is exactly what tail
/// percentiles need (p99 at 1.3 ms vs 1.4 ms is noise; 1 ms vs 2 ms is
/// signal) at a fraction of the cost of recording raw samples.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LatencyHist {
    pub buckets: [u64; HIST_BUCKETS],
}

impl Default for LatencyHist {
    fn default() -> Self {
        LatencyHist { buckets: [0; HIST_BUCKETS] }
    }
}

impl LatencyHist {
    /// Bucket index of a sample: `0` for sub-µs, else `ilog2(us) + 1`,
    /// clamped into range (the top bucket absorbs everything ≥ 2^62 µs,
    /// i.e. never in practice).
    pub fn bucket_of(us: u64) -> usize {
        (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    /// Representative value (µs) of one bucket: the geometric middle-ish
    /// `1.5 × lower bound` (0 for the sub-µs bucket, 1 for `[1, 2)`).
    fn rep_us(b: usize) -> u64 {
        match b {
            0 => 0,
            1 => 1,
            b => 3u64 << (b - 2),
        }
    }

    /// Inclusive upper bound (µs) of one bucket — what percentiles report.
    fn upper_us(b: usize) -> u64 {
        if b == 0 {
            0
        } else {
            1u64 << b
        }
    }

    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Fold another histogram in (per-lane → service-wide aggregation).
    pub fn merge(&mut self, other: &LatencyHist) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
    }

    /// Mean sample estimate (µs) from bucket representatives; 0 when the
    /// histogram is empty. This is the per-message service-time estimate
    /// the admission-shed projection uses (`PlanPolicy::shed`).
    pub fn mean_us(&self) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let sum: u64 = self
            .buckets
            .iter()
            .enumerate()
            .map(|(b, &c)| c.saturating_mul(Self::rep_us(b)))
            .fold(0, u64::saturating_add);
        sum / n
    }

    /// Percentile estimate (µs), `p` clamped into [0, 100]: the upper
    /// bound of the first bucket whose cumulative count covers `p` of the
    /// samples (a conservative tail estimate — log-bucketing reports "at
    /// most 2^b µs"). Empty histograms return 0, never NaN or a panic:
    /// a burst scenario that sheds everything still emits valid metrics.
    pub fn percentile_us(&self, p: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((p.clamp(0.0, 100.0) / 100.0) * n as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (b, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                return Self::upper_us(b);
            }
        }
        Self::upper_us(HIST_BUCKETS - 1)
    }
}

/// Per-submitter-lane counters (Host backend; lane index == shard index).
#[derive(Clone, Copy, Debug, Default)]
pub struct LaneStats {
    /// messages accepted into this lane's queue. Sends rejected by a
    /// stopped lane are not counted; a send that wins the race into the
    /// queue just as the submitter exits is counted but never served
    /// (its client sees a disconnect), so during a shutdown race this
    /// may exceed the lane's served total by the few in-flight sends.
    pub routed: u64,
    /// dots (fresh + pooled) executed by this lane's submitter
    pub executed: u64,
    /// sends that found this lane's queue full and had to block
    pub queue_full_stalls: u64,
    /// total microseconds senders spent blocked on this lane's full queue
    /// (the stall *time* behind `queue_full_stalls`' stall *events*; each
    /// stall is also folded into `queue_wait` — a blocked sender IS queue
    /// wait the request's own `submitted` stamp already covers, so the
    /// histogram attribution and this counter agree)
    pub stalled_us: u64,
    /// requests shed on this lane by the deadline policy — at admission
    /// (projected wait or full queue vs deadline, `PlanPolicy::shed`) or
    /// at serve time (deadline expired while queued). Sheds are clean
    /// rejects, not `errors`.
    pub shed: u64,
    /// requests shed by fair admission: the client was already at the
    /// per-client in-flight cap on this lane (`PlanPolicy::admits_client`)
    pub fair_sheds: u64,
    /// wake-ups where this lane entered a planner-approved adaptive
    /// batching window (waited up to `ServiceConfig::batch_window_us` for
    /// more requests); always 0 with the default window of 0
    pub window_waits: u64,
    /// queue-wait histogram: submit → serve-start per dot request, plus
    /// one sample per blocked send (see `stalled_us`)
    pub queue_wait: LatencyHist,
    /// service-time histogram: engine execution per dot request (every
    /// request in a coalesced batch records the batch's execution time —
    /// that is what it waited on)
    pub service_time: LatencyHist,
}

/// Aggregate service statistics.
#[derive(Clone, Debug, Default)]
pub struct ServiceStats {
    pub requests: u64,
    /// engine executions (Host backend)
    pub engine_calls: u64,
    /// streams admitted into shard-local pooled storage (Host backend)
    pub admitted: u64,
    /// dots served over already-admitted streams on their home shard.
    /// (Cross-shard split counts live in `ShardedEngine::stats` — the
    /// engine is process-global, so a per-service delta would misattribute
    /// splits whenever two services or a direct engine user coexist.)
    pub pooled_calls: u64,
    pub pjrt_calls: u64,
    pub batched_calls: u64,
    /// Host backend: engine batch calls that fused ≥ 2 queued dots into
    /// one execution (each also counts once in `engine_calls`)
    pub batches: u64,
    /// Host backend: dots served inside those batches
    pub batched_requests: u64,
    /// Host backend: admission bursts coalesced into one worker pass
    pub admit_batches: u64,
    pub errors: u64,
    /// Host backend: naive requests served at kahan because the
    /// calibration profile measured their size class compensation-free
    /// (`PlanPolicy::upgrade_accuracy`; 0 without a profile or with
    /// `ServiceConfig::auto_upgrade_accuracy` off)
    pub accuracy_upgrades: u64,
    /// Host backend: dots whose route the planner promoted to Split
    /// because the calibrated projection said the homed parallel path
    /// would blow the request's deadline, snapshotted from the backing
    /// engine ([`crate::engine::ShardedStats::deadline_splits`] —
    /// engine-level, like the split counts)
    pub deadline_splits: u64,
    /// calibration profiles rejected at load (corrupt, stale, or
    /// host-mismatched) — the process fell back to live calibration
    /// ([`crate::engine::profile::rejected_count`]; process-global)
    pub profile_rejected: u64,
    /// Host backend: dots whose fan-out the ECM governance layer capped
    /// below the realized worker count, snapshotted from the backing
    /// engine's counters ([`crate::engine::ShardedStats::capped_requests`]).
    /// Like the split counts, this is engine-level: two services sharing
    /// one engine both see the engine's total.
    pub capped_requests: u64,
    /// total sends that hit a full lane queue and blocked (back-pressure)
    pub queue_full_stalls: u64,
    /// total microseconds senders spent blocked on full lane queues (sum
    /// of [`LaneStats::stalled_us`])
    pub stalled_us: u64,
    /// requests shed by the deadline policy instead of queued/served (sum
    /// of [`LaneStats::shed`]; clean rejects, NOT counted in `errors` or
    /// `requests`)
    pub shed: u64,
    /// requests shed by per-client fair admission (sum of
    /// [`LaneStats::fair_sheds`])
    pub fair_sheds: u64,
    /// releases of an unknown or already-released stream handle — a clean
    /// no-op, counted here instead of silently swallowed (double release,
    /// a client racing another client's release)
    pub release_misses: u64,
    /// messages served during the shutdown drain (they were queued behind
    /// the shutdown marker and would have been dropped without the drain)
    pub drained: u64,
    /// lane wake-ups that entered an adaptive batching window (sum of
    /// [`LaneStats::window_waits`])
    pub window_waits: u64,
    /// submitter lanes restarted by the service supervisor (a lane thread
    /// died or wedged past `ServiceConfig::lane_wedge_us`; its queued
    /// requests are re-served by the replacement, its in-flight request
    /// fails cleanly as a disconnect → [`super::ServiceError::LaneDead`])
    pub lane_restarts: u64,
    /// shards the supervisor quarantined after they exhausted their
    /// respawn budget (`ServiceConfig::shard_respawn_budget`); quarantine
    /// drops a shard from fresh routing and split chunk-block assignment
    /// but never changes bits, and probes reinstate it
    pub quarantines: u64,
    /// engine worker threads replaced by supervision sweeps, snapshotted
    /// from the backing engine ([`crate::engine::ShardedStats::respawns`]
    /// — engine-level, like the split counts)
    pub respawns: u64,
    /// pin failures from those respawns (a respawned worker that lost its
    /// core pinning — the degraded-health signal `repro engine-info`
    /// warns on)
    pub respawn_pin_failures: u64,
    /// service-wide queue-wait histogram (every lane's merged)
    pub queue_wait: LatencyHist,
    /// service-wide service-time histogram (every lane's merged)
    pub service_time: LatencyHist,
    /// per-shard router lanes (empty for the Pjrt backend)
    pub lanes: Vec<LaneStats>,
}

/// One submitter lane's live counters.
pub(super) struct LaneCounters {
    pub(super) routed: AtomicU64,
    pub(super) executed: AtomicU64,
    pub(super) queue_full_stalls: AtomicU64,
    pub(super) stalled_us: AtomicU64,
    pub(super) shed: AtomicU64,
    pub(super) fair_sheds: AtomicU64,
    pub(super) window_waits: AtomicU64,
    /// live queue-depth gauge: +1 on every accepted send, -1 on every
    /// dequeue — what the admission-shed projection multiplies by the
    /// service-time estimate
    pub(super) queued: AtomicU64,
    /// per-client queued-message counts on this lane (fair admission):
    /// +1 on every accepted dot send, -1 on its dequeue; entries drop at
    /// zero so the map stays bounded by live clients
    pub(super) inflight: Mutex<HashMap<u64, u64>>,
    /// the lane's liveness heartbeat: busy while its submitter serves a
    /// wake-up's gather, idle between — what the supervisor's wedge sweep
    /// reads (`ServiceConfig::lane_wedge_us`)
    pub(super) hb: Heartbeat,
    /// the lane's submitter generation: bumped by the supervisor on every
    /// restart; a submitter whose epoch is stale exits at its next
    /// loop-top instead of double-serving the lane
    pub(super) epoch: AtomicUsize,
    queue_wait: [AtomicU64; HIST_BUCKETS],
    service_time: [AtomicU64; HIST_BUCKETS],
}

impl Default for LaneCounters {
    fn default() -> Self {
        LaneCounters {
            routed: AtomicU64::new(0),
            executed: AtomicU64::new(0),
            queue_full_stalls: AtomicU64::new(0),
            stalled_us: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            fair_sheds: AtomicU64::new(0),
            window_waits: AtomicU64::new(0),
            queued: AtomicU64::new(0),
            inflight: Mutex::new(HashMap::new()),
            hb: Heartbeat::new(),
            epoch: AtomicUsize::new(0),
            queue_wait: std::array::from_fn(|_| AtomicU64::new(0)),
            service_time: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

fn hist_snapshot(live: &[AtomicU64; HIST_BUCKETS]) -> LatencyHist {
    let mut h = LatencyHist::default();
    for (b, a) in h.buckets.iter_mut().zip(live.iter()) {
        *b = a.load(Ordering::Relaxed);
    }
    h
}

impl LaneCounters {
    pub(super) fn record_wait_us(&self, us: u64) {
        self.queue_wait[LatencyHist::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one serve duration for `n` requests at once: every request
    /// in a coalesced batch waited on the whole batch, so each gets the
    /// full batch duration attributed as its service time.
    pub(super) fn record_service_us_n(&self, us: u64, n: u64) {
        self.service_time[LatencyHist::bucket_of(us)].fetch_add(n, Ordering::Relaxed);
    }

    /// The lane's per-message service-time estimate (µs) for the
    /// admission-shed projection; 0 until the first serve lands.
    pub(super) fn est_service_us(&self) -> u64 {
        hist_snapshot(&self.service_time).mean_us()
    }

    pub(super) fn snapshot(&self) -> LaneStats {
        LaneStats {
            routed: self.routed.load(Ordering::Relaxed),
            executed: self.executed.load(Ordering::Relaxed),
            queue_full_stalls: self.queue_full_stalls.load(Ordering::Relaxed),
            stalled_us: self.stalled_us.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            fair_sheds: self.fair_sheds.load(Ordering::Relaxed),
            window_waits: self.window_waits.load(Ordering::Relaxed),
            queue_wait: hist_snapshot(&self.queue_wait),
            service_time: hist_snapshot(&self.service_time),
        }
    }
}

impl HostRouter {
    pub(super) fn snapshot(&self) -> ServiceStats {
        let lanes: Vec<LaneStats> = self.lanes.iter().map(|l| l.snapshot()).collect();
        let mut queue_wait = LatencyHist::default();
        let mut service_time = LatencyHist::default();
        for l in &lanes {
            queue_wait.merge(&l.queue_wait);
            service_time.merge(&l.service_time);
        }
        let est = self.engine.stats();
        ServiceStats {
            requests: self.requests.load(Ordering::Relaxed),
            engine_calls: self.engine_calls.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            pooled_calls: self.pooled_calls.load(Ordering::Relaxed),
            pjrt_calls: 0,
            batched_calls: 0,
            batches: self.batches.load(Ordering::Relaxed),
            batched_requests: self.batched_requests.load(Ordering::Relaxed),
            admit_batches: self.admit_batches.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            accuracy_upgrades: self.accuracy_upgrades.load(Ordering::Relaxed),
            deadline_splits: est.deadline_splits,
            profile_rejected: crate::engine::profile::rejected_count(),
            capped_requests: est.capped_requests,
            queue_full_stalls: lanes.iter().map(|l| l.queue_full_stalls).sum(),
            stalled_us: lanes.iter().map(|l| l.stalled_us).sum(),
            shed: lanes.iter().map(|l| l.shed).sum(),
            fair_sheds: lanes.iter().map(|l| l.fair_sheds).sum(),
            release_misses: self.release_misses.load(Ordering::Relaxed),
            drained: self.drained.load(Ordering::Relaxed),
            window_waits: lanes.iter().map(|l| l.window_waits).sum(),
            lane_restarts: self.lane_restarts.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            respawns: est.respawns,
            respawn_pin_failures: est.respawn_pin_failures,
            queue_wait,
            service_time,
            lanes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_log2_with_a_submicrosecond_floor() {
        assert_eq!(LatencyHist::bucket_of(0), 0);
        assert_eq!(LatencyHist::bucket_of(1), 1);
        assert_eq!(LatencyHist::bucket_of(2), 2);
        assert_eq!(LatencyHist::bucket_of(3), 2);
        assert_eq!(LatencyHist::bucket_of(4), 3);
        assert_eq!(LatencyHist::bucket_of(1023), 10);
        assert_eq!(LatencyHist::bucket_of(1024), 11);
        assert_eq!(LatencyHist::bucket_of(u64::MAX), HIST_BUCKETS - 1);
    }

    #[test]
    fn empty_histogram_is_safe_everywhere() {
        let h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean_us(), 0);
        assert_eq!(h.percentile_us(50.0), 0, "empty -> 0, never NaN or a panic");
        assert_eq!(h.percentile_us(99.0), 0);
    }

    #[test]
    fn percentile_walks_the_cumulative_counts() {
        let mut h = LatencyHist::default();
        // 90 samples in [1,2) us, 10 in [1024, 2048) us
        h.buckets[1] = 90;
        h.buckets[11] = 10;
        assert_eq!(h.count(), 100);
        assert_eq!(h.percentile_us(50.0), 2, "median is in the fast bucket");
        assert_eq!(h.percentile_us(90.0), 2);
        assert_eq!(h.percentile_us(95.0), 2048, "the tail lands in the slow bucket");
        assert_eq!(h.percentile_us(99.0), 2048);
        // single sample: every percentile reports its bucket
        let mut one = LatencyHist::default();
        one.buckets[LatencyHist::bucket_of(300)] = 1;
        assert_eq!(one.percentile_us(0.0), 512);
        assert_eq!(one.percentile_us(99.0), 512);
    }

    #[test]
    fn mean_merge_round_trip() {
        let mut a = LatencyHist::default();
        a.buckets[1] = 4; // 4 samples ~1 us
        let mut b = LatencyHist::default();
        b.buckets[5] = 4; // 4 samples ~24 us (3 << 3)
        a.merge(&b);
        assert_eq!(a.count(), 8);
        assert_eq!(a.mean_us(), (4 * 1 + 4 * 24) / 8);
    }
}
