//! Typed service errors.
//!
//! [`ServiceError`] replaces the old string replies in
//! [`super::DotResponse`]: clients branch on variants (is this a shed? a
//! validation error? a dead lane?) instead of string-prefix matching,
//! and the retry client ([`super::DotClient::submit_with_retry`]) reads
//! retryability and the retry-after hint straight off the error. The
//! `Display` impl reproduces the exact stable texts the string era
//! established — `"shed: …"`, `"stream released: …"`, `"length
//! mismatch …"` — so `to_string()` round-trips every existing log line,
//! test assertion, and blocking-API contract unchanged.

use std::fmt;

/// Why the service did not return a value for a request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServiceError {
    /// Admission shed: the target lane's queue was full when a deadlined
    /// request arrived. `queued` carries the shed verdict's queue depth
    /// when the planner projection made the call (`None` when the bounded
    /// channel itself rejected the send).
    ShedQueueFull { lane: usize, queued: Option<usize>, deadline_us: u64, retry_after_us: u64 },
    /// Admission shed: the projected queue wait exceeded the deadline
    /// ([`crate::engine::PlanPolicy::shed`]).
    ShedProjected {
        lane: usize,
        projected_wait_us: u64,
        deadline_us: u64,
        queued: usize,
        retry_after_us: u64,
    },
    /// Serve-time shed: the deadline expired while the request sat in the
    /// queue (the admission projection is an estimate; this is ground
    /// truth).
    ShedExpired { deadline_us: u64, waited_us: u64 },
    /// Fair-admission shed: the client was already at the per-client
    /// in-flight cap on the target lane
    /// ([`crate::engine::PlanPolicy::admits_client`]).
    ShedFairness { client: u64, cap: usize, lane: usize },
    /// A pooled operand's handle was never admitted or already released —
    /// possibly by another client racing this dot, which is a clean
    /// outcome, not an internal error.
    StreamReleased { handle: u64 },
    /// The operands have different lengths. The engine's documented policy
    /// is debug-assert + truncate; the service is the layer that turns a
    /// mismatch into a client-visible error.
    LengthMismatch { a: usize, b: usize },
    /// The request's accuracy string did not parse.
    UnknownAccuracy(String),
    /// The engine call panicked under the lane's panic isolation; carries
    /// the panic payload text.
    EnginePanic(String),
    /// The lane's submitter died before replying (the reply channel
    /// disconnected). Infrastructure, not the request's fault — the
    /// supervisor restarts the lane, so a retry lands on a live one.
    LaneDead,
    /// The service has stopped.
    Stopped,
    /// The serving backend cannot perform this operation (PJRT-path
    /// rejects and runtime errors); carries the backend's text.
    Unsupported(String),
}

impl ServiceError {
    /// Retry-worthy? `true` exactly for infrastructure outcomes a retry
    /// can fix — every shed (the lane was overloaded *then*) and a dead
    /// lane (the supervisor restarts it). Validation errors
    /// (length/accuracy/released-stream) and engine panics are
    /// deterministic: retrying them burns budget to fail identically.
    pub fn is_retryable(&self) -> bool {
        matches!(
            self,
            ServiceError::ShedQueueFull { .. }
                | ServiceError::ShedProjected { .. }
                | ServiceError::ShedExpired { .. }
                | ServiceError::ShedFairness { .. }
                | ServiceError::LaneDead
        )
    }

    /// The shed projection's earliest-useful-retry hint (µs), when the
    /// admission gate computed one ([`crate::engine::ShedVerdict`]).
    pub fn retry_after_us(&self) -> Option<u64> {
        match self {
            ServiceError::ShedQueueFull { retry_after_us, .. }
            | ServiceError::ShedProjected { retry_after_us, .. } => Some(*retry_after_us),
            _ => None,
        }
    }
}

impl fmt::Display for ServiceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServiceError::ShedQueueFull { lane, queued: None, deadline_us, .. } => {
                write!(f, "shed: lane {lane} queue is full (deadline {deadline_us} us)")
            }
            ServiceError::ShedQueueFull { lane, queued: Some(q), deadline_us, .. } => {
                write!(f, "shed: lane {lane} queue is full ({q} queued, deadline {deadline_us} us)")
            }
            ServiceError::ShedProjected { lane, projected_wait_us, deadline_us, queued, .. } => {
                write!(
                    f,
                    "shed: projected lane {lane} queue wait {projected_wait_us} us exceeds \
                     deadline {deadline_us} us ({queued} queued)"
                )
            }
            ServiceError::ShedExpired { deadline_us, waited_us } => {
                write!(f, "shed: deadline {deadline_us} us expired in queue (waited {waited_us} us)")
            }
            ServiceError::ShedFairness { client, cap, lane } => {
                write!(
                    f,
                    "shed: client {client} is at the per-client in-flight cap {cap} on lane {lane}"
                )
            }
            ServiceError::StreamReleased { handle } => {
                write!(f, "stream released: handle {handle} is not admitted")
            }
            ServiceError::LengthMismatch { a, b } => write!(f, "length mismatch {a} vs {b}"),
            ServiceError::UnknownAccuracy(s) => {
                write!(f, "unknown accuracy tier `{s}` (expected naive, kahan, dot2 or exact)")
            }
            ServiceError::EnginePanic(msg) => write!(f, "engine panic: {msg}"),
            ServiceError::LaneDead => {
                write!(f, "lane dead: the submitter exited before replying")
            }
            ServiceError::Stopped => write!(f, "service stopped"),
            ServiceError::Unsupported(s) => write!(f, "{s}"),
        }
    }
}

impl std::error::Error for ServiceError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_preserves_the_stable_string_era_texts() {
        assert_eq!(
            ServiceError::ShedQueueFull {
                lane: 2,
                queued: None,
                deadline_us: 500,
                retry_after_us: 1
            }
            .to_string(),
            "shed: lane 2 queue is full (deadline 500 us)"
        );
        assert_eq!(
            ServiceError::ShedQueueFull {
                lane: 2,
                queued: Some(8),
                deadline_us: 500,
                retry_after_us: 1
            }
            .to_string(),
            "shed: lane 2 queue is full (8 queued, deadline 500 us)"
        );
        assert_eq!(
            ServiceError::ShedProjected {
                lane: 0,
                projected_wait_us: 900,
                deadline_us: 100,
                queued: 3,
                retry_after_us: 800
            }
            .to_string(),
            "shed: projected lane 0 queue wait 900 us exceeds deadline 100 us (3 queued)"
        );
        assert_eq!(
            ServiceError::ShedExpired { deadline_us: 100, waited_us: 250 }.to_string(),
            "shed: deadline 100 us expired in queue (waited 250 us)"
        );
        assert_eq!(
            ServiceError::ShedFairness { client: 7, cap: 2, lane: 1 }.to_string(),
            "shed: client 7 is at the per-client in-flight cap 2 on lane 1"
        );
        assert_eq!(
            ServiceError::StreamReleased { handle: 42 }.to_string(),
            "stream released: handle 42 is not admitted"
        );
        assert_eq!(
            ServiceError::LengthMismatch { a: 3, b: 4 }.to_string(),
            "length mismatch 3 vs 4"
        );
        assert_eq!(
            ServiceError::UnknownAccuracy("fast".into()).to_string(),
            "unknown accuracy tier `fast` (expected naive, kahan, dot2 or exact)"
        );
        assert_eq!(
            ServiceError::EnginePanic("worker died".into()).to_string(),
            "engine panic: worker died"
        );
        assert_eq!(ServiceError::Stopped.to_string(), "service stopped");
        // every shed keeps the "shed: " prefix clients historically
        // matched on
        for e in [
            ServiceError::ShedQueueFull { lane: 0, queued: None, deadline_us: 1, retry_after_us: 1 },
            ServiceError::ShedExpired { deadline_us: 1, waited_us: 2 },
            ServiceError::ShedFairness { client: 0, cap: 1, lane: 0 },
        ] {
            assert!(e.to_string().starts_with("shed: "), "{e}");
        }
    }

    #[test]
    fn retryability_separates_infrastructure_from_validation() {
        assert!(ServiceError::ShedExpired { deadline_us: 1, waited_us: 2 }.is_retryable());
        assert!(ServiceError::LaneDead.is_retryable());
        assert!(!ServiceError::LengthMismatch { a: 1, b: 2 }.is_retryable());
        assert!(!ServiceError::UnknownAccuracy("x".into()).is_retryable());
        assert!(!ServiceError::EnginePanic("p".into()).is_retryable());
        assert!(!ServiceError::Stopped.is_retryable());
        let projected = ServiceError::ShedProjected {
            lane: 0,
            projected_wait_us: 900,
            deadline_us: 100,
            queued: 3,
            retry_after_us: 800,
        };
        assert_eq!(projected.retry_after_us(), Some(800));
        assert_eq!(ServiceError::LaneDead.retry_after_us(), None);
    }
}
