//! In-crate service tests, moved verbatim from the pre-split
//! `coordinator/service.rs` (the module split is behavior-preserving, so
//! the tests must not change — only the `pub(super)` markers on the
//! shared helpers are new). PR 5's config-validation and adaptive-window
//! tests live in `tests_window.rs` and reuse the helpers.

use super::*;
use crate::accuracy::exact::exact_dot_f32;
use crate::accuracy::gen_dot_f32;
use crate::engine::{EngineConfig, ShardedConfig, ShardedEngine, Topology};
use crate::util::Rng;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

fn artifacts_present() -> bool {
    // the stub Runtime (no `pjrt` feature) fails closed, so the PJRT
    // tests must skip even when artifacts exist on disk
    cfg!(feature = "pjrt")
        && crate::runtime::artifacts_dir().join("manifest.tsv").exists()
}

fn pjrt_config() -> ServiceConfig {
    ServiceConfig { backend: Backend::Pjrt, ..ServiceConfig::default() }
}

/// A private pinned engine for router tests (leaked: submitter threads
/// need `'static`, and the process exits with the test binary).
pub(super) fn leak_engine(topo: &Topology, threads: usize) -> &'static ShardedEngine {
    Box::leak(Box::new(ShardedEngine::from_topology(
        topo,
        ShardedConfig {
            engine: EngineConfig { threads, ..EngineConfig::default() },
            ..ShardedConfig::default()
        },
    )))
}

/// Occupy every worker of `shard` until `open` is called: lets a test
/// hold a submitter *inside* a parallel-path dot deterministically.
pub(super) struct Gate(Arc<(Mutex<bool>, Condvar)>);

impl Gate {
    pub(super) fn close(engine: &ShardedEngine, shard: usize) -> Gate {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        for w in 0..engine.shard(shard).threads() {
            let g = Arc::clone(&gate);
            engine.shard(shard).workers().submit_to(
                w,
                Box::new(move || {
                    let (m, cv) = &*g;
                    let mut open = m.lock().unwrap();
                    while !*open {
                        open = cv.wait(open).unwrap();
                    }
                }),
            );
        }
        Gate(gate)
    }

    pub(super) fn open(&self) {
        let (m, cv) = &*self.0;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
}

impl Drop for Gate {
    /// A test that panics with the gate still closed would otherwise
    /// deadlock: unwinding drops the `DotService`, whose shutdown
    /// joins a submitter blocked behind the gate jobs — the failure
    /// message would be masked by a CI timeout. Opening on drop makes
    /// every panic path unwind cleanly.
    fn drop(&mut self) {
        self.open();
    }
}

// ---- Host backend (default): no artifacts needed ----

#[test]
fn host_backend_round_trip_matches_exact() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let mut rng = Rng::new(5);
    let mut rxs = Vec::new();
    let mut expected = Vec::new();
    let mut scales = Vec::new();
    // mixed sizes: inline path and chunked-parallel path
    for (i, n) in [1000usize, 2048, 400_000].iter().enumerate() {
        let a = rng.normal_f32_vec(*n);
        let b = rng.normal_f32_vec(*n);
        expected.push(exact_dot_f32(&a, &b));
        scales.push(
            a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30),
        );
        rxs.push(client.submit(i as u64, if i == 1 { "naive" } else { "kahan" }, a, b));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, i as u64);
        let v = resp.value.expect("value") as f64;
        assert!(
            (v - expected[i]).abs() / scales[i] < 1e-4,
            "req {i}: {v} vs {}",
            expected[i]
        );
    }
    let stats = svc.stop();
    assert_eq!(stats.requests, 3);
    // a burst may coalesce into engine batches (timing-dependent), but
    // singles + batched requests must account for every request
    assert!(stats.engine_calls >= 1 && stats.engine_calls <= 3, "{stats:?}");
    assert_eq!(
        (stats.engine_calls - stats.batches) + stats.batched_requests,
        3,
        "{stats:?}"
    );
    assert_eq!(stats.pjrt_calls, 0);
    assert_eq!(stats.errors, 0);
    // every fresh request was routed to and executed by some lane
    assert_eq!(stats.lanes.iter().map(|l| l.executed).sum::<u64>(), 3);
}

#[test]
fn host_backend_kahan_survives_ill_conditioned_input() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let mut rng = Rng::new(9);
    let (a, b, exact, _cond) = gen_dot_f32(4096, 1e6, &mut rng);
    let absdot: f64 =
        a.iter().zip(&b).map(|(x, y)| (*x as f64 * *y as f64).abs()).sum::<f64>().max(1e-30);
    let v = client.dot_blocking("kahan", a, b).unwrap() as f64;
    assert!(
        (v - exact).abs() / absdot < 1e-5,
        "kahan service result must stay within the Kahan bound: {v} vs {exact}"
    );
    svc.stop();
}

#[test]
fn host_backend_rejects_length_mismatch() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let r = client.dot_blocking("kahan", vec![0.0; 10], vec![0.0; 11]);
    assert!(r.is_err());
    let stats = svc.stop();
    assert_eq!(stats.errors, 1);
}

#[test]
fn host_backend_pooled_streams_round_trip_on_home_shard() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let mut rng = Rng::new(21);
    let n = 50_000;
    let av = rng.normal_f32_vec(n);
    let bv = rng.normal_f32_vec(n);
    let exact = exact_dot_f32(&av, &bv);
    let scale: f64 =
        av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);

    let ha = client.admit_blocking(av).expect("admit a");
    // co-locate b with a so the steady-state pair shares a home shard
    let hb = client.admit_near_blocking(bv, Some(ha)).expect("admit b");
    assert_ne!(ha, hb);
    // admit once, dot many: the steady-state serving pattern
    let first = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
    assert!((first as f64 - exact).abs() / scale < 1e-6);
    for _ in 0..3 {
        let again = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
        assert_eq!(first.to_bits(), again.to_bits(), "home-shard dots are bit-stable");
    }
    // unknown handles and released handles are clean errors, not hangs
    assert!(client.dot_pooled_blocking("kahan", ha, 999).is_err());
    client.release(hb);
    assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err());

    let stats = svc.stop();
    assert_eq!(stats.admitted, 2);
    assert_eq!(stats.pooled_calls, 4);
    assert_eq!(stats.errors, 2);
    assert_eq!(stats.requests, 6);
}

#[test]
fn host_backend_pooled_rejects_length_mismatch() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let ha = client.admit_blocking(vec![1.0; 100]).unwrap();
    let hb = client.admit_blocking(vec![1.0; 101]).unwrap();
    assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err());
    let stats = svc.stop();
    assert_eq!(stats.errors, 1);
}

/// Regression for the lane-race the router pool introduced: with the
/// pair on *different* shards (plain round-robin admission), a
/// strictly sequential `submit_pooled(a, b)` → `release(b)` must
/// behave like the old single-router FIFO — the in-flight dot keeps
/// its operands, and only *later* submits see the release.
#[test]
fn release_after_submit_never_invalidates_inflight_cross_shard_dot() {
    let engine = leak_engine(&Topology::fake_even(2), 1);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    let mut rng = Rng::new(41);
    let n = 4096;
    let av = rng.normal_f32_vec(n);
    let bv = rng.normal_f32_vec(n);
    let exact = exact_dot_f32(&av, &bv);
    let scale: f64 =
        av.iter().zip(&bv).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
    for round in 0..20 {
        let ha = client.admit_blocking(av.clone()).unwrap();
        let hb = client.admit_blocking(bv.clone()).unwrap();
        let rx = client.submit_pooled(round, "kahan", ha, hb);
        client.release(hb);
        client.release(ha);
        let v = rx
            .recv()
            .expect("reply")
            .value
            .expect("release-after-submit must not invalidate the in-flight dot")
            as f64;
        assert!((v - exact).abs() / scale < 1e-6, "round {round}");
        // ...while a dot submitted after the release cleanly errors
        assert!(client.dot_pooled_blocking("kahan", ha, hb).is_err(), "round {round}");
    }
    let stats = svc.stop();
    assert_eq!(stats.admitted, 40);
    assert_eq!(stats.pooled_calls, 20);
    assert_eq!(stats.errors, 20);
    assert_eq!(stats.requests, 40);
}

// ---- router pool: concurrency, back-pressure, shutdown drain ----

/// Two independent requests must NOT serialize behind one router
/// thread: with shard 0's workers gated (its submitter is stuck inside
/// a parallel-path dot), a small request routed to shard 1 completes
/// while the first is still blocked.
#[test]
fn independent_requests_do_not_serialize_behind_one_router() {
    let engine = leak_engine(&Topology::fake_even(2), 2);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    let gate = Gate::close(engine, 0);

    let mut rng = Rng::new(31);
    let n = 200_000; // 1.6 MB total: parallel path, blocks on the gate
    let rx1 = client.submit(1, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
    // fresh requests round-robin: request 2 lands on shard 1
    let a2 = rng.normal_f32_vec(1000);
    let b2 = rng.normal_f32_vec(1000);
    let exact2 = exact_dot_f32(&a2, &b2);
    let rx2 = client.submit(2, "kahan", a2, b2);

    // shard 1 serves its request while shard 0 is still blocked
    let resp2 = rx2
        .recv_timeout(Duration::from_secs(30))
        .expect("request on the free shard must not queue behind the blocked one");
    let v2 = resp2.value.expect("value") as f64;
    assert!((v2 - exact2).abs() < 1e-2 * exact2.abs().max(1.0));
    assert!(
        matches!(rx1.try_recv(), Err(mpsc::TryRecvError::Empty)),
        "gated request cannot have completed"
    );

    gate.open();
    assert!(rx1.recv_timeout(Duration::from_secs(30)).expect("gated reply").value.is_ok());
    let stats = svc.stop();
    assert_eq!(stats.lanes.len(), 2);
    assert_eq!(stats.lanes[0].executed, 1, "{stats:?}");
    assert_eq!(stats.lanes[1].executed, 1, "{stats:?}");
}

/// Bounded lanes: with queue depth 1 and the only shard's workers
/// stalled, a burst of requests blocks the producer instead of growing
/// the queue, and the stall counter advances.
#[test]
fn backpressure_blocks_producer_and_counts_stalls() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        ServiceConfig { router_queue_depth: 1, ..ServiceConfig::default() },
        engine,
    );
    let gate = Gate::close(engine, 0);

    let accepted = Arc::new(AtomicU64::new(0));
    let (rx_tx, rx_rx) = mpsc::channel();
    let producer = {
        let client = client.clone();
        let accepted = Arc::clone(&accepted);
        std::thread::spawn(move || {
            let mut rng = Rng::new(33);
            // first request takes the parallel path and blocks on the
            // gate; the rest are small
            let sizes = [200_000usize, 64, 64, 64, 64];
            for (i, n) in sizes.iter().enumerate() {
                let rx = client.submit(
                    i as u64,
                    "kahan",
                    rng.normal_f32_vec(*n),
                    rng.normal_f32_vec(*n),
                );
                accepted.fetch_add(1, Ordering::SeqCst);
                rx_tx.send(rx).unwrap();
            }
        })
    };

    // the producer can hand over at most 2 requests while the gate is
    // closed: one executing (blocked), one in the depth-1 queue; the
    // third send blocks. Wait for that steady state, then verify it
    // holds — the queue must not keep growing.
    let t0 = Instant::now();
    while accepted.load(Ordering::SeqCst) < 2 && t0.elapsed() < Duration::from_secs(30) {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(accepted.load(Ordering::SeqCst), 2);
    std::thread::sleep(Duration::from_millis(100));
    assert_eq!(
        accepted.load(Ordering::SeqCst),
        2,
        "producer must be blocked by back-pressure, not queueing unboundedly"
    );

    gate.open();
    producer.join().unwrap();
    for rx in rx_rx.iter() {
        assert!(rx.recv().expect("reply").value.is_ok());
    }
    let stats = svc.stop();
    assert_eq!(stats.requests, 5);
    assert_eq!(stats.errors, 0);
    assert!(
        stats.queue_full_stalls >= 1,
        "blocked sends must be visible in stats: {stats:?}"
    );
    // the stall's *duration* is attributed too: the producer was held for
    // the ~100 ms verification window above, so the stalled-microseconds
    // counter and the queue-wait histogram must both have seen it
    assert!(
        stats.stalled_us >= 1_000,
        "stall time must be counted in microseconds: {stats:?}"
    );
    assert!(
        stats.queue_wait.count() >= 1,
        "stall time must fold into the queue-wait histogram: {stats:?}"
    );
}

/// Regression (shutdown-drop bug): requests queued behind the shutdown
/// marker must be served during the drain, not dropped with a
/// disconnected reply channel.
#[test]
fn shutdown_drains_queued_requests_instead_of_dropping() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) =
        DotService::start_on(ServiceConfig { router_queue_depth: 8, ..Default::default() }, engine);
    let gate = Gate::close(engine, 0);

    let mut rng = Rng::new(37);
    let n = 200_000;
    // the submitter picks this up and blocks inside the gated engine
    let rx1 = client.submit(1, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
    // inject the shutdown marker *ahead* of two more requests: without
    // the drain, the submitter would exit at the marker and drop them
    let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
    router.queues[0].send(Msg::Shutdown).unwrap();
    let rx2 = client.submit(2, "kahan", vec![1.0; 64], vec![2.0; 64]);
    let rx3 = client.submit(3, "kahan", vec![1.0; 64], vec![3.0; 64]);

    gate.open();
    let stats = svc.stop();
    assert!(rx1.recv().expect("pre-shutdown reply").value.is_ok());
    assert_eq!(rx2.recv().expect("drained reply 2").value.expect("value"), 128.0);
    assert_eq!(rx3.recv().expect("drained reply 3").value.expect("value"), 192.0);
    assert_eq!(stats.requests, 3);
    assert_eq!(stats.drained, 2, "{stats:?}");
    assert_eq!(stats.errors, 0);
}

// ---- lane batching: coalescing, admission batching, controls ----

/// Wait until shard 0's engine has started executing at least `n`
/// requests (the submitter is then *inside* the engine, so everything
/// submitted next queues up behind it deterministically).
pub(super) fn wait_engine_requests(engine: &ShardedEngine, n: u64) {
    let t0 = Instant::now();
    while engine.shard(0).stats().requests < n {
        assert!(t0.elapsed() < Duration::from_secs(30), "engine never started request {n}");
        std::thread::sleep(Duration::from_millis(1));
    }
}

/// THE tentpole behavior, deterministically: a lane that wakes up with
/// k ≥ 2 queued small dots executes them as ONE engine batch, with
/// bit-identical results to serial re-submission.
#[test]
fn lane_coalesces_queued_small_dots_into_one_engine_batch() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    let gate = Gate::close(engine, 0);

    let mut rng = Rng::new(61);
    let n_big = 200_000; // 1.6 MB: parallel path, blocks on the gate
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
    // the submitter must be INSIDE the big dot before the burst is
    // queued, so the burst becomes exactly one wake-up's gather
    wait_engine_requests(engine, 1);

    let smalls: Vec<(Vec<f32>, Vec<f32>)> = [512usize, 1024, 700, 2048, 64, 4096]
        .iter()
        .map(|&n| (rng.normal_f32_vec(n), rng.normal_f32_vec(n)))
        .collect();
    let rxs: Vec<_> = smalls
        .iter()
        .enumerate()
        .map(|(i, (a, b))| client.submit(1 + i as u64, "kahan", a.clone(), b.clone()))
        .collect();

    gate.open();
    assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
    let batched: Vec<f32> = rxs
        .into_iter()
        .map(|rx| {
            let resp = rx.recv_timeout(Duration::from_secs(30)).expect("batched reply");
            assert_eq!(resp.batch_size, 6, "all six queued smalls must share one batch");
            resp.value.expect("batched value")
        })
        .collect();
    // serial re-submission (blocking ⇒ no coalescing) must be
    // bit-identical: batching never changes bits
    for (i, (a, b)) in smalls.iter().enumerate() {
        let serial = client.dot_blocking("kahan", a.clone(), b.clone()).expect("serial");
        assert_eq!(
            serial.to_bits(),
            batched[i].to_bits(),
            "req {i}: batched vs serial bits differ"
        );
    }

    let stats = svc.stop();
    assert_eq!(stats.batches, 1, "{stats:?}");
    assert_eq!(stats.batched_requests, 6, "{stats:?}");
    assert_eq!(stats.requests, 13, "{stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
    // one batch call + the big dot + 6 serial singles
    assert_eq!(stats.engine_calls, 8, "{stats:?}");
    assert_eq!(stats.lanes[0].executed, 13, "{stats:?}");
    let est = engine.stats();
    assert_eq!(est.batched, 6, "engine must see the 6 batched dots: {est:?}");
}

/// `max_batch = 1` is the unbatched control: the identical burst
/// executes per-request.
#[test]
fn max_batch_one_disables_coalescing() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        ServiceConfig { max_batch: 1, ..ServiceConfig::default() },
        engine,
    );
    let gate = Gate::close(engine, 0);
    let mut rng = Rng::new(63);
    let n_big = 200_000;
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
    wait_engine_requests(engine, 1);
    let rxs: Vec<_> = (0..4)
        .map(|i| {
            client.submit(1 + i, "kahan", rng.normal_f32_vec(256), rng.normal_f32_vec(256))
        })
        .collect();
    gate.open();
    assert!(rx_big.recv().expect("big").value.is_ok());
    for rx in rxs {
        let resp = rx.recv().expect("reply");
        assert_eq!(resp.batch_size, 1);
        assert!(resp.value.is_ok());
    }
    let stats = svc.stop();
    assert_eq!(stats.batches, 0, "{stats:?}");
    assert_eq!(stats.batched_requests, 0, "{stats:?}");
    assert_eq!(stats.engine_calls, 5, "{stats:?}");
}

/// The ROADMAP item, deterministically: a burst of admissions to one
/// shard coalesces into ONE worker pass.
#[test]
fn admit_burst_coalesces_into_one_worker_pass() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    let gate = Gate::close(engine, 0);
    let mut rng = Rng::new(67);
    let n_big = 200_000;
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n_big), rng.normal_f32_vec(n_big));
    wait_engine_requests(engine, 1);

    // queue three admissions behind the blocked submitter (send the
    // raw messages: the blocking client API would deadlock here)
    let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
    let n = 4096;
    let va = rng.normal_f32_vec(n);
    let vb = rng.normal_f32_vec(n);
    let vc = rng.normal_f32_vec(n);
    let mut replies = Vec::new();
    for v in [&va, &vb, &vc] {
        let (reply, rx) = mpsc::channel();
        router.send_to(0, Msg::Admit { data: v.clone(), reply });
        replies.push(rx);
    }

    gate.open();
    assert!(rx_big.recv().expect("big").value.is_ok());
    let handles: Vec<u64> = replies
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(30)).expect("admit reply").expect("handle"))
        .collect();
    assert_eq!(handles.len(), 3);

    // the admitted streams are live and dot correctly
    let got = client.dot_pooled_blocking("kahan", handles[0], handles[1]).expect("pooled");
    let want = client.dot_blocking("kahan", va.clone(), vb.clone()).expect("direct");
    assert_eq!(got.to_bits(), want.to_bits());

    let stats = svc.stop();
    assert_eq!(stats.admitted, 3, "{stats:?}");
    assert_eq!(stats.admit_batches, 1, "burst must be one worker pass: {stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
}

/// `admit_pair` admits a co-located stream pair in a single message.
#[test]
fn admit_pair_places_both_streams_on_one_shard_in_one_message() {
    let engine = leak_engine(&Topology::fake_even(2), 1);
    let (svc, client) = DotService::start_on(ServiceConfig::default(), engine);
    let mut rng = Rng::new(71);
    let n = 8192;
    let va = rng.normal_f32_vec(n);
    let vb = rng.normal_f32_vec(n);
    let (ha, hb) = client.admit_pair_blocking(va.clone(), vb.clone()).expect("pair");
    assert_ne!(ha, hb);
    let ServiceInner::Host { router, .. } = &svc.inner else { unreachable!() };
    {
        let streams = router.streams.read().unwrap();
        assert_eq!(
            streams[&ha].shard, streams[&hb].shard,
            "pair must share one home shard"
        );
    }
    let got = client.dot_pooled_blocking("kahan", ha, hb).expect("pooled dot");
    let want = client.dot_blocking("kahan", va, vb).expect("direct dot");
    assert_eq!(got.to_bits(), want.to_bits(), "co-located pair must not change bits");
    let stats = svc.stop();
    assert_eq!(stats.admitted, 2, "{stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
}

// ---- overload protection: deadline sheds, fair lanes, histograms ----

/// Regression for the blocking-admission priority inversion: a request
/// WITH a deadline that meets a full lane must get a clean "shed" reply
/// immediately — while the lane is still wedged — instead of blocking
/// its sender behind the stalled queue.
#[test]
fn deadline_request_sheds_on_a_full_lane_instead_of_blocking() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        ServiceConfig { router_queue_depth: 1, ..ServiceConfig::default() },
        engine,
    );
    let gate = Gate::close(engine, 0);
    let mut rng = Rng::new(91);
    let n = 200_000; // parallel path: the submitter blocks on the gate
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
    wait_engine_requests(engine, 1);
    // fill the depth-1 queue (deadline-free, so it queues instead of shedding)
    let rx_q = client.submit(1, "kahan", vec![1.0; 64], vec![2.0; 64]);
    // the lane is now FULL and wedged: the old contract would block this
    // sender indefinitely; the deadline turns it into an immediate shed
    let rx_shed = client.submit_with_deadline(2, "kahan", vec![1.0; 64], vec![2.0; 64], 50_000);
    let err = rx_shed
        .recv_timeout(Duration::from_secs(10))
        .expect("shed reply must arrive while the lane is still wedged")
        .value
        .unwrap_err();
    assert!(
        matches!(
            err,
            ServiceError::ShedQueueFull { .. } | ServiceError::ShedProjected { .. }
        ),
        "admission sheds are typed: {err:?}"
    );
    assert!(err.is_retryable(), "sheds are retryable infrastructure errors: {err}");
    assert!(err.to_string().starts_with("shed: "), "stable shed error prefix: {err}");

    gate.open();
    assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
    assert_eq!(rx_q.recv().expect("queued reply").value.expect("value"), 128.0);
    let stats = svc.stop();
    assert_eq!(stats.shed, 1, "{stats:?}");
    assert_eq!(stats.requests, 2, "sheds never count as served requests: {stats:?}");
    assert_eq!(stats.errors, 0, "sheds are clean rejects, not errors: {stats:?}");
}

/// A request admitted in time whose deadline expires while it waits in
/// the queue is shed at serve time — and shedding NEVER changes the bits
/// of the requests that are served: each survivor is bit-identical to
/// serial re-submission on the idle service.
#[test]
fn expired_deadline_sheds_in_queue_and_served_bits_never_change() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        ServiceConfig { router_queue_depth: 8, ..ServiceConfig::default() },
        engine,
    );
    let gate = Gate::close(engine, 0);
    let mut rng = Rng::new(93);
    let n = 200_000;
    let rx_big = client.submit(0, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
    wait_engine_requests(engine, 1);

    // behind the wedged submitter: one 1 µs deadline (long expired by
    // serve time) between two deadline-free requests that must survive
    let pairs: Vec<(Vec<f32>, Vec<f32>)> =
        (0..3).map(|_| (rng.normal_f32_vec(512), rng.normal_f32_vec(512))).collect();
    let rx_doomed =
        client.submit_with_deadline(1, "kahan", pairs[0].0.clone(), pairs[0].1.clone(), 1);
    let rx_a = client.submit(2, "kahan", pairs[1].0.clone(), pairs[1].1.clone());
    let rx_b = client.submit(3, "kahan", pairs[2].0.clone(), pairs[2].1.clone());

    gate.open();
    assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
    let err = rx_doomed.recv().expect("shed reply").value.unwrap_err();
    assert!(
        matches!(err, ServiceError::ShedExpired { .. }),
        "queue expiry is its own typed shed: {err:?}"
    );
    assert!(
        err.to_string().starts_with("shed: deadline"),
        "expiry shed must say the deadline expired in queue: {err}"
    );
    let va = rx_a.recv().expect("a").value.expect("served despite the shed");
    let vb = rx_b.recv().expect("b").value.expect("served despite the shed");
    // bit-identity: the shedding service vs serial re-submission
    let sa = client.dot_blocking("kahan", pairs[1].0.clone(), pairs[1].1.clone()).unwrap();
    let sb = client.dot_blocking("kahan", pairs[2].0.clone(), pairs[2].1.clone()).unwrap();
    assert_eq!(va.to_bits(), sa.to_bits(), "shedding must not change served bits");
    assert_eq!(vb.to_bits(), sb.to_bits(), "shedding must not change served bits");

    let stats = svc.stop();
    assert_eq!(stats.shed, 1, "{stats:?}");
    assert_eq!(stats.requests, 5, "big + 2 survivors + 2 serial: {stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
    // the tail histograms saw the served requests: waits for everything
    // that reached a submitter, service time for everything executed
    assert!(stats.queue_wait.count() >= 5, "{stats:?}");
    assert!(stats.service_time.count() >= 5, "{stats:?}");
    assert!(
        stats.service_time.percentile_us(99.0) >= stats.service_time.percentile_us(50.0),
        "{stats:?}"
    );
}

/// Fair admission: with a per-client in-flight cap, the greedy client's
/// overflow is shed while the quiet client's request sails through —
/// the cap never punishes the client who isn't flooding the lane.
#[test]
fn per_client_cap_sheds_the_greedy_client_not_the_quiet_one() {
    let engine = leak_engine(&Topology::single_node(), 2);
    let (svc, client) = DotService::start_on(
        ServiceConfig {
            router_queue_depth: 8,
            per_client_inflight: 2,
            ..ServiceConfig::default()
        },
        engine,
    );
    let gate = Gate::close(engine, 0);
    let mut rng = Rng::new(95);
    let greedy = client.for_client(7);
    let quiet = client.for_client(8);

    let n = 200_000;
    let rx_big = greedy.submit(0, "kahan", rng.normal_f32_vec(n), rng.normal_f32_vec(n));
    // the big dot is DEQUEUED (in service) once the engine starts it, so
    // it no longer counts against greedy's queued-per-lane budget
    wait_engine_requests(engine, 1);

    let rx_g1 = greedy.submit(1, "kahan", vec![1.0; 64], vec![2.0; 64]);
    let rx_g2 = greedy.submit(2, "kahan", vec![1.0; 64], vec![3.0; 64]);
    // third queued request from the same client: over the cap of 2
    let rx_g3 = greedy.submit(3, "kahan", vec![1.0; 64], vec![4.0; 64]);
    let err = rx_g3.recv_timeout(Duration::from_secs(10)).expect("fair shed").value.unwrap_err();
    assert!(
        matches!(err, ServiceError::ShedFairness { client: 7, .. }),
        "fair sheds are typed with the client token: {err:?}"
    );
    assert!(err.to_string().starts_with("shed: client"), "fair sheds name the client: {err}");
    // the quiet client is under ITS cap: admitted despite greedy's flood
    let rx_quiet = quiet.submit(4, "kahan", vec![1.0; 64], vec![5.0; 64]);

    gate.open();
    assert!(rx_big.recv_timeout(Duration::from_secs(30)).expect("big").value.is_ok());
    assert_eq!(rx_g1.recv().expect("g1").value.expect("value"), 128.0);
    assert_eq!(rx_g2.recv().expect("g2").value.expect("value"), 192.0);
    assert_eq!(rx_quiet.recv().expect("quiet").value.expect("value"), 320.0);
    let stats = svc.stop();
    assert_eq!(stats.fair_sheds, 1, "{stats:?}");
    assert_eq!(stats.shed, 0, "fair sheds are counted separately: {stats:?}");
    assert_eq!(stats.requests, 4, "{stats:?}");
    assert_eq!(stats.errors, 0, "{stats:?}");
}

/// Satellite regression: releasing an unknown or already-released handle
/// is a counted no-op (`release_misses`), and a dot over a released
/// stream fails with the stable "stream released" error text.
#[test]
fn releasing_an_unknown_handle_is_counted_not_swallowed() {
    let (svc, client) = DotService::start(ServiceConfig::default()).unwrap();
    let ha = client.admit_blocking(vec![1.0; 64]).unwrap();
    let hb = client.admit_blocking(vec![2.0; 64]).unwrap();
    client.release(999); // never admitted: miss
    client.release(ha); // live: hit
    client.release(ha); // double release: miss
    let err = client.dot_pooled_blocking("kahan", ha, hb).unwrap_err();
    assert!(
        err.starts_with("stream released"),
        "released-handle dots keep the stable error text: {err}"
    );
    let stats = svc.stop();
    assert_eq!(stats.release_misses, 2, "{stats:?}");
    assert_eq!(stats.errors, 1, "{stats:?}");
}

// ---- Pjrt backend: skipped without artifacts ----

#[test]
fn service_round_trip_and_batching() {
    if !artifacts_present() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let (svc, client) = DotService::start(pjrt_config()).unwrap();
    let mut rng = Rng::new(5);
    let n = 2048;
    // submit a burst so the batcher can fuse them
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..6u64 {
        let a = rng.normal_f32_vec(n);
        let b = rng.normal_f32_vec(n);
        expected.push(exact_dot_f32(&a, &b));
        rxs.push(client.submit(i, "kahan", a, b));
    }
    let mut batched_seen = false;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.id, i as u64);
        let v = resp.value.expect("value") as f64;
        assert!((v - expected[i]).abs() < 1e-2, "req {i}: {v} vs {}", expected[i]);
        batched_seen |= resp.batch_size > 1;
    }
    let stats = svc.stop();
    assert_eq!(stats.requests, 6);
    assert!(stats.errors == 0);
    assert!(batched_seen, "burst of 6 should have batched at least once");
    assert!(stats.pjrt_calls < 6, "batching must reduce PJRT calls: {stats:?}");
}

#[test]
fn naive_and_kahan_variants_route_correctly() {
    if !artifacts_present() {
        return;
    }
    let (svc, client) = DotService::start(pjrt_config()).unwrap();
    let a = vec![1.0f32; 100];
    let b = vec![2.0f32; 100];
    let vk = client.dot_blocking("kahan", a.clone(), b.clone()).unwrap();
    let vn = client.dot_blocking("naive", a, b).unwrap();
    assert_eq!(vk, 200.0);
    assert_eq!(vn, 200.0);
    svc.stop();
}

#[test]
fn oversized_request_errors_cleanly() {
    if !artifacts_present() {
        return;
    }
    let (svc, client) = DotService::start(pjrt_config()).unwrap();
    let big = vec![0.0f32; 1 << 21]; // 2M > 65536 and > batched n
    let r = client.dot_blocking("kahan", big.clone(), big);
    assert!(r.is_err());
    svc.stop();
}
