//! `repro` — CLI entry point: regenerate every table and figure of the
//! paper, run validations, sweeps and the host microbenchmarks.
//!
//! Run `repro help` for the experiment list.

fn main() {
    let code = kahan_ecm::coordinator::cli_main();
    std::process::exit(code);
}
