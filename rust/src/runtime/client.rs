//! The PJRT client wrapper: compile-once / execute-many over the manifest's
//! HLO-text artifacts (pattern from /opt/xla-example/load_hlo).
//!
//! The real client needs the `xla` bindings plus a native xla_extension
//! install, neither of which the offline container ships, so it is gated
//! behind the off-by-default `pjrt` cargo feature. Without the feature a
//! stub with the identical public surface keeps every caller compiling;
//! `Runtime::new()` then fails cleanly and the host engine
//! (`crate::engine`) serves all dot traffic instead.

#[cfg(feature = "pjrt")]
mod pjrt_client {
    use crate::runtime::manifest::{ArtifactMeta, Manifest};
    use anyhow::{anyhow, bail, Context, Result};
    use std::collections::HashMap;

    /// A loaded PJRT runtime: CPU client + executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
    }

    impl Runtime {
        /// Create a CPU PJRT client and load the manifest from the default
        /// artifacts directory.
        pub fn new() -> Result<Self> {
            Self::with_manifest(Manifest::load_default()?)
        }

        pub fn with_manifest(manifest: Manifest) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
            Ok(Runtime { client, manifest, cache: HashMap::new() })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) the executable for `name`.
        pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(name) {
                let meta = self
                    .manifest
                    .get(name)
                    .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
                    .clone();
                let path = self.manifest.hlo_path(&meta);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
                self.cache.insert(name.to_string(), exe);
            }
            Ok(self.cache.get(name).unwrap())
        }

        /// Number of executables currently compiled.
        pub fn cached(&self) -> usize {
            self.cache.len()
        }

        fn execute_scalar_out(
            exe: &xla::PjRtLoadedExecutable,
            inputs: &[xla::Literal],
        ) -> Result<Vec<f32>> {
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
            out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
        }

        fn execute_scalar_out_f64(
            exe: &xla::PjRtLoadedExecutable,
            inputs: &[xla::Literal],
        ) -> Result<Vec<f64>> {
            let result = exe
                .execute::<xla::Literal>(inputs)
                .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            let out = result.to_tuple1().map_err(|e| anyhow!("tuple: {e:?}"))?;
            out.to_vec::<f64>().map_err(|e| anyhow!("to_vec: {e:?}"))
        }

        /// Pad `v` with zeros to `n` (zeros are neutral for dot/ksum,
        /// including under compensation).
        fn pad_f32(v: &[f32], n: usize) -> Vec<f32> {
            let mut out = v.to_vec();
            out.resize(n, 0.0);
            out
        }

        fn pad_f64(v: &[f64], n: usize) -> Vec<f64> {
            let mut out = v.to_vec();
            out.resize(n, 0.0);
            out
        }

        /// Run a (non-batched) f32 dot artifact on `a`,`b` (padded as needed).
        pub fn dot_f32(&mut self, name: &str, a: &[f32], b: &[f32]) -> Result<f32> {
            let meta = self.meta_checked(name, "f32", false)?;
            if a.len() != b.len() {
                bail!("length mismatch {} vs {}", a.len(), b.len());
            }
            if a.len() > meta.n {
                bail!("input {} exceeds artifact size {}", a.len(), meta.n);
            }
            let n = meta.n;
            let exe = self.load(name)?;
            let xa = xla::Literal::vec1(&Self::pad_f32(a, n));
            let xb = xla::Literal::vec1(&Self::pad_f32(b, n));
            let v = Self::execute_scalar_out(exe, &[xa, xb])?;
            Ok(v[0])
        }

        /// Run a (non-batched) f64 dot artifact.
        pub fn dot_f64(&mut self, name: &str, a: &[f64], b: &[f64]) -> Result<f64> {
            let meta = self.meta_checked(name, "f64", false)?;
            if a.len() != b.len() {
                bail!("length mismatch");
            }
            if a.len() > meta.n {
                bail!("input too long");
            }
            let n = meta.n;
            let exe = self.load(name)?;
            let xa = xla::Literal::vec1(&Self::pad_f64(a, n));
            let xb = xla::Literal::vec1(&Self::pad_f64(b, n));
            let v = Self::execute_scalar_out_f64(exe, &[xa, xb])?;
            Ok(v[0])
        }

        /// Run a f32 Kahan-sum artifact.
        pub fn ksum_f32(&mut self, name: &str, x: &[f32]) -> Result<f32> {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?
                .clone();
            if meta.kind != "ksum" {
                bail!("{name} is not a ksum artifact");
            }
            if x.len() > meta.n {
                bail!("input too long");
            }
            let n = meta.n;
            let exe = self.load(name)?;
            let xa = xla::Literal::vec1(&Self::pad_f32(x, n));
            let v = Self::execute_scalar_out(exe, &[xa])?;
            Ok(v[0])
        }

        /// Run a batched f32 dot artifact: `pairs` must have at most
        /// `meta.batch` rows (padded with zero rows to fill a batch).
        pub fn batched_dot_f32(
            &mut self,
            name: &str,
            pairs: &[(Vec<f32>, Vec<f32>)],
        ) -> Result<Vec<f32>> {
            let meta = self.meta_checked(name, "f32", true)?;
            if pairs.len() > meta.batch {
                bail!("batch {} exceeds artifact batch {}", pairs.len(), meta.batch);
            }
            let (bsz, n) = (meta.batch, meta.n);
            let mut xs = vec![0.0f32; bsz * n];
            let mut ys = vec![0.0f32; bsz * n];
            for (row, (a, b)) in pairs.iter().enumerate() {
                if a.len() != b.len() || a.len() > n {
                    bail!("row {row}: bad lengths {} {}", a.len(), b.len());
                }
                xs[row * n..row * n + a.len()].copy_from_slice(a);
                ys[row * n..row * n + b.len()].copy_from_slice(b);
            }
            let exe = self.load(name)?;
            let xa = xla::Literal::vec1(&xs)
                .reshape(&[bsz as i64, n as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            let xb = xla::Literal::vec1(&ys)
                .reshape(&[bsz as i64, n as i64])
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            let v = Self::execute_scalar_out(exe, &[xa, xb])?;
            Ok(v[..pairs.len()].to_vec())
        }

        fn meta_checked(&self, name: &str, dtype: &str, batched: bool) -> Result<ArtifactMeta> {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact `{name}`"))?;
            if meta.dtype != dtype {
                bail!("{name} has dtype {}, want {dtype}", meta.dtype);
            }
            if batched && meta.batch == 0 {
                bail!("{name} is not batched");
            }
            if !batched && meta.batch != 0 {
                bail!("{name} is batched");
            }
            Ok(meta.clone())
        }
    }
}

#[cfg(feature = "pjrt")]
pub use pjrt_client::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::runtime::manifest::Manifest;
    use anyhow::{bail, Result};

    /// Same-API stand-in for builds without the `pjrt` feature.
    ///
    /// Construction always fails (so no caller can silently compute wrong
    /// results); the methods exist only to keep the runtime surface
    /// compiling for benches, examples and the Pjrt service backend.
    pub struct Runtime {
        manifest: Manifest,
    }

    const DISABLED: &str =
        "built without the `pjrt` feature: PJRT execution is unavailable \
         (the host engine in crate::engine serves dot requests)";

    impl Runtime {
        pub fn new() -> Result<Self> {
            bail!(DISABLED)
        }

        pub fn with_manifest(_manifest: Manifest) -> Result<Self> {
            bail!(DISABLED)
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            "none (pjrt feature disabled)".to_string()
        }

        /// Stub `load` drops the executable handle from the signature — all
        /// in-tree callers discard it (`rt.load(name)?;`).
        pub fn load(&mut self, _name: &str) -> Result<()> {
            bail!(DISABLED)
        }

        pub fn cached(&self) -> usize {
            0
        }

        pub fn dot_f32(&mut self, _name: &str, _a: &[f32], _b: &[f32]) -> Result<f32> {
            bail!(DISABLED)
        }

        pub fn dot_f64(&mut self, _name: &str, _a: &[f64], _b: &[f64]) -> Result<f64> {
            bail!(DISABLED)
        }

        pub fn ksum_f32(&mut self, _name: &str, _x: &[f32]) -> Result<f32> {
            bail!(DISABLED)
        }

        pub fn batched_dot_f32(
            &mut self,
            _name: &str,
            _pairs: &[(Vec<f32>, Vec<f32>)],
        ) -> Result<Vec<f32>> {
            bail!(DISABLED)
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::Runtime;

    #[test]
    fn stub_runtime_fails_closed_with_clear_message() {
        let err = Runtime::new().err().expect("stub must not construct");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    fn runtime_or_skip() -> Option<Runtime> {
        if !super::super::manifest::artifacts_dir().join("manifest.tsv").exists() {
            eprintln!("skipping runtime test: run `make artifacts` first");
            return None;
        }
        Some(Runtime::new().expect("runtime"))
    }

    #[test]
    fn dot_f32_matches_exact() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let mut rng = Rng::new(1);
        let a = rng.normal_f32_vec(4096);
        let b = rng.normal_f32_vec(4096);
        let got = rt.dot_f32("dot_kahan_f32_n4096", &a, &b).unwrap() as f64;
        let want = exact_dot_f32(&a, &b);
        assert!((got - want).abs() < 1e-2, "got {got} want {want}");
        // naive artifact too
        let gn = rt.dot_f32("dot_naive_f32_n4096", &a, &b).unwrap() as f64;
        assert!((gn - want).abs() < 1e-1);
    }

    #[test]
    fn dot_f32_padding_matches_short_input() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let mut rng = Rng::new(2);
        let a = rng.normal_f32_vec(1000);
        let b = rng.normal_f32_vec(1000);
        let got = rt.dot_f32("dot_kahan_f32_n4096", &a, &b).unwrap() as f64;
        let want = exact_dot_f32(&a, &b);
        assert!((got - want).abs() < 1e-2, "got {got} want {want}");
    }

    #[test]
    fn executable_cache_reuses() {
        let Some(mut rt) = runtime_or_skip() else { return };
        let a = vec![1.0f32; 8];
        let b = vec![2.0f32; 8];
        rt.dot_f32("dot_kahan_f32_n4096", &a, &b).unwrap();
        assert_eq!(rt.cached(), 1);
        rt.dot_f32("dot_kahan_f32_n4096", &a, &b).unwrap();
        assert_eq!(rt.cached(), 1);
    }

    #[test]
    fn error_paths() {
        let Some(mut rt) = runtime_or_skip() else { return };
        assert!(rt.dot_f32("nope", &[], &[]).is_err());
        let too_long = vec![0.0f32; 5000];
        assert!(rt.dot_f32("dot_kahan_f32_n4096", &too_long, &too_long).is_err());
        let a = vec![0.0f32; 8];
        let b = vec![0.0f32; 9];
        assert!(rt.dot_f32("dot_kahan_f32_n4096", &a, &b).is_err());
        // dtype guard
        assert!(rt.dot_f32("dot_kahan_f64_n65536", &a, &a).is_err());
    }
}
