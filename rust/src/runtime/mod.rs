//! PJRT runtime: load the AOT-lowered HLO artifacts (`artifacts/*.hlo.txt`)
//! and execute them on the CPU PJRT client from the Rust hot path.
//!
//! Python never runs here — `make artifacts` produced the HLO text once at
//! build time (see `python/compile/aot.py` and /opt/xla-example/README.md
//! for why the interchange format is HLO *text*).

pub mod client;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{artifacts_dir, ArtifactMeta, Manifest};
