//! Artifact manifest: the TSV index `aot.py` writes next to the HLO files.

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Metadata for one AOT-compiled computation.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// "dot" or "ksum"
    pub kind: String,
    /// "naive" or "kahan"
    pub variant: String,
    /// "f32" or "f64"
    pub dtype: String,
    /// 0 for unbatched
    pub batch: usize,
    pub n: usize,
    pub block: usize,
    pub lanes: usize,
    pub file: String,
}

impl ArtifactMeta {
    pub fn num_inputs(&self) -> usize {
        if self.kind == "ksum" {
            1
        } else {
            2
        }
    }
}

/// Parsed manifest plus its directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactMeta>,
}

/// Locate the artifacts directory: $KAHAN_ECM_ARTIFACTS, then
/// `<manifest dir>/artifacts` relative to the crate root, then ./artifacts.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("KAHAN_ECM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let candidates = [
        concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"),
        "artifacts",
    ];
    for c in candidates {
        let p = PathBuf::from(c);
        if p.join("manifest.tsv").exists() {
            return p;
        }
    }
    PathBuf::from(candidates[0])
}

impl Manifest {
    /// Load `manifest.tsv` from `dir`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split('\t').collect();
            if f.len() != 9 {
                bail!("manifest line {} has {} fields, want 9", lineno + 1, f.len());
            }
            entries.push(ArtifactMeta {
                name: f[0].to_string(),
                kind: f[1].to_string(),
                variant: f[2].to_string(),
                dtype: f[3].to_string(),
                batch: f[4].parse().context("batch")?,
                n: f[5].parse().context("n")?,
                block: f[6].parse().context("block")?,
                lanes: f[7].parse().context("lanes")?,
                file: f[8].to_string(),
            });
        }
        if entries.is_empty() {
            bail!("manifest {path:?} has no entries");
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Load from the default location.
    pub fn load_default() -> Result<Self> {
        Self::load(&artifacts_dir())
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Find the smallest artifact matching kind/variant/dtype that can hold
    /// `n` elements (used by the service to route requests).
    pub fn best_fit(&self, kind: &str, variant: &str, dtype: &str, n: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind && e.variant == variant && e.dtype == dtype)
            .filter(|e| e.batch == 0 && e.n >= n)
            .min_by_key(|e| e.n)
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), body).unwrap();
    }

    #[test]
    fn parse_roundtrip() {
        let dir = std::env::temp_dir().join("kahan_ecm_manifest_test");
        write_manifest(
            &dir,
            "# name\tkind\tvariant\tdtype\tbatch\tn\tblock\tlanes\tfile\n\
             dot_kahan_f32_n4096\tdot\tkahan\tf32\t0\t4096\t4096\t1024\tdot_kahan_f32_n4096.hlo.txt\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.get("dot_kahan_f32_n4096").unwrap();
        assert_eq!(e.n, 4096);
        assert_eq!(e.num_inputs(), 2);
        assert!(m.hlo_path(e).to_string_lossy().ends_with(".hlo.txt"));
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let dir = std::env::temp_dir().join("kahan_ecm_manifest_fit");
        write_manifest(
            &dir,
            "a\tdot\tkahan\tf32\t0\t4096\t4096\t1024\ta.hlo.txt\n\
             b\tdot\tkahan\tf32\t0\t65536\t8192\t1024\tb.hlo.txt\n\
             c\tdot\tkahan\tf32\t8\t16384\t8192\t1024\tc.hlo.txt\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.best_fit("dot", "kahan", "f32", 1000).unwrap().name, "a");
        assert_eq!(m.best_fit("dot", "kahan", "f32", 5000).unwrap().name, "b");
        assert!(m.best_fit("dot", "kahan", "f32", 100_000).is_none());
        assert!(m.best_fit("dot", "naive", "f32", 10).is_none());
    }

    #[test]
    fn malformed_rows_rejected() {
        let dir = std::env::temp_dir().join("kahan_ecm_manifest_bad");
        write_manifest(&dir, "only\tthree\tfields\n");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // exercised fully once `make artifacts` has run; skip silently in
        // a bare checkout
        let dir = artifacts_dir();
        if dir.join("manifest.tsv").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.len() >= 8);
            assert!(m.get("dot_kahan_f32_n65536").is_some());
        }
    }
}
