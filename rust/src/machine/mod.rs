//! Machine models: everything Table 1 of the paper says about a socket,
//! in a form both the analytic ECM model (`crate::ecm`) and the trace-driven
//! simulator (`crate::sim`) consume.
//!
//! A `Machine` is a *description*, not behaviour: ports, pipeline latencies,
//! cache levels with inter-level bus widths, and the memory interface
//! (peak/load-only bandwidth plus the paper's empirical per-cache-line
//! latency penalty).

pub mod detect;
pub mod presets;

pub use presets::{all_presets, nearest_preset, preset, PresetId};

/// Functional unit classes relevant to the dot kernels (paper Table 1 rows
/// "Load/Store throughput", "ADD/MUL/FMA throughput").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Unit {
    Load,
    Store,
    Add,
    Mul,
    Fma,
}

/// Core execution resources of one CPU core.
#[derive(Clone, Debug)]
pub struct CoreModel {
    /// number of L1 load ports
    pub load_ports: u32,
    /// bytes one load port moves per cycle (16 on SNB/IVB, 32 on HSW/BDW)
    pub load_port_bytes: u32,
    /// number of store ports
    pub store_ports: u32,
    /// bytes one store port moves per cycle
    pub store_port_bytes: u32,
    /// stand-alone ADD/SUB pipes (1 on all four Xeons)
    pub add_ports: u32,
    /// MUL pipes (1 on SNB/IVB, 2 on HSW/BDW)
    pub mul_ports: u32,
    /// FMA pipes (0 on SNB/IVB, 2 on HSW/BDW)
    pub fma_ports: u32,
    /// pipeline latencies in cycles
    pub add_latency: u32,
    pub mul_latency: u32,
    pub fma_latency: u32,
    pub load_latency: u32,
    /// architectural SIMD registers available for unrolling (16 for AVX2)
    pub simd_registers: u32,
    /// widest native SIMD register in bytes (32 = AVX, 64 = AVX-512)
    pub simd_width_bytes: u32,
}

impl CoreModel {
    /// Port-cycles one instruction of `unit` at `width_bytes` occupies.
    ///
    /// Encodes the paper's key micro-architectural point: on SNB/IVB an AVX
    /// load is split into two 16-byte halves, so only one 32-byte load
    /// retires per cycle even though there are two load ports.
    pub fn slots(&self, unit: Unit, width_bytes: u32) -> f64 {
        match unit {
            Unit::Load => (width_bytes as f64 / self.load_port_bytes as f64).max(1.0),
            Unit::Store => (width_bytes as f64 / self.store_port_bytes as f64).max(1.0),
            // FP pipes are full-width on all modeled machines
            Unit::Add | Unit::Mul | Unit::Fma => 1.0,
        }
    }

    /// Number of ports that can execute `unit`.
    pub fn ports(&self, unit: Unit) -> u32 {
        match unit {
            Unit::Load => self.load_ports,
            Unit::Store => self.store_ports,
            Unit::Add => self.add_ports,
            Unit::Mul => self.mul_ports,
            Unit::Fma => self.fma_ports,
        }
    }

    pub fn latency(&self, unit: Unit) -> u32 {
        match unit {
            Unit::Load => self.load_latency,
            Unit::Store => 1,
            Unit::Add => self.add_latency,
            Unit::Mul => self.mul_latency,
            Unit::Fma => self.fma_latency,
        }
    }
}

/// One cache level (L1 is index 0). `bytes_per_cy_from_inner` is the bus
/// width toward the *next-inner* level (so for L2 it is the L2→L1 bus).
#[derive(Clone, Debug)]
pub struct CacheLevel {
    pub name: &'static str,
    pub size_bytes: u64,
    /// data bus bytes/cycle toward the next-inner level (L1 entry unused)
    pub bytes_per_cy_to_inner: u32,
    /// set associativity (used by the LRU cache simulator)
    pub ways: u32,
}

/// Memory interface of the socket.
#[derive(Clone, Debug)]
pub struct MemoryModel {
    /// nominal peak bandwidth (GB/s)
    pub peak_bw_gbs: f64,
    /// measured load-only bandwidth (GB/s) — what streaming loads see
    pub load_bw_gbs: f64,
    /// the paper's empirical latency penalty, cycles per cache line
    pub latency_penalty_cy_per_cl: f64,
}

/// A full socket description (Table 1 column).
#[derive(Clone, Debug)]
pub struct Machine {
    pub name: &'static str,
    pub shorthand: &'static str,
    pub xeon_model: &'static str,
    pub year: &'static str,
    pub clock_ghz: f64,
    pub cores: u32,
    pub threads: u32,
    pub core: CoreModel,
    /// cache levels, L1 first; all inclusive (Intel through BDW)
    pub caches: Vec<CacheLevel>,
    pub memory: MemoryModel,
    pub cache_line_bytes: u32,
    /// HSW quirk: Uncore clock drops when one core is active, stretching the
    /// L3↔L2 transfer time by this factor (5.54/4 on HSW, 1.0 elsewhere).
    pub uncore_single_core_factor: f64,
    /// main memory channels description (Table 1 "Main memory" row)
    pub dram: &'static str,
}

impl Machine {
    /// Cycles to move one cache line from memory to L3 at load-only
    /// bandwidth (Table 1 last row), *excluding* the latency penalty.
    pub fn t_l3mem_per_cl(&self) -> f64 {
        self.cache_line_bytes as f64 * self.clock_ghz / self.memory.load_bw_gbs
    }

    /// Cycles to move one cache line between cache level `outer` (1-based
    /// level index of the outer cache, e.g. 1 = L2→L1) and the next-inner
    /// level, accounting for the single-core Uncore quirk on the L3→L2 bus.
    pub fn t_cache_per_cl(&self, outer: usize, single_core: bool) -> f64 {
        let lvl = &self.caches[outer];
        let base = self.cache_line_bytes as f64 / lvl.bytes_per_cy_to_inner as f64;
        // the Uncore boundary is the L3→L2 bus (outer index 2)
        if outer == 2 && single_core {
            base * self.uncore_single_core_factor
        } else {
            base
        }
    }

    /// Last-level cache size (for sweep classification).
    pub fn llc_bytes(&self) -> u64 {
        self.caches.last().map(|c| c.size_bytes).unwrap_or(0)
    }

    /// Which memory-hierarchy level a working set of `bytes` lives in:
    /// 0 = L1, ..., caches.len() = main memory.
    pub fn residence_level(&self, bytes: u64) -> usize {
        for (i, c) in self.caches.iter().enumerate() {
            if bytes <= c.size_bytes {
                return i;
            }
        }
        self.caches.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use presets::*;

    #[test]
    fn table1_t_l3mem_values() {
        // Table 1 last row: 3.96 / 3.05 / 2.43 / 3.49 cy per CL
        let cases = [
            (PresetId::Snb, 3.96),
            (PresetId::Ivb, 3.05),
            (PresetId::Hsw, 2.43),
            (PresetId::Bdw, 3.49),
        ];
        for (id, expect) in cases {
            let m = preset(id);
            let got = m.t_l3mem_per_cl();
            assert!(
                (got - expect).abs() < 0.02,
                "{}: t_l3mem {got:.3} != {expect}",
                m.shorthand
            );
        }
    }

    #[test]
    fn cache_bus_cycles_per_cl() {
        let ivb = preset(PresetId::Ivb);
        assert_eq!(ivb.t_cache_per_cl(1, true), 2.0); // 32 B/cy L2→L1
        assert_eq!(ivb.t_cache_per_cl(2, true), 2.0); // 32 B/cy L3→L2
        let hsw = preset(PresetId::Hsw);
        assert_eq!(hsw.t_cache_per_cl(1, true), 1.0); // 64 B/cy L2→L1
        // HSW single-core Uncore slowdown: 2 cy * 5.54/4
        assert!((hsw.t_cache_per_cl(2, true) - 2.77).abs() < 1e-9);
        assert_eq!(hsw.t_cache_per_cl(2, false), 2.0);
    }

    #[test]
    fn avx_load_slots_by_generation() {
        let ivb = preset(PresetId::Ivb);
        assert_eq!(ivb.core.slots(Unit::Load, 32), 2.0); // split AVX load
        assert_eq!(ivb.core.slots(Unit::Load, 16), 1.0);
        let hsw = preset(PresetId::Hsw);
        assert_eq!(hsw.core.slots(Unit::Load, 32), 1.0);
    }

    #[test]
    fn residence_levels() {
        let ivb = preset(PresetId::Ivb);
        assert_eq!(ivb.residence_level(16 * 1024), 0);
        assert_eq!(ivb.residence_level(100 * 1024), 1);
        assert_eq!(ivb.residence_level(10 * 1024 * 1024), 2);
        assert_eq!(ivb.residence_level(200 * 1024 * 1024), 3);
    }
}
