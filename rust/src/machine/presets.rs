//! The four Table-1 sockets, encoded verbatim from the paper, plus the
//! empirical latency penalties from Table 2.
//!
//! | row            | SNB        | IVB        | HSW        | BDW      |
//! |----------------|-----------|------------|------------|----------|
//! | Xeon           | E5-2680   | E5-2690 v2 | E5-2695 v3 | D-1540   |
//! | clock (fixed)  | 2.7 GHz   | 2.2 GHz    | 2.3 GHz    | 1.8 GHz  |
//! | cores          | 8         | 10         | 14         | 8        |
//! | L1 ports       | 2×16+1×16 | 2×16+1×16  | 2×32+1×32  | 2×32+1×32|
//! | L2→L1 bus      | 32 B/cy   | 32 B/cy    | 64 B/cy    | 64 B/cy  |
//! | L3→L2 bus      | 32 B/cy   | 32 B/cy    | 32 B/cy    | 32 B/cy  |
//! | LLC            | 20 MiB    | 25 MiB     | 35 MiB     | 12 MiB   |
//! | load-only BW   | 43.6 GB/s | 46.1 GB/s  | 60.6 GB/s  | 33 GB/s  |
//! | mem penalty/CL | 2.55      | 1.45       | 5.55       | 0.5      |
//!
//! (penalty/CL is half the per-work-unit penalty of Table 2, since the dot
//! work unit moves two cache lines).

use super::{CacheLevel, CoreModel, Machine, MemoryModel};

/// Identifier for the paper's four testbed sockets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PresetId {
    Snb,
    Ivb,
    Hsw,
    Bdw,
}

impl PresetId {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "snb" | "sandybridge" => Some(Self::Snb),
            "ivb" | "ivybridge" => Some(Self::Ivb),
            "hsw" | "haswell" => Some(Self::Hsw),
            "bdw" | "broadwell" => Some(Self::Bdw),
            _ => None,
        }
    }
}

const KIB: u64 = 1024;
const MIB: u64 = 1024 * 1024;

fn pre_hsw_core() -> CoreModel {
    CoreModel {
        load_ports: 2,
        load_port_bytes: 16,
        store_ports: 1,
        store_port_bytes: 16,
        add_ports: 1,
        mul_ports: 1,
        fma_ports: 0,
        add_latency: 3,
        mul_latency: 5,
        fma_latency: 5, // unused (no FMA units)
        load_latency: 4,
        simd_registers: 16,
        simd_width_bytes: 32,
    }
}

fn hsw_core() -> CoreModel {
    CoreModel {
        load_ports: 2,
        load_port_bytes: 32,
        store_ports: 1,
        store_port_bytes: 32,
        add_ports: 1, // only one of the two FMA pipes takes stand-alone ADDs
        mul_ports: 2,
        fma_ports: 2,
        add_latency: 3,
        mul_latency: 5,
        fma_latency: 5,
        load_latency: 4,
        simd_registers: 16,
        simd_width_bytes: 32,
    }
}

fn caches(l3_mib: u64, l2l1_bus: u32) -> Vec<CacheLevel> {
    vec![
        CacheLevel { name: "L1", size_bytes: 32 * KIB, bytes_per_cy_to_inner: 0, ways: 8 },
        CacheLevel { name: "L2", size_bytes: 256 * KIB, bytes_per_cy_to_inner: l2l1_bus, ways: 8 },
        CacheLevel {
            name: "L3",
            size_bytes: l3_mib * MIB,
            bytes_per_cy_to_inner: 32,
            ways: 20,
        },
    ]
}

/// SandyBridge-EP, Xeon E5-2680.
pub fn snb() -> Machine {
    Machine {
        name: "SandyBridge-EP",
        shorthand: "SNB",
        xeon_model: "E5-2680",
        year: "03/2012",
        clock_ghz: 2.7,
        cores: 8,
        threads: 16,
        core: pre_hsw_core(),
        caches: caches(20, 32),
        memory: MemoryModel {
            peak_bw_gbs: 51.2,
            load_bw_gbs: 43.6,
            latency_penalty_cy_per_cl: 2.55,
        },
        cache_line_bytes: 64,
        uncore_single_core_factor: 1.0,
        dram: "4xDDR3-1600",
    }
}

/// IvyBridge-EP, Xeon E5-2690 v2 — the paper's primary analysis machine.
pub fn ivb() -> Machine {
    Machine {
        name: "IvyBridge-EP",
        shorthand: "IVB",
        xeon_model: "E5-2690 v2",
        year: "09/2013",
        clock_ghz: 2.2,
        cores: 10,
        threads: 20,
        core: pre_hsw_core(),
        caches: caches(25, 32),
        memory: MemoryModel {
            peak_bw_gbs: 51.2,
            load_bw_gbs: 46.1,
            latency_penalty_cy_per_cl: 1.45,
        },
        cache_line_bytes: 64,
        uncore_single_core_factor: 1.0,
        dram: "4xDDR3-1866",
    }
}

/// Haswell-EP, Xeon E5-2695 v3.
pub fn hsw() -> Machine {
    Machine {
        name: "Haswell-EP",
        shorthand: "HSW",
        xeon_model: "E5-2695 v3",
        year: "09/2014",
        clock_ghz: 2.3,
        cores: 14,
        threads: 28,
        core: hsw_core(),
        caches: caches(35, 64),
        memory: MemoryModel {
            peak_bw_gbs: 68.3,
            load_bw_gbs: 60.6,
            latency_penalty_cy_per_cl: 5.55,
        },
        cache_line_bytes: 64,
        // paper: T_L2L3 is 5.54 cy instead of 4 cy when one core is active
        uncore_single_core_factor: 5.54 / 4.0,
        dram: "4xDDR4-2133",
    }
}

/// Broadwell Xeon D-1540 (pre-release silicon in the paper).
pub fn bdw() -> Machine {
    Machine {
        name: "Broadwell-D",
        shorthand: "BDW",
        xeon_model: "D-1540",
        year: "03/2015",
        clock_ghz: 1.8,
        cores: 8,
        threads: 16,
        core: hsw_core(),
        caches: caches(12, 64),
        memory: MemoryModel {
            peak_bw_gbs: 34.1,
            load_bw_gbs: 33.0,
            latency_penalty_cy_per_cl: 0.5,
        },
        cache_line_bytes: 64,
        uncore_single_core_factor: 1.0,
        dram: "4xDDR4-2133",
    }
}

pub fn preset(id: PresetId) -> Machine {
    match id {
        PresetId::Snb => snb(),
        PresetId::Ivb => ivb(),
        PresetId::Hsw => hsw(),
        PresetId::Bdw => bdw(),
    }
}

/// All four sockets in paper order.
pub fn all_presets() -> Vec<Machine> {
    vec![snb(), ivb(), hsw(), bdw()]
}

/// The Table-1 socket closest to `m`: smallest summed relative distance on
/// clock, core count, and LLC size — the three figures every machine
/// (including a partially detected host) reliably has. Used by the ECM
/// governance bridge as the fallback model when host detection produces
/// implausible numbers.
pub fn nearest_preset(m: &Machine) -> PresetId {
    let ids = [PresetId::Snb, PresetId::Ivb, PresetId::Hsw, PresetId::Bdw];
    let rel = |a: f64, b: f64| ((a - b) / b.max(1e-9)).abs();
    let mut best = PresetId::Hsw;
    let mut best_d = f64::INFINITY;
    for id in ids {
        let p = preset(id);
        let d = rel(m.clock_ghz, p.clock_ghz)
            + rel(m.cores as f64, p.cores as f64)
            + rel(m.llc_bytes() as f64, p.llc_bytes() as f64);
        if d < best_d {
            best_d = d;
            best = id;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_shorthands() {
        assert_eq!(PresetId::parse("IVB"), Some(PresetId::Ivb));
        assert_eq!(PresetId::parse("haswell"), Some(PresetId::Hsw));
        assert_eq!(PresetId::parse("k6"), None);
    }

    #[test]
    fn table1_clock_and_cores() {
        let rows = [
            (snb(), 2.7, 8),
            (ivb(), 2.2, 10),
            (hsw(), 2.3, 14),
            (bdw(), 1.8, 8),
        ];
        for (m, f, c) in rows {
            assert_eq!(m.clock_ghz, f, "{}", m.shorthand);
            assert_eq!(m.cores, c, "{}", m.shorthand);
            assert_eq!(m.threads, 2 * c, "{}", m.shorthand);
        }
    }

    #[test]
    fn table1_llc_sizes() {
        assert_eq!(snb().llc_bytes(), 20 * MIB);
        assert_eq!(ivb().llc_bytes(), 25 * MIB);
        assert_eq!(hsw().llc_bytes(), 35 * MIB);
        assert_eq!(bdw().llc_bytes(), 12 * MIB);
    }

    #[test]
    fn fma_only_on_hsw_bdw() {
        assert_eq!(snb().core.fma_ports, 0);
        assert_eq!(ivb().core.fma_ports, 0);
        assert_eq!(hsw().core.fma_ports, 2);
        assert_eq!(bdw().core.fma_ports, 2);
    }

    #[test]
    fn nearest_preset_is_identity_on_the_presets_and_total_elsewhere() {
        for (m, id) in [
            (snb(), PresetId::Snb),
            (ivb(), PresetId::Ivb),
            (hsw(), PresetId::Hsw),
            (bdw(), PresetId::Bdw),
        ] {
            assert_eq!(nearest_preset(&m), id, "{}", m.shorthand);
        }
        // a mangled host-like machine still maps to *some* preset
        let mut odd = ivb();
        odd.clock_ghz = 3.1;
        odd.cores = 9;
        let _ = nearest_preset(&odd);
    }

    #[test]
    fn roofline_light_speed_ivb() {
        // paper §3: P_BW = (1 update / 8 B) * b_S = 5.76 GUP/s on IVB (SP)
        let m = ivb();
        let p_bw = m.memory.load_bw_gbs / 8.0;
        assert!((p_bw - 5.76).abs() < 0.01, "{p_bw}");
    }
}
