//! Host CPU detection: build a best-effort `Machine` for the machine the
//! crate is running on, so the ECM model and the host microbenchmarks
//! (`crate::bench`) can be compared on real silicon.
//!
//! Sources: /proc/cpuinfo (model name, flags), sysfs cache topology, and a
//! TSC-vs-monotonic-clock calibration for the effective frequency. Missing
//! information falls back to HSW-class defaults — close enough for any
//! post-2014 Xeon, which is what cloud containers run on.

use super::{CacheLevel, CoreModel, Machine, MemoryModel};

/// SIMD capabilities detected on the host.
#[derive(Clone, Copy, Debug, Default)]
pub struct HostSimd {
    pub sse: bool,
    pub avx2: bool,
    pub fma: bool,
    pub avx512f: bool,
}

pub fn host_simd() -> HostSimd {
    HostSimd {
        sse: is_x86_feature_detected!("sse4.2"),
        avx2: is_x86_feature_detected!("avx2"),
        fma: is_x86_feature_detected!("fma"),
        avx512f: is_x86_feature_detected!("avx512f"),
    }
}

fn read_sysfs_cache(level_index: u32) -> Option<(u64, u32)> {
    let base = format!("/sys/devices/system/cpu/cpu0/cache/index{level_index}");
    let size_s = std::fs::read_to_string(format!("{base}/size")).ok()?;
    let ways_s = std::fs::read_to_string(format!("{base}/ways_of_associativity")).ok()?;
    let size_s = size_s.trim();
    let bytes = if let Some(k) = size_s.strip_suffix('K') {
        k.parse::<u64>().ok()? * 1024
    } else if let Some(m) = size_s.strip_suffix('M') {
        m.parse::<u64>().ok()? * 1024 * 1024
    } else {
        size_s.parse::<u64>().ok()?
    };
    let ways = ways_s.trim().parse::<u32>().unwrap_or(8);
    Some((bytes, ways))
}

fn cpu_model_name() -> String {
    std::fs::read_to_string("/proc/cpuinfo")
        .ok()
        .and_then(|s| {
            s.lines()
                .find(|l| l.starts_with("model name"))
                .and_then(|l| l.split(':').nth(1))
                .map(|v| v.trim().to_string())
        })
        .unwrap_or_else(|| "unknown x86_64".to_string())
}

fn online_cpus() -> u32 {
    std::thread::available_parallelism().map(|n| n.get() as u32).unwrap_or(1)
}

/// Calibrate the TSC frequency in GHz against the monotonic clock.
pub fn calibrate_tsc_ghz() -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        use std::time::Instant;
        let t0 = Instant::now();
        let c0 = unsafe { core::arch::x86_64::_rdtsc() };
        // ~20 ms busy-wait; long enough that Instant noise is irrelevant
        while t0.elapsed().as_micros() < 20_000 {
            std::hint::spin_loop();
        }
        let c1 = unsafe { core::arch::x86_64::_rdtsc() };
        let dt = t0.elapsed().as_secs_f64();
        (c1.wrapping_sub(c0)) as f64 / dt / 1e9
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        2.0
    }
}

/// Build a best-effort machine model for the host.
///
/// Port widths/counts assume HSW-or-newer (2×32 B load + 1×32 B store, two
/// FMA pipes); bandwidths default to a single-channel cloud value and should
/// be overridden by a measured STREAM figure when available (see
/// `crate::bench::sweep::measure_load_bandwidth`).
pub fn detect_host() -> Machine {
    let simd = host_simd();
    let ghz = calibrate_tsc_ghz();
    let l1 = read_sysfs_cache(0).unwrap_or((32 * 1024, 8));
    let l2 = read_sysfs_cache(2).unwrap_or((1024 * 1024, 16));
    let l3 = read_sysfs_cache(3).unwrap_or((32 * 1024 * 1024, 16));

    // leak the strings: Machine uses &'static str for names (presets are
    // static); the one host detection per process makes this harmless
    let name: &'static str = Box::leak(format!("host ({})", cpu_model_name()).into_boxed_str());
    let ghz_s: &'static str = Box::leak(format!("{ghz:.2} GHz (tsc)").into_boxed_str());

    Machine {
        name,
        shorthand: "HOST",
        xeon_model: name,
        year: ghz_s,
        clock_ghz: ghz,
        cores: online_cpus(),
        threads: online_cpus(),
        core: CoreModel {
            load_ports: 2,
            load_port_bytes: 32,
            store_ports: 1,
            store_port_bytes: 32,
            add_ports: 1,
            mul_ports: 2,
            fma_ports: if simd.fma { 2 } else { 0 },
            add_latency: 4, // Skylake+: ADD goes through the 4-cy FMA pipe
            mul_latency: 4,
            fma_latency: 4,
            load_latency: 5,
            simd_registers: if simd.avx512f { 32 } else { 16 },
            simd_width_bytes: if simd.avx512f { 64 } else { 32 },
        },
        caches: vec![
            CacheLevel { name: "L1", size_bytes: l1.0, bytes_per_cy_to_inner: 0, ways: l1.1 },
            CacheLevel {
                name: "L2",
                size_bytes: l2.0,
                bytes_per_cy_to_inner: 64,
                ways: l2.1,
            },
            CacheLevel {
                name: "L3",
                size_bytes: l3.0,
                bytes_per_cy_to_inner: 32,
                ways: l3.1,
            },
        ],
        memory: MemoryModel {
            peak_bw_gbs: 12.0,
            load_bw_gbs: 10.0,
            latency_penalty_cy_per_cl: 2.0,
        },
        cache_line_bytes: 64,
        uncore_single_core_factor: 1.0,
        dram: "unknown (virtualized)",
    }
}

/// Cached TSC frequency (the calibration busy-waits ~20 ms; the sweep and
/// engine paths need it per measurement point).
pub fn calibrate_tsc_ghz_cached() -> f64 {
    static GHZ: std::sync::OnceLock<f64> = std::sync::OnceLock::new();
    *GHZ.get_or_init(calibrate_tsc_ghz)
}

/// Cached host detection. `detect_host` busy-waits ~20 ms calibrating the
/// TSC, so anything on a request path (the engine's size classifier, the
/// autotuner) must use this instead of re-detecting.
pub fn detect_host_cached() -> &'static Machine {
    static HOST: std::sync::OnceLock<Machine> = std::sync::OnceLock::new();
    HOST.get_or_init(detect_host)
}

/// NUMA domains on this host (1 on single-socket machines and containers
/// without a sysfs node hierarchy). Delegates to the engine's cached
/// topology discovery so detection and sharding can never disagree.
pub fn numa_node_count() -> usize {
    crate::engine::topology_cached().nodes.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_host_is_sane() {
        let m = detect_host();
        assert!(m.clock_ghz > 0.5 && m.clock_ghz < 7.0, "clock {}", m.clock_ghz);
        assert!(m.cores >= 1);
        assert_eq!(m.caches.len(), 3);
        assert!(m.caches[0].size_bytes >= 16 * 1024);
        assert!(m.caches[2].size_bytes > m.caches[1].size_bytes);
    }

    #[test]
    fn tsc_calibration_stable() {
        let a = calibrate_tsc_ghz();
        let b = calibrate_tsc_ghz();
        assert!((a - b).abs() / a < 0.2, "a={a} b={b}");
    }

    #[test]
    fn cached_host_is_stable() {
        let a = detect_host_cached() as *const Machine;
        let b = detect_host_cached() as *const Machine;
        assert_eq!(a, b, "detection must run once");
    }

    #[test]
    fn numa_node_count_matches_topology() {
        let n = numa_node_count();
        assert!(n >= 1);
        assert_eq!(n, crate::engine::topology_cached().nodes.len());
    }

    #[test]
    fn host_simd_no_panic() {
        let s = host_simd();
        // container built this crate with std::arch paths; sse must exist on
        // any x86_64
        #[cfg(target_arch = "x86_64")]
        assert!(s.sse);
        let _ = s;
    }
}
