//! Ogita–Rump–Oishi "GenDot": generate dot-product inputs with a prescribed
//! condition number (Algorithm 6.1 of "Accurate Sum and Dot Product").
//! Mirrors `python/compile/kernels/ref.py::gen_dot` so the two stacks
//! evaluate on statistically identical workloads.

use super::exact::{exact_dot_f32, exact_dot_f64, two_sum};
use crate::util::Rng;

/// Running Neumaier accumulation of `p` into `(s, c)` — the construction
/// below needs an accurate running dot to steer the cancellation. Built on
/// the crate's error-free `two_sum` rather than a second hand-rolled
/// compensated primitive.
fn neumaier_acc(p: f64, s: &mut f64, c: &mut f64) {
    let (t, e) = two_sum(*s, p);
    *c += e;
    *s = t;
}

/// The two-phase construction both precisions share, carried out in f64:
/// the first half spreads exponents up to `cond^(1/2)`, the second half
/// steers the running dot towards zero through the Neumaier accumulator.
fn gen_dot_core(n: usize, target_cond: f64, rng: &mut Rng) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 6, "gen_dot needs n >= 6");
    let b = target_cond.log2();
    let half = n / 2;

    let mut x = vec![0.0f64; n];
    let mut y = vec![0.0f64; n];
    for i in 0..half {
        let e = if i == 0 {
            (b / 2.0).round()
        } else if i == half - 1 {
            0.0
        } else {
            rng.range(0.0, b / 2.0).round()
        };
        x[i] = (2.0 * rng.uniform() - 1.0) * e.exp2();
        y[i] = (2.0 * rng.uniform() - 1.0) * e.exp2();
    }

    // running Neumaier accumulator over x[i]*y[i]
    let mut s = 0.0f64;
    let mut c = 0.0f64;
    for i in 0..half {
        neumaier_acc(x[i] * y[i], &mut s, &mut c);
    }

    // second half: drive the running dot towards zero
    for i in half..n {
        let frac = (i - half) as f64 / (n - half).max(1) as f64;
        let e = (b / 2.0 * (1.0 - frac)).round();
        x[i] = (2.0 * rng.uniform() - 1.0) * e.exp2();
        if x[i] == 0.0 {
            x[i] = 1.0;
        }
        let cur = s + c;
        y[i] = ((2.0 * rng.uniform() - 1.0) * e.exp2() - cur) / x[i];
        neumaier_acc(x[i] * y[i], &mut s, &mut c);
    }
    (x, y)
}

/// Generate `(x, y, exact, achieved_cond)` in f32 with dot-product condition
/// number near `target_cond`.
pub fn gen_dot_f32(n: usize, target_cond: f64, rng: &mut Rng) -> (Vec<f32>, Vec<f32>, f64, f64) {
    let (x, y) = gen_dot_core(n, target_cond, rng);
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    let yf: Vec<f32> = y.iter().map(|&v| v as f32).collect();
    let exact = exact_dot_f32(&xf, &yf);
    let absdot: f64 = xf.iter().zip(&yf).map(|(a, b)| (*a as f64 * *b as f64).abs()).sum();
    let cond = if exact == 0.0 { f64::INFINITY } else { 2.0 * absdot / exact.abs() };
    (xf, yf, exact, cond)
}

/// Generate `(x, y, exact, achieved_cond)` in f64 with dot-product
/// condition number near `target_cond` — the double-precision sibling of
/// [`gen_dot_f32`]. Unlike the f32 version there is no final cast, so the
/// carefully-cancelled construction survives intact and reachable
/// condition numbers extend to ~1/eps ≈ 1e15.
pub fn gen_dot_f64(n: usize, target_cond: f64, rng: &mut Rng) -> (Vec<f64>, Vec<f64>, f64, f64) {
    let (x, y) = gen_dot_core(n, target_cond, rng);
    let exact = exact_dot_f64(&x, &y);
    let absdot: f64 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
    let cond = if exact == 0.0 { f64::INFINITY } else { 2.0 * absdot / exact.abs() };
    (x, y, exact, cond)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hits_target_condition_within_slack() {
        // f32 caps the reachable condition number near 1/eps ~ 1e7..1e8:
        // casting the carefully-cancelled f64 construction to f32 perturbs
        // each element by eps*|x|, re-randomizing any cancellation beyond
        // 24 bits. So targets stay below that ceiling here.
        let mut rng = Rng::new(21);
        for target in [1e4, 1e6, 1e8] {
            let (_, _, exact, cond) = gen_dot_f32(512, target, &mut rng);
            assert!(exact.is_finite());
            assert!(
                cond >= target / 1e2 && cond <= target * 1e4,
                "target {target:e}, got {cond:e}"
            );
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let (x1, y1, _, _) = gen_dot_f32(64, 1e6, &mut Rng::new(3));
        let (x2, y2, _, _) = gen_dot_f32(64, 1e6, &mut Rng::new(3));
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn f64_hits_target_condition_within_slack() {
        let mut rng = Rng::new(23);
        for target in [1e6, 1e10, 1e14] {
            let (_, _, exact, cond) = gen_dot_f64(512, target, &mut rng);
            assert!(exact.is_finite());
            assert!(
                cond >= target / 1e2 && cond <= target * 1e4,
                "target {target:e}, got {cond:e}"
            );
        }
    }

    #[test]
    fn f64_deterministic_per_seed() {
        let (x1, y1, _, _) = gen_dot_f64(64, 1e10, &mut Rng::new(7));
        let (x2, y2, _, _) = gen_dot_f64(64, 1e10, &mut Rng::new(7));
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn higher_target_gives_higher_cond() {
        let mut rng = Rng::new(4);
        let (_, _, _, lo) = gen_dot_f32(256, 1e3, &mut rng);
        let (_, _, _, hi) = gen_dot_f32(256, 1e14, &mut rng);
        assert!(hi > lo * 1e3, "lo={lo:e} hi={hi:e}");
    }
}
