//! Numerical-accuracy substrate: error-free transformations, exact dot
//! products, compensated algorithm zoo and the Ogita–Rump–Oishi
//! ill-conditioned input generator.
//!
//! This is the motivation side of the paper (§1: "balancing performance vs.
//! accuracy"): it quantifies what the Kahan kernels buy, with a ground
//! truth that is provably exact (expansion arithmetic, Shewchuk-style).

pub mod algorithms;
pub mod analysis;
pub mod exact;
pub mod gendot;

pub use analysis::{error_sweep, AlgoError};
pub use exact::{exact_dot_f32, exact_dot_f64, two_prod, two_sum};
pub use gendot::{gen_dot_f32, gen_dot_f64};
