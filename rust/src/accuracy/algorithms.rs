//! The summation/dot algorithm zoo evaluated in the accuracy study: the
//! paper's naive and Kahan variants plus the classic alternatives its
//! related-work section cites (pairwise [3], Neumaier [2], Dot2 [5]).

use super::exact::{two_prod, two_sum};

/// Strictly sequential naive dot (Fig. 1a) in f32.
pub fn naive_f32(a: &[f32], b: &[f32]) -> f32 {
    crate::bench::kernels::scalar::naive_f32(a, b)
}

/// Strictly sequential Kahan dot (Fig. 1b) in f32.
pub fn kahan_f32(a: &[f32], b: &[f32]) -> f32 {
    crate::bench::kernels::scalar::kahan_seq_f32(a, b)
}

/// Lane-parallel Kahan (the paper's SIMD scheme; AVX2 on this host).
pub fn kahan_simd_f32(a: &[f32], b: &[f32]) -> f32 {
    crate::bench::kernels::avx2::kahan_f32(a, b)
}

/// Neumaier (improved Kahan): order-aware compensation; never worse than
/// Kahan, same cost class.
pub fn neumaier_f32(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    for i in 0..n {
        let p = a[i] * b[i];
        let t = s + p;
        if s.abs() >= p.abs() {
            c += (s - t) + p;
        } else {
            c += (p - t) + s;
        }
        s = t;
    }
    s + c
}

/// Pairwise (recursive halving) dot: O(eps * log n) error growth.
pub fn pairwise_f32(a: &[f32], b: &[f32]) -> f32 {
    fn rec(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        if n <= 8 {
            let mut s = 0.0f32;
            for i in 0..n {
                s += a[i] * b[i];
            }
            return s;
        }
        let mid = n / 2;
        rec(&a[..mid], &b[..mid]) + rec(&a[mid..], &b[mid..])
    }
    let n = a.len().min(b.len());
    rec(&a[..n], &b[..n])
}

/// Ogita–Rump–Oishi Dot2: TwoProduct + compensated accumulation of *both*
/// product and summation errors — as accurate as computing in doubled
/// precision, i.e. the only algorithm here whose error does NOT grow with
/// the condition number (until eps^2 * cond ~ 1).
pub fn dot2_f32(a: &[f32], b: &[f32]) -> f32 {
    // run the EFTs in f64? No — the point is a pure-f32 algorithm; Rust has
    // f32::mul_add, and two_sum is type-generic in structure.
    let n = a.len().min(b.len());
    let mut s = 0.0f32;
    let mut comp = 0.0f32;
    for i in 0..n {
        let (p, ep) = {
            let p = a[i] * b[i];
            let e = f32::mul_add(a[i], b[i], -p);
            (p, e)
        };
        let (t, es) = {
            let t = s + p;
            let bb = t - s;
            (t, (s - (t - bb)) + (p - bb))
        };
        s = t;
        comp += ep + es;
    }
    s + comp
}

/// f64 versions used for the DP accuracy columns.
pub fn naive_f64(a: &[f64], b: &[f64]) -> f64 {
    crate::bench::kernels::scalar::naive_f64(a, b)
}

pub fn kahan_f64(a: &[f64], b: &[f64]) -> f64 {
    crate::bench::kernels::scalar::kahan_seq_f64(a, b)
}

pub fn dot2_f64(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let mut s = 0.0f64;
    let mut comp = 0.0f64;
    for i in 0..n {
        let (p, ep) = two_prod(a[i], b[i]);
        let (t, es) = two_sum(s, p);
        s = t;
        comp += ep + es;
    }
    s + comp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    fn rel_err(x: f64, exact: f64) -> f64 {
        if exact == 0.0 {
            x.abs()
        } else {
            (x - exact).abs() / exact.abs()
        }
    }

    #[test]
    fn all_algorithms_exact_on_integers() {
        let a: Vec<f32> = (1..=64).map(|i| i as f32).collect();
        let b: Vec<f32> = (1..=64).map(|i| (65 - i) as f32).collect();
        let want = exact_dot_f32(&a, &b) as f32;
        for f in [naive_f32, kahan_f32, kahan_simd_f32, neumaier_f32, pairwise_f32, dot2_f32] {
            assert_eq!(f(&a, &b), want);
        }
    }

    /// Dot2's signature property: full accuracy even at extreme condition
    /// numbers where Kahan (no TwoProduct) degrades.
    #[test]
    fn dot2_survives_high_condition() {
        let mut rng = Rng::new(11);
        let (a, b, exact, cond) = crate::accuracy::gendot::gen_dot_f32(2000, 1e6, &mut rng);
        assert!(cond > 1e4, "generator failed: cond={cond:.3e}");
        let e_dot2 = rel_err(dot2_f32(&a, &b) as f64, exact);
        let e_kahan = rel_err(kahan_f32(&a, &b) as f64, exact);
        let e_naive = rel_err(naive_f32(&a, &b) as f64, exact);
        assert!(e_dot2 < 1e-5, "dot2 err {e_dot2:.3e}");
        assert!(e_dot2 <= e_kahan, "dot2 {e_dot2:.3e} vs kahan {e_kahan:.3e}");
        assert!(e_kahan <= e_naive * 4.0 + 1e-7);
    }

    #[test]
    fn neumaier_never_worse_than_naive() {
        crate::util::prop::check("neumaier_vs_naive", 30, |rng| {
            let n = 10 + rng.below(3000) as usize;
            let a: Vec<f32> =
                (0..n).map(|_| (rng.standard_normal() * (rng.range(0.0, 12.0)).exp2()) as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.standard_normal() as f32).collect();
            let exact = exact_dot_f32(&a, &b);
            let en = rel_err(naive_f32(&a, &b) as f64, exact);
            let ek = rel_err(neumaier_f32(&a, &b) as f64, exact);
            crate::prop_assert!(ek <= en * 1.001 + 1e-9, "neumaier {ek:e} vs naive {en:e}");
            Ok(())
        });
    }

    #[test]
    fn pairwise_beats_sequential_on_long_sums() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let a: Vec<f32> = (0..n).map(|_| rng.standard_normal().abs() as f32).collect();
        let b = vec![1.0f32; n];
        let exact = exact_dot_f32(&a, &b);
        let ep = rel_err(pairwise_f32(&a, &b) as f64, exact);
        let en = rel_err(naive_f32(&a, &b) as f64, exact);
        assert!(ep < en, "pairwise {ep:e} vs naive {en:e}");
    }
}
