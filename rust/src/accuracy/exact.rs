//! Error-free transformations and exact dot products.
//!
//! * [`two_sum`] — Knuth's branch-free EFT: a + b = s + e exactly.
//! * [`two_prod`] — FMA-based EFT: a * b = p + e exactly.
//! * [`exact_dot_f64`] — Shewchuk-style floating-point expansions: the dot
//!   product is accumulated as a sum of non-overlapping components with NO
//!   information loss, then rounded once at the end.
//! * [`exact_dot_f32`] — f32 products are exact in f64; a Neumaier f64
//!   accumulation leaves error ~2^-50 relative, i.e. ~2^26 times below the
//!   last bit of any f32 being evaluated — exact for all comparisons here.

/// Knuth TwoSum: returns (s, e) with s = fl(a+b) and a + b = s + e exactly.
#[inline]
pub fn two_sum(a: f64, b: f64) -> (f64, f64) {
    let s = a + b;
    let bb = s - a;
    let e = (a - (s - bb)) + (b - bb);
    (s, e)
}

/// Fast TwoSum (requires |a| >= |b|).
#[inline]
pub fn fast_two_sum(a: f64, b: f64) -> (f64, f64) {
    debug_assert!(a == 0.0 || a.abs() >= b.abs() || a.is_nan());
    let s = a + b;
    let e = b - (s - a);
    (s, e)
}

/// FMA TwoProduct: returns (p, e) with p = fl(a*b) and a*b = p + e exactly.
#[inline]
pub fn two_prod(a: f64, b: f64) -> (f64, f64) {
    let p = a * b;
    let e = f64::mul_add(a, b, -p);
    (p, e)
}

/// Grow a non-overlapping expansion by one value (Shewchuk GROW-EXPANSION).
fn grow_expansion(exp: &mut Vec<f64>, v: f64) {
    let mut q = v;
    let mut out = Vec::with_capacity(exp.len() + 1);
    for &h in exp.iter() {
        let (s, e) = two_sum(q, h);
        if e != 0.0 {
            out.push(e);
        }
        q = s;
    }
    out.push(q);
    *exp = out;
}

/// Exact f64 dot product: expansion accumulation of TwoProduct pairs,
/// rounded once. Exactness holds for any input free of overflow.
pub fn exact_dot_f64(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let mut exp: Vec<f64> = Vec::new();
    for i in 0..n {
        let (p, e) = two_prod(a[i], b[i]);
        if e != 0.0 {
            grow_expansion(&mut exp, e);
        }
        if p != 0.0 {
            grow_expansion(&mut exp, p);
        }
        // keep the expansion from growing unboundedly: it stays
        // non-overlapping, so its length is bounded by the exponent range /
        // 53 anyway (~40 components); nothing to do.
    }
    // components are non-overlapping; summing smallest-first loses nothing
    // beyond the final rounding
    exp.iter().sum()
}

/// Exact-for-f32 dot product (see module docs).
pub fn exact_dot_f32(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len().min(b.len());
    let mut s = 0.0f64;
    let mut c = 0.0f64;
    for i in 0..n {
        let p = a[i] as f64 * b[i] as f64; // exact: 24+24 bits < 53
        let t = s + p;
        if s.abs() >= p.abs() {
            c += (s - t) + p;
        } else {
            c += (p - t) + s;
        }
        s = t;
    }
    s + c
}

/// Condition number of a dot product: 2 |a|·|b| / |a·b|.
pub fn dot_condition_f32(a: &[f32], b: &[f32]) -> f64 {
    let abs: f64 = a
        .iter()
        .zip(b)
        .map(|(x, y)| (*x as f64 * *y as f64).abs())
        .sum();
    let exact = exact_dot_f32(a, b);
    if exact == 0.0 {
        f64::INFINITY
    } else {
        2.0 * abs / exact.abs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn two_sum_is_exact() {
        crate::util::prop::check("two_sum_exact", 200, |r| {
            let a = r.standard_normal() * 10f64.powi((r.below(60) as i32) - 30);
            let b = r.standard_normal() * 10f64.powi((r.below(60) as i32) - 30);
            let (s, e) = two_sum(a, b);
            // verify with 128-ish bit arithmetic via two_sum identity:
            // s + e must equal a + b exactly as an unevaluated pair
            let (s2, e2) = two_sum(s, e);
            crate::prop_assert!(s2 == s && e2 == e, "non-canonical: {a} {b}");
            // and the pair reproduces both inputs: (s + e) - b == a when
            // computed in expansion space
            let mut exp = vec![];
            grow_expansion(&mut exp, s);
            grow_expansion(&mut exp, e);
            grow_expansion(&mut exp, -a);
            grow_expansion(&mut exp, -b);
            crate::prop_assert!(exp.iter().sum::<f64>() == 0.0, "a={a} b={b}");
            Ok(())
        });
    }

    #[test]
    fn two_prod_is_exact() {
        crate::util::prop::check("two_prod_exact", 200, |r| {
            let a = r.standard_normal();
            let b = r.standard_normal();
            let (p, e) = two_prod(a, b);
            // compare against 113-bit arithmetic emulated via splitting
            let hi = a * b;
            crate::prop_assert!(p == hi, "p mismatch");
            // |e| must be below half an ulp of p
            crate::prop_assert!(e.abs() <= p.abs() * f64::EPSILON, "e too big: {e}");
            Ok(())
        });
    }

    #[test]
    fn exact_dot_f64_cancellation() {
        // catastrophic cancellation that any floating accumulation botches:
        // [1e200, 1, -1e200] . [1e-200 scaled...] -> designed residual
        let a = [1e16, 1.0, -1e16];
        let b = [1.0, 0.5, 1.0];
        assert_eq!(exact_dot_f64(&a, &b), 0.5);
    }

    #[test]
    fn exact_dot_f64_matches_integer_arithmetic() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            // small integers: dot is exactly representable, any correct
            // algorithm must nail it
            let n = 1 + r.below(100) as usize;
            let a: Vec<f64> = (0..n).map(|_| (r.below(2001) as i64 - 1000) as f64).collect();
            let b: Vec<f64> = (0..n).map(|_| (r.below(2001) as i64 - 1000) as f64).collect();
            let want: i64 = a.iter().zip(&b).map(|(x, y)| (*x as i64) * (*y as i64)).sum();
            assert_eq!(exact_dot_f64(&a, &b), want as f64);
        }
    }

    #[test]
    fn exact_dot_f32_vs_f64_path() {
        let mut r = Rng::new(6);
        let a: Vec<f32> = (0..1000).map(|_| r.standard_normal() as f32).collect();
        let b: Vec<f32> = (0..1000).map(|_| r.standard_normal() as f32).collect();
        let via32 = exact_dot_f32(&a, &b);
        let a64: Vec<f64> = a.iter().map(|&x| x as f64).collect();
        let b64: Vec<f64> = b.iter().map(|&x| x as f64).collect();
        let via64 = exact_dot_f64(&a64, &b64);
        assert!((via32 - via64).abs() <= 1e-12 * via64.abs().max(1.0));
    }

    #[test]
    fn condition_number_of_orthogonal_vectors_is_large() {
        let a = [1.0f32, 1.0];
        let b = [1.0f32, -1.0 + 1e-6];
        assert!(dot_condition_f32(&a, &b) > 1e5);
        let c = [1.0f32, 1.0];
        assert!(dot_condition_f32(&c, &c) == 2.0);
    }
}
