//! Accuracy sweep: relative error of each algorithm vs. condition number —
//! the classic Ogita–Rump–Oishi Fig. 6.4-style study, run over the paper's
//! kernel variants (the "why bother with Kahan" evidence).

use super::algorithms as alg;
use super::exact::exact_dot_f32;
use super::gendot::gen_dot_f32;
use crate::util::Rng;

/// Relative error of one algorithm at one condition point (median over
/// trials).
#[derive(Clone, Debug)]
pub struct AlgoError {
    pub algo: &'static str,
    pub target_cond: f64,
    pub median_cond: f64,
    pub median_rel_err: f64,
}

fn rel_err(x: f64, exact: f64) -> f64 {
    if exact == 0.0 {
        x.abs()
    } else {
        ((x - exact).abs() / exact.abs()).min(2.0) // cap: "no digits left"
    }
}

/// The algorithms reported by the accuracy experiment.
pub fn algorithm_list() -> Vec<(&'static str, fn(&[f32], &[f32]) -> f32)> {
    vec![
        ("naive-seq", alg::naive_f32),
        ("kahan-seq", alg::kahan_f32),
        ("kahan-simd", alg::kahan_simd_f32),
        ("neumaier", alg::neumaier_f32),
        ("pairwise", alg::pairwise_f32),
        ("dot2", alg::dot2_f32),
    ]
}

/// Sweep condition numbers; returns one row per (algorithm, cond target).
pub fn error_sweep(n: usize, cond_targets: &[f64], trials: usize, seed: u64) -> Vec<AlgoError> {
    let mut out = Vec::new();
    for &target in cond_targets {
        // collect per-trial errors for each algorithm
        let algos = algorithm_list();
        let mut errs: Vec<Vec<f64>> = vec![Vec::new(); algos.len()];
        let mut conds = Vec::new();
        for t in 0..trials {
            let mut rng = Rng::new(seed ^ (target.to_bits()).wrapping_add(t as u64));
            let (x, y, exact, cond) = gen_dot_f32(n, target, &mut rng);
            conds.push(cond);
            for (i, (_, f)) in algos.iter().enumerate() {
                errs[i].push(rel_err(f(&x, &y) as f64, exact));
            }
        }
        for (i, (name, _)) in algos.iter().enumerate() {
            out.push(AlgoError {
                algo: name,
                target_cond: target,
                median_cond: crate::util::stats::median(&conds),
                median_rel_err: crate::util::stats::median(&errs[i]),
            });
        }
    }
    out
}

/// Verify the exactness claim of the ground truth itself: compare
/// `exact_dot_f32` against integer arithmetic on integer-valued data.
pub fn self_check() -> bool {
    let mut rng = Rng::new(99);
    for _ in 0..32 {
        let n = 8 + rng.below(64) as usize;
        let a: Vec<f32> = (0..n).map(|_| (rng.below(201) as i64 - 100) as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| (rng.below(201) as i64 - 100) as f32).collect();
        let want: i64 = a.iter().zip(&b).map(|(x, y)| (*x as i64) * (*y as i64)).sum();
        if exact_dot_f32(&a, &b) != want as f64 {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn self_check_passes() {
        assert!(self_check());
    }

    /// The shape of the classic accuracy plot: naive degrades ~linearly in
    /// cond, compensated methods stay flat until eps*cond ~ 1 (Kahan) or
    /// eps^2*cond ~ 1 (dot2).
    #[test]
    fn error_growth_shapes() {
        let rows = error_sweep(1024, &[1e2, 1e10], 5, 7);
        let get = |algo: &str, cond: f64| {
            rows.iter()
                .find(|r| r.algo == algo && r.target_cond == cond)
                .unwrap()
                .median_rel_err
        };
        // benign data: everyone fine
        assert!(get("naive-seq", 1e2) < 1e-4);
        assert!(get("dot2", 1e2) < 1e-6);
        // brutal data: naive has no digits, dot2 still near-exact
        assert!(get("naive-seq", 1e10) > 1e-2);
        assert!(get("dot2", 1e10) < 1e-4);
        // kahan never worse than naive
        assert!(get("kahan-seq", 1e10) <= get("naive-seq", 1e10) * 1.5);
    }

    #[test]
    fn sweep_row_count() {
        let rows = error_sweep(256, &[1e3, 1e6, 1e9], 3, 1);
        assert_eq!(rows.len(), 3 * algorithm_list().len());
    }
}
