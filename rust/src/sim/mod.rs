//! The virtual testbed: a trace-driven simulator standing in for the
//! paper's four Xeon sockets (DESIGN.md §1, substitution table).
//!
//! Components:
//! * [`core`] — port scoreboard executing the kernel's virtual instruction
//!   stream with pipeline latencies and loop-carried dependencies;
//! * [`cache`] — set-associative, inclusive, LRU cache hierarchy simulated
//!   at cache-line granularity;
//! * [`params`] — per-socket behavioural constants that Table 1 does not
//!   carry (miss-handling overheads of the L2/Uncore datapaths);
//! * [`engine`] — single-core working-set sweep: composes core time and
//!   transfer time per the ECM overlap rules but with *simulated* residence
//!   and miss overheads, producing "measured-like" cycles per cache line;
//! * [`multicore`] — n cores sharing the memory interface (capacity
//!   queueing), producing the saturation curves of Figs. 3 and 4b.
//!
//! The simulator never reads ECM *predictions*; it shares only the machine
//! description and the kernel instruction streams, so model-vs-simulation
//! comparisons are meaningful (they disagree exactly where the paper's
//! model-vs-measurement plots disagree).

pub mod cache;
pub mod core;
pub mod engine;
pub mod multicore;
pub mod params;

pub use engine::{simulate_sweep, simulate_working_set, SweepPoint};
pub use multicore::simulate_scaling;
