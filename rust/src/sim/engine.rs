//! Single-core sweep engine: the virtual testbed's replacement for running
//! likwid-bench on real silicon.
//!
//! For a given kernel and per-stream working-set size it:
//! 1. runs the port scoreboard to get the steady-state in-core time,
//! 2. streams both arrays through the LRU cache hierarchy to find where
//!    each cache line is actually served from (no residence heuristics),
//! 3. composes core and transfer time per the ECM overlap rule, adding the
//!    level-specific miss-handling overheads (`params`) where the core has
//!    no slack to hide them, and
//! 4. applies a small deterministic jitter so curves look like measurements
//!    and downstream consumers cannot fit to exact model output.
//!
//! Output is in the paper's Fig. 2 unit: **cycles per cache line**.

use super::cache::CacheSim;
use super::core::steady_state_cycles_per_unit;
use super::params::SimParams;
use crate::isa::{KernelDesc, Op};
use crate::machine::Machine;

/// One point of a working-set sweep.
#[derive(Clone, Copy, Debug)]
pub struct SweepPoint {
    /// total working set (all streams), bytes
    pub ws_bytes: u64,
    /// simulated "measured" cycles per cache line
    pub cy_per_cl: f64,
    /// equivalent performance in GUP/s
    pub gups: f64,
    /// fraction of lines served per level [L1, L2, L3, Mem]
    pub service_mix: [f64; 4],
}

/// Load-port cycles per unit of work (T_nOL), computed directly from the
/// instruction stream.
fn load_port_cycles_per_unit(machine: &Machine, kernel: &KernelDesc) -> f64 {
    let c = &machine.core;
    let slots: f64 = kernel
        .insts
        .iter()
        .filter(|i| i.op == Op::Load)
        .map(|i| c.slots(crate::machine::Unit::Load, i.width_bytes))
        .sum();
    slots / kernel.units_per_stream_pass as f64 / c.load_ports as f64
}

/// Deterministic per-point jitter in [-1, 1] derived from the inputs.
fn jitter_unit(ws: u64, salt: u64) -> f64 {
    let mut h = ws ^ salt.wrapping_mul(0x9E3779B97F4A7C15);
    h ^= h >> 33;
    h = h.wrapping_mul(0xFF51AFD7ED558CCD);
    h ^= h >> 33;
    (h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0
}

/// Service mix for a steady-state cyclic traversal of the kernel's streams,
/// from the real LRU hierarchy. `elems` is the per-stream element count.
fn service_mix(machine: &Machine, kernel: &KernelDesc, elems: u64) -> [f64; 4] {
    let line = machine.cache_line_bytes as u64;
    let stream_bytes = elems * kernel.elem_bytes as u64;
    let total = stream_bytes * kernel.n_streams as u64;

    // beyond the LLC, cyclic LRU provably serves everything from memory
    // (zero reuse distance fits); keep simulating only inside a 25% margin
    // where set-imbalance effects could still matter
    if total > machine.llc_bytes() + machine.llc_bytes() / 4 {
        return [0.0, 0.0, 0.0, 1.0];
    }

    let mut sim = CacheSim::new(machine);
    // streams placed 1 GiB apart like likwid-bench's separate arrays
    let bases: Vec<u64> = (0..kernel.n_streams as u64).map(|s| s << 32).collect();
    let cls_per_stream = (stream_bytes + line - 1) / line;
    // warm-up traversal + measured traversal, interleaved like the kernel
    for pass in 0..2 {
        if pass == 1 {
            sim.reset_counters();
        }
        for cl in 0..cls_per_stream {
            for b in &bases {
                sim.access(b + cl * line);
            }
        }
    }
    let tot = sim.accesses as f64;
    [
        sim.served[0] as f64 / tot,
        sim.served[1] as f64 / tot,
        sim.served[2] as f64 / tot,
        sim.served[3] as f64 / tot,
    ]
}

/// Simulate one working-set size. `elems` is per-stream element count;
/// `single_core` selects the Uncore clock behaviour.
pub fn simulate_working_set(
    machine: &Machine,
    kernel: &KernelDesc,
    elems: u64,
    single_core: bool,
) -> SweepPoint {
    let t_core = steady_state_cycles_per_unit(&machine.core, kernel);
    simulate_working_set_with_core(machine, kernel, elems, single_core, t_core)
}

/// Ablation entry point: simulate with the miss-handling overheads zeroed
/// (and no jitter). The result collapses onto the analytic ECM model,
/// demonstrating the overheads are the *only* non-Table-1 behaviour in the
/// simulator (see `coordinator::ablation`).
pub fn simulate_working_set_no_overhead(
    machine: &Machine,
    kernel: &KernelDesc,
    elems: u64,
    single_core: bool,
) -> SweepPoint {
    let t_core = steady_state_cycles_per_unit(&machine.core, kernel);
    let params =
        SimParams { l2_miss_overhead_cy: 0.0, l3_miss_overhead_cy: 0.0, jitter_rel: 0.0 };
    simulate_with(machine, kernel, elems, single_core, t_core, params)
}

/// Same as [`simulate_working_set`] with a precomputed in-core time —
/// sweeps reuse one scoreboard run across all sizes (§Perf change 4).
pub fn simulate_working_set_with_core(
    machine: &Machine,
    kernel: &KernelDesc,
    elems: u64,
    single_core: bool,
    t_core: f64,
) -> SweepPoint {
    let params = SimParams::for_machine(machine.shorthand);
    simulate_with(machine, kernel, elems, single_core, t_core, params)
}

fn simulate_with(
    machine: &Machine,
    kernel: &KernelDesc,
    elems: u64,
    single_core: bool,
    t_core: f64,
    params: SimParams,
) -> SweepPoint {
    let t_nol = load_port_cycles_per_unit(machine, kernel);
    let mix = service_mix(machine, kernel, elems);

    // per-CL transfer cost and overhead by serving level
    let mut transfer_per_cl = [0.0f64; 4];
    let mut overhead_per_cl = [0.0f64; 4];
    for (level, (t, oh)) in transfer_per_cl.iter_mut().zip(overhead_per_cl.iter_mut()).enumerate()
    {
        for j in 1..=level.min(machine.caches.len() - 1) {
            *t += machine.t_cache_per_cl(j, single_core);
        }
        if level == machine.caches.len() {
            // unreachable with 3 cache levels + the [f64;4] layout below
        }
        *oh = match level {
            1 => params.l2_miss_overhead_cy,
            2 => params.l3_miss_overhead_cy,
            _ => 0.0,
        };
    }
    // memory level (index 3): all cache buses + DRAM time + latency penalty
    transfer_per_cl[3] = machine.t_cache_per_cl(1, single_core)
        + machine.t_cache_per_cl(2, single_core)
        + machine.t_l3mem_per_cl()
        + machine.memory.latency_penalty_cy_per_cl;

    // reads + write-backs cross every boundary for written streams
    let cls = kernel.cl_transfers_per_unit() as f64;
    let transfer_unit: f64 =
        cls * mix.iter().zip(transfer_per_cl).map(|(f, t)| f * t).sum::<f64>();
    let oh_unit: f64 = cls * mix.iter().zip(overhead_per_cl).map(|(f, t)| f * t).sum::<f64>();

    // ECM overlap rule, then account for miss-handling overhead the core
    // cannot hide: slack is the FP-work surplus over the serialized
    // load+transfer path
    let serialized = t_nol + transfer_unit;
    let base = t_core.max(serialized);
    let slack = base - serialized;
    let mut t_unit = base + (oh_unit - slack).max(0.0);

    // deterministic "measurement" jitter
    t_unit *= 1.0 + params.jitter_rel * jitter_unit(elems, kernel.insts.len() as u64);

    let ws_bytes = elems * kernel.bytes_per_iter(); // total across streams
    let cy_per_cl = t_unit / cls;
    let gups = kernel.iters_per_unit as f64 * machine.clock_ghz / t_unit;
    SweepPoint { ws_bytes, cy_per_cl, gups, service_mix: mix }
}

/// Default Fig. 2 x-axis: log-spaced total working sets from 8 KiB to 1 GiB.
pub fn default_sweep_sizes() -> Vec<u64> {
    let mut sizes = Vec::new();
    let mut ws = 8 * 1024u64;
    while ws <= 1 << 30 {
        sizes.push(ws);
        // 4 points per octave
        let next = ws as f64 * 2f64.powf(0.25);
        ws = next.round() as u64;
    }
    sizes
}

/// Sweep the working set; `sizes` are **total** bytes across streams.
pub fn simulate_sweep(
    machine: &Machine,
    kernel: &KernelDesc,
    sizes: &[u64],
    single_core: bool,
) -> Vec<SweepPoint> {
    let t_core = steady_state_cycles_per_unit(&machine.core, kernel);
    sizes
        .iter()
        .map(|&total| {
            let elems = total / kernel.bytes_per_iter().max(1);
            simulate_working_set_with_core(machine, kernel, elems.max(64), single_core, t_core)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecm;
    use crate::isa::{generate, Precision, Simd, Variant};
    use crate::machine::presets::ivb;

    const KIB: u64 = 1024;
    const MIB: u64 = 1024 * 1024;

    fn point(kernel: &KernelDesc, total_ws: u64) -> SweepPoint {
        let m = ivb();
        let elems = total_ws / kernel.bytes_per_iter();
        simulate_working_set(&m, kernel, elems, true)
    }

    /// Fig. 2 anchor values on IVB (SP), in cycles/CL (= cy per unit / 2):
    /// scalar flat ~32 everywhere; SSE ~8 in L1..L3; AVX ~4 in L1/L2.
    #[test]
    fn fig2_anchors() {
        let scalar = generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0);
        let sse = generate(Variant::Kahan, Simd::Sse, Precision::Sp, 0);
        let avx = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);

        for ws in [16 * KIB, 128 * KIB, 4 * MIB, 256 * MIB] {
            let p = point(&scalar, ws);
            assert!((p.cy_per_cl - 32.0).abs() < 2.0, "scalar at {ws}: {}", p.cy_per_cl);
        }
        // SSE: flat 8 cy/CL up to L3
        for ws in [16 * KIB, 128 * KIB, 4 * MIB] {
            let p = point(&sse, ws);
            assert!((p.cy_per_cl - 8.0).abs() < 0.8, "sse at {ws}: {}", p.cy_per_cl);
        }
        // AVX: 4 cy/CL in L1; slightly above in L2 (the paper's "falls
        // slightly short of the prediction in L2")
        let p = point(&avx, 16 * KIB);
        assert!((p.cy_per_cl - 4.0).abs() < 0.4, "avx L1: {}", p.cy_per_cl);
        let p = point(&avx, 128 * KIB);
        assert!(
            p.cy_per_cl > 4.05 && p.cy_per_cl < 5.5,
            "avx L2 should exceed the 4 cy/CL prediction slightly: {}",
            p.cy_per_cl
        );
        // memory: ~10.5 cy/CL (21 cy per unit)
        let p = point(&avx, 256 * MIB);
        assert!((p.cy_per_cl - 10.5).abs() < 1.0, "avx mem: {}", p.cy_per_cl);
    }

    /// Naive AVX and Kahan AVX must coincide from L2 outward (the headline).
    #[test]
    fn naive_equals_kahan_beyond_l2() {
        let naive = generate(Variant::Naive, Simd::Avx, Precision::Sp, 0);
        let kahan = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        for ws in [128 * KIB, 4 * MIB, 256 * MIB] {
            let pn = point(&naive, ws);
            let pk = point(&kahan, ws);
            let ratio = pk.cy_per_cl / pn.cy_per_cl;
            assert!(
                (0.93..=1.07).contains(&ratio),
                "ws {ws}: kahan/naive = {ratio:.3}"
            );
        }
        // ...but in L1 Kahan pays 2x (8 vs 4 cy/unit)
        let pn = point(&naive, 16 * KIB);
        let pk = point(&kahan, 16 * KIB);
        let ratio = pk.cy_per_cl / pn.cy_per_cl;
        assert!((1.7..=2.3).contains(&ratio), "L1 kahan/naive = {ratio:.3}");
    }

    /// The simulated curve must track the ECM prediction within 25% at every
    /// residence level (the paper's model-quality claim), while NOT being
    /// identical to it (it is a measurement stand-in, not the model).
    #[test]
    fn tracks_ecm_within_tolerance() {
        let m = ivb();
        for variant in [Variant::Naive, Variant::Kahan] {
            for simd in [Simd::Scalar, Simd::Sse, Simd::Avx] {
                let k = generate(variant, simd, Precision::Sp, 0);
                let e = ecm::build(&m, &k, true);
                for (level, ws) in [16 * KIB, 128 * KIB, 4 * MIB, 256 * MIB].iter().enumerate() {
                    let p = point(&k, *ws);
                    let pred = e.prediction(level) / 2.0; // per CL
                    let rel = (p.cy_per_cl - pred).abs() / pred;
                    assert!(
                        rel < 0.25,
                        "{variant:?}/{simd:?} level {level}: sim {:.2} vs ecm {pred:.2}",
                        p.cy_per_cl
                    );
                }
            }
        }
    }

    #[test]
    fn service_mix_transitions() {
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        let p = point(&k, 16 * KIB);
        assert!(p.service_mix[0] > 0.95, "L1 resident: {:?}", p.service_mix);
        let p = point(&k, 4 * MIB);
        assert!(p.service_mix[2] > 0.9, "L3 resident: {:?}", p.service_mix);
        let p = point(&k, 512 * MIB);
        assert!(p.service_mix[3] > 0.99, "mem resident: {:?}", p.service_mix);
    }

    #[test]
    fn sweep_is_monotone_ish_and_deterministic() {
        let m = ivb();
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        let sizes: Vec<u64> = vec![16 * KIB, 64 * KIB, 512 * KIB, 4 * MIB, 64 * MIB];
        let a = simulate_sweep(&m, &k, &sizes, true);
        let b = simulate_sweep(&m, &k, &sizes, true);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.cy_per_cl, y.cy_per_cl, "determinism");
        }
        assert!(a.last().unwrap().cy_per_cl > a[0].cy_per_cl * 1.5);
    }

    #[test]
    fn default_sizes_cover_hierarchy() {
        let s = default_sweep_sizes();
        assert!(s.len() > 40);
        assert!(*s.first().unwrap() <= 16 * KIB);
        assert!(*s.last().unwrap() >= 512 * MIB);
    }
}
