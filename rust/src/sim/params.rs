//! Behavioural simulator constants Table 1 does not carry: the per-line
//! overhead of the miss-handling datapaths.
//!
//! The paper *observes* (Fig. 2, Fig. 4a) that measurements fall slightly
//! short of the ECM prediction whenever data crosses the L2 or the Uncore
//! (L3) boundary, attributes it to prefetcher timing ("the L2-L1 hardware
//! prefetcher doing a better job for SSE than for AVX due to more relaxed
//! timings") and Uncore design inefficiencies, and notes BDW's Uncore is
//! markedly better. These constants encode exactly that: a fixed number of
//! extra cycles per cache line *served by* the given level that cannot be
//! hidden behind FP work when there is no core-time slack. They are
//! per-microarchitecture hardware properties (fixed once, not fitted per
//! kernel — every kernel/precision/SIMD variant shares them).

/// Extra, non-overlappable cycles per cache line by serving level.
#[derive(Clone, Copy, Debug)]
pub struct SimParams {
    /// per CL served by L2 (L1-miss handling / prefetch imperfection)
    pub l2_miss_overhead_cy: f64,
    /// per CL served by L3 (Uncore datapath inefficiency)
    pub l3_miss_overhead_cy: f64,
    /// deterministic relative jitter amplitude applied to "measured" values
    /// (mimics run-to-run variation of a real testbed; seeded, reproducible)
    pub jitter_rel: f64,
}

impl SimParams {
    /// Per-socket constants. IVB/HSW have the inefficient Uncores the paper
    /// calls out; BDW's is nearly ideal.
    pub fn for_machine(shorthand: &str) -> Self {
        match shorthand {
            "SNB" => SimParams { l2_miss_overhead_cy: 0.6, l3_miss_overhead_cy: 1.0, jitter_rel: 0.015 },
            "IVB" => SimParams { l2_miss_overhead_cy: 0.75, l3_miss_overhead_cy: 1.4, jitter_rel: 0.015 },
            "HSW" => SimParams { l2_miss_overhead_cy: 0.5, l3_miss_overhead_cy: 1.3, jitter_rel: 0.015 },
            "BDW" => SimParams { l2_miss_overhead_cy: 0.4, l3_miss_overhead_cy: 0.3, jitter_rel: 0.015 },
            _ => SimParams { l2_miss_overhead_cy: 0.6, l3_miss_overhead_cy: 1.0, jitter_rel: 0.02 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bdw_uncore_is_best() {
        let snb = SimParams::for_machine("SNB");
        let ivb = SimParams::for_machine("IVB");
        let bdw = SimParams::for_machine("BDW");
        assert!(bdw.l3_miss_overhead_cy < snb.l3_miss_overhead_cy);
        assert!(bdw.l3_miss_overhead_cy < ivb.l3_miss_overhead_cy);
    }

    #[test]
    fn unknown_machine_gets_defaults() {
        let p = SimParams::for_machine("HOST");
        assert!(p.l2_miss_overhead_cy > 0.0);
    }
}
