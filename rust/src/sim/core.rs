//! Port scoreboard: executes a kernel's virtual instruction stream on a
//! machine's functional units with pipeline latencies and register
//! dataflow, yielding the steady-state in-core cycles per unit of work.
//!
//! This is the trace-driven counterpart of the analytic `ecm::model::t_ol` /
//! `t_nol`: nothing here reads the ECM formulas; agreement between the two
//! is a cross-validation of both (see tests).

use crate::isa::{Inst, KernelDesc, Op};
use crate::machine::{CoreModel, Unit};

/// What a port can execute. The timeline is a sorted list of busy intervals
/// so later-ready instructions can backfill gaps an earlier long-latency
/// dependency left behind (out-of-order execution's effect on port
/// utilization); without backfill, dependency stalls serialize the ports and
/// ADD-bound kernels come out ~2.5x too slow.
#[derive(Clone, Debug)]
struct Port {
    caps: Vec<Op>,
    /// sorted, disjoint (start, end) busy intervals
    busy: Vec<(f64, f64)>,
    /// intervals before this are pruned; nothing may schedule before it
    floor: f64,
}

impl Port {
    /// Earliest start >= `ready` with a gap of length `occ`. Intervals that
    /// end before the candidate can never matter — skip them with a binary
    /// search instead of walking the whole timeline.
    fn earliest_start(&self, ready: f64, occ: f64) -> f64 {
        let mut candidate = ready.max(self.floor);
        // fast path: past the end of the timeline (the common steady-state
        // case for the bottleneck port)
        match self.busy.last() {
            None => return candidate,
            Some(&(_, e)) if candidate >= e => return candidate,
            _ => {}
        }
        let mut i = self.busy.partition_point(|&(_, e)| e <= candidate);
        while i < self.busy.len() {
            let (s, e) = self.busy[i];
            if candidate + occ <= s {
                break;
            }
            if e > candidate {
                candidate = e;
            }
            i += 1;
        }
        candidate
    }

    /// Reserve [start, start+occ), merging with touching neighbours in
    /// place. The slot came from `earliest_start`, so it cannot overlap an
    /// existing interval — only touch its direct neighbours; a full rebuild
    /// here (one allocation per issued instruction) dominated the whole
    /// simulator before the §Perf pass.
    fn reserve(&mut self, start: f64, occ: f64) {
        const EPS: f64 = 1e-9;
        let end = start + occ;
        // fast path: appending at the end of the timeline
        if let Some(last) = self.busy.last_mut() {
            if start >= last.1 {
                if start <= last.1 + EPS {
                    last.1 = end;
                } else {
                    self.busy.push((start, end));
                }
                return;
            }
        } else {
            self.busy.push((start, end));
            return;
        }
        let pos = self.busy.partition_point(|&(s, _)| s < start);
        let touches_prev = pos > 0 && self.busy[pos - 1].1 + EPS >= start;
        let touches_next = pos < self.busy.len() && end + EPS >= self.busy[pos].0;
        match (touches_prev, touches_next) {
            (true, true) => {
                self.busy[pos - 1].1 = self.busy[pos].1.max(end);
                self.busy.remove(pos);
            }
            (true, false) => self.busy[pos - 1].1 = self.busy[pos - 1].1.max(end),
            (false, true) => self.busy[pos].0 = start,
            (false, false) => self.busy.insert(pos, (start, end)),
        }
    }

    /// Drop intervals that ended before `horizon` (keeps the list small).
    fn compact(&mut self, horizon: f64) {
        if self.busy.len() > 64 {
            self.floor = self.floor.max(horizon);
            let f = self.floor;
            self.busy.retain(|&(_, e)| e >= f);
        }
    }

    fn horizon(&self) -> f64 {
        self.busy.last().map(|&(_, e)| e).unwrap_or(0.0)
    }
}

/// Scoreboard state across passes.
pub struct Scoreboard {
    ports: Vec<Port>,
    /// per-op list of capable port indices (precomputed: the capability
    /// scan was ~15% of issue time)
    ports_by_op: [Vec<u8>; 5],
    core: CoreModel,
    /// register id -> cycle its value becomes available (flat array: the
    /// generator's register ids are all < 256, and a HashMap here costs
    /// ~10x on the simulator's hottest path)
    reg_ready: Vec<f64>,
    /// program-order head: an instruction cannot issue before this minus the
    /// reorder window (models a finite OoO window)
    last_issue: f64,
    window: f64,
    /// completion times of loads that missed L1 (line-fill buffers);
    /// bounded at `max_fill_buffers` outstanding
    inflight_misses: std::collections::VecDeque<f64>,
    max_fill_buffers: usize,
}

impl Scoreboard {
    pub fn new(core: &CoreModel) -> Self {
        let mut ports = Vec::new();
        let port = |caps: Vec<Op>| Port { caps, busy: Vec::new(), floor: 0.0 };
        for _ in 0..core.load_ports {
            ports.push(port(vec![Op::Load]));
        }
        for _ in 0..core.store_ports {
            ports.push(port(vec![Op::Store]));
        }
        if core.fma_ports > 0 {
            // FMA pipes execute MUL and FMA; pipe 0 additionally takes
            // stand-alone ADDs (HSW/BDW port layout)
            for i in 0..core.fma_ports {
                let caps = if i == 0 {
                    vec![Op::Add, Op::Mul, Op::Fma]
                } else {
                    vec![Op::Mul, Op::Fma]
                };
                ports.push(port(caps));
            }
        } else {
            for _ in 0..core.add_ports {
                ports.push(port(vec![Op::Add]));
            }
            for _ in 0..core.mul_ports {
                // no FMA hardware: FMA ops fall back to the MUL pipe
                ports.push(port(vec![Op::Mul, Op::Fma]));
            }
        }
        let op_index = |op: Op| match op {
            Op::Load => 0usize,
            Op::Store => 1,
            Op::Add => 2,
            Op::Mul => 3,
            Op::Fma => 4,
        };
        let mut ports_by_op: [Vec<u8>; 5] = Default::default();
        for (i, p) in ports.iter().enumerate() {
            for &op in &p.caps {
                ports_by_op[op_index(op)].push(i as u8);
            }
        }
        Scoreboard {
            ports,
            ports_by_op,
            core: core.clone(),
            reg_ready: vec![0.0; 256],
            last_issue: 0.0,
            window: 60.0,
            inflight_misses: Default::default(),
            max_fill_buffers: 10, // Intel: 10 LFBs per core
        }
    }

    fn unit_of(op: Op) -> Unit {
        match op {
            Op::Load => Unit::Load,
            Op::Store => Unit::Store,
            Op::Add => Unit::Add,
            Op::Mul => Unit::Mul,
            Op::Fma => Unit::Fma,
        }
    }

    /// Issue one instruction; `extra_load_delay` adds cache-miss stall
    /// cycles to a load's completion. Returns the completion cycle.
    pub fn issue(&mut self, inst: &Inst, extra_load_delay: f64) -> f64 {
        let ready = inst
            .reads()
            .map(|r| self.reg_ready[r as usize & 0xff])
            .fold(0.0f64, f64::max);
        // finite reorder window: can't run arbitrarily far ahead of the
        // slowest in-flight instruction
        let mut ready = ready.max(self.last_issue - self.window);

        // line-fill buffers: a missing load cannot issue until a buffer
        // frees up (this is what really bounds latency tolerance)
        if inst.op == Op::Load && extra_load_delay > 0.0 {
            while let Some(&front) = self.inflight_misses.front() {
                if self.inflight_misses.len() >= self.max_fill_buffers {
                    ready = ready.max(front);
                    self.inflight_misses.pop_front();
                } else {
                    break;
                }
            }
        }

        let occupancy = self.core.slots(Self::unit_of(inst.op), inst.width_bytes);
        // pick the capable port that can start earliest (with backfill)
        let op_idx = match inst.op {
            Op::Load => 0usize,
            Op::Store => 1,
            Op::Add => 2,
            Op::Mul => 3,
            Op::Fma => 4,
        };
        let mut best: Option<(usize, f64)> = None;
        for &i in &self.ports_by_op[op_idx] {
            let start = self.ports[i as usize].earliest_start(ready, occupancy);
            if best.map(|(_, s)| start < s).unwrap_or(true) {
                best = Some((i as usize, start));
            }
        }
        let (pi, start) = best.unwrap_or_else(|| panic!("no port for {:?}", inst.op));
        self.ports[pi].reserve(start, occupancy);
        self.last_issue = self.last_issue.max(start);
        let prune = self.last_issue - 4.0 * self.window;
        self.ports[pi].compact(prune);

        let latency = self.core.latency(Self::unit_of(inst.op)) as f64
            + if inst.op == Op::Load { extra_load_delay } else { 0.0 };
        let done = start + latency;
        if inst.op == Op::Load && extra_load_delay > 0.0 {
            self.inflight_misses.push_back(done);
        }
        if inst.dest != crate::isa::inst::REG_NONE {
            self.reg_ready[inst.dest as usize & 0xff] = done;
        }
        done
    }

    /// Latest port-busy horizon (used to convert to elapsed cycles).
    pub fn horizon(&self) -> f64 {
        self.ports.iter().map(|p| p.horizon()).fold(0.0, f64::max)
    }
}

/// Steady-state in-core cycles per **unit of work**, assuming all loads hit
/// L1 (the `T_core` the ECM model calls max(T_OL, T_nOL)).
pub fn steady_state_cycles_per_unit(core: &CoreModel, kernel: &KernelDesc) -> f64 {
    let warm_passes = 16usize;
    let measure_passes = 64usize;
    let mut sb = Scoreboard::new(core);
    for _ in 0..warm_passes {
        for inst in &kernel.insts {
            sb.issue(inst, 0.0);
        }
    }
    let start = sb.horizon();
    for _ in 0..measure_passes {
        for inst in &kernel.insts {
            sb.issue(inst, 0.0);
        }
    }
    let elapsed = sb.horizon() - start;
    elapsed / (measure_passes * kernel.units_per_stream_pass) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ecm;
    use crate::isa::{compiler_kahan, generate, Precision, Simd, Variant};
    use crate::machine::presets::{hsw, ivb};

    fn close(a: f64, b: f64, rel: f64) -> bool {
        (a - b).abs() <= rel * b.abs()
    }

    /// The scoreboard must agree with the analytic ECM in-core time for the
    /// paper's four §3 kernels on IVB (L1-resident data).
    #[test]
    fn matches_ecm_core_time_ivb() {
        let m = ivb();
        for (variant, simd, expect) in [
            (Variant::Naive, Simd::Avx, 4.0),    // max(T_OL=2, T_nOL=4)
            (Variant::Kahan, Simd::Scalar, 64.0),
            (Variant::Kahan, Simd::Sse, 16.0),
            (Variant::Kahan, Simd::Avx, 8.0),
        ] {
            let k = generate(variant, simd, Precision::Sp, 0);
            let sim = steady_state_cycles_per_unit(&m.core, &k);
            assert!(
                close(sim, expect, 0.12),
                "{variant:?} {simd:?}: sim {sim:.2} vs paper {expect}"
            );
            let e = ecm::build(&m, &k, true);
            assert!(
                close(sim, e.prediction(0), 0.12),
                "{variant:?} {simd:?}: sim {sim:.2} vs ecm {:.2}",
                e.prediction(0)
            );
        }
    }

    /// HSW FMA trick: the scoreboard should show the ~20% L1 speedup that
    /// comes from dual FMA pipes, limited by register-capped unrolling.
    #[test]
    fn hsw_fma_l1_speedup_emerges() {
        let m = hsw();
        let add = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        let fma = generate(Variant::KahanFma, Simd::Avx, Precision::Sp, 0);
        let t_add = steady_state_cycles_per_unit(&m.core, &add);
        let t_fma = steady_state_cycles_per_unit(&m.core, &fma);
        let speedup = t_add / t_fma;
        assert!(
            (1.05..=1.45).contains(&speedup),
            "FMA L1 speedup {speedup:.2} (t_add={t_add:.2}, t_fma={t_fma:.2})"
        );
    }

    /// The compiler-generated Kahan loop (single chain, no unrolling) is
    /// latency-bound: ~4 ops x 3 cy per scalar iteration = ~192 cy/unit.
    #[test]
    fn compiler_kahan_is_latency_bound() {
        let m = ivb();
        let k = compiler_kahan(Precision::Sp);
        let t = steady_state_cycles_per_unit(&m.core, &k);
        assert!(
            (150.0..=230.0).contains(&t),
            "compiler variant {t:.1} cy/unit, expected latency-dominated ~192"
        );
    }

    /// DP scalar kahan: 32 cy per unit (paper).
    #[test]
    fn dp_scalar_core_time() {
        let m = ivb();
        let k = generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0);
        let t = steady_state_cycles_per_unit(&m.core, &k);
        assert!(close(t, 32.0, 0.12), "{t}");
    }

    /// Load stalls propagate: adding per-load delay slows the naive kernel
    /// (load-bound) but barely affects scalar Kahan (ADD-bound).
    #[test]
    fn load_delay_sensitivity() {
        let m = ivb();
        let naive = generate(Variant::Naive, Simd::Avx, Precision::Sp, 0);
        let scalar = generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0);
        let run = |k: &crate::isa::KernelDesc, delay: f64| {
            let mut sb = Scoreboard::new(&m.core);
            for _ in 0..50 {
                for i in &k.insts {
                    sb.issue(i, delay);
                }
            }
            sb.horizon() / (50.0 * k.units_per_stream_pass as f64)
        };
        let naive_slow = run(&naive, 20.0) / run(&naive, 0.0);
        let scalar_slow = run(&scalar, 20.0) / run(&scalar, 0.0);
        assert!(naive_slow > 1.10, "naive {naive_slow}");
        assert!(scalar_slow < naive_slow, "scalar {scalar_slow} vs naive {naive_slow}");
    }
}
