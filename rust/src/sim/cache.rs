//! Set-associative, inclusive, LRU cache-hierarchy simulator at cache-line
//! granularity.
//!
//! This is a *mechanistic* cache: real sets, real ways, true LRU stacks.
//! It reproduces the textbook cyclic-streaming behaviour the paper's
//! working-set sweeps rely on (a stream that exceeds a level's capacity gets
//! zero hits there under LRU) without hand-coding that rule anywhere.

use crate::machine::Machine;

/// Where an access was served from: 0 = L1, 1 = L2, 2 = L3,
/// `n_levels` = main memory.
pub type ServiceLevel = usize;

struct Level {
    sets: usize,
    ways: usize,
    /// per set: LRU stack of tags, most-recent first
    tags: Vec<Vec<u64>>,
}

impl Level {
    fn new(size_bytes: u64, ways: u32, line: u32) -> Self {
        let lines = (size_bytes / line as u64).max(1) as usize;
        let ways = (ways as usize).min(lines).max(1);
        let sets = (lines / ways).max(1);
        Level { sets, ways, tags: vec![Vec::new(); sets] }
    }

    /// Touch a cache line; returns true on hit. Inserts/refreshes MRU.
    /// (A rotate-based variant was tried in the §Perf pass and reverted:
    /// no measurable gain over remove+insert at <= 20 ways.)
    fn touch(&mut self, cl_addr: u64) -> bool {
        let set = (cl_addr % self.sets as u64) as usize;
        let stack = &mut self.tags[set];
        if let Some(pos) = stack.iter().position(|&t| t == cl_addr) {
            let tag = stack.remove(pos);
            stack.insert(0, tag);
            true
        } else {
            stack.insert(0, cl_addr);
            if stack.len() > self.ways {
                stack.pop();
            }
            false
        }
    }

    fn contains(&self, cl_addr: u64) -> bool {
        let set = (cl_addr % self.sets as u64) as usize;
        self.tags[set].contains(&cl_addr)
    }
}

/// An inclusive multi-level cache hierarchy.
pub struct CacheSim {
    levels: Vec<Level>,
    line_bytes: u32,
    pub accesses: u64,
    /// hits served per level (last entry = memory)
    pub served: Vec<u64>,
}

impl CacheSim {
    pub fn new(machine: &Machine) -> Self {
        let line = machine.cache_line_bytes;
        let levels = machine
            .caches
            .iter()
            .map(|c| Level::new(c.size_bytes, c.ways, line))
            .collect::<Vec<_>>();
        let n = levels.len();
        CacheSim { levels, line_bytes: line, accesses: 0, served: vec![0; n + 1] }
    }

    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }

    /// Access a byte address; returns the level that served the line.
    /// All inner levels are filled on the way back (inclusive hierarchy).
    pub fn access(&mut self, byte_addr: u64) -> ServiceLevel {
        let cl = byte_addr / self.line_bytes as u64;
        self.accesses += 1;
        let mut served = self.levels.len(); // memory unless a level hits
        for (i, lvl) in self.levels.iter_mut().enumerate() {
            if lvl.touch(cl) {
                served = i;
                break;
            }
        }
        // `touch` inserted the line into every level that missed, so the
        // hierarchy stays inclusive on fills. Outer levels deliberately do
        // NOT see inner hits (an L2 only observes L1 misses); the resulting
        // (rare) inclusivity violation on outer eviction is the usual
        // simulator simplification and is irrelevant for streaming sweeps.
        self.served[served] += 1;
        served
    }

    /// Whether a byte address is currently resident in `level`.
    pub fn resident_in(&self, byte_addr: u64, level: usize) -> bool {
        self.levels[level].contains(byte_addr / self.line_bytes as u64)
    }

    /// Reset counters (not contents).
    pub fn reset_counters(&mut self) {
        self.accesses = 0;
        self.served.iter_mut().for_each(|c| *c = 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::presets::ivb;

    fn stream_pass(sim: &mut CacheSim, bytes: u64, line: u64) {
        let mut a = 0u64;
        while a < bytes {
            sim.access(a);
            a += line;
        }
    }

    #[test]
    fn small_stream_lives_in_l1_after_warmup() {
        let m = ivb();
        let mut sim = CacheSim::new(&m);
        let ws = 16 * 1024; // fits 32 KiB L1
        stream_pass(&mut sim, ws, 64);
        sim.reset_counters();
        stream_pass(&mut sim, ws, 64);
        assert_eq!(sim.served[0], ws / 64, "all L1 hits after warmup");
    }

    #[test]
    fn cyclic_stream_larger_than_l1_gets_no_l1_hits() {
        // classic LRU worst case: ws slightly above capacity -> 0% hits
        let m = ivb();
        let mut sim = CacheSim::new(&m);
        let ws = 64 * 1024; // 2x L1
        stream_pass(&mut sim, ws, 64);
        sim.reset_counters();
        stream_pass(&mut sim, ws, 64);
        assert_eq!(sim.served[0], 0, "L1 must thrash");
        assert_eq!(sim.served[1], ws / 64, "L2 serves everything");
    }

    #[test]
    fn l3_sized_stream_served_by_l3() {
        let m = ivb();
        let mut sim = CacheSim::new(&m);
        let ws = 4 * 1024 * 1024; // > L2 (256 KiB), < L3 (25 MiB)
        stream_pass(&mut sim, ws, 64);
        sim.reset_counters();
        stream_pass(&mut sim, ws, 64);
        assert_eq!(sim.served[0] + sim.served[1], 0);
        assert_eq!(sim.served[2], ws / 64);
    }

    #[test]
    fn beyond_llc_goes_to_memory() {
        let m = ivb();
        let mut sim = CacheSim::new(&m);
        let ws = 64 * 1024 * 1024; // > 25 MiB L3
        stream_pass(&mut sim, ws, 64);
        sim.reset_counters();
        stream_pass(&mut sim, ws, 64);
        assert_eq!(sim.served[3], ws / 64, "memory serves everything");
    }

    #[test]
    fn inclusive_fill_makes_second_touch_l1() {
        let m = ivb();
        let mut sim = CacheSim::new(&m);
        assert_eq!(sim.access(0), 3); // cold: memory
        assert_eq!(sim.access(0), 0); // now L1
        assert_eq!(sim.access(8), 0); // same cache line
    }

    #[test]
    fn two_streams_interleaved() {
        // dot's access pattern: a[i], b[i] alternating, far apart
        let m = ivb();
        let mut sim = CacheSim::new(&m);
        let n = 1024u64; // 2 x 8 KiB working set, fits L1
        for i in 0..n {
            sim.access(i * 8);
            sim.access(1 << 30 | (i * 8));
        }
        sim.reset_counters();
        for i in 0..n {
            sim.access(i * 8);
            sim.access(1 << 30 | (i * 8));
        }
        assert_eq!(sim.served[0], sim.accesses);
    }
}
