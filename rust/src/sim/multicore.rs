//! Multicore scaling on the virtual testbed: n cores run the single-core
//! engine concurrently and share the memory interface, modeled as a
//! capacity server (cache lines per cycle at load-only bandwidth).
//!
//! Saturation *emerges* from capacity: each core demands
//! `cls_per_unit / T_unit` lines per cycle; once aggregate demand exceeds
//! the interface capacity, cores stall proportionally. This reproduces the
//! paper's P(n) = min(n·P_ECM, I·b_S) without encoding that formula.

use super::engine::simulate_working_set;
use crate::isa::KernelDesc;
use crate::machine::Machine;

/// One point of a simulated scaling run.
#[derive(Clone, Copy, Debug)]
pub struct ScalePoint {
    pub cores: u32,
    pub gups: f64,
    /// fraction of the memory interface capacity in use (1.0 = saturated)
    pub bw_utilization: f64,
}

/// Simulate in-memory scaling for 1..=max_cores.
///
/// `elems` should put the working set well beyond the LLC (per core).
pub fn simulate_scaling(
    machine: &Machine,
    kernel: &KernelDesc,
    elems: u64,
    max_cores: u32,
) -> Vec<ScalePoint> {
    // multicore run: Uncore at full clock (single_core = false)
    let single = simulate_working_set(machine, kernel, elems, false);
    let t_unit_single = kernel.iters_per_unit as f64 * machine.clock_ghz / single.gups;

    // memory interface capacity in cache lines per cycle
    let capacity_cl_per_cy = 1.0 / machine.t_l3mem_per_cl();
    let cls = kernel.cl_transfers_per_unit() as f64;

    (1..=max_cores)
        .map(|n| {
            let demand = n as f64 * cls / t_unit_single; // CL/cy wanted
            let (t_unit_eff, util) = if demand <= capacity_cl_per_cy {
                (t_unit_single, demand / capacity_cl_per_cy)
            } else {
                // stall: per-core unit time stretches so aggregate demand
                // exactly matches capacity
                (n as f64 * cls / capacity_cl_per_cy, 1.0)
            };
            let per_core = kernel.iters_per_unit as f64 * machine.clock_ghz / t_unit_eff;
            ScalePoint { cores: n, gups: n as f64 * per_core, bw_utilization: util }
        })
        .collect()
}

/// First core count at which the simulated curve is within 2% of its
/// maximum (a "measured" saturation point).
pub fn observed_saturation(points: &[ScalePoint]) -> u32 {
    let max = points.iter().map(|p| p.gups).fold(0.0, f64::max);
    points
        .iter()
        .find(|p| p.gups >= 0.98 * max)
        .map(|p| p.cores)
        .unwrap_or(points.last().map(|p| p.cores).unwrap_or(1))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{compiler_kahan, generate, Precision, Simd, Variant};
    use crate::machine::presets::{bdw, hsw, ivb, snb};

    const ELEMS_MEM: u64 = 64 * 1024 * 1024; // 512 MiB total in SP

    /// Fig. 3a: on IVB (SP) the vectorized variants saturate near the
    /// roofline (~5.76 GUP/s) at ~4 cores; scalar stays linear to 10 cores
    /// (~5.5) without saturating; the compiler variant crawls.
    #[test]
    fn fig3a_shapes() {
        let m = ivb();
        let avx = simulate_scaling(&m, &generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0), ELEMS_MEM, 10);
        let sat = observed_saturation(&avx);
        assert!((3..=5).contains(&sat), "AVX saturation at {sat}");
        let peak = avx.last().unwrap().gups;
        assert!((peak - 5.76).abs() < 0.4, "AVX peak {peak}");

        let scalar = simulate_scaling(&m, &generate(Variant::Kahan, Simd::Scalar, Precision::Sp, 0), ELEMS_MEM, 10);
        assert!(scalar.last().unwrap().bw_utilization < 1.0, "scalar must not saturate");
        assert!((scalar.last().unwrap().gups - 5.5).abs() < 0.4);

        let compiler = simulate_scaling(&m, &compiler_kahan(Precision::Sp), ELEMS_MEM, 10);
        assert!(compiler.last().unwrap().gups < 2.0, "compiler variant is devastatingly slow");
    }

    /// Fig. 3b: DP scalar saturates around 6 cores at ~2.88 GUP/s.
    #[test]
    fn fig3b_dp_scalar_saturates() {
        let m = ivb();
        let k = generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0);
        let pts = simulate_scaling(&m, &k, ELEMS_MEM, 10);
        let sat = observed_saturation(&pts);
        assert!((5..=7).contains(&sat), "DP scalar saturation at {sat}");
        assert!((pts.last().unwrap().gups - 2.88).abs() < 0.2);
    }

    /// Fig. 4b: saturated performance ranks by memory bandwidth:
    /// HSW > SNB ~ IVB > BDW.
    #[test]
    fn fig4b_saturated_ranking() {
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        let peak = |m: &crate::machine::Machine| {
            simulate_scaling(m, &k, ELEMS_MEM, m.cores).last().unwrap().gups
        };
        let (s, i, h, b) = (peak(&snb()), peak(&ivb()), peak(&hsw()), peak(&bdw()));
        assert!(h > s && h > i && h > b, "HSW fastest: {h} vs {s} {i} {b}");
        assert!(b < s && b < i, "BDW slowest: {b}");
        assert!((h - 60.6 / 8.0).abs() < 0.5, "HSW near its roofline: {h}");
    }

    #[test]
    fn scaling_monotone() {
        let m = ivb();
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        let pts = simulate_scaling(&m, &k, ELEMS_MEM, 10);
        for w in pts.windows(2) {
            assert!(w[1].gups >= w[0].gups - 1e-9);
        }
    }
}
