//! # kahan-ecm
//!
//! Reproduction of *"Performance analysis of the Kahan-enhanced scalar
//! product on current multicore processors"* (Hofmann, Fey, Eitzinger,
//! Hager, Wellein — PPAM/LNCS 2015).
//!
//! The crate contains, as one coherent framework (see `DESIGN.md`):
//!
//! * [`machine`] — Table-1 socket descriptions (SNB/IVB/HSW/BDW presets +
//!   host detection);
//! * [`isa`] — generated virtual-assembly dot kernels (naive / Kahan /
//!   Kahan-FMA at scalar/SSE/AVX/AVX-512, SP/DP);
//! * [`ecm`] — the Execution–Cache–Memory analytic model (Table 2, Eq. 2);
//! * [`sim`] — a trace-driven virtual testbed (port scoreboard + cache
//!   hierarchy + memory interface) standing in for the paper's silicon;
//! * [`bench`] — a likwid-bench-style host microbenchmark framework with
//!   real `std::arch` SIMD Kahan kernels;
//! * [`engine`] — the persistent parallel dot engine and its NUMA-sharded
//!   serving tier: pooled aligned buffers, pinned per-domain worker pools
//!   with chunked compensated reduction, autotuned kernel dispatch, a
//!   locality-aware shard router (the serving hot path), and the pure
//!   request-planning layer (`engine::plan`) every routing threshold
//!   flows through;
//! * [`accuracy`] — error-free transformations, exact dot products and the
//!   Ogita–Rump–Oishi ill-conditioned generator;
//! * [`runtime`] — PJRT execution of the AOT-lowered JAX/Pallas artifacts;
//! * [`coordinator`] — experiment registry, reports, validation against the
//!   paper's published numbers, and the concurrent dot service (per-shard
//!   router pool with bounded, back-pressured queues).

pub mod accuracy;
pub mod bench;
pub mod coordinator;
pub mod ecm;
pub mod engine;
pub mod isa;
pub mod machine;
pub mod runtime;
pub mod sim;
pub mod util;
