//! Kernel generators: emit the virtual-instruction stream for one *pass* of
//! the (naive|Kahan) dot loop at a given SIMD width, precision and unroll
//! factor — the analog of the paper's hand-written likwid-bench assembly.
//!
//! Terminology (matches the paper):
//! * **unit of work** — one cache line of each stream: 16 SP / 8 DP
//!   iterations.
//! * **pass** — `unroll` units; each vector operation in a pass gets its own
//!   accumulator *slot* (modulo the register budget), which is exactly the
//!   paper's "modulo unrolling" that hides ADD/FMA pipeline latency.

use super::inst::{Inst, Op, Simd, StreamRef, REG_C_BASE, REG_SUM_BASE, REG_TMP_BASE};

/// Algorithm variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// Fig. 1a — uncompensated.
    Naive,
    /// Fig. 1b — Kahan compensation on the ADD pipes.
    Kahan,
    /// §4 trick: compensated adds issued as FMAs with unit multiplicand so
    /// both HSW/BDW FMA pipes can execute them.
    KahanFma,
}

impl Variant {
    pub fn name(self) -> &'static str {
        match self {
            Variant::Naive => "naive",
            Variant::Kahan => "kahan",
            Variant::KahanFma => "kahan-fma",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Some(Variant::Naive),
            "kahan" => Some(Variant::Kahan),
            "kahan-fma" | "kahanfma" | "fma" => Some(Variant::KahanFma),
            _ => None,
        }
    }
}

/// Accuracy tier of a dot-product request — the algorithm class, orthogonal
/// to the ISA-flavor [`Variant`] a concrete kernel implements it with. The
/// serving stack (registry, autotuner, planner, engine, shards, service)
/// keys every lookup by `(Accuracy, Precision)`; `Variant` survives as
/// kernel metadata for the ISA-model side (`isa::generate`, ECM, sim).
///
/// The ladder, in increasing accuracy: `Naive` (Fig. 1a, error grows with
/// eps·n·cond), `Kahan` (Fig. 1b compensation), `Dot2` (Ogita–Rump–Oishi
/// TwoProd + 2Sum — as if computed in doubled precision, error independent
/// of the condition number until eps²·cond ≈ 1), `Exact` (Shewchuk
/// expansion / wide accumulation — correctly rounded, scalar-only).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Accuracy {
    Naive,
    Kahan,
    Dot2,
    Exact,
}

impl Accuracy {
    /// Every tier, ladder order (least to most accurate).
    pub const ALL: [Accuracy; 4] = [Accuracy::Naive, Accuracy::Kahan, Accuracy::Dot2, Accuracy::Exact];

    pub fn name(self) -> &'static str {
        match self {
            Accuracy::Naive => "naive",
            Accuracy::Kahan => "kahan",
            Accuracy::Dot2 => "dot2",
            Accuracy::Exact => "exact",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Some(Accuracy::Naive),
            "kahan" | "kahan-fma" | "kahanfma" => Some(Accuracy::Kahan),
            "dot2" | "oro" | "ogita-rump-oishi" => Some(Accuracy::Dot2),
            "exact" => Some(Accuracy::Exact),
            _ => None,
        }
    }
}

/// Element precision.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    Sp,
    Dp,
}

impl Precision {
    pub fn elem_bytes(self) -> u32 {
        match self {
            Precision::Sp => 4,
            Precision::Dp => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Sp => "SP",
            Precision::Dp => "DP",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sp" | "f32" | "single" => Some(Precision::Sp),
            "dp" | "f64" | "double" => Some(Precision::Dp),
            _ => None,
        }
    }
}

/// Architectural SIMD register budget assumed by the generator (AVX2: 16
/// ymm registers). Loads in flight + iteration temporaries reserve a few.
const SIMD_REGS: u32 = 16;
const RESERVED_REGS: u32 = 4;

/// A generated kernel: the instruction stream for one pass plus the metadata
/// the ECM model and the simulator need.
#[derive(Clone, Debug)]
pub struct KernelDesc {
    pub name: String,
    pub variant: Variant,
    pub simd: Simd,
    pub prec: Precision,
    /// units of work per pass (the unroll factor in units)
    pub units_per_stream_pass: usize,
    /// independent accumulator slots actually allocated
    pub slots: usize,
    /// FP operations on the loop-carried dependency cycle of one slot
    /// (naive: 1 add; Kahan: the 4-op y→t→d→c cycle)
    pub carried_chain_ops: u32,
    /// instruction stream for one pass
    pub insts: Vec<Inst>,
    /// scalar iterations represented by one unit of work (16 SP / 8 DP)
    pub iters_per_unit: usize,
    /// input streams (dot reads two arrays)
    pub n_streams: usize,
    /// how many of those streams are also written back (axpy: 1); written
    /// cache lines cost an extra write-back transfer at every boundary
    pub written_streams: usize,
    pub elem_bytes: u32,
    /// flops per scalar iteration (dot: 1 mul + 1 add = 2)
    pub flops_per_iter: f64,
}

impl KernelDesc {
    /// Scalar iterations per pass.
    pub fn iters_per_pass(&self) -> usize {
        self.iters_per_unit * self.units_per_stream_pass
    }

    /// Bytes read from all streams per unit of work (= one CL per stream).
    pub fn bytes_per_unit(&self, cache_line: u32) -> u64 {
        self.n_streams as u64 * cache_line as u64
    }

    /// Cache lines touched per unit of work.
    pub fn cls_per_unit(&self) -> u64 {
        self.n_streams as u64
    }

    /// Cache-line *transfers* per unit of work: reads plus write-backs of
    /// written streams (write-allocate reads are already in `n_streams`).
    pub fn cl_transfers_per_unit(&self) -> u64 {
        (self.n_streams + self.written_streams) as u64
    }

    /// Bytes of input consumed per scalar iteration (8 B SP, 16 B DP).
    pub fn bytes_per_iter(&self) -> u64 {
        self.n_streams as u64 * self.elem_bytes as u64
    }

    /// Bytes of memory *traffic* per iteration, including write-backs
    /// (axpy DP: 8 x-read + 8 y-read + 8 y-write = 24 B).
    pub fn traffic_bytes_per_iter(&self) -> u64 {
        (self.n_streams + self.written_streams) as u64 * self.elem_bytes as u64
    }
}

/// Accumulator registers one slot needs (sum, and for Kahan the c term).
fn regs_per_slot(variant: Variant) -> u32 {
    match variant {
        Variant::Naive => 1,
        Variant::Kahan | Variant::KahanFma => 2,
    }
}

/// FP ops on the carried dependency cycle of one slot.
fn chain_ops(variant: Variant) -> u32 {
    match variant {
        Variant::Naive => 1,
        // y = p - c ; t = s + y ; d = t - s ; c' = d - y : the longest cycle
        // runs through all four (c' of iteration i feeds y of i+1)
        Variant::Kahan | Variant::KahanFma => 4,
    }
}

/// Maximum slots the register file supports.
fn slot_budget(variant: Variant) -> u32 {
    (SIMD_REGS - RESERVED_REGS) / regs_per_slot(variant)
}

/// Default unroll (units per pass): enough slots to hide the FP pipeline
/// latency of the carried chain, assuming IVB-class 3-cycle ADDs and 1 op/cy
/// issue per chain op class — the "proper modulo unrolling" the paper always
/// applies. Clamped to the register budget.
pub fn default_unroll(variant: Variant, simd: Simd, prec: Precision) -> usize {
    let vec_per_unit = vec_ops_per_unit(simd, prec);
    // latency(3 or 5) * chain_ops cycles per slot iteration; during that time
    // the issue ports retire ~ops_per_vec_iter cycles of work per slot
    let lat = match variant {
        Variant::KahanFma => 5,
        _ => 3,
    };
    let ops_per_vec = match variant {
        Variant::Naive => 1.0,
        Variant::Kahan => 4.0,
        Variant::KahanFma => 2.5, // 5 FMA-class ops over 2 ports
    };
    let slots_needed = ((chain_ops(variant) * lat) as f64 / ops_per_vec).ceil() as u32;
    let slots = slots_needed.clamp(1, slot_budget(variant));
    ((slots as usize) + vec_per_unit - 1) / vec_per_unit
}

/// Vector operations per unit of work (one CL per stream).
fn vec_ops_per_unit(simd: Simd, prec: Precision) -> usize {
    let iters = 64 / prec.elem_bytes() as usize; // per cache line
    iters / simd.lanes(prec.elem_bytes()) as usize
}

/// Generate the kernel. `unroll == 0` selects `default_unroll`.
pub fn generate(variant: Variant, simd: Simd, prec: Precision, unroll: usize) -> KernelDesc {
    generate_ext(variant, simd, prec, unroll, None)
}

/// Like [`generate`] but with an explicit accumulator-slot count.
///
/// `slots_override = Some(1)` models what the paper calls the
/// "compiler-generated" Kahan loop: the loop-carried dependency on `c`
/// prevents both SIMD vectorization and modulo unrolling, so a single
/// accumulator chain serializes on the ADD pipeline latency.
pub fn generate_ext(
    variant: Variant,
    simd: Simd,
    prec: Precision,
    unroll: usize,
    slots_override: Option<usize>,
) -> KernelDesc {
    let unroll = if unroll == 0 { default_unroll(variant, simd, prec) } else { unroll };
    let elem = prec.elem_bytes();
    let width = simd.width_bytes(elem);
    let vec_per_unit = vec_ops_per_unit(simd, prec);
    let n_vec = vec_per_unit * unroll;
    let slots = match slots_override {
        Some(s) => s.clamp(1, n_vec),
        None => (n_vec as u32).min(slot_budget(variant)) as usize,
    };

    let mut insts = Vec::with_capacity(n_vec * 7);
    for v in 0..n_vec {
        let slot = (v % slots) as u16;
        let s_reg = REG_SUM_BASE + slot;
        let c_reg = REG_C_BASE + slot;
        // iteration-local temporaries (reused across units; dataflow within
        // an iteration is what matters for scheduling)
        let t_base = REG_TMP_BASE + ((v % 8) as u16) * 8;
        let (r_a, r_b, r_p, r_y, r_d) =
            (t_base, t_base + 1, t_base + 2, t_base + 3, t_base + 4);

        insts.push(Inst::load(width, r_a, StreamRef(0)));
        insts.push(Inst::load(width, r_b, StreamRef(1)));
        match variant {
            Variant::Naive => {
                insts.push(Inst::binop(Op::Mul, width, r_p, r_a, r_b));
                insts.push(Inst::binop(Op::Add, width, s_reg, s_reg, r_p));
            }
            Variant::Kahan => {
                insts.push(Inst::binop(Op::Mul, width, r_p, r_a, r_b));
                // y = p - c
                insts.push(Inst::binop(Op::Add, width, r_y, r_p, c_reg));
                // t = s + y   (t is renamed onto the sum register)
                insts.push(Inst::binop(Op::Add, width, s_reg, s_reg, r_y));
                // d = t - s_old (dataflow: depends on t)
                insts.push(Inst::binop(Op::Add, width, r_d, s_reg, r_y));
                // c' = d - y
                insts.push(Inst::binop(Op::Add, width, c_reg, r_d, r_y));
            }
            Variant::KahanFma => {
                // product via FMA pipe (p = a*b + 0)
                insts.push(Inst::fma(width, r_p, r_a, r_b, r_p));
                // compensated adds as FMAs with unit multiplicand
                insts.push(Inst::fma(width, r_y, r_p, r_p, c_reg)); // y = p - c
                insts.push(Inst::fma(width, s_reg, s_reg, s_reg, r_y)); // t = s + y
                insts.push(Inst::fma(width, r_d, s_reg, s_reg, r_y)); // d = t - s
                insts.push(Inst::fma(width, c_reg, r_d, r_d, r_y)); // c' = d - y
            }
        }
    }

    let iters_per_unit = 64 / elem as usize;
    KernelDesc {
        name: format!("{}-{}-{}", variant.name(), simd.name(), prec.name()),
        variant,
        simd,
        prec,
        units_per_stream_pass: unroll,
        slots,
        carried_chain_ops: chain_ops(variant),
        insts,
        iters_per_unit,
        n_streams: 2,
        written_streams: 0,
        elem_bytes: elem,
        flops_per_iter: 2.0,
    }
}

/// The paper's kernel zoo: every (variant × SIMD) combination analyzed in
/// §3, for one precision.
pub fn paper_kernels(prec: Precision) -> Vec<KernelDesc> {
    vec![
        generate(Variant::Naive, Simd::Avx, prec, 0),
        generate(Variant::Kahan, Simd::Scalar, prec, 0),
        generate(Variant::Kahan, Simd::Sse, prec, 0),
        generate(Variant::Kahan, Simd::Avx, prec, 0),
    ]
}

/// The "compiler-generated" Kahan loop of Figs. 3a/3b: scalar, no unrolling,
/// one serialized accumulator chain.
pub fn compiler_kahan(prec: Precision) -> KernelDesc {
    let mut k = generate_ext(Variant::Kahan, Simd::Scalar, prec, 1, Some(1));
    k.name = format!("kahan-compiler-{}", prec.name());
    k
}

/// §5 generalization ("blueprint for other load-dominated streaming
/// kernels"): the pure summation kernel — one input stream, no multiply.
/// Kahan sum per iteration: y = x - c; t = s + y; d = t - s; c' = d - y
/// (4 ADDs); naive sum: 1 ADD.
pub fn generate_sum(variant: Variant, simd: Simd, prec: Precision, unroll: usize) -> KernelDesc {
    let unroll = if unroll == 0 { default_unroll(variant, simd, prec) } else { unroll };
    let elem = prec.elem_bytes();
    let width = simd.width_bytes(elem);
    let vec_per_unit = vec_ops_per_unit(simd, prec);
    let n_vec = vec_per_unit * unroll;
    let slots = (n_vec as u32).min(slot_budget(variant)) as usize;

    let mut insts = Vec::with_capacity(n_vec * 6);
    for v in 0..n_vec {
        let slot = (v % slots) as u16;
        let s_reg = REG_SUM_BASE + slot;
        let c_reg = REG_C_BASE + slot;
        let t_base = REG_TMP_BASE + ((v % 8) as u16) * 8;
        let (r_x, r_y, r_d) = (t_base, t_base + 1, t_base + 2);

        insts.push(Inst::load(width, r_x, StreamRef(0)));
        match variant {
            Variant::Naive => {
                insts.push(Inst::binop(Op::Add, width, s_reg, s_reg, r_x));
            }
            Variant::Kahan => {
                insts.push(Inst::binop(Op::Add, width, r_y, r_x, c_reg));
                insts.push(Inst::binop(Op::Add, width, s_reg, s_reg, r_y));
                insts.push(Inst::binop(Op::Add, width, r_d, s_reg, r_y));
                insts.push(Inst::binop(Op::Add, width, c_reg, r_d, r_y));
            }
            Variant::KahanFma => {
                insts.push(Inst::fma(width, r_y, r_x, r_x, c_reg));
                insts.push(Inst::fma(width, s_reg, s_reg, s_reg, r_y));
                insts.push(Inst::fma(width, r_d, s_reg, s_reg, r_y));
                insts.push(Inst::fma(width, c_reg, r_d, r_d, r_y));
            }
        }
    }

    KernelDesc {
        name: format!("{}-sum-{}-{}", variant.name(), simd.name(), prec.name()),
        variant,
        simd,
        prec,
        units_per_stream_pass: unroll,
        slots,
        carried_chain_ops: chain_ops(variant),
        insts,
        iters_per_unit: 64 / elem as usize,
        n_streams: 1,
        written_streams: 0,
        elem_bytes: elem,
        flops_per_iter: 1.0,
    }
}

/// STREAM-style axpy (`y[i] = a*x[i] + y[i]`): the store-traffic member of
/// the ECM kernel family (Stengel et al. [11] use daxpy as the canonical
/// example). No accumulation — so no Kahan variant — but it exercises the
/// store ports and write-back traffic the dot/sum kernels never touch.
pub fn generate_axpy(simd: Simd, prec: Precision, unroll: usize) -> KernelDesc {
    let unroll = if unroll == 0 { 2 } else { unroll };
    let elem = prec.elem_bytes();
    let width = simd.width_bytes(elem);
    let vec_per_unit = vec_ops_per_unit(simd, prec);
    let n_vec = vec_per_unit * unroll;

    let mut insts = Vec::with_capacity(n_vec * 4);
    for v in 0..n_vec {
        let t_base = REG_TMP_BASE + ((v % 8) as u16) * 8;
        let (r_x, r_y, r_p) = (t_base, t_base + 1, t_base + 2);
        insts.push(Inst::load(width, r_x, StreamRef(0)));
        insts.push(Inst::load(width, r_y, StreamRef(1)));
        // a*x (the scalar a lives in a register); + y; store y
        insts.push(Inst::binop(Op::Mul, width, r_p, r_x, r_x));
        insts.push(Inst::binop(Op::Add, width, r_p, r_p, r_y));
        insts.push(Inst {
            op: Op::Store,
            width_bytes: width,
            dest: crate::isa::inst::REG_NONE,
            srcs: [r_p, crate::isa::inst::REG_NONE, crate::isa::inst::REG_NONE],
            stream: Some(StreamRef(1)),
        });
    }

    KernelDesc {
        name: format!("axpy-{}-{}", simd.name(), prec.name()),
        variant: Variant::Naive,
        simd,
        prec,
        units_per_stream_pass: unroll,
        slots: n_vec.max(1),
        carried_chain_ops: 1, // no loop-carried dependency
        insts,
        iters_per_unit: 64 / elem as usize,
        n_streams: 2,
        written_streams: 1,
        elem_bytes: elem,
        flops_per_iter: 2.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_ops_per_unit_table() {
        assert_eq!(vec_ops_per_unit(Simd::Scalar, Precision::Sp), 16);
        assert_eq!(vec_ops_per_unit(Simd::Sse, Precision::Sp), 4);
        assert_eq!(vec_ops_per_unit(Simd::Avx, Precision::Sp), 2);
        assert_eq!(vec_ops_per_unit(Simd::Avx512, Precision::Sp), 1);
        assert_eq!(vec_ops_per_unit(Simd::Scalar, Precision::Dp), 8);
        assert_eq!(vec_ops_per_unit(Simd::Avx, Precision::Dp), 2);
    }

    #[test]
    fn default_unroll_saturates_add_port() {
        // Kahan AVX SP: chain = 4 ops * 3 cy = 12 cy; 4 adds per vec op
        // retire in 4 cy, so >= 3 slots are needed; slots come in whole
        // units (2 vec ops each) => 2 units, 4 slots.
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        assert!(k.slots >= 3, "slots={}", k.slots);
        // naive: 3-cycle chain, 1 add per vec op => 3 slots minimum
        let k = generate(Variant::Naive, Simd::Avx, Precision::Sp, 0);
        assert!(k.slots >= 3);
    }

    #[test]
    fn fma_slots_hit_register_budget() {
        // FMA chain = 4 ops * 5 cy = 20 cy; 2.5 cy issue per vec op => 8
        // slots wanted but the register file caps Kahan at 6.
        let k = generate(Variant::KahanFma, Simd::Avx, Precision::Sp, 0);
        assert_eq!(k.slots, 6, "paper: HSW/BDW run out of registers");
    }

    #[test]
    fn slots_never_exceed_budget() {
        for variant in [Variant::Naive, Variant::Kahan, Variant::KahanFma] {
            for simd in [Simd::Scalar, Simd::Sse, Simd::Avx, Simd::Avx512] {
                for prec in [Precision::Sp, Precision::Dp] {
                    for unroll in [0usize, 1, 2, 8, 32] {
                        let k = generate(variant, simd, prec, unroll);
                        assert!(k.slots as u32 <= slot_budget(variant));
                        assert!(k.slots >= 1);
                    }
                }
            }
        }
    }

    #[test]
    fn bytes_per_iter() {
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        assert_eq!(k.bytes_per_iter(), 8); // paper: 1 update / 8 B (SP)
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Dp, 0);
        assert_eq!(k.bytes_per_iter(), 16); // 1 update / 16 B (DP)
    }

    #[test]
    fn kernel_names() {
        let k = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 0);
        assert_eq!(k.name, "kahan-AVX-SP");
        let k = generate(Variant::KahanFma, Simd::Avx512, Precision::Dp, 0);
        assert_eq!(k.name, "kahan-fma-AVX-512-DP");
    }

    #[test]
    fn paper_zoo_has_four_kernels() {
        let zoo = paper_kernels(Precision::Sp);
        assert_eq!(zoo.len(), 4);
        assert_eq!(zoo[0].variant, Variant::Naive);
    }
}
