//! Virtual instructions: what the paper's assembly listings contain, reduced
//! to the fields performance analysis needs (operation class, SIMD width,
//! register dataflow, source stream of loads).

/// Operation classes — each maps to one functional-unit class of
/// `crate::machine::Unit`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Load,
    Store,
    Add,
    Mul,
    Fma,
}

/// SIMD width of an instruction / kernel flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Simd {
    Scalar,
    Sse,
    Avx,
    Avx512,
}

impl Simd {
    /// Register width in bytes for a given element size.
    pub fn width_bytes(self, elem_bytes: u32) -> u32 {
        match self {
            Simd::Scalar => elem_bytes,
            Simd::Sse => 16,
            Simd::Avx => 32,
            Simd::Avx512 => 64,
        }
    }

    /// Lanes per register for a given element size.
    pub fn lanes(self, elem_bytes: u32) -> u32 {
        self.width_bytes(elem_bytes) / elem_bytes
    }

    pub fn name(self) -> &'static str {
        match self {
            Simd::Scalar => "scalar",
            Simd::Sse => "SSE",
            Simd::Avx => "AVX",
            Simd::Avx512 => "AVX-512",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(Simd::Scalar),
            "sse" => Some(Simd::Sse),
            "avx" | "avx2" => Some(Simd::Avx),
            "avx512" | "avx-512" => Some(Simd::Avx512),
            _ => None,
        }
    }
}

/// Which input stream a load reads (dot has two: a and b).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StreamRef(pub u8);

/// Virtual register ids. The generator uses a fixed convention so tests and
/// the scheduler can identify accumulators:
///   REG_SUM_BASE + k   : running sum, unroll slot k
///   REG_C_BASE + k     : Kahan compensation, unroll slot k
///   REG_TMP_BASE ...   : iteration-local temporaries
pub const REG_SUM_BASE: u16 = 0;
pub const REG_C_BASE: u16 = 64;
pub const REG_TMP_BASE: u16 = 128;
pub const REG_NONE: u16 = u16::MAX;

/// One virtual instruction.
#[derive(Clone, Copy, Debug)]
pub struct Inst {
    pub op: Op,
    /// register width in bytes (4/8 scalar, 16 SSE, 32 AVX, 64 AVX-512)
    pub width_bytes: u32,
    /// destination register (REG_NONE for stores)
    pub dest: u16,
    /// source registers (REG_NONE padding)
    pub srcs: [u16; 3],
    /// for loads/stores: which stream is accessed
    pub stream: Option<StreamRef>,
}

impl Inst {
    pub fn load(width: u32, dest: u16, stream: StreamRef) -> Self {
        Inst { op: Op::Load, width_bytes: width, dest, srcs: [REG_NONE; 3], stream: Some(stream) }
    }

    pub fn binop(op: Op, width: u32, dest: u16, a: u16, b: u16) -> Self {
        debug_assert!(matches!(op, Op::Add | Op::Mul));
        Inst { op, width_bytes: width, dest, srcs: [a, b, REG_NONE], stream: None }
    }

    pub fn fma(width: u32, dest: u16, a: u16, b: u16, c: u16) -> Self {
        Inst { op: Op::Fma, width_bytes: width, dest, srcs: [a, b, c], stream: None }
    }

    /// Registers this instruction reads.
    pub fn reads(&self) -> impl Iterator<Item = u16> + '_ {
        self.srcs.iter().copied().filter(|&r| r != REG_NONE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn simd_widths_and_lanes() {
        assert_eq!(Simd::Scalar.width_bytes(4), 4);
        assert_eq!(Simd::Scalar.width_bytes(8), 8);
        assert_eq!(Simd::Sse.lanes(4), 4);
        assert_eq!(Simd::Avx.lanes(4), 8);
        assert_eq!(Simd::Avx.lanes(8), 4);
        assert_eq!(Simd::Avx512.lanes(4), 16);
    }

    #[test]
    fn parse_simd() {
        assert_eq!(Simd::parse("AVX2"), Some(Simd::Avx));
        assert_eq!(Simd::parse("sse"), Some(Simd::Sse));
        assert_eq!(Simd::parse("mmx"), None);
    }

    #[test]
    fn inst_reads_skip_none() {
        let i = Inst::binop(Op::Add, 32, 1, 2, 3);
        assert_eq!(i.reads().collect::<Vec<_>>(), vec![2, 3]);
        let l = Inst::load(32, 5, StreamRef(0));
        assert_eq!(l.reads().count(), 0);
    }
}
