//! Virtual instruction set: the machine-checkable analog of the paper's
//! hand-written assembly kernels.
//!
//! A `KernelDesc` holds the instruction stream for **one unit of work** (one
//! cache line of each input stream = 16 SP / 8 DP scalar iterations) exactly
//! as the paper counts it, plus stream metadata. Both the analytic ECM model
//! (`crate::ecm`) and the cycle-level simulator (`crate::sim`) consume this
//! stream, so they can never disagree about what the kernel *is*.

pub mod inst;
pub mod kernelgen;

pub use inst::{Inst, Op, Simd, StreamRef};
pub use kernelgen::{
    compiler_kahan, generate, generate_axpy, generate_ext, generate_sum, paper_kernels, Accuracy,
    KernelDesc, Precision, Variant,
};

#[cfg(test)]
mod tests {
    use super::*;

    /// Instruction counts normalized to one unit of work (a pass spans
    /// `units_per_stream_pass` units).
    fn counts(k: &KernelDesc) -> (usize, usize, usize, usize) {
        let mut loads = 0;
        let mut adds = 0;
        let mut muls = 0;
        let mut fmas = 0;
        for i in &k.insts {
            match i.op {
                Op::Load => loads += 1,
                Op::Add => adds += 1,
                Op::Mul => muls += 1,
                Op::Fma => fmas += 1,
                Op::Store => {}
            }
        }
        let u = k.units_per_stream_pass;
        assert_eq!(loads % u, 0);
        (loads / u, adds / u, muls / u, fmas / u)
    }

    /// §3 of the paper counts, per unit of work (16 SP iterations):
    ///  naive AVX:    4 loads, 2 MUL, 2 ADD
    ///  Kahan scalar: 32 loads, 16 MUL, 64 ADD
    ///  Kahan SSE:    8 loads, 4 MUL, 16 ADD
    ///  Kahan AVX:    4 loads, 2 MUL, 8 ADD
    #[test]
    fn paper_instruction_counts_sp() {
        let cases = [
            (Variant::Naive, Simd::Avx, (4, 2, 2, 0)),
            (Variant::Kahan, Simd::Scalar, (32, 64, 16, 0)),
            (Variant::Kahan, Simd::Sse, (8, 16, 4, 0)),
            (Variant::Kahan, Simd::Avx, (4, 8, 2, 0)),
        ];
        for (variant, simd, (l, a, m, f)) in cases {
            let k = generate(variant, simd, Precision::Sp, 0);
            let (loads, adds, muls, fmas) = counts(&k);
            assert_eq!(
                (loads, adds, muls, fmas),
                (l, a, m, f),
                "{variant:?} {simd:?}"
            );
            assert_eq!(k.iters_per_unit, 16);
        }
    }

    /// DP halves the iterations per cache line but the SIMD instruction
    /// counts per unit are unchanged; scalar DP has half the instructions of
    /// scalar SP.
    #[test]
    fn paper_instruction_counts_dp() {
        let k = generate(Variant::Kahan, Simd::Scalar, Precision::Dp, 0);
        let (loads, adds, muls, _) = counts(&k);
        assert_eq!((loads, adds, muls), (16, 32, 8));
        assert_eq!(k.iters_per_unit, 8);

        let k = generate(Variant::Kahan, Simd::Avx, Precision::Dp, 0);
        let (loads, adds, muls, _) = counts(&k);
        assert_eq!((loads, adds, muls), (4, 8, 2));
    }

    /// The FMA variant (HSW/BDW trick: ADD as FMA with unit multiplicand)
    /// turns all four ADD-pipe ops into FMA-pipe ops.
    #[test]
    fn fma_variant_moves_adds_to_fma_pipes() {
        let k = generate(Variant::KahanFma, Simd::Avx, Precision::Sp, 0);
        let (loads, adds, _, fmas) = counts(&k);
        assert_eq!(loads, 4);
        assert_eq!(adds, 0);
        assert_eq!(fmas, 10); // 2 product-FMAs + 8 compensated-add FMAs
    }

    /// AVX-512 halves the vector instruction count again.
    #[test]
    fn avx512_counts() {
        let k = generate(Variant::Kahan, Simd::Avx512, Precision::Sp, 0);
        let (loads, adds, muls, _) = counts(&k);
        assert_eq!((loads, adds, muls), (2, 4, 1));
    }

    /// Every non-load instruction must depend (transitively) on both loads
    /// of its iteration — guards against generating dead code.
    #[test]
    fn dataflow_reaches_accumulator() {
        for simd in [Simd::Scalar, Simd::Sse, Simd::Avx] {
            let k = generate(Variant::Kahan, simd, Precision::Sp, 0);
            // the last instruction of each iteration writes the running sum
            let sum_writes: Vec<_> =
                k.insts.iter().filter(|i| i.dest == inst::REG_SUM_BASE).collect();
            assert!(!sum_writes.is_empty(), "{simd:?}");
        }
    }

    #[test]
    fn unroll_scales_stream_and_unit() {
        let base = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 1);
        let u4 = generate(Variant::Kahan, Simd::Avx, Precision::Sp, 4);
        assert_eq!(u4.insts.len(), 4 * base.insts.len());
        assert_eq!(u4.units_per_stream_pass, 4);
        assert_eq!(u4.iters_per_unit, base.iters_per_unit);
    }
}
