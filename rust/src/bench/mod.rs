//! Host microbenchmark framework — the likwid-bench analog (DESIGN.md §1).
//!
//! Real `std::arch` SIMD implementations of the paper's kernels run on the
//! machine this crate executes on, with TSC timing, working-set sweeps and a
//! thread-scaling harness. This validates the paper's *qualitative* headline
//! ("vectorized Kahan comes for free outside L1") on genuine silicon, while
//! the quantitative per-socket reproduction lives in `crate::sim`.

pub mod kernels;
pub mod sweep;
pub mod threads;
pub mod timer;

pub use kernels::{registry, HostKernel};
pub use sweep::{run_sweep, HostSweepPoint};
