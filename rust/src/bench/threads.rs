//! Thread-scaling harness: n pinned threads each stream their own working
//! set, aggregate GUP/s is reported per thread count — the measurement side
//! of Figs. 3a/3b/4b.
//!
//! On this container only one core is online, so host scaling degenerates to
//! n = 1 (the simulator carries the multicore reproduction); the harness
//! still exercises the full path — spawn, pin, barrier, measure, reduce —
//! and scales on real multicore hosts.

use super::kernels::{HostKernel, KernelFn};
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

/// Result for one thread count.
#[derive(Clone, Copy, Debug)]
pub struct ThreadScalePoint {
    pub threads: u32,
    pub gups: f64,
    /// per-thread GUP/s spread (max/min), contention indicator
    pub imbalance: f64,
}

/// Pin the calling thread to `cpu` (best effort; ignored on failure).
pub fn pin_to_cpu(cpu: usize) {
    #[cfg(target_os = "linux")]
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        libc::CPU_SET(cpu % libc::CPU_SETSIZE as usize, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
    }
}

/// Run `kernel` on `threads` pinned threads for ~`millis` ms each over a
/// per-thread working set of `elems` elements per stream.
pub fn run_threads(kernel: &HostKernel, threads: u32, elems: usize, millis: u64) -> ThreadScalePoint {
    let barrier = Arc::new(Barrier::new(threads as usize));
    let stop = Arc::new(AtomicBool::new(false));
    let mut handles = Vec::new();

    for t in 0..threads {
        let barrier = barrier.clone();
        let stop = stop.clone();
        let f = kernel.f;
        handles.push(std::thread::spawn(move || {
            pin_to_cpu(t as usize);
            let mut rng = Rng::new(1000 + t as u64);
            let mut iters = 0u64;
            let elapsed;
            match f {
                KernelFn::F32(f) => {
                    let a = rng.normal_f32_vec(elems);
                    let b = rng.normal_f32_vec(elems);
                    std::hint::black_box(f(&a, &b));
                    barrier.wait();
                    let t0 = Instant::now();
                    while !stop.load(Ordering::Relaxed) {
                        std::hint::black_box(f(&a, &b));
                        iters += 1;
                        if t0.elapsed().as_millis() as u64 >= millis {
                            stop.store(true, Ordering::Relaxed);
                        }
                    }
                    elapsed = t0.elapsed().as_secs_f64();
                }
                KernelFn::F64(f) => {
                    let a = rng.normal_f64_vec(elems);
                    let b = rng.normal_f64_vec(elems);
                    std::hint::black_box(f(&a, &b));
                    barrier.wait();
                    let t0 = Instant::now();
                    while !stop.load(Ordering::Relaxed) {
                        std::hint::black_box(f(&a, &b));
                        iters += 1;
                        if t0.elapsed().as_millis() as u64 >= millis {
                            stop.store(true, Ordering::Relaxed);
                        }
                    }
                    elapsed = t0.elapsed().as_secs_f64();
                }
            }
            // updates/s for this thread
            iters as f64 * elems as f64 / elapsed / 1e9
        }));
    }

    let per_thread: Vec<f64> = handles.into_iter().map(|h| h.join().expect("bench thread")).collect();
    let total: f64 = per_thread.iter().sum();
    let max = per_thread.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_thread.iter().cloned().fold(f64::MAX, f64::min);
    ThreadScalePoint { threads, gups: total, imbalance: if min > 0.0 { max / min } else { f64::NAN } }
}

/// Scaling curve for 1..=max_threads.
pub fn scaling_curve(kernel: &HostKernel, max_threads: u32, elems: usize, millis: u64) -> Vec<ThreadScalePoint> {
    (1..=max_threads).map(|n| run_threads(kernel, n, elems, millis)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::kernels::by_name;

    #[test]
    fn single_thread_run_produces_throughput() {
        let k = by_name("kahan-AVX2-SP").unwrap();
        let p = run_threads(&k, 1, 64 * 1024, 30);
        assert_eq!(p.threads, 1);
        assert!(p.gups > 0.01, "{p:?}");
    }

    #[test]
    fn two_threads_do_not_crash_on_one_cpu() {
        let k = by_name("naive-AVX2-SP").unwrap();
        let p = run_threads(&k, 2, 16 * 1024, 20);
        assert!(p.gups > 0.0);
    }

    #[test]
    fn pin_is_best_effort() {
        pin_to_cpu(0);
        pin_to_cpu(999); // wraps, must not panic
    }
}
