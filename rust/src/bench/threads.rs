//! Thread-scaling harness: n pinned threads each stream their own working
//! set, aggregate GUP/s is reported per thread count — the measurement side
//! of Figs. 3a/3b/4b.
//!
//! The harness runs on the persistent [`WorkerPool`] from `crate::engine`:
//! [`scaling_curve`] spawns the pool once and reuses it for every thread
//! count (the pool's workers are already pinned), instead of spawning and
//! pinning fresh threads per measurement point.
//!
//! Timing: every iteration samples `Instant::now()` exactly once and that
//! same sample drives both the stop decision and the reported elapsed
//! time, so the final iteration of a slow thread is never charged against
//! a clock read taken before it finished (the old code read
//! `t0.elapsed()` again after the loop, biasing per-thread GUP/s).
//!
//! On this container only one core is online, so host scaling degenerates
//! to n = 1 (the simulator carries the multicore reproduction); the
//! harness still exercises the full path — submit, barrier, measure,
//! reduce — and scales on real multicore hosts.

use super::kernels::{HostKernel, KernelFn};
use crate::engine::WorkerPool;
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Barrier};
use std::time::Instant;

/// Result for one thread count.
#[derive(Clone, Copy, Debug)]
pub struct ThreadScalePoint {
    pub threads: u32,
    pub gups: f64,
    /// per-thread GUP/s spread (max/min), contention indicator; 1.0 for a
    /// single thread by definition
    pub imbalance: f64,
}

/// Logical CPUs this **process** is allowed to run on AND that are online
/// — under taskset / cgroup cpusets the allowed ids need not start at 0
/// (so a bare `0..available_parallelism()` range would name forbidden
/// CPUs), and on hotplug-capable VMs `Cpus_allowed` can include ids that
/// are not online (so the mask alone would name unpinnable CPUs).
///
/// The affinity mask is read from `/proc/self/status`
/// (`Cpus_allowed_list` of the thread-group leader) rather than
/// `sched_getaffinity(0)`: the latter reports the *calling thread's*
/// mask, which `pin_to_cpu` itself shrinks — a pool built from an
/// already-pinned thread would otherwise wrap every worker onto that one
/// CPU and report success. Fallbacks: the calling thread's mask, then
/// `0..available_parallelism()`. The result is intersected with
/// `/sys/devices/system/cpu/online`, sorted, cached for the process
/// lifetime, and never empty.
pub fn allowed_cpus() -> Vec<usize> {
    static ALLOWED: std::sync::OnceLock<Vec<usize>> = std::sync::OnceLock::new();
    ALLOWED
        .get_or_init(|| {
            let mut cpus = process_mask_cpus();
            if let Some(online) = online_cpu_list() {
                if cpus.is_empty() {
                    cpus = online;
                } else {
                    cpus.retain(|c| online.binary_search(c).is_ok());
                }
            }
            if cpus.is_empty() {
                let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
                cpus = (0..n).collect();
            }
            cpus
        })
        .clone()
}

/// The process affinity mask as CPU ids (may include offline ids; see
/// [`allowed_cpus`] for the intersection). Empty when unreadable.
fn process_mask_cpus() -> Vec<usize> {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        if let Some(line) = status.lines().find(|l| l.starts_with("Cpus_allowed_list:")) {
            let cpus = crate::engine::topology::parse_cpu_list(
                line.trim_start_matches("Cpus_allowed_list:"),
            );
            if !cpus.is_empty() {
                return cpus;
            }
        }
    }
    #[cfg(target_os = "linux")]
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        if libc::sched_getaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &mut set) == 0 {
            let mut cpus = Vec::new();
            for c in 0..libc::CPU_SETSIZE as usize {
                if libc::CPU_ISSET(c, &set) {
                    cpus.push(c);
                }
            }
            if !cpus.is_empty() {
                return cpus;
            }
        }
    }
    Vec::new()
}

/// The kernel's online CPU list, if readable (sorted; `None` off-Linux or
/// when sysfs is hidden).
fn online_cpu_list() -> Option<Vec<usize>> {
    std::fs::read_to_string("/sys/devices/system/cpu/online")
        .ok()
        .map(|s| crate::engine::topology::parse_cpu_list(&s))
        .filter(|v| !v.is_empty())
}

/// Pin the calling thread to the `cpu`-th CPU of the process's *allowed*
/// CPU set, wrapping over that set — not over `CPU_SETSIZE` (the kernel's
/// 1024-slot mask), where wrapping silently requested offline CPUs on
/// oversubscribed pools, and not over a bare online count, which names
/// forbidden ids under taskset/cgroup masks. The old code also discarded
/// the `sched_setaffinity` result, so an unpinned thread gave no signal.
///
/// Best effort with a signal: returns `true` iff the affinity call
/// succeeded (always `false` on non-Linux, where pinning is unsupported).
pub fn pin_to_cpu(cpu: usize) -> bool {
    let allowed = allowed_cpus();
    pin_to_exact_cpu(allowed[cpu % allowed.len()])
}

/// Pin the calling thread to exactly logical CPU `cpu` (no wrapping; the
/// caller vouches the id is valid, e.g. it came from a sysfs NUMA node
/// cpulist). Returns `true` iff the affinity call succeeded.
pub fn pin_to_exact_cpu(cpu: usize) -> bool {
    #[cfg(target_os = "linux")]
    {
        if cpu >= libc::CPU_SETSIZE as usize {
            return false;
        }
        unsafe {
            let mut set: libc::cpu_set_t = std::mem::zeroed();
            libc::CPU_ZERO(&mut set);
            libc::CPU_SET(cpu, &mut set);
            libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set) == 0
        }
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
        false
    }
}

/// Timed streaming loop shared by both precisions: returns this thread's
/// updates/s. One `Instant::now()` per iteration serves both the stop
/// check and the elapsed measurement.
fn stream_loop<T: Copy>(
    f: fn(&[T], &[T]) -> T,
    a: &[T],
    b: &[T],
    millis: u64,
    barrier: &Barrier,
    stop: &AtomicBool,
) -> f64 {
    std::hint::black_box(f(a, b)); // warm caches + page-fault the streams
    barrier.wait();
    let t0 = Instant::now();
    let mut t_end = t0;
    let mut iters = 0u64;
    while !stop.load(Ordering::Relaxed) {
        std::hint::black_box(f(a, b));
        iters += 1;
        t_end = Instant::now();
        if t_end.duration_since(t0).as_millis() as u64 >= millis {
            stop.store(true, Ordering::Relaxed);
        }
    }
    let elapsed = t_end.duration_since(t0).as_secs_f64().max(1e-9);
    iters as f64 * a.len().min(b.len()) as f64 / elapsed / 1e9
}

/// Run `kernel` on `threads` workers of an existing pool for ~`millis` ms
/// each over a per-thread working set of `elems` elements per stream.
/// Workers `0..threads` of `pool` are used (they are pinned to CPUs
/// `0..threads`), so `threads` must not exceed `pool.size()`.
pub fn run_threads_on(
    pool: &WorkerPool,
    kernel: &HostKernel,
    threads: u32,
    elems: usize,
    millis: u64,
) -> ThreadScalePoint {
    assert!(threads >= 1, "need at least one thread");
    assert!(
        threads as usize <= pool.size(),
        "asked for {threads} threads on a pool of {}",
        pool.size()
    );
    let barrier = Arc::new(Barrier::new(threads as usize));
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<f64>();

    for t in 0..threads {
        let barrier = Arc::clone(&barrier);
        let stop = Arc::clone(&stop);
        let tx = tx.clone();
        let f = kernel.f;
        pool.submit_to(
            t as usize,
            Box::new(move || {
                let mut rng = Rng::new(1000 + t as u64);
                let gups = match f {
                    KernelFn::F32(f) => {
                        let a = rng.normal_f32_vec(elems);
                        let b = rng.normal_f32_vec(elems);
                        stream_loop(f, &a, &b, millis, &barrier, &stop)
                    }
                    KernelFn::F64(f) => {
                        let a = rng.normal_f64_vec(elems);
                        let b = rng.normal_f64_vec(elems);
                        stream_loop(f, &a, &b, millis, &barrier, &stop)
                    }
                };
                let _ = tx.send(gups);
            }),
        );
    }
    drop(tx);

    let per_thread: Vec<f64> = rx.iter().collect();
    assert_eq!(per_thread.len(), threads as usize, "a bench worker died");
    let total: f64 = per_thread.iter().sum();
    let max = per_thread.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_thread.iter().cloned().fold(f64::MAX, f64::min);
    let imbalance = if per_thread.len() <= 1 {
        1.0
    } else if min > 0.0 {
        max / min
    } else {
        f64::INFINITY
    };
    ThreadScalePoint { threads, gups: total, imbalance }
}

/// Convenience wrapper: run one measurement on a transient pool.
pub fn run_threads(kernel: &HostKernel, threads: u32, elems: usize, millis: u64) -> ThreadScalePoint {
    let pool = WorkerPool::new(threads as usize);
    run_threads_on(&pool, kernel, threads, elems, millis)
}

/// Scaling curve for 1..=max_threads over ONE persistent worker pool
/// (spawned and pinned once, reused for every point).
pub fn scaling_curve(kernel: &HostKernel, max_threads: u32, elems: usize, millis: u64) -> Vec<ThreadScalePoint> {
    let pool = WorkerPool::new(max_threads.max(1) as usize);
    (1..=max_threads).map(|n| run_threads_on(&pool, kernel, n, elems, millis)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::kernels::by_name;

    #[test]
    fn single_thread_run_produces_throughput() {
        let k = by_name("kahan-AVX2-SP").unwrap();
        let p = run_threads(&k, 1, 64 * 1024, 30);
        assert_eq!(p.threads, 1);
        assert!(p.gups > 0.01, "{p:?}");
        assert_eq!(p.imbalance, 1.0, "single thread is balanced by definition: {p:?}");
    }

    #[test]
    fn two_threads_do_not_crash_on_one_cpu() {
        let k = by_name("naive-AVX2-SP").unwrap();
        let p = run_threads(&k, 2, 16 * 1024, 20);
        assert!(p.gups > 0.0);
        assert!(p.imbalance.is_finite() && p.imbalance >= 1.0, "{p:?}");
    }

    #[test]
    fn pool_is_reused_across_points() {
        let k = by_name("kahan-scalar-SP").unwrap();
        let pool = WorkerPool::new(2);
        let p1 = run_threads_on(&pool, &k, 1, 8 * 1024, 10);
        let p2 = run_threads_on(&pool, &k, 2, 8 * 1024, 10);
        let p1b = run_threads_on(&pool, &k, 1, 8 * 1024, 10);
        assert!(p1.gups > 0.0 && p2.gups > 0.0 && p1b.gups > 0.0);
    }

    #[test]
    fn scaling_curve_has_every_point() {
        let k = by_name("kahan-scalar-SP").unwrap();
        let pts = scaling_curve(&k, 2, 8 * 1024, 10);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].threads, 1);
        assert_eq!(pts[1].threads, 2);
    }

    #[test]
    fn pin_is_best_effort() {
        let allowed = allowed_cpus();
        assert!(!allowed.is_empty());
        // on Linux, wrapping over the process's *allowed* set must land on
        // a pinnable CPU even under taskset/cgroup masks whose ids don't
        // start at 0; elsewhere pinning reports failure
        let a = pin_to_cpu(0);
        let b = pin_to_cpu(999); // wraps over the allowed set, must not panic
        if cfg!(target_os = "linux") {
            assert!(a && b, "wrapped pin must target an allowed CPU ({allowed:?})");
        } else {
            assert!(!a && !b);
        }
        // out-of-mask exact pin reports failure instead of silently
        // pinning somewhere else
        assert!(!pin_to_exact_cpu(usize::MAX));
    }
}
