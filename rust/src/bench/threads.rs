//! Thread-scaling harness: n pinned threads each stream their own working
//! set, aggregate GUP/s is reported per thread count — the measurement side
//! of Figs. 3a/3b/4b.
//!
//! The harness runs on the persistent [`WorkerPool`] from `crate::engine`:
//! [`scaling_curve`] spawns the pool once and reuses it for every thread
//! count (the pool's workers are already pinned), instead of spawning and
//! pinning fresh threads per measurement point.
//!
//! Timing: every iteration samples `Instant::now()` exactly once and that
//! same sample drives both the stop decision and the reported elapsed
//! time, so the final iteration of a slow thread is never charged against
//! a clock read taken before it finished (the old code read
//! `t0.elapsed()` again after the loop, biasing per-thread GUP/s).
//!
//! On this container only one core is online, so host scaling degenerates
//! to n = 1 (the simulator carries the multicore reproduction); the
//! harness still exercises the full path — submit, barrier, measure,
//! reduce — and scales on real multicore hosts.

use super::kernels::{HostKernel, KernelFn};
use crate::engine::WorkerPool;
use crate::util::Rng;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Barrier};
use std::time::Instant;

/// Result for one thread count.
#[derive(Clone, Copy, Debug)]
pub struct ThreadScalePoint {
    pub threads: u32,
    pub gups: f64,
    /// per-thread GUP/s spread (max/min), contention indicator; 1.0 for a
    /// single thread by definition
    pub imbalance: f64,
}

/// Pin the calling thread to `cpu` (best effort; ignored on failure).
pub fn pin_to_cpu(cpu: usize) {
    #[cfg(target_os = "linux")]
    unsafe {
        let mut set: libc::cpu_set_t = std::mem::zeroed();
        libc::CPU_ZERO(&mut set);
        libc::CPU_SET(cpu % libc::CPU_SETSIZE as usize, &mut set);
        libc::sched_setaffinity(0, std::mem::size_of::<libc::cpu_set_t>(), &set);
    }
    #[cfg(not(target_os = "linux"))]
    {
        let _ = cpu;
    }
}

/// Timed streaming loop shared by both precisions: returns this thread's
/// updates/s. One `Instant::now()` per iteration serves both the stop
/// check and the elapsed measurement.
fn stream_loop<T: Copy>(
    f: fn(&[T], &[T]) -> T,
    a: &[T],
    b: &[T],
    millis: u64,
    barrier: &Barrier,
    stop: &AtomicBool,
) -> f64 {
    std::hint::black_box(f(a, b)); // warm caches + page-fault the streams
    barrier.wait();
    let t0 = Instant::now();
    let mut t_end = t0;
    let mut iters = 0u64;
    while !stop.load(Ordering::Relaxed) {
        std::hint::black_box(f(a, b));
        iters += 1;
        t_end = Instant::now();
        if t_end.duration_since(t0).as_millis() as u64 >= millis {
            stop.store(true, Ordering::Relaxed);
        }
    }
    let elapsed = t_end.duration_since(t0).as_secs_f64().max(1e-9);
    iters as f64 * a.len().min(b.len()) as f64 / elapsed / 1e9
}

/// Run `kernel` on `threads` workers of an existing pool for ~`millis` ms
/// each over a per-thread working set of `elems` elements per stream.
/// Workers `0..threads` of `pool` are used (they are pinned to CPUs
/// `0..threads`), so `threads` must not exceed `pool.size()`.
pub fn run_threads_on(
    pool: &WorkerPool,
    kernel: &HostKernel,
    threads: u32,
    elems: usize,
    millis: u64,
) -> ThreadScalePoint {
    assert!(threads >= 1, "need at least one thread");
    assert!(
        threads as usize <= pool.size(),
        "asked for {threads} threads on a pool of {}",
        pool.size()
    );
    let barrier = Arc::new(Barrier::new(threads as usize));
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<f64>();

    for t in 0..threads {
        let barrier = Arc::clone(&barrier);
        let stop = Arc::clone(&stop);
        let tx = tx.clone();
        let f = kernel.f;
        pool.submit_to(
            t as usize,
            Box::new(move || {
                let mut rng = Rng::new(1000 + t as u64);
                let gups = match f {
                    KernelFn::F32(f) => {
                        let a = rng.normal_f32_vec(elems);
                        let b = rng.normal_f32_vec(elems);
                        stream_loop(f, &a, &b, millis, &barrier, &stop)
                    }
                    KernelFn::F64(f) => {
                        let a = rng.normal_f64_vec(elems);
                        let b = rng.normal_f64_vec(elems);
                        stream_loop(f, &a, &b, millis, &barrier, &stop)
                    }
                };
                let _ = tx.send(gups);
            }),
        );
    }
    drop(tx);

    let per_thread: Vec<f64> = rx.iter().collect();
    assert_eq!(per_thread.len(), threads as usize, "a bench worker died");
    let total: f64 = per_thread.iter().sum();
    let max = per_thread.iter().cloned().fold(f64::MIN, f64::max);
    let min = per_thread.iter().cloned().fold(f64::MAX, f64::min);
    let imbalance = if per_thread.len() <= 1 {
        1.0
    } else if min > 0.0 {
        max / min
    } else {
        f64::INFINITY
    };
    ThreadScalePoint { threads, gups: total, imbalance }
}

/// Convenience wrapper: run one measurement on a transient pool.
pub fn run_threads(kernel: &HostKernel, threads: u32, elems: usize, millis: u64) -> ThreadScalePoint {
    let pool = WorkerPool::new(threads as usize);
    run_threads_on(&pool, kernel, threads, elems, millis)
}

/// Scaling curve for 1..=max_threads over ONE persistent worker pool
/// (spawned and pinned once, reused for every point).
pub fn scaling_curve(kernel: &HostKernel, max_threads: u32, elems: usize, millis: u64) -> Vec<ThreadScalePoint> {
    let pool = WorkerPool::new(max_threads.max(1) as usize);
    (1..=max_threads).map(|n| run_threads_on(&pool, kernel, n, elems, millis)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::kernels::by_name;

    #[test]
    fn single_thread_run_produces_throughput() {
        let k = by_name("kahan-AVX2-SP").unwrap();
        let p = run_threads(&k, 1, 64 * 1024, 30);
        assert_eq!(p.threads, 1);
        assert!(p.gups > 0.01, "{p:?}");
        assert_eq!(p.imbalance, 1.0, "single thread is balanced by definition: {p:?}");
    }

    #[test]
    fn two_threads_do_not_crash_on_one_cpu() {
        let k = by_name("naive-AVX2-SP").unwrap();
        let p = run_threads(&k, 2, 16 * 1024, 20);
        assert!(p.gups > 0.0);
        assert!(p.imbalance.is_finite() && p.imbalance >= 1.0, "{p:?}");
    }

    #[test]
    fn pool_is_reused_across_points() {
        let k = by_name("kahan-scalar-SP").unwrap();
        let pool = WorkerPool::new(2);
        let p1 = run_threads_on(&pool, &k, 1, 8 * 1024, 10);
        let p2 = run_threads_on(&pool, &k, 2, 8 * 1024, 10);
        let p1b = run_threads_on(&pool, &k, 1, 8 * 1024, 10);
        assert!(p1.gups > 0.0 && p2.gups > 0.0 && p1b.gups > 0.0);
    }

    #[test]
    fn scaling_curve_has_every_point() {
        let k = by_name("kahan-scalar-SP").unwrap();
        let pts = scaling_curve(&k, 2, 8 * 1024, 10);
        assert_eq!(pts.len(), 2);
        assert_eq!(pts[0].threads, 1);
        assert_eq!(pts[1].threads, 2);
    }

    #[test]
    fn pin_is_best_effort() {
        pin_to_cpu(0);
        pin_to_cpu(999); // wraps, must not panic
    }
}
