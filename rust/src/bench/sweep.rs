//! Working-set sweep on the host — the likwid-bench measurement loop:
//! allocate two streams of the target size, warm the caches, time repeated
//! traversals, report cycles per cache line (Fig. 2's unit) and GUP/s.
//!
//! Cycles are TSC cycles; on every post-2010 Intel part the TSC runs at a
//! constant rate close to the nominal clock, which is exactly how the
//! paper's fixed-frequency measurements are denominated.

use super::kernels::{HostKernel, KernelFn};
use super::timer::measure_adaptive;
use crate::isa::Precision;
use crate::util::Rng;

/// One host sweep measurement.
#[derive(Clone, Copy, Debug)]
pub struct HostSweepPoint {
    /// total working set (both streams), bytes
    pub ws_bytes: u64,
    pub cy_per_cl: f64,
    pub gups: f64,
    /// run-to-run coefficient of variation (quality indicator)
    pub cv: f64,
}

/// Default host sweep sizes: 8 KiB .. 64 MiB total, 2 points per octave
/// (the container's LLC is typically ~32 MiB; going far beyond it just
/// burns benchmark time).
pub fn default_sizes() -> Vec<u64> {
    let mut v = Vec::new();
    let mut ws = 8 * 1024u64;
    while ws <= 64 * 1024 * 1024 {
        v.push(ws);
        v.push(ws * 3 / 2);
        ws *= 2;
    }
    v
}

/// Sweep one kernel across total working-set sizes.
///
/// `reps` timed repetitions per size; the timed region is auto-scaled so
/// small working sets are traversed many times per timing (amortizing the
/// timer and keeping the set cache-resident, like likwid-bench's iteration
/// count).
pub fn run_sweep(kernel: &HostKernel, sizes: &[u64], reps: usize, seed: u64) -> Vec<HostSweepPoint> {
    let mut rng = Rng::new(seed);
    let elem_bytes = match kernel.prec {
        Precision::Sp => 4,
        Precision::Dp => 8,
    } as u64;

    sizes
        .iter()
        .map(|&total| {
            let n = (total / (2 * elem_bytes)).max(64) as usize;
            let point = match kernel.f {
                KernelFn::F32(f) => {
                    let a = rng.normal_f32_vec(n);
                    let b = rng.normal_f32_vec(n);
                    let m = measure_adaptive(2_000_000.0, reps, || f(&a, &b));
                    (m.median_cy, m.cv)
                }
                KernelFn::F64(f) => {
                    let a = rng.normal_f64_vec(n);
                    let b = rng.normal_f64_vec(n);
                    let m = measure_adaptive(2_000_000.0, reps, || f(&a, &b));
                    (m.median_cy, m.cv)
                }
            };
            let (cy, cv) = point;
            let cls = (2 * n as u64 * elem_bytes) as f64 / 64.0;
            let ghz = crate::machine::detect::calibrate_tsc_ghz_cached();
            HostSweepPoint {
                ws_bytes: 2 * n as u64 * elem_bytes,
                cy_per_cl: cy / cls,
                gups: n as f64 * ghz / cy,
                cv,
            }
        })
        .collect()
}

/// Measured load-only memory bandwidth (GB/s): streams a working set far
/// beyond the LLC with the naive kernel and converts traversal time to
/// bandwidth. Used to refine the detected host machine model.
pub fn measure_load_bandwidth() -> f64 {
    let n = 32 * 1024 * 1024 / 4; // 64 MiB total across two f32 streams
    let mut rng = Rng::new(1);
    let a = rng.normal_f32_vec(n);
    let b = rng.normal_f32_vec(n);
    let f = super::kernels::avx2::naive_f32;
    let m = measure_adaptive(10_000_000.0, 5, || f(&a, &b));
    let bytes = (2 * n * 4) as f64;
    let ghz = crate::machine::detect::calibrate_tsc_ghz_cached();
    bytes * ghz / m.min_cy
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::kernels::by_name;

    #[test]
    fn sweep_produces_sane_numbers() {
        let k = by_name("kahan-AVX2-SP").unwrap();
        let pts = run_sweep(&k, &[16 * 1024, 256 * 1024], 3, 9);
        assert_eq!(pts.len(), 2);
        for p in &pts {
            assert!(p.cy_per_cl > 0.1 && p.cy_per_cl < 1000.0, "{p:?}");
            assert!(p.gups > 0.01, "{p:?}");
        }
    }

    #[test]
    fn default_sizes_monotone() {
        let s = default_sizes();
        for w in s.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(s[0] == 8 * 1024);
    }

    #[test]
    fn bandwidth_measurement_positive() {
        let bw = measure_load_bandwidth();
        assert!(bw > 0.5 && bw < 1000.0, "bw={bw} GB/s");
    }
}
