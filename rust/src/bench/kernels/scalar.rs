//! Scalar host kernels: the sequential baselines (Fig. 1a/1b verbatim) and
//! the modulo-unrolled scalar Kahan the paper benchmarks as "scalar".

use super::{compensated_fold_f32, compensated_fold_f64};

/// Fig. 1a, strictly sequential. The optimizer may not reassociate floats,
/// so this stays a single accumulator chain — the C-standard-conformant
/// naive dot.
pub fn naive_f32(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut s = 0.0f32;
    for i in 0..n {
        s += a[i] * b[i];
    }
    s
}

pub fn naive_f64(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let mut s = 0.0f64;
    for i in 0..n {
        s += a[i] * b[i];
    }
    s
}

/// Fig. 1b verbatim: one accumulator, one compensation term — what a
/// compiler that *respects* the dependency produces (the "compiler
/// variant" of Figs. 3a/3b, and also the most accurate sequential order).
pub fn kahan_seq_f32(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    for i in 0..n {
        let prod = a[i] * b[i];
        let y = prod - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
    }
    s
}

pub fn kahan_seq_f64(a: &[f64], b: &[f64]) -> f64 {
    let n = a.len().min(b.len());
    let mut s = 0.0f64;
    let mut c = 0.0f64;
    for i in 0..n {
        let prod = a[i] * b[i];
        let y = prod - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
    }
    s
}

macro_rules! kahan_unrolled {
    ($name:ident, $ty:ty, $fold:ident) => {
        /// Modulo-unrolled scalar Kahan: four independent (sum, c) slots
        /// hide the ADD pipeline latency — the paper's optimal "scalar"
        /// variant.
        pub fn $name(a: &[$ty], b: &[$ty]) -> $ty {
            const U: usize = 4;
            let n = a.len().min(b.len());
            let mut s = [0.0 as $ty; U];
            let mut c = [0.0 as $ty; U];
            let chunks = n / U;
            for i in 0..chunks {
                let base = i * U;
                // the four slots carry independent dependency chains
                for k in 0..U {
                    let prod = a[base + k] * b[base + k];
                    let y = prod - c[k];
                    let t = s[k] + y;
                    c[k] = (t - s[k]) - y;
                    s[k] = t;
                }
            }
            for i in chunks * U..n {
                let prod = a[i] * b[i];
                let y = prod - c[0];
                let t = s[0] + y;
                c[0] = (t - s[0]) - y;
                s[0] = t;
            }
            $fold(&s, &c)
        }
    };
}

kahan_unrolled!(kahan_unrolled_f32, f32, compensated_fold_f32);
kahan_unrolled!(kahan_unrolled_f64, f64, compensated_fold_f64);

macro_rules! dot2_seq {
    ($name:ident, $ty:ty) => {
        /// Strictly sequential Ogita–Rump–Oishi Dot2: TwoProd (FMA) + 2Sum
        /// per element, both error terms accumulated into one correction.
        /// Bit-identical to `accuracy::algorithms::dot2_*` (same op order).
        pub fn $name(a: &[$ty], b: &[$ty]) -> $ty {
            let n = a.len().min(b.len());
            let mut s = 0.0 as $ty;
            let mut comp = 0.0 as $ty;
            for i in 0..n {
                let p = a[i] * b[i];
                let ep = a[i].mul_add(b[i], -p);
                let t = s + p;
                let bb = t - s;
                let es = (s - (t - bb)) + (p - bb);
                s = t;
                comp += ep + es;
            }
            s + comp
        }
    };
}

dot2_seq!(dot2_seq_f32, f32);
dot2_seq!(dot2_seq_f64, f64);

macro_rules! dot2_unrolled {
    ($name:ident, $ty:ty, $fold:ident) => {
        /// Modulo-unrolled scalar Dot2: four independent (sum, correction)
        /// slots hide the 2Sum dependency-chain latency, mirroring the
        /// unrolled Kahan kernel's slot structure.
        pub fn $name(a: &[$ty], b: &[$ty]) -> $ty {
            const U: usize = 4;
            let n = a.len().min(b.len());
            let mut s = [0.0 as $ty; U];
            let mut comp = [0.0 as $ty; U];
            let chunks = n / U;
            for i in 0..chunks {
                let base = i * U;
                for k in 0..U {
                    let p = a[base + k] * b[base + k];
                    let ep = a[base + k].mul_add(b[base + k], -p);
                    let t = s[k] + p;
                    let bb = t - s[k];
                    let es = (s[k] - (t - bb)) + (p - bb);
                    s[k] = t;
                    comp[k] += ep + es;
                }
            }
            for i in chunks * U..n {
                let p = a[i] * b[i];
                let ep = a[i].mul_add(b[i], -p);
                let t = s[0] + p;
                let bb = t - s[0];
                let es = (s[0] - (t - bb)) + (p - bb);
                s[0] = t;
                comp[0] += ep + es;
            }
            // the compensated fold subtracts its comps argument (Fig. 1b
            // "to be subtracted" sign); Dot2 corrections are additive, so
            // they go in negated
            let negc = [-comp[0], -comp[1], -comp[2], -comp[3]];
            $fold(&s, &negc)
        }
    };
}

dot2_unrolled!(dot2_unrolled_f32, f32, compensated_fold_f32);
dot2_unrolled!(dot2_unrolled_f64, f64, compensated_fold_f64);

/// Correctly-rounded-for-f32 dot (Neumaier in f64 — exact products, ~2^-50
/// relative residual, far below half an f32 ulp). The `Accuracy::Exact`
/// registry entry; scalar expansion path, no SIMD claim.
pub fn exact_f32(a: &[f32], b: &[f32]) -> f32 {
    crate::accuracy::exact::exact_dot_f32(a, b) as f32
}

/// Exact f64 dot via Shewchuk expansion accumulation, rounded once.
pub fn exact_f64(a: &[f64], b: &[f64]) -> f64 {
    crate::accuracy::exact::exact_dot_f64(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_and_len_mismatch() {
        assert_eq!(naive_f32(&[], &[]), 0.0);
        assert_eq!(kahan_seq_f32(&[1.0, 2.0], &[3.0]), 3.0);
        assert_eq!(kahan_unrolled_f64(&[1.0; 10], &[2.0; 7]), 14.0);
    }

    #[test]
    fn simple_exact_values() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = [2.0f32, 2.0, 2.0, 2.0, 2.0];
        assert_eq!(naive_f32(&a, &b), 30.0);
        assert_eq!(kahan_seq_f32(&a, &b), 30.0);
        assert_eq!(kahan_unrolled_f32(&a, &b), 30.0);
    }

    #[test]
    fn dot2_matches_reference_and_survives_high_condition() {
        let a = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0];
        let b = [2.0f32; 7];
        assert_eq!(dot2_seq_f32(&a, &b), 56.0);
        assert_eq!(dot2_unrolled_f32(&a, &b), 56.0);
        assert_eq!(exact_f32(&a, &b), 56.0);
        // the sequential kernel IS the reference algorithm, bit for bit
        let mut rng = crate::util::Rng::new(17);
        let (a, b, exact, _) = crate::accuracy::gen_dot_f32(999, 1e6, &mut rng);
        assert_eq!(
            dot2_seq_f32(&a, &b).to_bits(),
            crate::accuracy::algorithms::dot2_f32(&a, &b).to_bits()
        );
        for f in [dot2_seq_f32, dot2_unrolled_f32, exact_f32] {
            let rel = ((f(&a, &b) as f64 - exact) / exact.abs().max(1e-30)).abs();
            assert!(rel < 1e-6, "dot2-class kernel off by {rel:e}");
        }
    }

    #[test]
    fn kahan_seq_recovers_lost_bits() {
        // 1e8 + 4096 * 0.5: naive f32 loses every 0.5, Kahan keeps them
        let n = 4097;
        let mut a = vec![0.5f32; n];
        a[0] = 1e8;
        let b = vec![1.0f32; n];
        let naive = naive_f32(&a, &b) as f64;
        let kahan = kahan_seq_f32(&a, &b) as f64;
        let exact = 1e8f64 + 0.5 * 4096.0;
        assert!((kahan - exact).abs() < 16.0, "kahan {kahan}");
        assert!((naive - exact).abs() > 1000.0, "naive should be way off: {naive}");
    }
}
