//! AVX-512 (512-bit) host kernels: 16 f32 / 8 f64 lanes. Post-paper
//! hardware; this is the extension study (does "Kahan for free" still hold
//! when the vector width doubles again? — yes, the ADD-throughput argument
//! is width-blind). Full variant set: naive, Kahan, and Kahan-FMA (the §4
//! trick with `vfmadd`/`vfmsub` on zmm — AVX-512F includes the FMA forms,
//! no separate feature bit needed), in both precisions.
//!
//! Every public entry dispatches on pointer alignment at the call site:
//! pooled-path buffers start on 64-byte boundaries (exactly one zmm), so
//! admitted streams take `_mm512_load_*`; arbitrary caller slices fall
//! back to `loadu`. Aligned and unaligned loads read identical values, so
//! the dispatch never changes results.

use super::{both_aligned, compensated_fold_f32, compensated_fold_f64};

/// zmm width in bytes — the alignment the `load` (vs `loadu`) forms need.
const ZMM_ALIGN: usize = 64;

macro_rules! avx512_wrappers {
    ($naive:ident, $kahan:ident, $kahan_fma:ident, $ty:ty,
     $naive_u:ident, $naive_a:ident, $kahan_u:ident, $kahan_a:ident,
     $fma_u:ident, $fma_a:ident,
     $naive_fb:path, $kahan_fb:path, $fma_fb:path) => {
        pub fn $naive(a: &[$ty], b: &[$ty]) -> $ty {
            if is_x86_feature_detected!("avx512f") {
                if both_aligned(a, b, ZMM_ALIGN) {
                    unsafe { $naive_a(a, b) }
                } else {
                    unsafe { $naive_u(a, b) }
                }
            } else {
                $naive_fb(a, b)
            }
        }

        pub fn $kahan(a: &[$ty], b: &[$ty]) -> $ty {
            if is_x86_feature_detected!("avx512f") {
                if both_aligned(a, b, ZMM_ALIGN) {
                    unsafe { $kahan_a(a, b) }
                } else {
                    unsafe { $kahan_u(a, b) }
                }
            } else {
                $kahan_fb(a, b)
            }
        }

        pub fn $kahan_fma(a: &[$ty], b: &[$ty]) -> $ty {
            if is_x86_feature_detected!("avx512f") {
                if both_aligned(a, b, ZMM_ALIGN) {
                    unsafe { $fma_a(a, b) }
                } else {
                    unsafe { $fma_u(a, b) }
                }
            } else {
                $fma_fb(a, b)
            }
        }
    };
}

avx512_wrappers!(
    naive_f32, kahan_f32, kahan_fma_f32, f32,
    naive_f32_impl, naive_f32_al, kahan_f32_impl, kahan_f32_al,
    kahan_fma_f32_impl, kahan_fma_f32_al,
    super::avx2::naive_f32, super::avx2::kahan_f32, super::avx2::kahan_fma_f32
);
avx512_wrappers!(
    naive_f64, kahan_f64, kahan_fma_f64, f64,
    naive_f64_impl, naive_f64_al, kahan_f64_impl, kahan_f64_al,
    kahan_fma_f64_impl, kahan_fma_f64_al,
    super::avx2::naive_f64, super::avx2::kahan_f64, super::avx2::kahan_fma_f64
);

/// Dot2 wrapper: AVX-512F includes the FMA forms, so availability is the
/// same single feature bit as the other zmm kernels; the fallback is the
/// AVX2 Dot2 (which itself falls back to the unrolled scalar Dot2).
macro_rules! avx512_dot2_wrapper {
    ($name:ident, $ty:ty, $u:ident, $al:ident, $fb:path) => {
        pub fn $name(a: &[$ty], b: &[$ty]) -> $ty {
            if is_x86_feature_detected!("avx512f") {
                if both_aligned(a, b, ZMM_ALIGN) {
                    unsafe { $al(a, b) }
                } else {
                    unsafe { $u(a, b) }
                }
            } else {
                $fb(a, b)
            }
        }
    };
}

avx512_dot2_wrapper!(dot2_f32, f32, dot2_f32_impl, dot2_f32_al, super::avx2::dot2_f32);
avx512_dot2_wrapper!(dot2_f64, f64, dot2_f64_impl, dot2_f64_al, super::avx2::dot2_f64);

/// Two-slot naive body (one zmm pair per slot, 2·L elements per pass),
/// horizontal reduce, scalar tail.
macro_rules! naive_avx512_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $add:ident,
     $zero:ident, $reduce:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let mut s0 = $zero();
        let mut s1 = $zero();
        let mut i = 0usize;
        while i + 2 * $lanes <= n {
            s0 = $add(s0, $mul($load($a.as_ptr().add(i)), $load($b.as_ptr().add(i))));
            s1 = $add(
                s1,
                $mul($load($a.as_ptr().add(i + $lanes)), $load($b.as_ptr().add(i + $lanes))),
            );
            i += 2 * $lanes;
        }
        let mut s = $reduce($add(s0, s1));
        while i < n {
            s += $a[i] * $b[i];
            i += 1;
        }
        s
    }};
}

/// Two-slot Kahan body: per-lane sum + compensation per slot, compensated
/// scalar tail, compensated lane fold.
macro_rules! kahan_avx512_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $sub:ident,
     $add:ident, $zero:ident, $store:ident, $fold:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let mut s0 = $zero();
        let mut c0 = $zero();
        let mut s1 = $zero();
        let mut c1 = $zero();
        let mut i = 0usize;
        while i + 2 * $lanes <= n {
            let p0 = $mul($load($a.as_ptr().add(i)), $load($b.as_ptr().add(i)));
            let y0 = $sub(p0, c0);
            let t0 = $add(s0, y0);
            c0 = $sub($sub(t0, s0), y0);
            s0 = t0;

            let p1 = $mul($load($a.as_ptr().add(i + $lanes)), $load($b.as_ptr().add(i + $lanes)));
            let y1 = $sub(p1, c1);
            let t1 = $add(s1, y1);
            c1 = $sub($sub(t1, s1), y1);
            s1 = t1;
            i += 2 * $lanes;
        }
        let mut sums = [0.0 as $elem; 2 * $lanes];
        let mut comps = [0.0 as $elem; 2 * $lanes];
        $store(sums.as_mut_ptr(), s0);
        $store(sums.as_mut_ptr().add($lanes), s1);
        $store(comps.as_mut_ptr(), c0);
        $store(comps.as_mut_ptr().add($lanes), c1);
        let mut s = 0.0 as $elem;
        let mut c = 0.0 as $elem;
        while i < n {
            let prod = $a[i] * $b[i];
            let y = prod - c;
            let t = s + y;
            c = (t - s) - y;
            s = t;
            i += 1;
        }
        let head = $fold(&sums, &comps);
        $fold(&[head, s], &[0.0 as $elem, c])
    }};
}

/// Four-slot Kahan-FMA body: the compensation subtraction fuses into the
/// product (`y = a*b - c` rounds once) and the accumulate issues as
/// `t = s*1 + y`, so both operations run on the FMA pipes (§4 trick, zmm
/// edition — four slots to cover the longer FMA latency).
macro_rules! kahan_fma_avx512_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $fmadd:ident, $fmsub:ident,
     $sub:ident, $set1:ident, $zero:ident, $store:ident, $fold:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let ones = $set1(1.0);
        let mut s = [$zero(); 4];
        let mut c = [$zero(); 4];
        let mut i = 0usize;
        while i + 4 * $lanes <= n {
            for k in 0..4 {
                let x = $load($a.as_ptr().add(i + k * $lanes));
                let yv = $load($b.as_ptr().add(i + k * $lanes));
                let y = $fmsub(x, yv, c[k]);
                let t = $fmadd(s[k], ones, y);
                c[k] = $sub($sub(t, s[k]), y);
                s[k] = t;
            }
            i += 4 * $lanes;
        }
        let mut sums = [0.0 as $elem; 4 * $lanes];
        let mut comps = [0.0 as $elem; 4 * $lanes];
        for k in 0..4 {
            $store(sums.as_mut_ptr().add(k * $lanes), s[k]);
            $store(comps.as_mut_ptr().add(k * $lanes), c[k]);
        }
        let mut st = 0.0 as $elem;
        let mut ct = 0.0 as $elem;
        while i < n {
            let prod = $a[i] * $b[i];
            let y = prod - ct;
            let t = st + y;
            ct = (t - st) - y;
            st = t;
            i += 1;
        }
        let head = $fold(&sums, &comps);
        $fold(&[head, st], &[0.0 as $elem, ct])
    }};
}

/// Two-slot Ogita–Rump–Oishi Dot2 body (zmm edition of
/// `avx2::dot2_avx_body!`): TwoProd via `vfmsub` + branch-free 2Sum per
/// slot, per-lane correction registers, Dot2 scalar tail, negated-
/// correction compensated fold (the fold subtracts; Dot2 corrections add).
macro_rules! dot2_avx512_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $fmsub:ident,
     $sub:ident, $add:ident, $zero:ident, $store:ident, $fold:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let mut s = [$zero(); 2];
        let mut c = [$zero(); 2];
        let mut i = 0usize;
        while i + 2 * $lanes <= n {
            for k in 0..2 {
                let x = $load($a.as_ptr().add(i + k * $lanes));
                let yv = $load($b.as_ptr().add(i + k * $lanes));
                let p = $mul(x, yv);
                let ep = $fmsub(x, yv, p);
                let t = $add(s[k], p);
                let bb = $sub(t, s[k]);
                let es = $add($sub(s[k], $sub(t, bb)), $sub(p, bb));
                s[k] = t;
                c[k] = $add(c[k], $add(ep, es));
            }
            i += 2 * $lanes;
        }
        let mut sums = [0.0 as $elem; 2 * $lanes];
        let mut comps = [0.0 as $elem; 2 * $lanes];
        for k in 0..2 {
            $store(sums.as_mut_ptr().add(k * $lanes), s[k]);
            $store(comps.as_mut_ptr().add(k * $lanes), c[k]);
        }
        for v in comps.iter_mut() {
            *v = -*v;
        }
        let mut st = 0.0 as $elem;
        let mut ct = 0.0 as $elem;
        while i < n {
            let p = $a[i] * $b[i];
            let ep = $a[i].mul_add($b[i], -p);
            let t = st + p;
            let bb = t - st;
            let es = (st - (t - bb)) + (p - bb);
            st = t;
            ct += ep + es;
        }
        let head = $fold(&sums, &comps);
        $fold(&[head, st], &[0.0 as $elem, -ct])
    }};
}

/// Instantiate the `loadu` and aligned-`load` flavors of one body macro
/// (`$lanes` = zmm lane count for the element type: 16 f32 / 8 f64).
macro_rules! avx512_impl_pair {
    ($body:ident, $unaligned:ident, $aligned:ident, $elem:ty, $lanes:expr,
     $loadu:ident, $loada:ident $(, $rest:ident)*) => {
        #[target_feature(enable = "avx512f")]
        unsafe fn $unaligned(a: &[$elem], b: &[$elem]) -> $elem {
            $body!(a, b, $elem, $lanes, $loadu $(, $rest)*)
        }

        #[target_feature(enable = "avx512f")]
        unsafe fn $aligned(a: &[$elem], b: &[$elem]) -> $elem {
            $body!(a, b, $elem, $lanes, $loada $(, $rest)*)
        }
    };
}

avx512_impl_pair!(
    naive_avx512_body, naive_f32_impl, naive_f32_al, f32, 16,
    _mm512_loadu_ps, _mm512_load_ps,
    _mm512_mul_ps, _mm512_add_ps, _mm512_setzero_ps, _mm512_reduce_add_ps
);
avx512_impl_pair!(
    naive_avx512_body, naive_f64_impl, naive_f64_al, f64, 8,
    _mm512_loadu_pd, _mm512_load_pd,
    _mm512_mul_pd, _mm512_add_pd, _mm512_setzero_pd, _mm512_reduce_add_pd
);
avx512_impl_pair!(
    kahan_avx512_body, kahan_f32_impl, kahan_f32_al, f32, 16,
    _mm512_loadu_ps, _mm512_load_ps,
    _mm512_mul_ps, _mm512_sub_ps, _mm512_add_ps, _mm512_setzero_ps, _mm512_storeu_ps,
    compensated_fold_f32
);
avx512_impl_pair!(
    kahan_avx512_body, kahan_f64_impl, kahan_f64_al, f64, 8,
    _mm512_loadu_pd, _mm512_load_pd,
    _mm512_mul_pd, _mm512_sub_pd, _mm512_add_pd, _mm512_setzero_pd, _mm512_storeu_pd,
    compensated_fold_f64
);
avx512_impl_pair!(
    kahan_fma_avx512_body, kahan_fma_f32_impl, kahan_fma_f32_al, f32, 16,
    _mm512_loadu_ps, _mm512_load_ps,
    _mm512_fmadd_ps, _mm512_fmsub_ps, _mm512_sub_ps, _mm512_set1_ps, _mm512_setzero_ps,
    _mm512_storeu_ps, compensated_fold_f32
);
avx512_impl_pair!(
    kahan_fma_avx512_body, kahan_fma_f64_impl, kahan_fma_f64_al, f64, 8,
    _mm512_loadu_pd, _mm512_load_pd,
    _mm512_fmadd_pd, _mm512_fmsub_pd, _mm512_sub_pd, _mm512_set1_pd, _mm512_setzero_pd,
    _mm512_storeu_pd, compensated_fold_f64
);
avx512_impl_pair!(
    dot2_avx512_body, dot2_f32_impl, dot2_f32_al, f32, 16,
    _mm512_loadu_ps, _mm512_load_ps,
    _mm512_mul_ps, _mm512_fmsub_ps, _mm512_sub_ps, _mm512_add_ps, _mm512_setzero_ps,
    _mm512_storeu_ps, compensated_fold_f32
);
avx512_impl_pair!(
    dot2_avx512_body, dot2_f64_impl, dot2_f64_al, f64, 8,
    _mm512_loadu_pd, _mm512_load_pd,
    _mm512_mul_pd, _mm512_fmsub_pd, _mm512_sub_pd, _mm512_add_pd, _mm512_setzero_pd,
    _mm512_storeu_pd, compensated_fold_f64
);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_cases_any_isa() {
        // runs the avx512 path on capable hosts, the avx2 fallback elsewhere
        let a: Vec<f32> = (1..=200).map(|i| i as f32).collect();
        let b = vec![1.0f32; 200];
        assert_eq!(naive_f32(&a, &b), 20100.0);
        assert_eq!(kahan_f32(&a, &b), 20100.0);
        assert_eq!(kahan_fma_f32(&a, &b), 20100.0);
        assert_eq!(dot2_f32(&a, &b), 20100.0);
        let a: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        let b = vec![1.0f64; 200];
        assert_eq!(naive_f64(&a, &b), 20100.0);
        assert_eq!(kahan_f64(&a, &b), 20100.0);
        assert_eq!(kahan_fma_f64(&a, &b), 20100.0);
        assert_eq!(dot2_f64(&a, &b), 20100.0);
    }

    #[test]
    fn tails() {
        for n in [5usize, 17, 33, 65, 129] {
            let a = vec![1.5f32; n];
            let b = vec![2.0f32; n];
            assert_eq!(kahan_f32(&a, &b), 3.0 * n as f32, "n={n}");
            assert_eq!(kahan_fma_f32(&a, &b), 3.0 * n as f32, "n={n}");
            assert_eq!(dot2_f32(&a, &b), 3.0 * n as f32, "n={n}");
            let a = vec![1.5f64; n];
            let b = vec![2.0f64; n];
            assert_eq!(kahan_f64(&a, &b), 3.0 * n as f64, "n={n}");
            assert_eq!(naive_f64(&a, &b), 3.0 * n as f64, "n={n}");
        }
    }

    /// Aligned-load dispatch must not change values: compare every variant
    /// on a 64-byte-aligned view of the data vs a DETERMINISTICALLY
    /// misaligned view of the same values (an offset into an
    /// over-allocated copy, chosen so the head provably misses every
    /// 64-byte boundary — a plain `Vec` head alone could land aligned by
    /// allocator luck, making the comparison vacuous).
    #[test]
    fn aligned_and_unaligned_paths_agree() {
        let pool = crate::engine::BufferPool::new();
        let n = 203;
        let src: Vec<f32> = (0..n).map(|i| (i as f32).sin()).collect();
        let a = pool.admit(&src);
        let b = pool.admit(&src);
        assert_eq!(a.addr() % 64, 0);
        let mis = crate::bench::kernels::tests_support::misaligned_copy(&src, 64);
        for (f, name) in [
            (naive_f32 as fn(&[f32], &[f32]) -> f32, "naive"),
            (kahan_f32, "kahan"),
            (kahan_fma_f32, "kahan-fma"),
            (dot2_f32, "dot2"),
        ] {
            let via_aligned = f(a.as_slice(), b.as_slice());
            let via_loadu = f(mis.as_slice(), mis.as_slice());
            assert_eq!(via_aligned.to_bits(), via_loadu.to_bits(), "{name}");
        }
    }
}
