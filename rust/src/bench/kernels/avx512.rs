//! AVX-512 (512-bit) host kernels: 16 f32 lanes. Post-paper hardware; this
//! is the extension study (does "Kahan for free" still hold when the vector
//! width doubles again? — yes, the ADD-throughput argument is width-blind).

use super::compensated_fold_f32;

pub fn naive_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx512f") {
        unsafe { naive_f32_impl(a, b) }
    } else {
        super::avx2::naive_f32(a, b)
    }
}

pub fn kahan_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx512f") {
        unsafe { kahan_f32_impl(a, b) }
    } else {
        super::avx2::kahan_f32(a, b)
    }
}

#[target_feature(enable = "avx512f")]
unsafe fn naive_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let n = a.len().min(b.len());
    let mut s0 = _mm512_setzero_ps();
    let mut s1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        s0 = _mm512_add_ps(
            s0,
            _mm512_mul_ps(_mm512_loadu_ps(a.as_ptr().add(i)), _mm512_loadu_ps(b.as_ptr().add(i))),
        );
        s1 = _mm512_add_ps(
            s1,
            _mm512_mul_ps(
                _mm512_loadu_ps(a.as_ptr().add(i + 16)),
                _mm512_loadu_ps(b.as_ptr().add(i + 16)),
            ),
        );
        i += 32;
    }
    let mut s = _mm512_reduce_add_ps(_mm512_add_ps(s0, s1));
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[target_feature(enable = "avx512f")]
unsafe fn kahan_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    const L: usize = 16;
    let n = a.len().min(b.len());
    let mut s0 = _mm512_setzero_ps();
    let mut c0 = _mm512_setzero_ps();
    let mut s1 = _mm512_setzero_ps();
    let mut c1 = _mm512_setzero_ps();
    let mut i = 0usize;
    while i + 2 * L <= n {
        let p0 = _mm512_mul_ps(_mm512_loadu_ps(a.as_ptr().add(i)), _mm512_loadu_ps(b.as_ptr().add(i)));
        let y0 = _mm512_sub_ps(p0, c0);
        let t0 = _mm512_add_ps(s0, y0);
        c0 = _mm512_sub_ps(_mm512_sub_ps(t0, s0), y0);
        s0 = t0;

        let p1 = _mm512_mul_ps(
            _mm512_loadu_ps(a.as_ptr().add(i + L)),
            _mm512_loadu_ps(b.as_ptr().add(i + L)),
        );
        let y1 = _mm512_sub_ps(p1, c1);
        let t1 = _mm512_add_ps(s1, y1);
        c1 = _mm512_sub_ps(_mm512_sub_ps(t1, s1), y1);
        s1 = t1;
        i += 2 * L;
    }
    let mut sums = [0.0f32; 2 * L];
    let mut comps = [0.0f32; 2 * L];
    _mm512_storeu_ps(sums.as_mut_ptr(), s0);
    _mm512_storeu_ps(sums.as_mut_ptr().add(L), s1);
    _mm512_storeu_ps(comps.as_mut_ptr(), c0);
    _mm512_storeu_ps(comps.as_mut_ptr().add(L), c1);
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    while i < n {
        let prod = a[i] * b[i];
        let y = prod - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
        i += 1;
    }
    let head = compensated_fold_f32(&sums, &comps);
    compensated_fold_f32(&[head, s], &[0.0, c])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_cases_any_isa() {
        // runs the avx512 path on capable hosts, the avx2 fallback elsewhere
        let a: Vec<f32> = (1..=200).map(|i| i as f32).collect();
        let b = vec![1.0f32; 200];
        assert_eq!(naive_f32(&a, &b), 20100.0);
        assert_eq!(kahan_f32(&a, &b), 20100.0);
    }

    #[test]
    fn tails() {
        for n in [5usize, 17, 33, 65] {
            let a = vec![1.5f32; n];
            let b = vec![2.0f32; n];
            assert_eq!(kahan_f32(&a, &b), 3.0 * n as f32, "n={n}");
        }
    }
}
