//! Fused multi-dot kernels: execute a batch of independent small dot
//! products in ONE kernel call, sharing loop/dispatch/reduction overhead
//! across requests.
//!
//! The paper's small-N regime is bounded by per-iteration and per-call
//! overhead, not arithmetic; the CCPE follow-up's fix at the instruction
//! level — more independent accumulator chains via unrolling — applies one
//! level up too: stripe *requests* across the unroll slots. Each request
//! keeps its own accumulator state (sum + compensation), so a batch of B
//! short dependency chains fills the ADD/FMA pipes that a single short
//! chain leaves idle, while loop control and the call prologue are paid
//! once instead of B times.
//!
//! # The batching invariant
//!
//! **Batching never changes bits.** Every fused kernel here is paired (via
//! [`BatchKernel::matches`]) with one single-dot kernel from the main
//! registry, and produces, for every request in the batch, *exactly* the
//! value that single-dot kernel produces for that request alone. This holds
//! by construction: interleaving only reorders operations *between*
//! requests, never within one — each request's own operation sequence
//! (slot structure, iteration order, tail handling, reduction order) is
//! copied verbatim from its single-dot twin, and IEEE arithmetic on
//! independent data is oblivious to interleaving. Batches with leftover
//! requests (batch size not a multiple of the interleave width) finish by
//! calling the single-dot twin directly. Property-tested on
//! Ogita–Rump–Oishi ill-conditioned inputs below and in
//! `rust/tests/test_batch.rs`.
//!
//! The engine only ever *selects* a fused kernel through the autotuned
//! dispatch table (`engine::autotune`), which pairs it with the single
//! winner of the same `(Precision, SizeClass)` cell and keeps it only where
//! calibration shows fusion winning — so correctness never depends on the
//! performance question.

use super::{avx2, scalar, compensated_fold_f32, compensated_fold_f64};

/// A fused multi-dot entry point: `f(pairs, out)` writes `out[i] = dot of
/// pairs[i]` for every `i` (slices must be the same length).
pub type BatchFnF32 = fn(&[(&[f32], &[f32])], &mut [f32]);
pub type BatchFnF64 = fn(&[(&[f64], &[f64])], &mut [f64]);

/// One fused kernel entry point (one per precision).
#[derive(Clone, Copy)]
pub enum BatchKernelFn {
    F32(BatchFnF32),
    F64(BatchFnF64),
}

/// Registry entry: one fused multi-dot kernel, tied to the single-dot
/// kernel it reproduces bit-for-bit per request.
#[derive(Clone, Copy)]
pub struct BatchKernel {
    pub name: &'static str,
    /// name of the single-dot registry kernel each per-request result is
    /// bit-identical to (the pairing the dispatch table relies on)
    pub matches: &'static str,
    /// whether the host CPU supports the required ISA extension
    pub available: bool,
    pub f: BatchKernelFn,
}

impl BatchKernel {
    pub fn call_f32(&self, pairs: &[(&[f32], &[f32])], out: &mut [f32]) {
        match self.f {
            BatchKernelFn::F32(f) => f(pairs, out),
            BatchKernelFn::F64(_) => panic!("{} is a f64 batch kernel", self.name),
        }
    }

    pub fn call_f64(&self, pairs: &[(&[f64], &[f64])], out: &mut [f64]) {
        match self.f {
            BatchKernelFn::F64(f) => f(pairs, out),
            BatchKernelFn::F32(_) => panic!("{} is a f32 batch kernel", self.name),
        }
    }
}

/// Serial fallback executor: one single-dot call per pair. This is what a
/// batch degrades to when no fused kernel exists (or calibration showed
/// fusion losing) — the handoff/admission coalescing above this layer
/// still applies, only the kernel fusion is skipped.
pub fn serial_f32(f: fn(&[f32], &[f32]) -> f32, pairs: &[(&[f32], &[f32])], out: &mut [f32]) {
    assert_eq!(pairs.len(), out.len());
    for (o, &(a, b)) in out.iter_mut().zip(pairs) {
        *o = f(a, b);
    }
}

pub fn serial_f64(f: fn(&[f64], &[f64]) -> f64, pairs: &[(&[f64], &[f64])], out: &mut [f64]) {
    assert_eq!(pairs.len(), out.len());
    for (o, &(a, b)) in out.iter_mut().zip(pairs) {
        *o = f(a, b);
    }
}

/// One sequential-Kahan step (Fig. 1b) — identical to the body of
/// `scalar::kahan_seq_*`.
macro_rules! kahan_step {
    ($a:ident, $b:ident, $i:expr, $s:ident, $c:ident) => {{
        let prod = $a[$i] * $b[$i];
        let y = prod - $c;
        let t = $s + y;
        $c = (t - $s) - y;
        $s = t;
    }};
}

/// 4-way fused twin of the strictly sequential Kahan dot
/// (`kahan-compiler-*`): four requests advance in lock step through one
/// loop, each on its own `(s, c)` chain. The single kernel is a *single*
/// latency-bound dependency chain — striping four independent requests
/// across the iteration is exactly the paper's modulo-unrolling win, paid
/// for by other requests instead of other slots.
macro_rules! kahan_seq_batch_impl {
    ($name:ident, $ty:ty, $single:path) => {
        pub fn $name(pairs: &[(&[$ty], &[$ty])], out: &mut [$ty]) {
            assert_eq!(pairs.len(), out.len());
            let mut g = 0usize;
            while g + 4 <= pairs.len() {
                let (a0, b0) = pairs[g];
                let (a1, b1) = pairs[g + 1];
                let (a2, b2) = pairs[g + 2];
                let (a3, b3) = pairs[g + 3];
                let n0 = a0.len().min(b0.len());
                let n1 = a1.len().min(b1.len());
                let n2 = a2.len().min(b2.len());
                let n3 = a3.len().min(b3.len());
                let m = n0.min(n1).min(n2).min(n3);
                let (mut s0, mut c0) = (0.0 as $ty, 0.0 as $ty);
                let (mut s1, mut c1) = (0.0 as $ty, 0.0 as $ty);
                let (mut s2, mut c2) = (0.0 as $ty, 0.0 as $ty);
                let (mut s3, mut c3) = (0.0 as $ty, 0.0 as $ty);
                for i in 0..m {
                    kahan_step!(a0, b0, i, s0, c0);
                    kahan_step!(a1, b1, i, s1, c1);
                    kahan_step!(a2, b2, i, s2, c2);
                    kahan_step!(a3, b3, i, s3, c3);
                }
                // finish each request alone: the continuation of its own
                // (unchanged) operation sequence
                for i in m..n0 {
                    kahan_step!(a0, b0, i, s0, c0);
                }
                for i in m..n1 {
                    kahan_step!(a1, b1, i, s1, c1);
                }
                for i in m..n2 {
                    kahan_step!(a2, b2, i, s2, c2);
                }
                for i in m..n3 {
                    kahan_step!(a3, b3, i, s3, c3);
                }
                out[g] = s0;
                out[g + 1] = s1;
                out[g + 2] = s2;
                out[g + 3] = s3;
                g += 4;
            }
            // leftover requests run the single-dot twin itself
            while g < pairs.len() {
                let (a, b) = pairs[g];
                out[g] = $single(a, b);
                g += 1;
            }
        }
    };
}

kahan_seq_batch_impl!(kahan_seq_batch_f32, f32, scalar::kahan_seq_f32);
kahan_seq_batch_impl!(kahan_seq_batch_f64, f64, scalar::kahan_seq_f64);

/// 4-way fused twin of the sequential naive dot (`naive-scalar-*`): same
/// striping as the Kahan twin, single accumulator per request.
macro_rules! naive_seq_batch_impl {
    ($name:ident, $ty:ty, $single:path) => {
        pub fn $name(pairs: &[(&[$ty], &[$ty])], out: &mut [$ty]) {
            assert_eq!(pairs.len(), out.len());
            let mut g = 0usize;
            while g + 4 <= pairs.len() {
                let (a0, b0) = pairs[g];
                let (a1, b1) = pairs[g + 1];
                let (a2, b2) = pairs[g + 2];
                let (a3, b3) = pairs[g + 3];
                let n0 = a0.len().min(b0.len());
                let n1 = a1.len().min(b1.len());
                let n2 = a2.len().min(b2.len());
                let n3 = a3.len().min(b3.len());
                let m = n0.min(n1).min(n2).min(n3);
                let mut s0 = 0.0 as $ty;
                let mut s1 = 0.0 as $ty;
                let mut s2 = 0.0 as $ty;
                let mut s3 = 0.0 as $ty;
                for i in 0..m {
                    s0 += a0[i] * b0[i];
                    s1 += a1[i] * b1[i];
                    s2 += a2[i] * b2[i];
                    s3 += a3[i] * b3[i];
                }
                for i in m..n0 {
                    s0 += a0[i] * b0[i];
                }
                for i in m..n1 {
                    s1 += a1[i] * b1[i];
                }
                for i in m..n2 {
                    s2 += a2[i] * b2[i];
                }
                for i in m..n3 {
                    s3 += a3[i] * b3[i];
                }
                out[g] = s0;
                out[g + 1] = s1;
                out[g + 2] = s2;
                out[g + 3] = s3;
                g += 4;
            }
            while g < pairs.len() {
                let (a, b) = pairs[g];
                out[g] = $single(a, b);
                g += 1;
            }
        }
    };
}

naive_seq_batch_impl!(naive_seq_batch_f32, f32, scalar::naive_f32);
naive_seq_batch_impl!(naive_seq_batch_f64, f64, scalar::naive_f64);

/// One 4-slot AVX2 Kahan iteration over `$a/$b` at offset `$i` — the exact
/// loop body of `avx2::kahan_avx_body!` (slot order 0→3, same op order per
/// slot), with accumulators held in 4-element arrays.
macro_rules! kahan_iter4 {
    ($a:ident, $b:ident, $i:expr, $s:ident, $c:ident, $lanes:expr,
     $load:ident, $mul:ident, $sub:ident, $add:ident) => {{
        let p0 = $mul($load($a.as_ptr().add($i)), $load($b.as_ptr().add($i)));
        let y0 = $sub(p0, $c[0]);
        let t0 = $add($s[0], y0);
        $c[0] = $sub($sub(t0, $s[0]), y0);
        $s[0] = t0;

        let p1 = $mul($load($a.as_ptr().add($i + $lanes)), $load($b.as_ptr().add($i + $lanes)));
        let y1 = $sub(p1, $c[1]);
        let t1 = $add($s[1], y1);
        $c[1] = $sub($sub(t1, $s[1]), y1);
        $s[1] = t1;

        let p2 = $mul(
            $load($a.as_ptr().add($i + 2 * $lanes)),
            $load($b.as_ptr().add($i + 2 * $lanes)),
        );
        let y2 = $sub(p2, $c[2]);
        let t2 = $add($s[2], y2);
        $c[2] = $sub($sub(t2, $s[2]), y2);
        $s[2] = t2;

        let p3 = $mul(
            $load($a.as_ptr().add($i + 3 * $lanes)),
            $load($b.as_ptr().add($i + 3 * $lanes)),
        );
        let y3 = $sub(p3, $c[3]);
        let t3 = $add($s[3], y3);
        $c[3] = $sub($sub(t3, $s[3]), y3);
        $s[3] = t3;
    }};
}

/// The exact epilogue of `avx2::kahan_avx_body!` for one request: store the
/// 4 slots, run the compensated scalar tail from `$i`, then the two
/// compensated folds, in the single kernel's order.
macro_rules! kahan_finish {
    ($a:ident, $b:ident, $i:ident, $n:expr, $s:ident, $c:ident, $elem:ty, $lanes:expr,
     $store:ident, $fold:ident) => {{
        let mut sums = [0.0 as $elem; 4 * $lanes];
        let mut comps = [0.0 as $elem; 4 * $lanes];
        $store(sums.as_mut_ptr(), $s[0]);
        $store(sums.as_mut_ptr().add($lanes), $s[1]);
        $store(sums.as_mut_ptr().add(2 * $lanes), $s[2]);
        $store(sums.as_mut_ptr().add(3 * $lanes), $s[3]);
        $store(comps.as_mut_ptr(), $c[0]);
        $store(comps.as_mut_ptr().add($lanes), $c[1]);
        $store(comps.as_mut_ptr().add(2 * $lanes), $c[2]);
        $store(comps.as_mut_ptr().add(3 * $lanes), $c[3]);
        let mut st = 0.0 as $elem;
        let mut ct = 0.0 as $elem;
        while $i < $n {
            kahan_step!($a, $b, $i, st, ct);
            $i += 1;
        }
        let head = $fold(&sums, &comps);
        $fold(&[head, st], &[0.0 as $elem, ct])
    }};
}

/// Two requests through the 4-slot AVX2 Kahan body with interleaved main
/// loops. While both requests have a full 4-slot stripe left, one combined
/// iteration advances both (8 independent chains in flight); once one runs
/// short, the other finishes alone. Either way each request's own op
/// sequence equals `avx2::kahan_f32/f64` exactly.
macro_rules! kahan_avx2_x2_impl {
    ($name:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $sub:ident,
     $add:ident, $zero:ident, $store:ident, $fold:ident) => {
        #[target_feature(enable = "avx2")]
        unsafe fn $name(
            a0: &[$elem],
            b0: &[$elem],
            a1: &[$elem],
            b1: &[$elem],
        ) -> ($elem, $elem) {
            use core::arch::x86_64::*;
            let n0 = a0.len().min(b0.len());
            let n1 = a1.len().min(b1.len());
            let mut s0 = [$zero(); 4];
            let mut c0 = [$zero(); 4];
            let mut s1 = [$zero(); 4];
            let mut c1 = [$zero(); 4];
            let mut i0 = 0usize;
            let mut i1 = 0usize;
            while i0 + 4 * $lanes <= n0 && i1 + 4 * $lanes <= n1 {
                kahan_iter4!(a0, b0, i0, s0, c0, $lanes, $load, $mul, $sub, $add);
                kahan_iter4!(a1, b1, i1, s1, c1, $lanes, $load, $mul, $sub, $add);
                i0 += 4 * $lanes;
                i1 += 4 * $lanes;
            }
            while i0 + 4 * $lanes <= n0 {
                kahan_iter4!(a0, b0, i0, s0, c0, $lanes, $load, $mul, $sub, $add);
                i0 += 4 * $lanes;
            }
            while i1 + 4 * $lanes <= n1 {
                kahan_iter4!(a1, b1, i1, s1, c1, $lanes, $load, $mul, $sub, $add);
                i1 += 4 * $lanes;
            }
            let r0 = kahan_finish!(a0, b0, i0, n0, s0, c0, $elem, $lanes, $store, $fold);
            let r1 = kahan_finish!(a1, b1, i1, n1, s1, c1, $elem, $lanes, $store, $fold);
            (r0, r1)
        }
    };
}

kahan_avx2_x2_impl!(
    kahan_avx2_x2_f32,
    f32,
    8,
    _mm256_loadu_ps,
    _mm256_mul_ps,
    _mm256_sub_ps,
    _mm256_add_ps,
    _mm256_setzero_ps,
    _mm256_storeu_ps,
    compensated_fold_f32
);
kahan_avx2_x2_impl!(
    kahan_avx2_x2_f64,
    f64,
    4,
    _mm256_loadu_pd,
    _mm256_mul_pd,
    _mm256_sub_pd,
    _mm256_add_pd,
    _mm256_setzero_pd,
    _mm256_storeu_pd,
    compensated_fold_f64
);

/// One 4-slot AVX2 naive iteration — the exact loop body of
/// `avx2::naive_f32_impl`/`naive_f64_impl` with accumulators in an array.
macro_rules! naive_iter4 {
    ($a:ident, $b:ident, $i:expr, $s:ident, $lanes:expr, $load:ident, $mul:ident, $add:ident) => {{
        $s[0] = $add($s[0], $mul($load($a.as_ptr().add($i)), $load($b.as_ptr().add($i))));
        $s[1] = $add(
            $s[1],
            $mul($load($a.as_ptr().add($i + $lanes)), $load($b.as_ptr().add($i + $lanes))),
        );
        $s[2] = $add(
            $s[2],
            $mul(
                $load($a.as_ptr().add($i + 2 * $lanes)),
                $load($b.as_ptr().add($i + 2 * $lanes)),
            ),
        );
        $s[3] = $add(
            $s[3],
            $mul(
                $load($a.as_ptr().add($i + 3 * $lanes)),
                $load($b.as_ptr().add($i + 3 * $lanes)),
            ),
        );
    }};
}

/// The exact epilogue of `avx2::naive_f32_impl`/`naive_f64_impl` for one
/// request: store the 4 slots, in-order lane sum, scalar tail.
macro_rules! naive_finish {
    ($a:ident, $b:ident, $i:ident, $n:expr, $s:ident, $elem:ty, $lanes:expr, $store:ident) => {{
        let mut lanes = [0.0 as $elem; 4 * $lanes];
        $store(lanes.as_mut_ptr(), $s[0]);
        $store(lanes.as_mut_ptr().add($lanes), $s[1]);
        $store(lanes.as_mut_ptr().add(2 * $lanes), $s[2]);
        $store(lanes.as_mut_ptr().add(3 * $lanes), $s[3]);
        let mut acc: $elem = lanes.iter().sum();
        while $i < $n {
            acc += $a[$i] * $b[$i];
            $i += 1;
        }
        acc
    }};
}

/// Two requests through the 4-slot AVX2 naive body (interleaved main
/// loops); per-request op sequence equals `avx2::naive_f32/f64` exactly,
/// including the in-order lane sum of the epilogue.
macro_rules! naive_avx2_x2_impl {
    ($name:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $add:ident,
     $zero:ident, $store:ident) => {
        #[target_feature(enable = "avx2")]
        unsafe fn $name(
            a0: &[$elem],
            b0: &[$elem],
            a1: &[$elem],
            b1: &[$elem],
        ) -> ($elem, $elem) {
            use core::arch::x86_64::*;
            let n0 = a0.len().min(b0.len());
            let n1 = a1.len().min(b1.len());
            let mut s0 = [$zero(); 4];
            let mut s1 = [$zero(); 4];
            let mut i0 = 0usize;
            let mut i1 = 0usize;
            while i0 + 4 * $lanes <= n0 && i1 + 4 * $lanes <= n1 {
                naive_iter4!(a0, b0, i0, s0, $lanes, $load, $mul, $add);
                naive_iter4!(a1, b1, i1, s1, $lanes, $load, $mul, $add);
                i0 += 4 * $lanes;
                i1 += 4 * $lanes;
            }
            while i0 + 4 * $lanes <= n0 {
                naive_iter4!(a0, b0, i0, s0, $lanes, $load, $mul, $add);
                i0 += 4 * $lanes;
            }
            while i1 + 4 * $lanes <= n1 {
                naive_iter4!(a1, b1, i1, s1, $lanes, $load, $mul, $add);
                i1 += 4 * $lanes;
            }
            let r0 = naive_finish!(a0, b0, i0, n0, s0, $elem, $lanes, $store);
            let r1 = naive_finish!(a1, b1, i1, n1, s1, $elem, $lanes, $store);
            (r0, r1)
        }
    };
}

naive_avx2_x2_impl!(
    naive_avx2_x2_f32,
    f32,
    8,
    _mm256_loadu_ps,
    _mm256_mul_ps,
    _mm256_add_ps,
    _mm256_setzero_ps,
    _mm256_storeu_ps
);
naive_avx2_x2_impl!(
    naive_avx2_x2_f64,
    f64,
    4,
    _mm256_loadu_pd,
    _mm256_mul_pd,
    _mm256_add_pd,
    _mm256_setzero_pd,
    _mm256_storeu_pd
);

/// Public wrapper over a pairwise-fused AVX2 twin: requests are taken two
/// at a time; a trailing odd request (and the no-AVX2 fallback) calls the
/// single-dot twin itself, so results are bit-identical in every case.
macro_rules! avx2_batch_wrapper {
    ($name:ident, $ty:ty, $x2:ident, $single:path) => {
        pub fn $name(pairs: &[(&[$ty], &[$ty])], out: &mut [$ty]) {
            assert_eq!(pairs.len(), out.len());
            if !is_x86_feature_detected!("avx2") {
                // same values as the single kernel's own fallback chain
                for (o, &(a, b)) in out.iter_mut().zip(pairs) {
                    *o = $single(a, b);
                }
                return;
            }
            let mut g = 0usize;
            while g + 2 <= pairs.len() {
                let (a0, b0) = pairs[g];
                let (a1, b1) = pairs[g + 1];
                let (r0, r1) = unsafe { $x2(a0, b0, a1, b1) };
                out[g] = r0;
                out[g + 1] = r1;
                g += 2;
            }
            if g < pairs.len() {
                let (a, b) = pairs[g];
                out[g] = $single(a, b);
            }
        }
    };
}

avx2_batch_wrapper!(kahan_avx2_batch_f32, f32, kahan_avx2_x2_f32, avx2::kahan_f32);
avx2_batch_wrapper!(kahan_avx2_batch_f64, f64, kahan_avx2_x2_f64, avx2::kahan_f64);
avx2_batch_wrapper!(naive_avx2_batch_f32, f32, naive_avx2_x2_f32, avx2::naive_f32);
avx2_batch_wrapper!(naive_avx2_batch_f64, f64, naive_avx2_x2_f64, avx2::naive_f64);

/// Detect CPU features and build the batch registry (runs once; see
/// [`batch_registry_static`]).
fn detect_batch_registry() -> Vec<BatchKernel> {
    let avx2 = is_x86_feature_detected!("avx2");
    vec![
        // --- f32 ---
        BatchKernel { name: "batch4-kahan-compiler-SP", matches: "kahan-compiler-SP", available: true, f: BatchKernelFn::F32(kahan_seq_batch_f32) },
        BatchKernel { name: "batch4-naive-scalar-SP", matches: "naive-scalar-SP", available: true, f: BatchKernelFn::F32(naive_seq_batch_f32) },
        BatchKernel { name: "batch2-kahan-AVX2-SP", matches: "kahan-AVX2-SP", available: avx2, f: BatchKernelFn::F32(kahan_avx2_batch_f32) },
        BatchKernel { name: "batch2-naive-AVX2-SP", matches: "naive-AVX2-SP", available: avx2, f: BatchKernelFn::F32(naive_avx2_batch_f32) },
        // --- f64 ---
        BatchKernel { name: "batch4-kahan-compiler-DP", matches: "kahan-compiler-DP", available: true, f: BatchKernelFn::F64(kahan_seq_batch_f64) },
        BatchKernel { name: "batch4-naive-scalar-DP", matches: "naive-scalar-DP", available: true, f: BatchKernelFn::F64(naive_seq_batch_f64) },
        BatchKernel { name: "batch2-kahan-AVX2-DP", matches: "kahan-AVX2-DP", available: avx2, f: BatchKernelFn::F64(kahan_avx2_batch_f64) },
        BatchKernel { name: "batch2-naive-AVX2-DP", matches: "naive-AVX2-DP", available: avx2, f: BatchKernelFn::F64(naive_avx2_batch_f64) },
    ]
}

/// The process-wide fused-kernel registry (feature detection runs once).
pub fn batch_registry_static() -> &'static [BatchKernel] {
    static REGISTRY: std::sync::OnceLock<Vec<BatchKernel>> = std::sync::OnceLock::new();
    REGISTRY.get_or_init(detect_batch_registry)
}

/// The fused twin of a single-dot registry kernel, if one exists and the
/// host supports it.
pub fn batch_for(single_name: &str) -> Option<&'static BatchKernel> {
    batch_registry_static().iter().find(|k| k.available && k.matches == single_name)
}

#[cfg(test)]
mod tests {
    use super::super::{by_name, KernelFn};
    use super::*;
    use crate::accuracy::{gen_dot_f32, gen_dot_f64};
    use crate::util::Rng;

    fn single_f32(name: &str) -> fn(&[f32], &[f32]) -> f32 {
        match by_name(name).expect("matched single kernel must exist").f {
            KernelFn::F32(f) => f,
            KernelFn::F64(_) => panic!("{name} is not f32"),
        }
    }

    fn single_f64(name: &str) -> fn(&[f64], &[f64]) -> f64 {
        match by_name(name).expect("matched single kernel must exist").f {
            KernelFn::F64(f) => f,
            KernelFn::F32(_) => panic!("{name} is not f64"),
        }
    }

    /// THE invariant: every available fused kernel is bit-identical, per
    /// request, to its single-dot twin — on ill-conditioned
    /// Ogita–Rump–Oishi inputs, random lengths (tails included), and every
    /// batch size 1..=6 (odd sizes exercise the leftover path).
    #[test]
    fn fused_kernels_bit_identical_to_single_twin() {
        crate::util::prop::check("batch-kernels-bit-identical", 25, |rng| {
            let bsz = 1 + rng.below(6) as usize;
            let mut pairs_f32: Vec<(Vec<f32>, Vec<f32>)> = Vec::new();
            let mut pairs_f64: Vec<(Vec<f64>, Vec<f64>)> = Vec::new();
            for _ in 0..bsz {
                // mix ill-conditioned constructions with awkward lengths
                if rng.uniform() < 0.5 {
                    let n = 6 + rng.below(600) as usize;
                    let (a, b, _, _) = gen_dot_f32(n, 1e6, rng);
                    pairs_f32.push((a, b));
                    let n = 6 + rng.below(600) as usize;
                    let (a, b, _, _) = gen_dot_f64(n, 1e10, rng);
                    pairs_f64.push((a, b));
                } else {
                    let n = rng.below(130) as usize; // covers 0, 1, tails
                    pairs_f32.push((rng.normal_f32_vec(n), rng.normal_f32_vec(n)));
                    let n = rng.below(70) as usize;
                    pairs_f64.push((rng.normal_f64_vec(n), rng.normal_f64_vec(n)));
                }
            }
            let view32: Vec<(&[f32], &[f32])> =
                pairs_f32.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
            let view64: Vec<(&[f64], &[f64])> =
                pairs_f64.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
            for k in batch_registry_static().iter().filter(|k| k.available) {
                match k.f {
                    BatchKernelFn::F32(_) => {
                        let f = single_f32(k.matches);
                        let mut out = vec![0.0f32; view32.len()];
                        k.call_f32(&view32, &mut out);
                        for (i, &(a, b)) in view32.iter().enumerate() {
                            let want = f(a, b);
                            crate::prop_assert!(
                                out[i].to_bits() == want.to_bits(),
                                "{} req {i}/{bsz} (n={}): {:e} vs single {:e}",
                                k.name,
                                a.len(),
                                out[i],
                                want
                            );
                        }
                    }
                    BatchKernelFn::F64(_) => {
                        let f = single_f64(k.matches);
                        let mut out = vec![0.0f64; view64.len()];
                        k.call_f64(&view64, &mut out);
                        for (i, &(a, b)) in view64.iter().enumerate() {
                            let want = f(a, b);
                            crate::prop_assert!(
                                out[i].to_bits() == want.to_bits(),
                                "{} req {i}/{bsz} (n={}): {:e} vs single {:e}",
                                k.name,
                                a.len(),
                                out[i],
                                want
                            );
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn serial_fallback_is_trivially_identical() {
        let mut rng = Rng::new(91);
        let pairs: Vec<(Vec<f32>, Vec<f32>)> =
            (0..5).map(|_| (rng.normal_f32_vec(100), rng.normal_f32_vec(100))).collect();
        let view: Vec<(&[f32], &[f32])> =
            pairs.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
        let mut out = vec![0.0f32; 5];
        serial_f32(scalar::kahan_unrolled_f32, &view, &mut out);
        for (i, &(a, b)) in view.iter().enumerate() {
            assert_eq!(out[i].to_bits(), scalar::kahan_unrolled_f32(a, b).to_bits());
        }
    }

    #[test]
    fn every_fused_kernel_matches_a_registered_single_kernel() {
        for k in batch_registry_static() {
            let single = by_name(k.matches)
                .unwrap_or_else(|| panic!("{}: no single kernel named {}", k.name, k.matches));
            // precision of the pairing must line up
            match (k.f, single.f) {
                (BatchKernelFn::F32(_), KernelFn::F32(_)) => {}
                (BatchKernelFn::F64(_), KernelFn::F64(_)) => {}
                _ => panic!("{}: precision mismatch with {}", k.name, k.matches),
            }
            // lookup by the single name finds this kernel when available
            if k.available {
                assert!(batch_for(k.matches).is_some());
            }
        }
        assert!(batch_for("bogus-kernel").is_none());
    }

    #[test]
    fn exact_small_cases() {
        let a: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let b = vec![1.0f32; 100];
        let pairs: Vec<(&[f32], &[f32])> =
            (0..5).map(|_| (a.as_slice(), b.as_slice())).collect();
        let mut out = vec![0.0f32; 5];
        kahan_seq_batch_f32(&pairs, &mut out);
        assert_eq!(out, vec![5050.0; 5]);
        kahan_avx2_batch_f32(&pairs, &mut out);
        assert_eq!(out, vec![5050.0; 5]);
        naive_avx2_batch_f32(&pairs, &mut out);
        assert_eq!(out, vec![5050.0; 5]);
    }
}
