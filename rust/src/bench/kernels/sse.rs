//! SSE (128-bit) host kernels: 4 f32 / 2 f64 lanes, two accumulator slots.

use super::{compensated_fold_f32, compensated_fold_f64};

/// Safe wrapper; falls back to the unrolled scalar kernel if SSE4.2 is
/// somehow absent (it never is on x86_64, but the registry checks anyway).
pub fn kahan_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("sse4.2") {
        unsafe { kahan_f32_impl(a, b) }
    } else {
        super::scalar::kahan_unrolled_f32(a, b)
    }
}

pub fn kahan_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("sse4.2") {
        unsafe { kahan_f64_impl(a, b) }
    } else {
        super::scalar::kahan_unrolled_f64(a, b)
    }
}

#[target_feature(enable = "sse4.2")]
unsafe fn kahan_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let n = a.len().min(b.len());
    // two slots x 4 lanes: 8 elements per pass
    let mut s0 = _mm_setzero_ps();
    let mut c0 = _mm_setzero_ps();
    let mut s1 = _mm_setzero_ps();
    let mut c1 = _mm_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let x0 = _mm_loadu_ps(a.as_ptr().add(i));
        let y0 = _mm_loadu_ps(b.as_ptr().add(i));
        let p0 = _mm_mul_ps(x0, y0);
        let yy0 = _mm_sub_ps(p0, c0);
        let t0 = _mm_add_ps(s0, yy0);
        c0 = _mm_sub_ps(_mm_sub_ps(t0, s0), yy0);
        s0 = t0;

        let x1 = _mm_loadu_ps(a.as_ptr().add(i + 4));
        let y1 = _mm_loadu_ps(b.as_ptr().add(i + 4));
        let p1 = _mm_mul_ps(x1, y1);
        let yy1 = _mm_sub_ps(p1, c1);
        let t1 = _mm_add_ps(s1, yy1);
        c1 = _mm_sub_ps(_mm_sub_ps(t1, s1), yy1);
        s1 = t1;
        i += 8;
    }
    let mut sums = [0.0f32; 8];
    let mut comps = [0.0f32; 8];
    _mm_storeu_ps(sums.as_mut_ptr(), s0);
    _mm_storeu_ps(sums.as_mut_ptr().add(4), s1);
    _mm_storeu_ps(comps.as_mut_ptr(), c0);
    _mm_storeu_ps(comps.as_mut_ptr().add(4), c1);
    // scalar compensated tail
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    while i < n {
        let prod = a[i] * b[i];
        let y = prod - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
        i += 1;
    }
    let head = compensated_fold_f32(&sums, &comps);
    compensated_fold_f32(&[head, s], &[0.0, c])
}

#[target_feature(enable = "sse4.2")]
unsafe fn kahan_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len().min(b.len());
    let mut s0 = _mm_setzero_pd();
    let mut c0 = _mm_setzero_pd();
    let mut s1 = _mm_setzero_pd();
    let mut c1 = _mm_setzero_pd();
    let mut i = 0usize;
    while i + 4 <= n {
        let x0 = _mm_loadu_pd(a.as_ptr().add(i));
        let y0 = _mm_loadu_pd(b.as_ptr().add(i));
        let p0 = _mm_mul_pd(x0, y0);
        let yy0 = _mm_sub_pd(p0, c0);
        let t0 = _mm_add_pd(s0, yy0);
        c0 = _mm_sub_pd(_mm_sub_pd(t0, s0), yy0);
        s0 = t0;

        let x1 = _mm_loadu_pd(a.as_ptr().add(i + 2));
        let y1 = _mm_loadu_pd(b.as_ptr().add(i + 2));
        let p1 = _mm_mul_pd(x1, y1);
        let yy1 = _mm_sub_pd(p1, c1);
        let t1 = _mm_add_pd(s1, yy1);
        c1 = _mm_sub_pd(_mm_sub_pd(t1, s1), yy1);
        s1 = t1;
        i += 4;
    }
    let mut sums = [0.0f64; 4];
    let mut comps = [0.0f64; 4];
    _mm_storeu_pd(sums.as_mut_ptr(), s0);
    _mm_storeu_pd(sums.as_mut_ptr().add(2), s1);
    _mm_storeu_pd(comps.as_mut_ptr(), c0);
    _mm_storeu_pd(comps.as_mut_ptr().add(2), c1);
    let mut s = 0.0f64;
    let mut c = 0.0f64;
    while i < n {
        let prod = a[i] * b[i];
        let y = prod - c;
        let t = s + y;
        c = (t - s) - y;
        s = t;
        i += 1;
    }
    let head = compensated_fold_f64(&sums, &comps);
    compensated_fold_f64(&[head, s], &[0.0, c])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_simple_values() {
        let a: Vec<f32> = (1..=17).map(|i| i as f32).collect();
        let b = vec![2.0f32; 17];
        // 2 * 17*18/2 = 306
        assert_eq!(kahan_f32(&a, &b), 306.0);
        let a: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let b = vec![3.0f64; 9];
        assert_eq!(kahan_f64(&a, &b), 135.0);
    }

    #[test]
    fn tail_only_input() {
        assert_eq!(kahan_f32(&[2.0, 3.0, 4.0], &[1.0, 1.0, 1.0]), 9.0);
        assert_eq!(kahan_f64(&[2.0], &[5.0]), 10.0);
    }
}
