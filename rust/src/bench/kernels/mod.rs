//! Real dot-product kernels for the host CPU: the paper's assembly zoo
//! re-created with `std::arch` intrinsics.
//!
//! Every SIMD variant keeps *per-lane* partial sums and (for Kahan)
//! per-lane compensation terms with several independent accumulator slots
//! (modulo unrolling), exactly like the paper's hand-written assembly; the
//! final cross-lane reduction is itself compensated.
//!
//! Rust floating-point semantics are strict IEEE — there is no fast-math
//! mode that could rewrite `(t - s) - y` to zero, which is the trap the
//! paper warns about for C compilers at high optimization levels.

pub mod avx2;
pub mod avx512;
pub mod batch;
pub mod scalar;
pub mod sse;

use crate::isa::{Accuracy, Precision, Simd, Variant};

/// True when both slice heads sit on an `align`-byte boundary — the pooled
/// fast path (`engine::BufferPool` guarantees 64-byte block starts, and
/// chunk boundaries are cut on cache-line element multiples). Aligned and
/// unaligned loads read identical values, so dispatching on this never
/// changes results, only the load µops.
pub(crate) fn both_aligned<T>(a: &[T], b: &[T], align: usize) -> bool {
    (a.as_ptr() as usize) % align == 0 && (b.as_ptr() as usize) % align == 0
}

/// A host kernel entry point (one per precision).
#[derive(Clone, Copy)]
pub enum KernelFn {
    F32(fn(&[f32], &[f32]) -> f32),
    F64(fn(&[f64], &[f64]) -> f64),
}

/// Registry entry: one benchmarkable host kernel.
///
/// Lookups on the request path are keyed by `(accuracy, prec)`; `variant`
/// survives as ISA-flavor metadata (the paper's naive / Kahan / Kahan-FMA
/// instruction-mix taxonomy, consumed by the model/simulator side).
#[derive(Clone, Copy)]
pub struct HostKernel {
    pub name: &'static str,
    /// algorithm class of the result — the request-facing axis
    pub accuracy: Accuracy,
    pub variant: Variant,
    pub simd: Simd,
    pub prec: Precision,
    /// whether the host CPU supports the required ISA extension
    pub available: bool,
    pub f: KernelFn,
}

impl HostKernel {
    pub fn call_f32(&self, a: &[f32], b: &[f32]) -> f32 {
        match self.f {
            KernelFn::F32(f) => f(a, b),
            KernelFn::F64(_) => panic!("{} is a f64 kernel", self.name),
        }
    }

    pub fn call_f64(&self, a: &[f64], b: &[f64]) -> f64 {
        match self.f {
            KernelFn::F64(f) => f(a, b),
            KernelFn::F32(_) => panic!("{} is a f32 kernel", self.name),
        }
    }
}

/// Compensated (Neumaier) fold used for all horizontal reductions: sums the
/// lane partial sums and then folds in the pending per-lane compensations
/// (which the kernels store with "to be subtracted" sign, matching Fig. 1b).
pub fn compensated_fold_f32(sums: &[f32], comps: &[f32]) -> f32 {
    let mut s = 0.0f32;
    let mut c = 0.0f32;
    let mut add = |v: f32| {
        let t = s + v;
        if s.abs() >= v.abs() {
            c += (s - t) + v;
        } else {
            c += (v - t) + s;
        }
        s = t;
    };
    for &v in sums {
        add(v);
    }
    for &v in comps {
        add(-v);
    }
    s + c
}

/// f64 twin of [`compensated_fold_f32`].
pub fn compensated_fold_f64(sums: &[f64], comps: &[f64]) -> f64 {
    let mut s = 0.0f64;
    let mut c = 0.0f64;
    let mut add = |v: f64| {
        let t = s + v;
        if s.abs() >= v.abs() {
            c += (s - t) + v;
        } else {
            c += (v - t) + s;
        }
        s = t;
    };
    for &v in sums {
        add(v);
    }
    for &v in comps {
        add(-v);
    }
    s + c
}

/// Detect CPU features and build the registry (runs once; see
/// [`registry_static`]).
fn detect_registry() -> Vec<HostKernel> {
    let avx2 = is_x86_feature_detected!("avx2");
    let fma = avx2 && is_x86_feature_detected!("fma");
    let avx512 = is_x86_feature_detected!("avx512f");
    let sse = is_x86_feature_detected!("sse4.2");

    vec![
        // --- f32 ---
        HostKernel { name: "naive-scalar-SP", accuracy: Accuracy::Naive, variant: Variant::Naive, simd: Simd::Scalar, prec: Precision::Sp, available: true, f: KernelFn::F32(scalar::naive_f32) },
        HostKernel { name: "naive-AVX2-SP", accuracy: Accuracy::Naive, variant: Variant::Naive, simd: Simd::Avx, prec: Precision::Sp, available: avx2, f: KernelFn::F32(avx2::naive_f32) },
        HostKernel { name: "kahan-compiler-SP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Scalar, prec: Precision::Sp, available: true, f: KernelFn::F32(scalar::kahan_seq_f32) },
        HostKernel { name: "kahan-scalar-SP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Scalar, prec: Precision::Sp, available: true, f: KernelFn::F32(scalar::kahan_unrolled_f32) },
        HostKernel { name: "kahan-SSE-SP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Sse, prec: Precision::Sp, available: sse, f: KernelFn::F32(sse::kahan_f32) },
        HostKernel { name: "kahan-AVX2-SP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Avx, prec: Precision::Sp, available: avx2, f: KernelFn::F32(avx2::kahan_f32) },
        HostKernel { name: "kahan-fma-AVX2-SP", accuracy: Accuracy::Kahan, variant: Variant::KahanFma, simd: Simd::Avx, prec: Precision::Sp, available: fma, f: KernelFn::F32(avx2::kahan_fma_f32) },
        HostKernel { name: "naive-AVX512-SP", accuracy: Accuracy::Naive, variant: Variant::Naive, simd: Simd::Avx512, prec: Precision::Sp, available: avx512, f: KernelFn::F32(avx512::naive_f32) },
        HostKernel { name: "kahan-AVX512-SP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Avx512, prec: Precision::Sp, available: avx512, f: KernelFn::F32(avx512::kahan_f32) },
        HostKernel { name: "kahan-fma-AVX512-SP", accuracy: Accuracy::Kahan, variant: Variant::KahanFma, simd: Simd::Avx512, prec: Precision::Sp, available: avx512, f: KernelFn::F32(avx512::kahan_fma_f32) },
        HostKernel { name: "dot2-compiler-SP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Scalar, prec: Precision::Sp, available: true, f: KernelFn::F32(scalar::dot2_seq_f32) },
        HostKernel { name: "dot2-scalar-SP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Scalar, prec: Precision::Sp, available: true, f: KernelFn::F32(scalar::dot2_unrolled_f32) },
        HostKernel { name: "dot2-AVX2-SP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Avx, prec: Precision::Sp, available: fma, f: KernelFn::F32(avx2::dot2_f32) },
        HostKernel { name: "dot2-AVX512-SP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Avx512, prec: Precision::Sp, available: avx512, f: KernelFn::F32(avx512::dot2_f32) },
        HostKernel { name: "exact-scalar-SP", accuracy: Accuracy::Exact, variant: Variant::Kahan, simd: Simd::Scalar, prec: Precision::Sp, available: true, f: KernelFn::F32(scalar::exact_f32) },
        // --- f64 ---
        HostKernel { name: "naive-scalar-DP", accuracy: Accuracy::Naive, variant: Variant::Naive, simd: Simd::Scalar, prec: Precision::Dp, available: true, f: KernelFn::F64(scalar::naive_f64) },
        HostKernel { name: "naive-AVX2-DP", accuracy: Accuracy::Naive, variant: Variant::Naive, simd: Simd::Avx, prec: Precision::Dp, available: avx2, f: KernelFn::F64(avx2::naive_f64) },
        HostKernel { name: "kahan-compiler-DP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Scalar, prec: Precision::Dp, available: true, f: KernelFn::F64(scalar::kahan_seq_f64) },
        HostKernel { name: "kahan-scalar-DP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Scalar, prec: Precision::Dp, available: true, f: KernelFn::F64(scalar::kahan_unrolled_f64) },
        HostKernel { name: "kahan-SSE-DP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Sse, prec: Precision::Dp, available: sse, f: KernelFn::F64(sse::kahan_f64) },
        HostKernel { name: "kahan-AVX2-DP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Avx, prec: Precision::Dp, available: avx2, f: KernelFn::F64(avx2::kahan_f64) },
        HostKernel { name: "kahan-fma-AVX2-DP", accuracy: Accuracy::Kahan, variant: Variant::KahanFma, simd: Simd::Avx, prec: Precision::Dp, available: fma, f: KernelFn::F64(avx2::kahan_fma_f64) },
        HostKernel { name: "naive-AVX512-DP", accuracy: Accuracy::Naive, variant: Variant::Naive, simd: Simd::Avx512, prec: Precision::Dp, available: avx512, f: KernelFn::F64(avx512::naive_f64) },
        HostKernel { name: "kahan-AVX512-DP", accuracy: Accuracy::Kahan, variant: Variant::Kahan, simd: Simd::Avx512, prec: Precision::Dp, available: avx512, f: KernelFn::F64(avx512::kahan_f64) },
        HostKernel { name: "kahan-fma-AVX512-DP", accuracy: Accuracy::Kahan, variant: Variant::KahanFma, simd: Simd::Avx512, prec: Precision::Dp, available: avx512, f: KernelFn::F64(avx512::kahan_fma_f64) },
        HostKernel { name: "dot2-compiler-DP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Scalar, prec: Precision::Dp, available: true, f: KernelFn::F64(scalar::dot2_seq_f64) },
        HostKernel { name: "dot2-scalar-DP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Scalar, prec: Precision::Dp, available: true, f: KernelFn::F64(scalar::dot2_unrolled_f64) },
        HostKernel { name: "dot2-AVX2-DP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Avx, prec: Precision::Dp, available: fma, f: KernelFn::F64(avx2::dot2_f64) },
        HostKernel { name: "dot2-AVX512-DP", accuracy: Accuracy::Dot2, variant: Variant::KahanFma, simd: Simd::Avx512, prec: Precision::Dp, available: avx512, f: KernelFn::F64(avx512::dot2_f64) },
        HostKernel { name: "exact-scalar-DP", accuracy: Accuracy::Exact, variant: Variant::Kahan, simd: Simd::Scalar, prec: Precision::Dp, available: true, f: KernelFn::F64(scalar::exact_f64) },
    ]
}

/// The process-wide kernel registry. CPU feature detection and the
/// registry `Vec` are built once behind a `OnceLock` — `by_name` and the
/// engine's autotuner sit on the per-request path, so they must not
/// re-detect (`is_x86_feature_detected!` is a cpuid + cache lookup) or
/// reallocate per call.
pub fn registry_static() -> &'static [HostKernel] {
    static REGISTRY: std::sync::OnceLock<Vec<HostKernel>> = std::sync::OnceLock::new();
    REGISTRY.get_or_init(detect_registry)
}

/// All host kernels, with availability determined at runtime (compat
/// wrapper over [`registry_static`] for callers that want ownership).
pub fn registry() -> Vec<HostKernel> {
    registry_static().to_vec()
}

/// Look up a kernel by name (exact match; allocation-free).
pub fn by_name(name: &str) -> Option<HostKernel> {
    registry_static().iter().find(|k| k.name == name).copied()
}

/// Test-only helper shared by the per-ISA alignment-dispatch tests.
#[cfg(test)]
pub(crate) mod tests_support {
    /// A copy of `src` whose slice head is GUARANTEED not aligned to
    /// `align` bytes. A plain `Vec` head alone could land aligned by
    /// allocator luck, silently turning an aligned-vs-unaligned
    /// comparison into aligned-vs-aligned; here the values live at an
    /// element offset into an over-allocated buffer, with the offset
    /// found at runtime so the head provably misses every boundary.
    pub struct MisalignedCopy<T> {
        buf: Vec<T>,
        off: usize,
        len: usize,
    }

    impl<T: Copy> MisalignedCopy<T> {
        pub fn as_slice(&self) -> &[T] {
            &self.buf[self.off..self.off + self.len]
        }
    }

    pub fn misaligned_copy<T: Copy + Default>(src: &[T], align: usize) -> MisalignedCopy<T> {
        let elem = std::mem::size_of::<T>();
        let slots = align / elem + 1;
        let mut buf = vec![T::default(); src.len() + slots];
        // element offsets advance `elem` bytes apiece, so among
        // `align/elem + 1` consecutive offsets at most one can sit on an
        // `align` boundary — a misaligned one always exists
        let off = (1..=slots)
            .find(|&o| (buf[o..].as_ptr() as usize) % align != 0)
            .expect("a misaligned offset always exists");
        buf[off..off + src.len()].copy_from_slice(src);
        MisalignedCopy { buf, off, len: src.len() }
    }

    #[test]
    fn misaligned_copy_is_misaligned_and_value_identical() {
        let src: Vec<f32> = (0..100).map(|i| i as f32 * 0.25).collect();
        let m = misaligned_copy(&src, 64);
        assert_ne!(m.as_slice().as_ptr() as usize % 64, 0);
        assert_eq!(m.as_slice(), &src[..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accuracy::exact::exact_dot_f32;
    use crate::util::Rng;

    fn gauss_pair(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut r = Rng::new(seed);
        (r.normal_f32_vec(n), r.normal_f32_vec(n))
    }

    /// Every available f32 kernel must agree with the exact dot to within a
    /// few ULP-scale bounds on benign data, at awkward lengths too.
    #[test]
    fn all_f32_kernels_close_to_exact() {
        for n in [1usize, 7, 64, 1000, 4096, 10_001] {
            let (a, b) = gauss_pair(n, 42 + n as u64);
            let exact = exact_dot_f32(&a, &b);
            let scale: f64 =
                a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
            for k in registry().into_iter().filter(|k| k.available) {
                if let KernelFn::F32(_) = k.f {
                    let got = k.call_f32(&a, &b) as f64;
                    let rel = (got - exact).abs() / scale;
                    assert!(rel < 1e-5, "{} at n={n}: rel={rel:e}", k.name);
                }
            }
        }
    }

    #[test]
    fn all_f64_kernels_close_to_exact() {
        use crate::accuracy::exact::exact_dot_f64;
        for n in [3usize, 100, 4097] {
            let mut r = Rng::new(7 + n as u64);
            let a = r.normal_f64_vec(n);
            let b = r.normal_f64_vec(n);
            let exact = exact_dot_f64(&a, &b);
            let scale: f64 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum::<f64>().max(1e-300);
            for k in registry().into_iter().filter(|k| k.available) {
                if let KernelFn::F64(_) = k.f {
                    let got = k.call_f64(&a, &b);
                    let rel = (got - exact).abs() / scale;
                    assert!(rel < 1e-13, "{} at n={n}: rel={rel:e}", k.name);
                }
            }
        }
    }

    /// The numerical payoff on real silicon: every Kahan variant must beat
    /// sequential naive summation on the large-accumulator workload.
    #[test]
    fn kahan_beats_naive_on_large_accumulator() {
        let n = 65_536;
        let mut r = Rng::new(3);
        let mut a: Vec<f32> = (0..n).map(|_| r.uniform() as f32).collect();
        a[0] = 1e8;
        let b = vec![1.0f32; n];
        let exact = exact_dot_f32(&a, &b);
        let naive_err = (scalar::naive_f32(&a, &b) as f64 - exact).abs();
        for k in registry().into_iter().filter(|k| k.available) {
            if k.accuracy == Accuracy::Naive {
                continue;
            }
            if let KernelFn::F32(_) = k.f {
                let err = (k.call_f32(&a, &b) as f64 - exact).abs();
                assert!(
                    err * 50.0 < naive_err,
                    "{}: compensated err {err:e} vs naive {naive_err:e}",
                    k.name
                );
            }
        }
    }

    /// Every accuracy tier has at least one always-available kernel per
    /// precision (the guarantee `kernel_for_*` and the autotuner rely on),
    /// and every tier is represented in the registry.
    #[test]
    fn every_accuracy_tier_covered_per_precision() {
        for acc in Accuracy::ALL {
            for prec in [Precision::Sp, Precision::Dp] {
                assert!(
                    registry_static()
                        .iter()
                        .any(|k| k.accuracy == acc && k.prec == prec && k.available),
                    "no available kernel for {:?}/{:?}",
                    acc,
                    prec
                );
            }
        }
        // Exact is scalar-only by policy: no SIMD claim on the expansion path
        for k in registry_static().iter().filter(|k| k.accuracy == Accuracy::Exact) {
            assert_eq!(k.simd, Simd::Scalar, "{} must stay scalar", k.name);
        }
    }

    /// Property: all kernels agree with each other within a tight bound on
    /// random data of random length (catches tail-handling bugs).
    #[test]
    fn kernels_agree_random_lengths() {
        crate::util::prop::check("host-kernels-agree", 40, |rng| {
            let n = 1 + rng.below(5000) as usize;
            let a = rng.normal_f32_vec(n);
            let b = rng.normal_f32_vec(n);
            let exact = exact_dot_f32(&a, &b);
            let scale: f64 =
                a.iter().zip(&b).map(|(x, y)| (x * y).abs() as f64).sum::<f64>().max(1e-30);
            for k in registry().into_iter().filter(|k| k.available) {
                if let KernelFn::F32(_) = k.f {
                    let got = k.call_f32(&a, &b) as f64;
                    crate::prop_assert!(
                        ((got - exact).abs() / scale) < 2e-5,
                        "{} n={n}: {got} vs {exact}",
                        k.name
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn compensated_fold_recovers_small_terms() {
        // 2^23 + 0.5 + 0.25 + 0.125: plain f32 summation drops every small
        // term (ties-to-even at ulp = 1); the compensated fold keeps them in
        // `c` and rounds the true sum 8388608.875 to the nearest f32.
        let sums = [8388608.0f32, 0.5, 0.25, 0.125];
        let comps = [0.0f32; 4];
        let naive: f32 = sums.iter().sum();
        assert_eq!(naive, 8388608.0, "naive must lose the small terms");
        let folded = compensated_fold_f32(&sums, &comps);
        assert_eq!(folded, 8388609.0, "fold must keep them");
    }

    #[test]
    fn registry_has_both_precisions_and_lookup_works() {
        let r = registry();
        assert!(r.iter().any(|k| k.prec == Precision::Sp));
        assert!(r.iter().any(|k| k.prec == Precision::Dp));
        assert!(by_name("kahan-AVX2-SP").is_some());
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn registry_is_cached_behind_once_lock() {
        // same backing storage on every call: feature detection ran once
        assert!(std::ptr::eq(registry_static().as_ptr(), registry_static().as_ptr()));
        assert_eq!(registry_static().len(), registry().len());
    }
}
