//! AVX2 (256-bit) host kernels: 8 f32 / 4 f64 lanes, four accumulator
//! slots, plus the §4 FMA variant (compensated adds issued as FMAs with a
//! unit multiplicand so both FMA pipes participate).
//!
//! Every public entry dispatches on pointer alignment at the call site:
//! pooled-path buffers start on 64-byte boundaries (two whole ymm), so
//! admitted streams take `_mm256_load_*`; arbitrary caller slices fall
//! back to `loadu`. Aligned and unaligned loads read identical values, so
//! the dispatch never changes results, only the load µops.

use super::{both_aligned, compensated_fold_f32, compensated_fold_f64};

/// ymm width in bytes — the alignment the `load` (vs `loadu`) forms need.
const YMM_ALIGN: usize = 32;

pub fn naive_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { naive_f32_al(a, b) }
        } else {
            unsafe { naive_f32_impl(a, b) }
        }
    } else {
        super::scalar::naive_f32(a, b)
    }
}

pub fn naive_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { naive_f64_al(a, b) }
        } else {
            unsafe { naive_f64_impl(a, b) }
        }
    } else {
        super::scalar::naive_f64(a, b)
    }
}

pub fn kahan_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { kahan_f32_al(a, b) }
        } else {
            unsafe { kahan_f32_impl(a, b) }
        }
    } else {
        super::sse::kahan_f32(a, b)
    }
}

pub fn kahan_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { kahan_f64_al(a, b) }
        } else {
            unsafe { kahan_f64_impl(a, b) }
        }
    } else {
        super::sse::kahan_f64(a, b)
    }
}

pub fn kahan_fma_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { kahan_fma_f32_al(a, b) }
        } else {
            unsafe { kahan_fma_f32_impl(a, b) }
        }
    } else {
        kahan_f32(a, b)
    }
}

pub fn kahan_fma_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { kahan_fma_f64_al(a, b) }
        } else {
            unsafe { kahan_fma_f64_impl(a, b) }
        }
    } else {
        kahan_f64(a, b)
    }
}

pub fn dot2_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { dot2_f32_al(a, b) }
        } else {
            unsafe { dot2_f32_impl(a, b) }
        }
    } else {
        super::scalar::dot2_unrolled_f32(a, b)
    }
}

pub fn dot2_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        if both_aligned(a, b, YMM_ALIGN) {
            unsafe { dot2_f64_al(a, b) }
        } else {
            unsafe { dot2_f64_impl(a, b) }
        }
    } else {
        super::scalar::dot2_unrolled_f64(a, b)
    }
}

/// Four-slot naive body; `$load` selects `loadu` vs aligned `load`.
macro_rules! naive_avx_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $add:ident,
     $zero:ident, $store:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let mut s0 = $zero();
        let mut s1 = $zero();
        let mut s2 = $zero();
        let mut s3 = $zero();
        let mut i = 0usize;
        while i + 4 * $lanes <= n {
            s0 = $add(s0, $mul($load($a.as_ptr().add(i)), $load($b.as_ptr().add(i))));
            s1 = $add(
                s1,
                $mul($load($a.as_ptr().add(i + $lanes)), $load($b.as_ptr().add(i + $lanes))),
            );
            s2 = $add(
                s2,
                $mul(
                    $load($a.as_ptr().add(i + 2 * $lanes)),
                    $load($b.as_ptr().add(i + 2 * $lanes)),
                ),
            );
            s3 = $add(
                s3,
                $mul(
                    $load($a.as_ptr().add(i + 3 * $lanes)),
                    $load($b.as_ptr().add(i + 3 * $lanes)),
                ),
            );
            i += 4 * $lanes;
        }
        let mut lanes = [0.0 as $elem; 4 * $lanes];
        $store(lanes.as_mut_ptr(), s0);
        $store(lanes.as_mut_ptr().add($lanes), s1);
        $store(lanes.as_mut_ptr().add(2 * $lanes), s2);
        $store(lanes.as_mut_ptr().add(3 * $lanes), s3);
        let mut s: $elem = lanes.iter().sum();
        while i < n {
            s += $a[i] * $b[i];
            i += 1;
        }
        s
    }};
}

#[target_feature(enable = "avx2")]
unsafe fn naive_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    naive_avx_body!(
        a, b, f32, 8, _mm256_loadu_ps, _mm256_mul_ps, _mm256_add_ps, _mm256_setzero_ps,
        _mm256_storeu_ps
    )
}

#[target_feature(enable = "avx2")]
unsafe fn naive_f32_al(a: &[f32], b: &[f32]) -> f32 {
    naive_avx_body!(
        a, b, f32, 8, _mm256_load_ps, _mm256_mul_ps, _mm256_add_ps, _mm256_setzero_ps,
        _mm256_storeu_ps
    )
}

#[target_feature(enable = "avx2")]
unsafe fn naive_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    naive_avx_body!(
        a, b, f64, 4, _mm256_loadu_pd, _mm256_mul_pd, _mm256_add_pd, _mm256_setzero_pd,
        _mm256_storeu_pd
    )
}

#[target_feature(enable = "avx2")]
unsafe fn naive_f64_al(a: &[f64], b: &[f64]) -> f64 {
    naive_avx_body!(
        a, b, f64, 4, _mm256_load_pd, _mm256_mul_pd, _mm256_add_pd, _mm256_setzero_pd,
        _mm256_storeu_pd
    )
}

macro_rules! kahan_avx_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident,
     $sub:ident, $add:ident, $zero:ident, $store:ident) => {{
        use core::arch::x86_64::*;
        const L: usize = $lanes;
        let n = $a.len().min($b.len());
        let mut s0 = $zero();
        let mut c0 = $zero();
        let mut s1 = $zero();
        let mut c1 = $zero();
        let mut s2 = $zero();
        let mut c2 = $zero();
        let mut s3 = $zero();
        let mut c3 = $zero();
        let mut i = 0usize;
        while i + 4 * L <= n {
            // slot 0..3, each on its own 256-bit stripe
            let p0 = $mul($load($a.as_ptr().add(i)), $load($b.as_ptr().add(i)));
            let y0 = $sub(p0, c0);
            let t0 = $add(s0, y0);
            c0 = $sub($sub(t0, s0), y0);
            s0 = t0;

            let p1 = $mul($load($a.as_ptr().add(i + L)), $load($b.as_ptr().add(i + L)));
            let y1 = $sub(p1, c1);
            let t1 = $add(s1, y1);
            c1 = $sub($sub(t1, s1), y1);
            s1 = t1;

            let p2 = $mul($load($a.as_ptr().add(i + 2 * L)), $load($b.as_ptr().add(i + 2 * L)));
            let y2 = $sub(p2, c2);
            let t2 = $add(s2, y2);
            c2 = $sub($sub(t2, s2), y2);
            s2 = t2;

            let p3 = $mul($load($a.as_ptr().add(i + 3 * L)), $load($b.as_ptr().add(i + 3 * L)));
            let y3 = $sub(p3, c3);
            let t3 = $add(s3, y3);
            c3 = $sub($sub(t3, s3), y3);
            s3 = t3;
            i += 4 * L;
        }
        let mut sums = [0.0 as $elem; 4 * L];
        let mut comps = [0.0 as $elem; 4 * L];
        $store(sums.as_mut_ptr(), s0);
        $store(sums.as_mut_ptr().add(L), s1);
        $store(sums.as_mut_ptr().add(2 * L), s2);
        $store(sums.as_mut_ptr().add(3 * L), s3);
        $store(comps.as_mut_ptr(), c0);
        $store(comps.as_mut_ptr().add(L), c1);
        $store(comps.as_mut_ptr().add(2 * L), c2);
        $store(comps.as_mut_ptr().add(3 * L), c3);
        // compensated scalar tail
        let mut s = 0.0 as $elem;
        let mut c = 0.0 as $elem;
        while i < n {
            let prod = $a[i] * $b[i];
            let y = prod - c;
            let t = s + y;
            c = (t - s) - y;
            s = t;
            i += 1;
        }
        (sums, comps, s, c)
    }};
}

#[target_feature(enable = "avx2")]
unsafe fn kahan_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    let (sums, comps, s, c) = kahan_avx_body!(
        a, b, f32, 8, _mm256_loadu_ps, _mm256_mul_ps, _mm256_sub_ps, _mm256_add_ps,
        _mm256_setzero_ps, _mm256_storeu_ps
    );
    let head = compensated_fold_f32(&sums, &comps);
    compensated_fold_f32(&[head, s], &[0.0, c])
}

#[target_feature(enable = "avx2")]
unsafe fn kahan_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    let (sums, comps, s, c) = kahan_avx_body!(
        a, b, f64, 4, _mm256_loadu_pd, _mm256_mul_pd, _mm256_sub_pd, _mm256_add_pd,
        _mm256_setzero_pd, _mm256_storeu_pd
    );
    let head = compensated_fold_f64(&sums, &comps);
    compensated_fold_f64(&[head, s], &[0.0, c])
}

#[target_feature(enable = "avx2")]
unsafe fn kahan_f32_al(a: &[f32], b: &[f32]) -> f32 {
    let (sums, comps, s, c) = kahan_avx_body!(
        a, b, f32, 8, _mm256_load_ps, _mm256_mul_ps, _mm256_sub_ps, _mm256_add_ps,
        _mm256_setzero_ps, _mm256_storeu_ps
    );
    let head = compensated_fold_f32(&sums, &comps);
    compensated_fold_f32(&[head, s], &[0.0, c])
}

#[target_feature(enable = "avx2")]
unsafe fn kahan_f64_al(a: &[f64], b: &[f64]) -> f64 {
    let (sums, comps, s, c) = kahan_avx_body!(
        a, b, f64, 4, _mm256_load_pd, _mm256_mul_pd, _mm256_sub_pd, _mm256_add_pd,
        _mm256_setzero_pd, _mm256_storeu_pd
    );
    let head = compensated_fold_f64(&sums, &comps);
    compensated_fold_f64(&[head, s], &[0.0, c])
}

/// FMA flavor: `t = s*1 + y` and the product via `fmadd(x, y, -c)`... the
/// subtraction of the compensation is fused into the product FMA, which both
/// saves one op and (bonus over the paper) makes the product *error* smaller
/// because `x*y - c` rounds once. 6 slots: the register budget the paper's
/// §4 discussion hits. `$load` selects `loadu` vs aligned `load`.
macro_rules! kahan_fma_avx_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $fmadd:ident, $fmsub:ident,
     $sub:ident, $set1:ident, $zero:ident, $store:ident, $fold:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let ones = $set1(1.0);
        let mut s = [$zero(); 6];
        let mut c = [$zero(); 6];
        let mut i = 0usize;
        while i + 6 * $lanes <= n {
            for k in 0..6 {
                let x = $load($a.as_ptr().add(i + k * $lanes));
                let yv = $load($b.as_ptr().add(i + k * $lanes));
                // y = x*b - c (fused)
                let y = $fmsub(x, yv, c[k]);
                // t = s*1 + y (keeps the ADD on the FMA pipes)
                let t = $fmadd(s[k], ones, y);
                c[k] = $sub($sub(t, s[k]), y);
                s[k] = t;
            }
            i += 6 * $lanes;
        }
        let mut sums = [0.0 as $elem; 6 * $lanes];
        let mut comps = [0.0 as $elem; 6 * $lanes];
        for k in 0..6 {
            $store(sums.as_mut_ptr().add(k * $lanes), s[k]);
            $store(comps.as_mut_ptr().add(k * $lanes), c[k]);
        }
        let mut st = 0.0 as $elem;
        let mut ct = 0.0 as $elem;
        while i < n {
            let prod = $a[i] * $b[i];
            let y = prod - ct;
            let t = st + y;
            ct = (t - st) - y;
            st = t;
            i += 1;
        }
        let head = $fold(&sums, &comps);
        $fold(&[head, st], &[0.0 as $elem, ct])
    }};
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kahan_fma_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    kahan_fma_avx_body!(
        a, b, f32, 8, _mm256_loadu_ps, _mm256_fmadd_ps, _mm256_fmsub_ps, _mm256_sub_ps,
        _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps, compensated_fold_f32
    )
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kahan_fma_f32_al(a: &[f32], b: &[f32]) -> f32 {
    kahan_fma_avx_body!(
        a, b, f32, 8, _mm256_load_ps, _mm256_fmadd_ps, _mm256_fmsub_ps, _mm256_sub_ps,
        _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps, compensated_fold_f32
    )
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kahan_fma_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    kahan_fma_avx_body!(
        a, b, f64, 4, _mm256_loadu_pd, _mm256_fmadd_pd, _mm256_fmsub_pd, _mm256_sub_pd,
        _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, compensated_fold_f64
    )
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kahan_fma_f64_al(a: &[f64], b: &[f64]) -> f64 {
    kahan_fma_avx_body!(
        a, b, f64, 4, _mm256_load_pd, _mm256_fmadd_pd, _mm256_fmsub_pd, _mm256_sub_pd,
        _mm256_set1_pd, _mm256_setzero_pd, _mm256_storeu_pd, compensated_fold_f64
    )
}

/// Ogita–Rump–Oishi Dot2 body: per slot, TwoProd via FMA (`ep = x*y - p`
/// rounds the product error exactly) then a branch-free 2Sum of the product
/// into the slot's lane sums, with BOTH error terms accumulated in a
/// per-lane correction register — the per-lane sum/compensation structure
/// of `kahan_fma_avx_body!`, one accuracy rung up. Four slots: the 2Sum
/// chain is 6 ops deep, so four independent chains cover the ADD latency
/// within the register budget (4×2 accumulators + 5 temporaries).
macro_rules! dot2_avx_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident, $fmsub:ident,
     $sub:ident, $add:ident, $zero:ident, $store:ident, $fold:ident) => {{
        use core::arch::x86_64::*;
        let n = $a.len().min($b.len());
        let mut s = [$zero(); 4];
        let mut c = [$zero(); 4];
        let mut i = 0usize;
        while i + 4 * $lanes <= n {
            for k in 0..4 {
                let x = $load($a.as_ptr().add(i + k * $lanes));
                let yv = $load($b.as_ptr().add(i + k * $lanes));
                // TwoProd: p = fl(x*y), ep = x*y - p exactly (one FMA)
                let p = $mul(x, yv);
                let ep = $fmsub(x, yv, p);
                // branch-free 2Sum of p into the slot sum (Knuth)
                let t = $add(s[k], p);
                let bb = $sub(t, s[k]);
                let es = $add($sub(s[k], $sub(t, bb)), $sub(p, bb));
                s[k] = t;
                c[k] = $add(c[k], $add(ep, es));
            }
            i += 4 * $lanes;
        }
        let mut sums = [0.0 as $elem; 4 * $lanes];
        let mut comps = [0.0 as $elem; 4 * $lanes];
        for k in 0..4 {
            $store(sums.as_mut_ptr().add(k * $lanes), s[k]);
            $store(comps.as_mut_ptr().add(k * $lanes), c[k]);
        }
        // Dot2 corrections are additive; the compensated fold subtracts
        // its comps argument, so they go in negated
        for v in comps.iter_mut() {
            *v = -*v;
        }
        // Dot2 scalar tail
        let mut st = 0.0 as $elem;
        let mut ct = 0.0 as $elem;
        while i < n {
            let p = $a[i] * $b[i];
            let ep = $a[i].mul_add($b[i], -p);
            let t = st + p;
            let bb = t - st;
            let es = (st - (t - bb)) + (p - bb);
            st = t;
            ct += ep + es;
        }
        let head = $fold(&sums, &comps);
        $fold(&[head, st], &[0.0 as $elem, -ct])
    }};
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot2_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    dot2_avx_body!(
        a, b, f32, 8, _mm256_loadu_ps, _mm256_mul_ps, _mm256_fmsub_ps, _mm256_sub_ps,
        _mm256_add_ps, _mm256_setzero_ps, _mm256_storeu_ps, compensated_fold_f32
    )
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot2_f32_al(a: &[f32], b: &[f32]) -> f32 {
    dot2_avx_body!(
        a, b, f32, 8, _mm256_load_ps, _mm256_mul_ps, _mm256_fmsub_ps, _mm256_sub_ps,
        _mm256_add_ps, _mm256_setzero_ps, _mm256_storeu_ps, compensated_fold_f32
    )
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot2_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    dot2_avx_body!(
        a, b, f64, 4, _mm256_loadu_pd, _mm256_mul_pd, _mm256_fmsub_pd, _mm256_sub_pd,
        _mm256_add_pd, _mm256_setzero_pd, _mm256_storeu_pd, compensated_fold_f64
    )
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot2_f64_al(a: &[f64], b: &[f64]) -> f64 {
    dot2_avx_body!(
        a, b, f64, 4, _mm256_load_pd, _mm256_mul_pd, _mm256_fmsub_pd, _mm256_sub_pd,
        _mm256_add_pd, _mm256_setzero_pd, _mm256_storeu_pd, compensated_fold_f64
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_cases() {
        let a: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let b = vec![1.0f32; 100];
        assert_eq!(naive_f32(&a, &b), 5050.0);
        assert_eq!(kahan_f32(&a, &b), 5050.0);
        assert_eq!(kahan_fma_f32(&a, &b), 5050.0);
        assert_eq!(dot2_f32(&a, &b), 5050.0);
        let a: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let b = vec![1.0f64; 100];
        assert_eq!(naive_f64(&a, &b), 5050.0);
        assert_eq!(kahan_f64(&a, &b), 5050.0);
        assert_eq!(kahan_fma_f64(&a, &b), 5050.0);
        assert_eq!(dot2_f64(&a, &b), 5050.0);
    }

    #[test]
    fn odd_tails() {
        for n in [1usize, 7, 31, 33, 47, 63] {
            let a = vec![2.0f32; n];
            let b = vec![3.0f32; n];
            assert_eq!(kahan_f32(&a, &b), (6 * n) as f32, "n={n}");
            assert_eq!(kahan_fma_f32(&a, &b), (6 * n) as f32, "n={n}");
            assert_eq!(dot2_f32(&a, &b), (6 * n) as f32, "n={n}");
        }
    }

    /// Dot2's signature property holds for the SIMD kernel too: full
    /// accuracy at condition numbers where Kahan degrades.
    #[test]
    fn dot2_avx2_survives_high_condition() {
        let mut rng = crate::util::Rng::new(23);
        let (a, b, exact, cond) = crate::accuracy::gen_dot_f32(4096, 1e6, &mut rng);
        assert!(cond > 1e4);
        let rel = ((dot2_f32(&a, &b) as f64 - exact) / exact.abs().max(1e-30)).abs();
        assert!(rel < 1e-6, "dot2-AVX2 err {rel:e} at cond {cond:.3e}");
    }

    /// The 64-byte-aligned (pooled) path must be bit-identical to the
    /// `loadu` path on the same values — aligned loads only change µops.
    /// The unaligned side is a guaranteed-misaligned copy (a bare `Vec`
    /// could land 32-byte-aligned by allocator luck and test nothing).
    #[test]
    fn aligned_dispatch_is_bit_identical() {
        let pool = crate::engine::BufferPool::new();
        let n = 137; // forces main loop + tail
        let src: Vec<f32> = (0..n).map(|i| (i as f32).cos()).collect();
        let a = pool.admit(&src);
        let b = pool.admit(&src);
        assert_eq!(a.addr() % 64, 0);
        let mis = crate::bench::kernels::tests_support::misaligned_copy(&src, 32);
        for (f, name) in [
            (naive_f32 as fn(&[f32], &[f32]) -> f32, "naive"),
            (kahan_f32, "kahan"),
            (kahan_fma_f32, "kahan-fma"),
            (dot2_f32, "dot2"),
        ] {
            let pooled = f(a.as_slice(), b.as_slice());
            let plain = f(mis.as_slice(), mis.as_slice());
            assert_eq!(pooled.to_bits(), plain.to_bits(), "{name}");
        }
        let srcd: Vec<f64> = (0..n).map(|i| (i as f64).cos()).collect();
        let ad = pool.admit(&srcd);
        let bd = pool.admit(&srcd);
        let misd = crate::bench::kernels::tests_support::misaligned_copy(&srcd, 32);
        for (f, name) in [
            (naive_f64 as fn(&[f64], &[f64]) -> f64, "naive"),
            (kahan_f64, "kahan"),
            (kahan_fma_f64, "kahan-fma"),
            (dot2_f64, "dot2"),
        ] {
            let pooled = f(ad.as_slice(), bd.as_slice());
            let plain = f(misd.as_slice(), misd.as_slice());
            assert_eq!(pooled.to_bits(), plain.to_bits(), "{name}");
        }
    }
}
