//! AVX2 (256-bit) host kernels: 8 f32 / 4 f64 lanes, four accumulator
//! slots, plus the §4 FMA variant (compensated adds issued as FMAs with a
//! unit multiplicand so both FMA pipes participate).

use super::{compensated_fold_f32, compensated_fold_f64};

pub fn naive_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") {
        unsafe { naive_f32_impl(a, b) }
    } else {
        super::scalar::naive_f32(a, b)
    }
}

pub fn naive_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") {
        unsafe { naive_f64_impl(a, b) }
    } else {
        super::scalar::naive_f64(a, b)
    }
}

pub fn kahan_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") {
        unsafe { kahan_f32_impl(a, b) }
    } else {
        super::sse::kahan_f32(a, b)
    }
}

pub fn kahan_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") {
        unsafe { kahan_f64_impl(a, b) }
    } else {
        super::sse::kahan_f64(a, b)
    }
}

pub fn kahan_fma_f32(a: &[f32], b: &[f32]) -> f32 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        unsafe { kahan_fma_f32_impl(a, b) }
    } else {
        kahan_f32(a, b)
    }
}

pub fn kahan_fma_f64(a: &[f64], b: &[f64]) -> f64 {
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        unsafe { kahan_fma_f64_impl(a, b) }
    } else {
        kahan_f64(a, b)
    }
}

#[target_feature(enable = "avx2")]
unsafe fn naive_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    let n = a.len().min(b.len());
    let mut s0 = _mm256_setzero_ps();
    let mut s1 = _mm256_setzero_ps();
    let mut s2 = _mm256_setzero_ps();
    let mut s3 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 32 <= n {
        s0 = _mm256_add_ps(s0, _mm256_mul_ps(_mm256_loadu_ps(a.as_ptr().add(i)), _mm256_loadu_ps(b.as_ptr().add(i))));
        s1 = _mm256_add_ps(s1, _mm256_mul_ps(_mm256_loadu_ps(a.as_ptr().add(i + 8)), _mm256_loadu_ps(b.as_ptr().add(i + 8))));
        s2 = _mm256_add_ps(s2, _mm256_mul_ps(_mm256_loadu_ps(a.as_ptr().add(i + 16)), _mm256_loadu_ps(b.as_ptr().add(i + 16))));
        s3 = _mm256_add_ps(s3, _mm256_mul_ps(_mm256_loadu_ps(a.as_ptr().add(i + 24)), _mm256_loadu_ps(b.as_ptr().add(i + 24))));
        i += 32;
    }
    let mut lanes = [0.0f32; 32];
    _mm256_storeu_ps(lanes.as_mut_ptr(), s0);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(8), s1);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(16), s2);
    _mm256_storeu_ps(lanes.as_mut_ptr().add(24), s3);
    let mut s: f32 = lanes.iter().sum();
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[target_feature(enable = "avx2")]
unsafe fn naive_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    let n = a.len().min(b.len());
    let mut s0 = _mm256_setzero_pd();
    let mut s1 = _mm256_setzero_pd();
    let mut s2 = _mm256_setzero_pd();
    let mut s3 = _mm256_setzero_pd();
    let mut i = 0usize;
    while i + 16 <= n {
        s0 = _mm256_add_pd(s0, _mm256_mul_pd(_mm256_loadu_pd(a.as_ptr().add(i)), _mm256_loadu_pd(b.as_ptr().add(i))));
        s1 = _mm256_add_pd(s1, _mm256_mul_pd(_mm256_loadu_pd(a.as_ptr().add(i + 4)), _mm256_loadu_pd(b.as_ptr().add(i + 4))));
        s2 = _mm256_add_pd(s2, _mm256_mul_pd(_mm256_loadu_pd(a.as_ptr().add(i + 8)), _mm256_loadu_pd(b.as_ptr().add(i + 8))));
        s3 = _mm256_add_pd(s3, _mm256_mul_pd(_mm256_loadu_pd(a.as_ptr().add(i + 12)), _mm256_loadu_pd(b.as_ptr().add(i + 12))));
        i += 16;
    }
    let mut lanes = [0.0f64; 16];
    _mm256_storeu_pd(lanes.as_mut_ptr(), s0);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(4), s1);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(8), s2);
    _mm256_storeu_pd(lanes.as_mut_ptr().add(12), s3);
    let mut s: f64 = lanes.iter().sum();
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

macro_rules! kahan_avx_body {
    ($a:ident, $b:ident, $elem:ty, $lanes:expr, $load:ident, $mul:ident,
     $sub:ident, $add:ident, $zero:ident, $store:ident) => {{
        use core::arch::x86_64::*;
        const L: usize = $lanes;
        let n = $a.len().min($b.len());
        let mut s0 = $zero();
        let mut c0 = $zero();
        let mut s1 = $zero();
        let mut c1 = $zero();
        let mut s2 = $zero();
        let mut c2 = $zero();
        let mut s3 = $zero();
        let mut c3 = $zero();
        let mut i = 0usize;
        while i + 4 * L <= n {
            // slot 0..3, each on its own 256-bit stripe
            let p0 = $mul($load($a.as_ptr().add(i)), $load($b.as_ptr().add(i)));
            let y0 = $sub(p0, c0);
            let t0 = $add(s0, y0);
            c0 = $sub($sub(t0, s0), y0);
            s0 = t0;

            let p1 = $mul($load($a.as_ptr().add(i + L)), $load($b.as_ptr().add(i + L)));
            let y1 = $sub(p1, c1);
            let t1 = $add(s1, y1);
            c1 = $sub($sub(t1, s1), y1);
            s1 = t1;

            let p2 = $mul($load($a.as_ptr().add(i + 2 * L)), $load($b.as_ptr().add(i + 2 * L)));
            let y2 = $sub(p2, c2);
            let t2 = $add(s2, y2);
            c2 = $sub($sub(t2, s2), y2);
            s2 = t2;

            let p3 = $mul($load($a.as_ptr().add(i + 3 * L)), $load($b.as_ptr().add(i + 3 * L)));
            let y3 = $sub(p3, c3);
            let t3 = $add(s3, y3);
            c3 = $sub($sub(t3, s3), y3);
            s3 = t3;
            i += 4 * L;
        }
        let mut sums = [0.0 as $elem; 4 * L];
        let mut comps = [0.0 as $elem; 4 * L];
        $store(sums.as_mut_ptr(), s0);
        $store(sums.as_mut_ptr().add(L), s1);
        $store(sums.as_mut_ptr().add(2 * L), s2);
        $store(sums.as_mut_ptr().add(3 * L), s3);
        $store(comps.as_mut_ptr(), c0);
        $store(comps.as_mut_ptr().add(L), c1);
        $store(comps.as_mut_ptr().add(2 * L), c2);
        $store(comps.as_mut_ptr().add(3 * L), c3);
        // compensated scalar tail
        let mut s = 0.0 as $elem;
        let mut c = 0.0 as $elem;
        while i < n {
            let prod = $a[i] * $b[i];
            let y = prod - c;
            let t = s + y;
            c = (t - s) - y;
            s = t;
            i += 1;
        }
        (sums, comps, s, c)
    }};
}

#[target_feature(enable = "avx2")]
unsafe fn kahan_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    let (sums, comps, s, c) = kahan_avx_body!(
        a, b, f32, 8, _mm256_loadu_ps, _mm256_mul_ps, _mm256_sub_ps, _mm256_add_ps,
        _mm256_setzero_ps, _mm256_storeu_ps
    );
    let head = compensated_fold_f32(&sums, &comps);
    compensated_fold_f32(&[head, s], &[0.0, c])
}

#[target_feature(enable = "avx2")]
unsafe fn kahan_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    let (sums, comps, s, c) = kahan_avx_body!(
        a, b, f64, 4, _mm256_loadu_pd, _mm256_mul_pd, _mm256_sub_pd, _mm256_add_pd,
        _mm256_setzero_pd, _mm256_storeu_pd
    );
    let head = compensated_fold_f64(&sums, &comps);
    compensated_fold_f64(&[head, s], &[0.0, c])
}

/// FMA flavor: `t = s*1 + y` and the product via `fmadd(x, y, -c)`... the
/// subtraction of the compensation is fused into the product FMA, which both
/// saves one op and (bonus over the paper) makes the product *error* smaller
/// because `x*y - c` rounds once.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kahan_fma_f32_impl(a: &[f32], b: &[f32]) -> f32 {
    use core::arch::x86_64::*;
    const L: usize = 8;
    let n = a.len().min(b.len());
    let ones = _mm256_set1_ps(1.0);
    let mut s = [_mm256_setzero_ps(); 6];
    let mut c = [_mm256_setzero_ps(); 6];
    let mut i = 0usize;
    while i + 6 * L <= n {
        // 6 slots: the register budget the paper's §4 discussion hits
        macro_rules! slot {
            ($k:expr) => {{
                let x = _mm256_loadu_ps(a.as_ptr().add(i + $k * L));
                let yv = _mm256_loadu_ps(b.as_ptr().add(i + $k * L));
                // y = x*b - c (fused)
                let y = _mm256_fmsub_ps(x, yv, c[$k]);
                // t = s*1 + y (keeps the ADD on the FMA pipes)
                let t = _mm256_fmadd_ps(s[$k], ones, y);
                c[$k] = _mm256_sub_ps(_mm256_sub_ps(t, s[$k]), y);
                s[$k] = t;
            }};
        }
        slot!(0);
        slot!(1);
        slot!(2);
        slot!(3);
        slot!(4);
        slot!(5);
        i += 6 * L;
    }
    let mut sums = [0.0f32; 6 * L];
    let mut comps = [0.0f32; 6 * L];
    for k in 0..6 {
        _mm256_storeu_ps(sums.as_mut_ptr().add(k * L), s[k]);
        _mm256_storeu_ps(comps.as_mut_ptr().add(k * L), c[k]);
    }
    let mut st = 0.0f32;
    let mut ct = 0.0f32;
    while i < n {
        let prod = a[i] * b[i];
        let y = prod - ct;
        let t = st + y;
        ct = (t - st) - y;
        st = t;
        i += 1;
    }
    let head = compensated_fold_f32(&sums, &comps);
    compensated_fold_f32(&[head, st], &[0.0, ct])
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn kahan_fma_f64_impl(a: &[f64], b: &[f64]) -> f64 {
    use core::arch::x86_64::*;
    const L: usize = 4;
    let n = a.len().min(b.len());
    let ones = _mm256_set1_pd(1.0);
    let mut s = [_mm256_setzero_pd(); 6];
    let mut c = [_mm256_setzero_pd(); 6];
    let mut i = 0usize;
    while i + 6 * L <= n {
        macro_rules! slot {
            ($k:expr) => {{
                let x = _mm256_loadu_pd(a.as_ptr().add(i + $k * L));
                let yv = _mm256_loadu_pd(b.as_ptr().add(i + $k * L));
                let y = _mm256_fmsub_pd(x, yv, c[$k]);
                let t = _mm256_fmadd_pd(s[$k], ones, y);
                c[$k] = _mm256_sub_pd(_mm256_sub_pd(t, s[$k]), y);
                s[$k] = t;
            }};
        }
        slot!(0);
        slot!(1);
        slot!(2);
        slot!(3);
        slot!(4);
        slot!(5);
        i += 6 * L;
    }
    let mut sums = [0.0f64; 6 * L];
    let mut comps = [0.0f64; 6 * L];
    for k in 0..6 {
        _mm256_storeu_pd(sums.as_mut_ptr().add(k * L), s[k]);
        _mm256_storeu_pd(comps.as_mut_ptr().add(k * L), c[k]);
    }
    let mut st = 0.0f64;
    let mut ct = 0.0f64;
    while i < n {
        let prod = a[i] * b[i];
        let y = prod - ct;
        let t = st + y;
        ct = (t - st) - y;
        st = t;
        i += 1;
    }
    let head = compensated_fold_f64(&sums, &comps);
    compensated_fold_f64(&[head, st], &[0.0, ct])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_cases() {
        let a: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let b = vec![1.0f32; 100];
        assert_eq!(naive_f32(&a, &b), 5050.0);
        assert_eq!(kahan_f32(&a, &b), 5050.0);
        assert_eq!(kahan_fma_f32(&a, &b), 5050.0);
        let a: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let b = vec![1.0f64; 100];
        assert_eq!(naive_f64(&a, &b), 5050.0);
        assert_eq!(kahan_f64(&a, &b), 5050.0);
        assert_eq!(kahan_fma_f64(&a, &b), 5050.0);
    }

    #[test]
    fn odd_tails() {
        for n in [1usize, 7, 31, 33, 47, 63] {
            let a = vec![2.0f32; n];
            let b = vec![3.0f32; n];
            assert_eq!(kahan_f32(&a, &b), (6 * n) as f32, "n={n}");
            assert_eq!(kahan_fma_f32(&a, &b), (6 * n) as f32, "n={n}");
        }
    }
}
