//! Cycle-accurate timing via the TSC, with serialization fences, repetition
//! control and robust (median) aggregation — what likwid-bench's measurement
//! core does.

/// Serialized timestamp read (lfence; rdtsc).
#[inline]
pub fn rdtsc() -> u64 {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_mm_lfence();
        let t = core::arch::x86_64::_rdtsc();
        core::arch::x86_64::_mm_lfence();
        t
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        use std::time::{SystemTime, UNIX_EPOCH};
        SystemTime::now().duration_since(UNIX_EPOCH).unwrap().as_nanos() as u64
    }
}

/// Measurement of one benchmark configuration.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// median cycles per invocation
    pub median_cy: f64,
    /// minimum (best-case) cycles per invocation
    pub min_cy: f64,
    /// coefficient of variation across repetitions
    pub cv: f64,
    pub reps: usize,
}

/// Run `f` for `reps` timed repetitions (after `warmup` untimed ones) and
/// aggregate robustly. `f` should return a value that depends on the work
/// so the optimizer cannot elide it; it is consumed by `std::hint::black_box`.
pub fn measure<T, F: FnMut() -> T>(warmup: usize, reps: usize, mut f: F) -> Measurement {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = rdtsc();
        std::hint::black_box(f());
        let t1 = rdtsc();
        samples.push(t1.wrapping_sub(t0) as f64);
    }
    Measurement {
        median_cy: crate::util::stats::median(&samples),
        min_cy: crate::util::stats::min(&samples),
        cv: crate::util::stats::cv(&samples),
        reps,
    }
}

/// Adaptive measurement: repeat the kernel inside the timed region until it
/// runs for at least `min_cycles`, to push timer overhead below noise for
/// tiny working sets. Returns cycles per single invocation.
pub fn measure_adaptive<T, F: FnMut() -> T>(min_cycles: f64, reps: usize, mut f: F) -> Measurement {
    // estimate one invocation
    std::hint::black_box(f());
    let t0 = rdtsc();
    std::hint::black_box(f());
    let once = (rdtsc().wrapping_sub(t0) as f64).max(1.0);
    let inner = (min_cycles / once).ceil().max(1.0) as usize;

    let m = measure(2, reps, || {
        for _ in 0..inner {
            std::hint::black_box(f());
        }
    });
    Measurement {
        median_cy: m.median_cy / inner as f64,
        min_cy: m.min_cy / inner as f64,
        cv: m.cv,
        reps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rdtsc_monotone() {
        let a = rdtsc();
        let b = rdtsc();
        assert!(b >= a);
    }

    #[test]
    fn measure_scales_with_work() {
        let v: Vec<f64> = (0..50_000).map(|i| i as f64).collect();
        let small = measure(2, 9, || v[..5_000].iter().sum::<f64>());
        let large = measure(2, 9, || v.iter().sum::<f64>());
        assert!(
            large.min_cy > 3.0 * small.min_cy,
            "10x work must cost >3x cycles: {} vs {}",
            large.min_cy,
            small.min_cy
        );
    }

    #[test]
    fn adaptive_agrees_with_direct_on_big_work() {
        let v: Vec<f64> = (0..100_000).map(|i| i as f64).collect();
        let direct = measure(2, 9, || v.iter().sum::<f64>());
        let adaptive = measure_adaptive(1000.0, 9, || v.iter().sum::<f64>());
        let ratio = adaptive.min_cy / direct.min_cy;
        assert!((0.5..=2.0).contains(&ratio), "ratio {ratio}");
    }
}
