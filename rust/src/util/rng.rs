//! Deterministic PRNG: SplitMix64 core with convenience samplers.
//!
//! Every simulated or generated number in this crate flows through a seeded
//! `Rng`, so experiment outputs are bit-reproducible (`DESIGN.md` §5).

/// SplitMix64 (Steele et al., "Fast splittable pseudorandom number
/// generators"), passes BigCrush for the output sizes used here.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller deviate
    gauss_spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15), gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias < 2^-64 * n, irrelevant here
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (deterministic, no rejection).
    pub fn standard_normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    pub fn normal_f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.standard_normal() as f32).collect()
    }

    pub fn normal_f64_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.standard_normal()).collect()
    }

    /// Derive an independent child stream (for parallel workers).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.standard_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut a = Rng::new(5);
        let mut c = a.fork();
        let av: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let cv: Vec<u64> = (0..16).map(|_| c.next_u64()).collect();
        assert_ne!(av, cv);
    }
}
