//! Aligned ASCII / Markdown table rendering for experiment reports.

/// A simple column-aligned table. Rows are strings; alignment is computed at
/// render time. Used by every experiment report and by the benches.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self { title: title.to_string(), ..Default::default() }
    }

    pub fn headers<S: Into<String>, I: IntoIterator<Item = S>>(mut self, hs: I) -> Self {
        self.headers = hs.into_iter().map(Into::into).collect();
        self
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Self {
        self.rows.push(cells.into_iter().map(Into::into).collect());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    fn widths(&self) -> Vec<usize> {
        let ncol = self
            .rows
            .iter()
            .map(|r| r.len())
            .chain(std::iter::once(self.headers.len()))
            .max()
            .unwrap_or(0);
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = w[i].max(h.chars().count());
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as an aligned plain-text table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, width) in w.iter().enumerate() {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                line.push_str(&format!("{cell:<width$}"));
                if i + 1 != w.len() {
                    line.push_str("  ");
                }
            }
            line.trim_end().to_string()
        };
        if !self.headers.is_empty() {
            out.push_str(&fmt_row(&self.headers));
            out.push('\n');
            let total: usize = w.iter().sum::<usize>() + 2 * (w.len().saturating_sub(1));
            out.push_str(&"-".repeat(total));
            out.push('\n');
        }
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as GitHub-flavored Markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let ncol = self.widths().len();
        let hdr: Vec<String> = (0..ncol)
            .map(|i| self.headers.get(i).cloned().unwrap_or_default())
            .collect();
        out.push_str(&format!("| {} |\n", hdr.join(" | ")));
        out.push_str(&format!("|{}\n", "---|".repeat(ncol)));
        for r in &self.rows {
            let cells: Vec<String> =
                (0..ncol).map(|i| r.get(i).cloned().unwrap_or_default()).collect();
            out.push_str(&format!("| {} |\n", cells.join(" | ")));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new("t").headers(["a", "long-header"]);
        t.row(["xxxxxx", "1"]);
        t.row(["y", "22"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert!(lines[1].starts_with("a       "));
        assert!(lines[3].contains("xxxxxx  1"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("").headers(["x", "y"]);
        t.row(["1", "2"]);
        let md = t.render_markdown();
        assert!(md.starts_with("| x | y |\n|---|---|\n| 1 | 2 |"));
    }

    #[test]
    fn ragged_rows_ok() {
        let mut t = Table::new("").headers(["a"]);
        t.row(["1", "2", "3"]);
        assert!(t.render().contains("3"));
    }
}
