//! Small shared utilities: deterministic PRNG, statistics, table/CSV
//! rendering, a mini property-testing harness, and a CLI argument parser.
//!
//! The container is offline, so these replace `rand`, `proptest`, `clap`,
//! `prettytable` and `csv` (see Cargo.toml header note).

pub mod cli;
pub mod csv;
pub mod faults;
pub mod fmt;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;

pub use rng::Rng;
pub use table::Table;
