//! Number formatting helpers shared by the table/notation printers.

/// Format a cycle count the way the paper does: integers bare, otherwise
/// up to two decimals with trailing zeros trimmed ("6.1", "5.54", "8").
pub fn cy(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    let rounded = (v * 100.0).round() / 100.0;
    if (rounded - rounded.round()).abs() < 1e-9 {
        format!("{}", rounded.round() as i64)
    } else {
        let s = format!("{rounded:.2}");
        s.trim_end_matches('0').trim_end_matches('.').to_string()
    }
}

/// Format a performance number with 3 significant digits ("8.80", "0.55").
pub fn perf(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    if v == 0.0 {
        return "0.00".into();
    }
    let digits = v.abs().log10().floor() as i32;
    let decimals = (2 - digits).max(0) as usize;
    format!("{v:.decimals$}")
}

/// Format a byte count with binary units ("32 KiB", "2.5 MiB").
pub fn bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if (v - v.round()).abs() < 1e-9 {
        format!("{} {}", v.round() as u64, UNITS[u])
    } else {
        format!("{:.1} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cy_matches_paper_style() {
        assert_eq!(cy(8.0), "8");
        assert_eq!(cy(6.1), "6.1");
        assert_eq!(cy(5.54), "5.54");
        assert_eq!(cy(18.100000001), "18.1");
        assert_eq!(cy(7.92), "7.92");
    }

    #[test]
    fn perf_three_sig_digits() {
        assert_eq!(perf(8.8), "8.80");
        assert_eq!(perf(0.55), "0.550");
        assert_eq!(perf(4.4), "4.40");
        assert_eq!(perf(28.0), "28.0");
    }

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(64), "64 B");
        assert_eq!(bytes(32 * 1024), "32 KiB");
        assert_eq!(bytes(20 * 1024 * 1024), "20 MiB");
        assert_eq!(bytes(2560), "2.5 KiB");
    }
}
