//! Mini property-testing harness (offline replacement for `proptest`).
//!
//! Runs a property over `cases` deterministic random inputs derived from a
//! base seed; on failure it reports the case seed so the exact input can be
//! replayed with `check_one`. No shrinking — inputs here are small enough to
//! debug directly from the seed.

use crate::util::rng::Rng;

/// Result of a property over one generated input.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` seeded inputs. Panics (test-failure style) with
/// the first failing seed and message.
pub fn check(name: &str, cases: u64, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single case by seed (for debugging a `check` failure).
pub fn check_one(seed: u64, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Assert helper: build an `Err` with formatted context unless `cond`.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always-true", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |r| {
            let v = r.uniform();
            if v >= 0.0 {
                Err(format!("v={v}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn deterministic_inputs_per_name() {
        let mut first: Vec<u64> = vec![];
        check("det", 5, |r| {
            first.push(r.next_u64());
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check("det", 5, |r| {
            second.push(r.next_u64());
            Ok(())
        });
        assert_eq!(first, second);
    }
}
