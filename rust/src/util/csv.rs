//! Minimal CSV writer for experiment data series (the files a plotting tool
//! or the paper's gnuplot scripts would consume).

use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;

/// Write `rows` (plus a header) to `path` as CSV. Values containing commas
/// or quotes are quoted per RFC 4180.
pub fn write_csv<P: AsRef<Path>>(
    path: P,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(File::create(path)?);
    writeln!(w, "{}", header.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","))?;
    for row in rows {
        writeln!(w, "{}", row.iter().map(|c| escape(c)).collect::<Vec<_>>().join(","))?;
    }
    Ok(())
}

fn escape(cell: &str) -> String {
    if cell.contains(',') || cell.contains('"') || cell.contains('\n') {
        format!("\"{}\"", cell.replace('"', "\"\""))
    } else {
        cell.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let dir = std::env::temp_dir().join("kahan_ecm_csv_test");
        let p = dir.join("t.csv");
        write_csv(&p, &["a", "b"], &[vec!["1".into(), "x,y".into()]]).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a,b\n1,\"x,y\"\n");
    }

    #[test]
    fn escape_quotes() {
        assert_eq!(escape("he said \"hi\""), "\"he said \"\"hi\"\"\"");
        assert_eq!(escape("plain"), "plain");
    }
}
