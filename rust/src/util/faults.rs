//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a schedule of faults keyed by **site** (a static
//! string naming the code location — `"worker"`, `"chunk"`,
//! `"split_chunk"`, `"lane"`), **index** (which worker / chunk / lane),
//! and **hit number** (the n-th time that (site, index) is reached).
//! Execution layers call [`check`] at their named sites; when the
//! process-global plan has a matching entry for the current hit, the
//! action fires exactly once. Everything is counted deterministically,
//! so a seeded plan over a deterministic workload reproduces the same
//! failure in every run — tests and CI inject the fault, then assert
//! the *recovery*: respawn counters, lane restarts, quarantines, and
//! the bit-identity of every served request.
//!
//! Without the `faultinject` cargo feature, [`check`] compiles to an
//! inlined `None` and the hooks vanish from the hot path entirely. With
//! the feature but no installed plan, the cost is one relaxed atomic
//! load per hook.
//!
//! The plan is process-global (the hook sites have no engine or service
//! handle in scope), so tests that install plans must serialize — the
//! `faultinject` CI job runs with `--test-threads=1` and every test
//! resets the plan on exit (see `rust/tests/test_faults.rs`).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock, RwLock};
use std::time::Instant;

/// What an injected fault does at its site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// Panic with a recognizable payload (`"faultinject: ..."`). At a
    /// chunk site this exercises the caught-panic path; at a worker or
    /// lane site it kills the thread and exercises supervision.
    Panic,
    /// Kill the thread cleanly: a worker returns from its loop (its
    /// popped job is dropped, so the job's reply channel disconnects
    /// and the collector sees a clean "worker died", never a fabricated
    /// partial); a lane returns from its loop before serving.
    Die,
    /// Stall the site for the given number of microseconds — a wedged
    /// worker or lane, as seen by the heartbeat sweep.
    Stall(u64),
}

/// One scheduled fault: fire `action` on the `nth_hit`-th time
/// `(site, index)` is reached (0-based).
#[derive(Clone, Debug)]
struct FaultEntry {
    site: &'static str,
    index: usize,
    nth_hit: u64,
    action: FaultAction,
}

/// A deterministic schedule of faults. Build one with the chainable
/// constructors, [`install`](FaultPlan::install) it, run the workload,
/// then [`reset`] — see the module doc for the serialization contract.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    entries: Vec<FaultEntry>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Schedule `action` for the `nth_hit`-th visit of `(site, index)`.
    pub fn fault(
        mut self,
        site: &'static str,
        index: usize,
        nth_hit: u64,
        action: FaultAction,
    ) -> FaultPlan {
        self.entries.push(FaultEntry { site, index, nth_hit, action });
        self
    }

    /// A seeded random plan: `count` faults drawn over the given sites
    /// and index/hit ranges — the chaos-test generator. Deterministic
    /// for a fixed seed.
    pub fn seeded(
        seed: u64,
        count: usize,
        sites: &[&'static str],
        max_index: usize,
        max_hit: u64,
    ) -> FaultPlan {
        let mut rng = crate::util::Rng::new(seed);
        let mut plan = FaultPlan::new();
        for _ in 0..count {
            let site = sites[rng.below(sites.len() as u64) as usize];
            let index = rng.below(max_index.max(1) as u64) as usize;
            let nth_hit = rng.below(max_hit.max(1));
            let action = match rng.below(3) {
                0 => FaultAction::Panic,
                1 => FaultAction::Die,
                _ => FaultAction::Stall(1_000 + rng.below(5_000)),
            };
            plan = plan.fault(site, index, nth_hit, action);
        }
        plan
    }

    /// Number of scheduled faults at `site` (tests size their recovery
    /// assertions from the plan itself).
    pub fn count_at(&self, site: &'static str) -> usize {
        self.entries.iter().filter(|e| e.site == site).count()
    }

    /// Install this plan process-globally, resetting all hit counters.
    /// Replaces any previously installed plan.
    pub fn install(self) {
        let g = global();
        {
            let mut counters = g.counters.lock().unwrap_or_else(|p| p.into_inner());
            counters.clear();
        }
        *g.plan.write().unwrap_or_else(|p| p.into_inner()) = Some(self);
        g.enabled.store(true, Ordering::SeqCst);
    }
}

/// Remove any installed plan (hooks return to their no-op fast path).
pub fn reset() {
    let g = global();
    g.enabled.store(false, Ordering::SeqCst);
    *g.plan.write().unwrap_or_else(|p| p.into_inner()) = None;
    g.counters.lock().unwrap_or_else(|p| p.into_inner()).clear();
}

struct FaultGlobal {
    enabled: AtomicBool,
    plan: RwLock<Option<FaultPlan>>,
    /// hit counters per (site, index); a Vec keeps this allocation-light
    /// for the handful of sites a plan names
    counters: Mutex<Vec<(&'static str, usize, u64)>>,
}

fn global() -> &'static FaultGlobal {
    static G: OnceLock<FaultGlobal> = OnceLock::new();
    G.get_or_init(|| FaultGlobal {
        enabled: AtomicBool::new(false),
        plan: RwLock::new(None),
        counters: Mutex::new(Vec::new()),
    })
}

/// The hook the execution layers call at their named sites: returns the
/// scheduled action iff the installed plan has an entry for the current
/// hit of `(site, index)`. Counts the hit either way (when a plan is
/// installed), so schedules stay deterministic across mixed workloads.
#[cfg(feature = "faultinject")]
pub fn check(site: &'static str, index: usize) -> Option<FaultAction> {
    let g = global();
    if !g.enabled.load(Ordering::Relaxed) {
        return None;
    }
    let hit = {
        let mut counters = g.counters.lock().unwrap_or_else(|p| p.into_inner());
        match counters.iter_mut().find(|(s, i, _)| *s == site && *i == index) {
            Some(entry) => {
                let h = entry.2;
                entry.2 += 1;
                h
            }
            None => {
                counters.push((site, index, 1));
                0
            }
        }
    };
    let plan = g.plan.read().unwrap_or_else(|p| p.into_inner());
    plan.as_ref()?
        .entries
        .iter()
        .find(|e| e.site == site && e.index == index && e.nth_hit == hit)
        .map(|e| e.action)
}

/// Without the feature the hook is a compile-time no-op.
#[cfg(not(feature = "faultinject"))]
#[inline(always)]
pub fn check(_site: &'static str, _index: usize) -> Option<FaultAction> {
    None
}

/// Execute an injected action *in place* for sites where Panic and
/// Stall make sense locally; returns `true` if the caller should die
/// (thread-exit is the caller's job — only it knows how to exit
/// cleanly). `None` action → no-op, returns `false`.
pub fn act(action: Option<FaultAction>) -> bool {
    match action {
        None => false,
        Some(FaultAction::Panic) => panic!("faultinject: injected panic"),
        Some(FaultAction::Stall(us)) => {
            std::thread::sleep(std::time::Duration::from_micros(us));
            false
        }
        Some(FaultAction::Die) => true,
    }
}

/// Microseconds since the process-wide monotonic origin — the heartbeat
/// clock the supervision sweeps compare against. Never 0 (0 is the
/// "idle" sentinel in the heartbeat slots).
pub fn now_us() -> u64 {
    static ORIGIN: OnceLock<Instant> = OnceLock::new();
    let t = ORIGIN.get_or_init(Instant::now).elapsed().as_micros() as u64;
    t.max(1)
}

/// A heartbeat slot: 0 = idle, otherwise the [`now_us`] timestamp at
/// which the owner started its current unit of work. The supervision
/// sweeps read it to tell "busy" from "wedged".
#[derive(Debug, Default)]
pub struct Heartbeat(AtomicU64);

impl Heartbeat {
    pub fn new() -> Heartbeat {
        Heartbeat(AtomicU64::new(0))
    }

    /// Mark the owner busy as of now.
    pub fn busy(&self) {
        self.0.store(now_us(), Ordering::Relaxed);
    }

    /// Mark the owner idle.
    pub fn idle(&self) {
        self.0.store(0, Ordering::Relaxed);
    }

    /// Busy for longer than `threshold_us`? (`false` when idle or when
    /// the threshold is 0 — 0 disables wedge detection.)
    pub fn wedged(&self, threshold_us: u64) -> bool {
        if threshold_us == 0 {
            return false;
        }
        let since = self.0.load(Ordering::Relaxed);
        since != 0 && now_us().saturating_sub(since) > threshold_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_counts_sites() {
        let p = FaultPlan::new()
            .fault("worker", 0, 0, FaultAction::Die)
            .fault("worker", 1, 2, FaultAction::Panic)
            .fault("lane", 0, 0, FaultAction::Stall(100));
        assert_eq!(p.count_at("worker"), 2);
        assert_eq!(p.count_at("lane"), 1);
        assert_eq!(p.count_at("chunk"), 0);
    }

    #[test]
    fn seeded_plans_are_deterministic() {
        let a = FaultPlan::seeded(42, 8, &["worker", "lane"], 4, 10);
        let b = FaultPlan::seeded(42, 8, &["worker", "lane"], 4, 10);
        assert_eq!(a.entries.len(), 8);
        for (x, y) in a.entries.iter().zip(&b.entries) {
            assert_eq!(x.site, y.site);
            assert_eq!(x.index, y.index);
            assert_eq!(x.nth_hit, y.nth_hit);
            assert_eq!(x.action, y.action);
        }
    }

    #[test]
    fn heartbeat_wedge_detection() {
        let hb = Heartbeat::new();
        assert!(!hb.wedged(1), "idle is never wedged");
        hb.busy();
        assert!(!hb.wedged(0), "threshold 0 disables detection");
        assert!(!hb.wedged(60_000_000), "fresh work is not wedged");
        std::thread::sleep(std::time::Duration::from_millis(3));
        assert!(hb.wedged(1_000), "stale heartbeat past the threshold is wedged");
        hb.idle();
        assert!(!hb.wedged(1_000));
    }

    // `check` with an installed plan is exercised by the `faultinject`
    // feature job (rust/tests/test_faults.rs); without the feature it
    // must be a constant None.
    #[cfg(not(feature = "faultinject"))]
    #[test]
    fn check_is_noop_without_feature() {
        FaultPlan::new().fault("worker", 0, 0, FaultAction::Die).install();
        assert_eq!(check("worker", 0), None);
        reset();
    }
}
