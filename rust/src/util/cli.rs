//! Tiny CLI argument parser (offline replacement for `clap`).
//!
//! Grammar: `repro <subcommand> [--key value]... [--flag]...`. Unknown keys
//! are rejected at `finish()` so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self, CliError> {
        let mut it = raw.into_iter().peekable();
        let subcommand = match it.peek() {
            Some(s) if !s.starts_with("--") => it.next(),
            _ => None,
        };
        let mut kv = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(tok) = it.next() {
            let key = tok
                .strip_prefix("--")
                .ok_or_else(|| CliError(format!("expected --option, got `{tok}`")))?
                .to_string();
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    kv.insert(key, it.next().unwrap());
                }
                _ => flags.push(key),
            }
        }
        Ok(Self { subcommand, kv, flags, consumed: Default::default() })
    }

    pub fn from_env() -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    /// String option with default.
    pub fn opt(&self, key: &str, default: &str) -> String {
        self.consumed.borrow_mut().push(key.to_string());
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_maybe(&self, key: &str) -> Option<String> {
        self.consumed.borrow_mut().push(key.to_string());
        self.kv.get(key).cloned()
    }

    /// Typed numeric option with default.
    pub fn num<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, CliError> {
        self.consumed.borrow_mut().push(key.to_string());
        match self.kv.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| CliError(format!("--{key}: cannot parse `{v}`"))),
        }
    }

    /// Boolean flag (present or absent).
    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Reject any option that no `opt`/`num`/`flag` call asked about.
    pub fn finish(&self) -> Result<(), CliError> {
        let seen = self.consumed.borrow();
        for k in self.kv.keys().chain(self.flags.iter()) {
            if !seen.iter().any(|s| s == k) {
                return Err(CliError(format!("unknown option --{k}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig2", "--arch", "ivb", "--csv"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig2"));
        assert_eq!(a.opt("arch", "snb"), "ivb");
        assert!(a.flag("csv"));
        a.finish().unwrap();
    }

    #[test]
    fn numeric_parse_and_default() {
        let a = parse(&["x", "--cores", "10"]);
        assert_eq!(a.num("cores", 1u32).unwrap(), 10);
        assert_eq!(a.num("reps", 3u32).unwrap(), 3);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--cores", "ten"]);
        assert!(a.num("cores", 1u32).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse(&["x", "--bogus", "1"]);
        assert!(a.finish().is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }
}
