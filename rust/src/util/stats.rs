//! Basic statistics over `f64` samples (median/MAD based, robust to the
//! timing outliers a shared VM produces).

/// Arithmetic mean. Empty input returns NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). Fewer than two samples returns NaN.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in samples"));
    v
}

/// Median. Empty input returns NaN.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let v = sorted(xs);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (unscaled).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let v = sorted(xs);
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Coefficient of variation (stddev / |mean|).
pub fn cv(xs: &[f64]) -> f64 {
    stddev(xs) / mean(xs).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(mean(&xs), 22.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [10.0, 10.0, 10.0, 10.0, 1000.0];
        assert_eq!(mad(&xs), 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(median(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
    }
}
