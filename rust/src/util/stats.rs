//! Basic statistics over `f64` samples (median/MAD based, robust to the
//! timing outliers a shared VM produces).

/// Arithmetic mean. Empty input returns NaN.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). Fewer than two samples returns NaN.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

fn sorted(xs: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    // total order, not partial_cmp().expect(): a NaN sample (a timing
    // read that failed, a ratio over an empty scenario) must not panic a
    // stats call — NaNs sort to the top and the quantile math stays
    // well-defined for everything below them
    v.sort_by(|a, b| a.total_cmp(b));
    v
}

/// Median. Empty input returns NaN.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let v = sorted(xs);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (unscaled).
pub fn mad(xs: &[f64]) -> f64 {
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// Linear-interpolated percentile, p clamped into [0, 100]. Empty input
/// returns NaN (see [`percentile_or`] for the guarded form emitters use).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let v = sorted(xs);
    if v.len() == 1 {
        return v[0];
    }
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// [`percentile`], but a sample set with no answer (empty — e.g. a burst
/// scenario that shed everything — or all-NaN) yields `fallback` instead
/// of NaN, so a JSON emitter never writes an invalid/null metric field.
pub fn percentile_or(xs: &[f64], p: f64, fallback: f64) -> f64 {
    let v = percentile(xs, p);
    if v.is_finite() {
        v
    } else {
        fallback
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Coefficient of variation (stddev / |mean|).
pub fn cv(xs: &[f64]) -> f64 {
    stddev(xs) / mean(xs).abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_simple() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(mean(&xs), 22.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn median_even() {
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
    }

    #[test]
    fn mad_robust_to_outlier() {
        let xs = [10.0, 10.0, 10.0, 10.0, 1000.0];
        assert_eq!(mad(&xs), 0.0);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [5.0, 1.0, 3.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((stddev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn empty_inputs_are_nan() {
        assert!(mean(&[]).is_nan());
        assert!(median(&[]).is_nan());
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_single_sample_and_clamped_p() {
        // the one-sample case every burst scenario that sheds all-but-one
        // request produces
        assert_eq!(percentile(&[42.0], 0.0), 42.0);
        assert_eq!(percentile(&[42.0], 99.0), 42.0);
        // out-of-range p clamps instead of indexing out of bounds
        assert_eq!(percentile(&[1.0, 2.0], 150.0), 2.0);
        assert_eq!(percentile(&[1.0, 2.0], -5.0), 1.0);
    }

    #[test]
    fn nan_samples_never_panic() {
        // a NaN sample sorts to the top under total_cmp; the call must
        // not panic (the old partial_cmp().expect() did)
        let xs = [3.0, f64::NAN, 1.0];
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn percentile_or_guards_the_empty_and_nan_cases() {
        assert_eq!(percentile_or(&[], 99.0, 0.0), 0.0, "empty -> fallback, not NaN");
        assert_eq!(percentile_or(&[f64::NAN], 99.0, -1.0), -1.0, "all-NaN -> fallback");
        assert_eq!(percentile_or(&[5.0, 1.0], 100.0, 0.0), 5.0, "real data passes through");
    }
}
